"""Perf-analysis tooling: VMEM/MXU estimates + HLO inspector invariants."""

import numpy as np
import pytest

from compile.kernels import analysis as A
from compile import inspect_hlo as I


def test_vmem_estimate_scales_with_blocks():
    small = A.moe_ffn_estimate(t=256, h=32, f=64, e=64, block_t=32, block_e=2)
    big = A.moe_ffn_estimate(t=256, h=32, f=64, e=64, block_t=128, block_e=8)
    assert big.vmem_bytes > small.vmem_bytes
    assert small.fits_vmem and big.fits_vmem
    assert big.grid == (2, 8, 1)
    assert small.grid == (8, 32, 1)


def test_mxu_utilization_bounds():
    for (h, f, e) in [(32, 64, 8), (4096, 14336, 8), (2048, 1024, 64)]:
        est = A.moe_ffn_estimate(t=1024, h=h, f=f, e=e, block_t=128, block_e=8)
        assert 0.0 < est.mxu_utilization <= 1.0


def test_paper_scale_blocks_fit_vmem():
    """Every Table-1 model must have a VMEM-feasible block config with
    decent MXU occupancy — the L1 §Perf claim."""
    for name, est in A.paper_scale_table():
        assert est is not None, f"{name}: no feasible block config"
        assert est.fits_vmem, name
        assert est.mxu_utilization > 0.25, (name, est.mxu_utilization)


def test_sweep_prefers_larger_blocks_until_vmem():
    best = A.sweep_block_sizes(t=1024, h=4096, f=14336, e=8, dtype_bytes=2)
    # Mixtral-scale panels are huge; the F axis must be tiled.
    assert best is not None and best.fits_vmem
    assert best.grid[2] >= 2, best  # cannot hold a full 14336-wide panel


def test_topk_gate_estimate_vpu_shaped():
    est = A.topk_gate_estimate(t=768, e=64, block_t=128)
    assert est.fits_vmem
    # O(E^2) compare tensor dominates VMEM
    assert est.vmem_bytes > 128 * 64 * 64 * 4 * 0.9


def test_hlo_inspector_parses_real_artifact(tmp_path):
    # synth a minimal HLO-ish file
    text = """HloModule test
ENTRY %main (p0: f32[2,2]) -> f32[2,2] {
  %p0 = f32[2,2] parameter(0)
  %dot = f32[2,2] dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %w = f32[2,2] while(%dot), condition=%c, body=%b
  ROOT %out = f32[2,2] add(%w, %p0)
}
"""
    p = tmp_path / "t.hlo.txt"
    p.write_text(text)
    info = I.analyze(str(p))
    assert info["counts"]["dot"] == 1
    assert info["counts"]["while"] == 1
    assert info["counts"]["add"] == 1
    assert not I.check_decode_invariants(info)


def test_hlo_inspector_flags_unrolled_decode(tmp_path):
    text = "HloModule t\nENTRY %m () -> f32[] {\n  ROOT %c = f32[] constant(0)\n}\n"
    p = tmp_path / "d.hlo.txt"
    p.write_text(text)
    info = I.analyze(str(p))
    probs = I.check_decode_invariants(info)
    assert any("scan" in x for x in probs)
