"""Synthetic corpora/task generators: structural invariants the Rust
harness depends on (prompt lengths, answer placement, vocab ranges)."""

import numpy as np
import pytest

from compile import configs as C, data as D


@pytest.fixture(scope="module")
def corp():
    return D.corpora()


def test_corpus_tokens_in_text_range(corp):
    rng = np.random.default_rng(0)
    for name, c in corp.items():
        seq = c.sample(rng, 200)
        assert seq.min() >= C.TEXT_BASE
        assert seq.max() < C.TEXT_BASE + C.N_TEXT


def test_corpora_have_distinct_statistics(corp):
    """PTB analogue is peaked (low entropy), C4 flatter — ppl separation."""
    rng = np.random.default_rng(1)

    def bigram_entropy(c):
        seq = c.sample(rng, 4000) - C.TEXT_BASE
        counts = np.zeros((C.N_TEXT, C.N_TEXT)) + 1e-9
        for a, b in zip(seq, seq[1:]):
            counts[a, b] += 1
        p = counts / counts.sum(1, keepdims=True)
        rows = -np.sum(p * np.log(p), axis=1)
        w = counts.sum(1) / counts.sum()
        return float(np.sum(rows * w))

    h = {n: bigram_entropy(c) for n, c in corp.items()}
    assert h["ptb"] < h["c4"], h


def test_corpus_deterministic_given_seed():
    a = D.MarkovCorpus(seed=5, order=2, alpha=1.1)
    b = D.MarkovCorpus(seed=5, order=2, alpha=1.1)
    r1, r2 = np.random.default_rng(3), np.random.default_rng(3)
    assert np.array_equal(a.sample(r1, 64), b.sample(r2, 64))


def test_passkey_structure(corp):
    rng = np.random.default_rng(2)
    for depth in [0.1, 0.5, 0.9]:
        seq, plen, vals = D.make_passkey(rng, corp["c4"], 96, depth)
        assert seq[0] == C.BOS
        assert seq[plen - 2] == C.QRY and seq[plen - 1] == C.KEY
        assert np.array_equal(seq[plen:plen + 3], vals)
        assert seq[plen + 3] == C.EOS
        kpos = np.where(seq == C.KEY)[0]
        assert len(kpos) == 2  # planted cue + query-time cue
        assert np.array_equal(seq[kpos[0] + 1:kpos[0] + 4], vals)


def test_passkey_depth_ordering(corp):
    rng = np.random.default_rng(3)
    s1, _, _ = D.make_passkey(rng, corp["c4"], 96, 0.1)
    s2, _, _ = D.make_passkey(rng, corp["c4"], 96, 0.9)
    assert np.where(s1 == C.KEY)[0][0] < np.where(s2 == C.KEY)[0][0]


def test_longqa_answer_matches_fact(corp):
    rng = np.random.default_rng(4)
    for _ in range(10):
        seq, plen, ans = D.make_longqa(rng, corp["c4"], 96)
        # the asked name appears as a FACT whose values equal the answer
        name = seq[plen - 2]
        fact_pos = [p for p in np.where(seq == C.FACT)[0] if seq[p + 1] == name]
        assert fact_pos, "asked name not present as FACT"
        assert any(np.array_equal(seq[p + 2:p + 4], ans) for p in fact_pos)
        assert np.array_equal(seq[plen:plen + 2], ans)


def test_probe_tasks_label_candidates(corp):
    rng = np.random.default_rng(5)
    for name, fn in D.PROBE_TASKS.items():
        seq, plen, cands, label = fn(rng, corp, 64)
        assert 0 <= label < 4, name
        assert cands.shape[0] == 4, name
        assert plen <= len(seq) + 1


def test_vlm_tasks(corp):
    rng = np.random.default_rng(6)
    for name, fn in D.VLM_TASKS.items():
        seq, plen, cands, label = fn(rng, 96)
        assert seq[1] == C.IMG
        assert 0 <= label < cands.shape[0], name


def test_training_batch_shape_and_range(corp):
    rng = np.random.default_rng(7)
    b = D.training_batch(rng, corp, 4, 96, vlm=True)
    assert b.shape == (4, 96)
    assert b.min() >= 0 and b.max() < C.VOCAB


def test_eval_suite_arrays_complete():
    arrays, meta = D.build_eval_suite(seq_len=96, n_ppl=2, n_passkey=5,
                                      n_longqa=3, n_probe=2, n_vlm=2)
    for t in meta["tasks"]:
        kind = meta["tasks"][t]["kind"]
        if kind == "perplexity":
            assert t in arrays
        elif kind == "multiple_choice":
            for suffix in ["prompts", "plen", "cands", "labels"]:
                assert f"{t}_{suffix}" in arrays, (t, suffix)
    assert arrays["passkey_prompts"].dtype == np.int32
    # prompt lengths are consistent with the padded arrays
    pk = arrays["passkey_prompts"]
    for i, plen in enumerate(arrays["passkey_plen"]):
        assert pk[i, plen - 1] == C.KEY and pk[i, plen - 2] == C.QRY
