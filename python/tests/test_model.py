"""L2 correctness: shapes, prefill/decode consistency, runtime-k semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C, data as D, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = C.ModelConfig(name="mini", n_layers=3, n_experts=8, top_k=2,
                    hidden=32, ffn=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(D.training_batch(rng, D.corpora(), CFG.batch,
                                        CFG.prefill_len, vlm=False))


def _full_k():
    return jnp.full((CFG.n_layers,), CFG.top_k, jnp.int32)


def _zero_bias():
    return jnp.zeros((CFG.n_layers, CFG.n_experts))


def test_param_shapes(params):
    lp = params["layers"]
    L, E, H, F = CFG.n_layers, CFG.n_experts, CFG.hidden, CFG.ffn
    assert params["embed"].shape == (CFG.vocab, H)
    assert lp["gate"].shape == (L, H, E)
    assert lp["w1"].shape == (L, E, H, F)
    assert lp["w2"].shape == (L, E, F, H)


def test_param_leaf_names_are_stable(params):
    names = M.param_leaf_names(params)
    assert names[0] == "embed" and "layers/gate" in names
    assert len(names) == len(set(names)) == 12


def test_prefill_shapes(params, tokens):
    logits, kv = M.forward_prefill(params, tokens, _full_k(), _zero_bias(),
                                   CFG, use_kernels=False)
    assert logits.shape == (CFG.batch, CFG.prefill_len, CFG.vocab)
    assert kv.shape == (CFG.n_layers, 2, CFG.batch, CFG.max_seq,
                        CFG.n_heads, CFG.head_dim)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_decode_matches_prefill_logits(params, tokens):
    """Teacher-forced decode must reproduce prefill logits step by step."""
    k_vec, bias = _full_k(), _zero_bias()
    logits, _ = M.forward_prefill(params, tokens, k_vec, bias, CFG,
                                  use_kernels=False)
    # prefill the first T-3 tokens, then decode 3 teacher-forced steps
    cut = CFG.prefill_len - 3
    pref = tokens.at[:, cut:].set(0)
    _, kv = M.forward_prefill(params, pref, k_vec, bias, CFG,
                              use_kernels=False)
    mask = (jnp.arange(CFG.max_seq) < cut).astype(jnp.float32)
    kv = kv * mask[None, None, None, :, None, None]
    for step in range(3):
        pos = jnp.full((CFG.batch,), cut + step, jnp.int32)
        dl, kv = M.forward_decode(params, kv, tokens[:, cut + step], pos,
                                  k_vec, bias, CFG, use_kernels=False)
        np.testing.assert_allclose(np.asarray(dl),
                                   np.asarray(logits[:, cut + step]),
                                   rtol=1e-4, atol=1e-4)


def test_kernel_and_ref_paths_agree(params, tokens):
    k_vec, bias = _full_k(), _zero_bias()
    l1, kv1 = M.forward_prefill(params, tokens, k_vec, bias, CFG,
                                use_kernels=False)
    l2, kv2 = M.forward_prefill(params, tokens, k_vec, bias, CFG,
                                use_kernels=True)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(kv2), np.asarray(kv1),
                               rtol=5e-4, atol=5e-4)


def test_k_vector_is_per_layer(params, tokens):
    """Changing one layer's k changes the output; k=k_base reproduces base."""
    bias = _zero_bias()
    base, _ = M.forward_prefill(params, tokens, _full_k(), bias, CFG,
                                use_kernels=False)
    k2 = _full_k().at[1].set(1)
    red, _ = M.forward_prefill(params, tokens, k2, bias, CFG,
                               use_kernels=False)
    assert not np.allclose(np.asarray(red), np.asarray(base))
    again, _ = M.forward_prefill(params, tokens, _full_k(), bias, CFG,
                                 use_kernels=False)
    np.testing.assert_allclose(np.asarray(again), np.asarray(base))


def test_gate_bias_prunes_experts(params, tokens):
    """Inter-pruning bias changes outputs but keeps them finite/normalized."""
    bias = _zero_bias().at[:, :4].set(-1e9)  # prune half the experts
    logits, _ = M.forward_prefill(params, tokens, _full_k(), bias, CFG,
                                  use_kernels=False)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_moe_layer_forward_profiles(params):
    """Stage-1 graph: delta monotone in k on real layer weights."""
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(1), (64, CFG.hidden))
    bias = jnp.zeros((CFG.n_experts,))
    base = M.moe_layer_forward(x, lp["gate"], bias, lp["w1"], lp["w3"],
                               lp["w2"], CFG.top_k, CFG, use_kernels=True)
    deltas = []
    for k in range(1, CFG.top_k + 1):
        y = M.moe_layer_forward(x, lp["gate"], bias, lp["w1"], lp["w3"],
                                lp["w2"], k, CFG, use_kernels=True)
        deltas.append(float(jnp.linalg.norm(y - base)))
    assert deltas[-1] < 1e-4
    assert deltas[0] >= deltas[-1]


def test_loss_decreases_quickly():
    """A few Adam steps on the mixture must reduce the loss (trainability)."""
    from compile import train as T
    cfg = C.ModelConfig(name="mini", n_layers=2, n_experts=4, top_k=2,
                        hidden=16, ffn=32, train_batch=2, train_seq=48)
    _, log = T.train_model(cfg, steps=12, log_every=1, progress=False)
    assert log["loss"][-1] < log["loss"][0], log["loss"]


def test_loss_masks_padding():
    cfg = C.ModelConfig(name="mini", n_layers=2, n_experts=4, top_k=2,
                        hidden=16, ffn=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.full((cfg.batch, 32), C.PAD, jnp.int32).at[:, 0].set(C.BOS)
    toks = toks.at[:, 1:4].set(50)
    (loss, (ce, bal)) = M.loss_fn(params, toks, cfg)
    assert np.isfinite(float(loss)) and float(ce) > 0
