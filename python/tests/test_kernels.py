"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/dtypes/k; every property the Rust side relies on
(nested selection, zero-weight off-expert, monotone router mass) is pinned
here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.moe_ffn import moe_ffn, moe_block
from compile.kernels.topk_gate import topk_gate

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# topk_gate
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([8, 32, 128]),
    e=st.sampled_from([8, 60, 64]),
    k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_gate_matches_ref(t, e, k, seed):
    k = min(k, e)
    scores = rand(seed, (t, e))
    got = topk_gate(scores, k, k_base=k)
    want = ref.topk_gate_ref(scores, k, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    t=st.sampled_from([16, 64]),
    e=st.sampled_from([8, 60]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_gate_rows_sum_to_one(t, e, seed):
    scores = rand(seed, (t, e))
    for k in range(1, min(e, 8) + 1):
        w = np.asarray(topk_gate(scores, k, k_base=8))
        np.testing.assert_allclose(w.sum(-1), np.ones(t), rtol=1e-5)
        # exactly k strictly-positive entries per row
        assert (w > 0).sum(-1).tolist() == [k] * t


def test_gate_nested_selection():
    """Top-k sets are nested in k (Stage-1 monotonicity foundation)."""
    scores = rand(3, (32, 16))
    prev = None
    for k in range(1, 9):
        sel = np.asarray(topk_gate(scores, k, k_base=8)) > 0
        if prev is not None:
            assert np.all(sel | ~prev), f"selection not nested at k={k}"
        prev = sel


def test_gate_full_k_equals_softmax():
    scores = rand(7, (16, 8))
    w = np.asarray(topk_gate(scores, 8, k_base=8))
    want = np.asarray(jax.nn.softmax(scores, axis=-1))
    np.testing.assert_allclose(w, want, rtol=1e-5, atol=1e-6)


def test_gate_tie_break_deterministic():
    scores = jnp.zeros((4, 8))  # all tied -> lowest indices win
    w = np.asarray(topk_gate(scores, 3, k_base=8))
    assert np.all(w[:, :3] > 0) and np.all(w[:, 3:] == 0)


def test_gate_block_t_invariance():
    scores = rand(11, (128, 8))
    a = np.asarray(topk_gate(scores, 2, k_base=2, block_t=128))
    b = np.asarray(topk_gate(scores, 2, k_base=2, block_t=32))
    np.testing.assert_allclose(a, b, rtol=1e-6)


# ---------------------------------------------------------------------------
# moe_ffn
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([8, 64, 128]),
    e=st.sampled_from([4, 8, 60]),
    h=st.sampled_from([16, 32]),
    f=st.sampled_from([32, 64]),
    k=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_moe_ffn_matches_ref(t, e, h, f, k, seed):
    k = min(k, e)
    x = rand(seed, (t, h))
    w1 = rand(seed + 1, (e, h, f), 0.1)
    w3 = rand(seed + 2, (e, h, f), 0.1)
    w2 = rand(seed + 3, (e, f, h), 0.1)
    weights = ref.topk_gate_ref(rand(seed + 4, (t, e)), k, k)
    got = moe_ffn(x, w1, w3, w2, weights)
    want = ref.moe_ffn_ref(x, w1, w3, w2, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-5)


def test_moe_ffn_block_shape_invariance():
    """Accumulation across expert blocks must not change the result."""
    t, e, h, f = 64, 8, 16, 32
    x = rand(0, (t, h))
    w1, w3 = rand(1, (e, h, f), 0.1), rand(2, (e, h, f), 0.1)
    w2 = rand(3, (e, f, h), 0.1)
    weights = ref.topk_gate_ref(rand(4, (t, e)), 2, 2)
    base = np.asarray(moe_ffn(x, w1, w3, w2, weights, block_t=64, block_e=8))
    for bt, be in [(32, 8), (64, 4), (16, 2), (64, 1)]:
        got = np.asarray(moe_ffn(x, w1, w3, w2, weights, block_t=bt, block_e=be))
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-6)


def test_moe_ffn_zero_weights_zero_output():
    t, e, h, f = 16, 4, 8, 16
    x = rand(0, (t, h))
    out = moe_ffn(x, rand(1, (e, h, f)), rand(2, (e, h, f)),
                  rand(3, (e, f, h)), jnp.zeros((t, e)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_moe_ffn_single_expert_is_plain_swiglu():
    t, h, f = 16, 8, 16
    x = rand(0, (t, h))
    w1, w3, w2 = rand(1, (1, h, f), 0.2), rand(2, (1, h, f), 0.2), rand(3, (1, f, h), 0.2)
    weights = jnp.ones((t, 1))
    got = np.asarray(moe_ffn(x, w1, w3, w2, weights))
    want = (jax.nn.silu(x @ w1[0]) * (x @ w3[0])) @ w2[0]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# moe_block (router + FFN composed)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_moe_block_matches_ref(k, seed):
    t, e, h, f, kb = 32, 8, 16, 32, 6
    k = min(k, kb)
    x = rand(seed, (t, h))
    gate = rand(seed + 1, (h, e), 0.5)
    bias = jnp.zeros((e,))
    w1, w3 = rand(seed + 2, (e, h, f), 0.1), rand(seed + 3, (e, h, f), 0.1)
    w2 = rand(seed + 4, (e, f, h), 0.1)
    got, gw = moe_block(x, gate, bias, w1, w3, w2, k, kb, block_t=32, block_e=4)
    want, ww = ref.moe_block_ref(x, gate, bias, w1, w3, w2, k, kb)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ww), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-5)


def test_moe_block_gate_bias_excludes_experts():
    """-1e9 gate bias (inter-pruning) must make experts unreachable."""
    t, e, h, f, kb = 16, 8, 16, 32, 4
    x = rand(0, (t, h))
    gate = rand(1, (h, e), 0.5)
    bias = jnp.zeros((e,)).at[jnp.array([2, 5])].set(-1e9)
    w1, w3 = rand(2, (e, h, f), 0.1), rand(3, (e, h, f), 0.1)
    w2 = rand(4, (e, f, h), 0.1)
    _, gw = moe_block(x, gate, bias, w1, w3, w2, 4, kb, block_t=16, block_e=8)
    gw = np.asarray(gw)
    assert np.all(gw[:, [2, 5]] == 0), "pruned experts received gate mass"
    np.testing.assert_allclose(gw.sum(-1), np.ones(t), rtol=1e-5)


def test_moe_block_delta_monotone_in_k():
    """‖y_k − y_base‖_F non-increasing in k — LExI Stage-1's key property."""
    t, e, h, f, kb = 64, 16, 16, 32, 8
    x = rand(0, (t, h))
    gate = rand(1, (h, e), 0.5)
    bias = jnp.zeros((e,))
    w1, w3 = rand(2, (e, h, f), 0.1), rand(3, (e, h, f), 0.1)
    w2 = rand(4, (e, f, h), 0.1)
    base, _ = moe_block(x, gate, bias, w1, w3, w2, kb, kb, block_t=64, block_e=8)
    deltas = []
    for k in range(1, kb + 1):
        y, _ = moe_block(x, gate, bias, w1, w3, w2, k, kb, block_t=64, block_e=8)
        deltas.append(float(jnp.linalg.norm(y - base)))
    assert deltas[-1] < 1e-4, "delta at k_base must be ~0"
    for a, b in zip(deltas, deltas[1:]):
        assert b <= a + 1e-5, f"delta not monotone: {deltas}"
