"""AOT export path: HLO text emission, manifest integrity, cached reload."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, configs as C, model as M, train as T

MINI = C.ModelConfig(name="mini-aot", n_layers=2, n_experts=8, top_k=2,
                     hidden=16, ffn=32, train_steps=2)


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("aot")
    os.makedirs(d / "mini-aot", exist_ok=True)
    return str(d)


def test_export_graphs_emit_parseable_hlo(out_dir):
    files = aot.export_model_graphs(MINI, os.path.join(out_dir, "mini-aot"))
    for key in ["prefill", "decode", "moe_layer"]:
        path = os.path.join(out_dir, "mini-aot", files[key])
        text = open(path).read()
        assert text.startswith("HloModule"), f"{key} not HLO text"
        assert "ENTRY" in text
        # jax>=0.5 64-bit-id protos are the failure mode we avoid; text ids
        # stay small
        assert len(text) < 5_000_000


def test_param_roundtrip_npz(out_dir):
    params = M.init_params(MINI, jax.random.PRNGKey(0))
    path = os.path.join(out_dir, "mini-aot", "params.npz")
    T.save_params_npz(params, path)
    loaded = aot.load_params_npz(MINI, path)
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(loaded)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_order_matches_flatten(out_dir):
    """Rust feeds inputs in manifest order; it must equal jax's traversal."""
    specs = aot.param_specs(MINI)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    names = ["/".join(str(k.key) for k in p) for p, _ in flat]
    params = M.init_params(MINI, jax.random.PRNGKey(0))
    assert names == M.param_leaf_names(params)
    # dict ordering in jax is sorted-by-key: embed < layers/* < ln_f
    assert names[0] == "embed" and names[-1] == "ln_f"


def test_build_model_manifest_entry(out_dir):
    entry = aot.build_model(MINI, out_dir, steps=2, force=False)
    assert entry["files"]["prefill"] == "prefill.hlo.txt"
    assert entry["param_order"][0] == "embed"
    assert entry["param_shapes"]["layers/w1"] == [2, 8, 16, 32]
    assert entry["profile_tokens"] == aot.PROFILE_TOKENS
    # calibration stats exist and are [L, E]
    calib = np.load(os.path.join(out_dir, "mini-aot", "calib.npz"))
    assert calib["sel_freq"].shape == (2, 8)
    assert np.all(calib["sel_freq"] >= 0)


def test_table1_structure_matches_paper():
    """The analogue registry must preserve the paper's Table-1 structure."""
    t1 = {
        "deepseek-vl2-tiny": (12, 64, 6),
        "olmoe-1b-7b": (16, 64, 8),
        "qwen1.5-moe-a2.7b": (24, 60, 4),
        "deepseek-v2-lite": (27, 64, 6),
        "minicpm-moe-8x2b": (40, 8, 2),
        "mixtral-8x7b": (32, 8, 2),
    }
    for name, (l, e, k) in t1.items():
        cfg = C.MODELS[name]
        assert (cfg.n_layers, cfg.n_experts, cfg.top_k) == (l, e, k), name
