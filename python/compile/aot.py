"""AOT exporter: trains the analogues (once) and lowers the inference
graphs to HLO *text* for the Rust/PJRT runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
Env:    LEXI_MODELS=qwen1.5-moe-a2.7b,...   subset of models
        LEXI_STEPS=250                       training-step override
        LEXI_FORCE=1                         retrain even if cached

Python runs only here (build time); the Rust binary is self-contained
afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs as C
from . import data as D
from . import model as M
from . import train as T

PROFILE_TOKENS = 128  # token count of the Stage-1 moe_layer graph


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg: C.ModelConfig):
    """ShapeDtypeStructs mirroring model.init_params (no RNG cost)."""
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    return jax.tree_util.tree_map(lambda s: _spec(s.shape, s.dtype), params)


def export_model_graphs(cfg: C.ModelConfig, out_dir: str):
    """Lower prefill / decode / moe_layer for one model; return file map."""
    L, E, H, F = cfg.n_layers, cfg.n_experts, cfg.hidden, cfg.ffn
    B, Tp = cfg.batch, cfg.prefill_len
    nh, hd = cfg.n_heads, cfg.head_dim
    p_specs = param_specs(cfg)
    kvec_s = _spec((L,), jnp.int32)
    bias_s = _spec((L, E), jnp.float32)

    files = {}

    def prefill(params, tokens, k_vec, gate_bias):
        logits, kv = M.forward_prefill(params, tokens, k_vec, gate_bias, cfg,
                                       use_kernels=True)
        return logits, kv

    lowered = jax.jit(prefill).lower(
        p_specs, _spec((B, Tp), jnp.int32), kvec_s, bias_s)
    files["prefill"] = "prefill.hlo.txt"
    with open(os.path.join(out_dir, files["prefill"]), "w") as f:
        f.write(to_hlo_text(lowered))

    kv_s = _spec((L, 2, B, cfg.max_seq, nh, hd), jnp.float32)

    def decode(params, kv, tokens, pos, k_vec, gate_bias):
        return M.forward_decode(params, kv, tokens, pos, k_vec, gate_bias,
                                cfg, use_kernels=True)

    lowered = jax.jit(decode).lower(
        p_specs, kv_s, _spec((B,), jnp.int32), _spec((B,), jnp.int32),
        kvec_s, bias_s)
    files["decode"] = "decode.hlo.txt"
    with open(os.path.join(out_dir, files["decode"]), "w") as f:
        f.write(to_hlo_text(lowered))

    def moe_layer(x, gate_w, gate_bias, w1, w3, w2, k):
        return (M.moe_layer_forward(x, gate_w, gate_bias, w1, w3, w2, k, cfg,
                                    use_kernels=True),)

    lowered = jax.jit(moe_layer).lower(
        _spec((PROFILE_TOKENS, H)), _spec((H, E)), _spec((E,)),
        _spec((E, H, F)), _spec((E, H, F)), _spec((E, F, H)),
        _spec((), jnp.int32))
    files["moe_layer"] = "moe_layer.hlo.txt"
    with open(os.path.join(out_dir, files["moe_layer"]), "w") as f:
        f.write(to_hlo_text(lowered))

    return files


def load_params_npz(cfg: C.ModelConfig, path: str):
    """Inverse of train.save_params_npz (for cached re-export)."""
    npz = np.load(path)
    specs = param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    leaves = []
    for p, spec in flat:
        name = "/".join(str(k.key) for k in p)
        leaves.append(jnp.asarray(npz[name]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def build_model(cfg: C.ModelConfig, out_root: str, steps: int | None,
                force: bool):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    params_path = os.path.join(out_dir, "params.npz")

    if force or not os.path.exists(params_path):
        params, log = T.train_model(cfg, steps=steps)
        T.save_params_npz(params, params_path)
        T.save_log(log, os.path.join(out_dir, "train_log.json"))
    else:
        print(f"[{cfg.name}] cached params found, skipping training")
        params = load_params_npz(cfg, params_path)

    calib_path = os.path.join(out_dir, "calib.npz")
    if force or not os.path.exists(calib_path):
        stats = T.calibration_stats(params, cfg)
        np.savez(calib_path, **stats)

    files = export_model_graphs(cfg, out_dir)
    files["params"] = "params.npz"
    files["calib"] = "calib.npz"
    files["train_log"] = "train_log.json"

    specs = param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    order, shapes = [], {}
    for p, spec in flat:
        name = "/".join(str(k.key) for k in p)
        order.append(name)
        shapes[name] = list(spec.shape)

    entry = cfg.to_dict()
    entry["files"] = files
    entry["param_order"] = order
    entry["param_shapes"] = shapes
    entry["profile_tokens"] = PROFILE_TOKENS
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=os.environ.get("LEXI_MODELS", ""))
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("LEXI_STEPS", "0")) or None)
    ap.add_argument("--force", action="store_true",
                    default=os.environ.get("LEXI_FORCE", "") == "1")
    args = ap.parse_args()

    names = [n for n in args.models.split(",") if n] or C.ALL_NAMES
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}, "vocab": {
        "size": C.VOCAB, "pad": C.PAD, "bos": C.BOS, "eos": C.EOS,
        "key": C.KEY, "qry": C.QRY, "fact": C.FACT, "ask": C.ASK,
        "ans": C.ANS, "sep": C.SEP, "img": C.IMG,
        "val_base": C.VAL_BASE, "n_vals": C.N_VALS,
        "text_base": C.TEXT_BASE, "n_text": C.N_TEXT,
        "img_base": C.IMG_BASE, "n_img": C.N_IMG,
    }}

    for name in names:
        cfg = C.MODELS[name]
        print(f"=== building {name} (L={cfg.n_layers} E={cfg.n_experts} "
              f"k={cfg.top_k}) ===", flush=True)
        manifest["models"][name] = build_model(cfg, args.out, args.steps,
                                               args.force)

    corp_dir = os.path.join(args.out, "corpora")
    if args.force or not os.path.exists(os.path.join(corp_dir, "meta.json")):
        meta = D.write_eval_suite(corp_dir, seq_len=C.MODELS[names[0]].prefill_len)
        print(f"eval suite: {len(meta['tasks'])} tasks")
    manifest["corpora_dir"] = "corpora"

    # Merge with an existing manifest so per-model subsets compose.
    man_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(man_path) and not args.force:
        with open(man_path) as f:
            old = json.load(f)
        old_models = old.get("models", {})
        old_models.update(manifest["models"])
        manifest["models"] = old_models
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {man_path} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
