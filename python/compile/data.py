"""Synthetic corpora + evaluation task suites (DESIGN.md §3 substitutions).

Each generator stands in for one of the paper's datasets and measures the
same capability axis:

  * three Markov corpora with distinct statistics  -> C4 / PTB / WikiText ppl
  * passkey-in-garbage retrieval                   -> passkey task (Fig. 6)
  * scattered FACT/ASK extractive QA (token F1)    -> Qasper / LongBench (Fig. 5)
  * nine structured probe tasks (4-way MC)         -> LM-Eval 9-task avg (Fig. 4)
  * three image-token-prefix probe tasks           -> MME / MMMU / ScienceQA (Fig. 8)

Everything is emitted as int32 npz arrays + a JSON sidecar so the Rust
harness can replay them without Python.
"""

import json

import numpy as np

from . import configs as C

# ---------------------------------------------------------------------------
# Markov corpora ("C4", "PTB", "WikiText" analogues)
# ---------------------------------------------------------------------------


def _zipf_probs(rng, n, alpha):
    """Zipf-ish row with a random permutation so rows differ."""
    p = 1.0 / np.arange(1, n + 1) ** alpha
    rng.shuffle(p)
    return p / p.sum()


class MarkovCorpus:
    """Order-1 or order-2 Markov chain over the text-token range."""

    def __init__(self, seed: int, order: int, alpha: float,
                 motif: bool = False):
        self.order = order
        self.alpha = alpha
        self.motif = motif
        rng = np.random.default_rng(seed)
        n = C.N_TEXT
        if order == 1:
            self.trans = np.stack([_zipf_probs(rng, n, alpha) for _ in range(n)])
        else:
            # Factored order-2: P(x_t | x_{t-1}, x_{t-2}) mixes two order-1
            # tables — full n^2 x n tables would be 2M rows of noise.
            self.t1 = np.stack([_zipf_probs(rng, n, alpha) for _ in range(n)])
            self.t2 = np.stack([_zipf_probs(rng, n, alpha) for _ in range(n)])
        self.init = _zipf_probs(rng, 1.2, n) if False else _zipf_probs(rng, n, 1.1)
        # Optional periodic motif ("wikitext" headers): a fixed 6-token
        # phrase injected every ~24 tokens.
        self.motif_toks = rng.integers(0, n, size=6)

    def sample(self, rng, length: int) -> np.ndarray:
        n = C.N_TEXT
        out = np.empty(length, dtype=np.int64)
        out[0] = rng.choice(n, p=self.init)
        if self.order >= 2:
            out[1] = rng.choice(n, p=self.t1[out[0]]) if length > 1 else 0
        start = 1 if self.order == 1 else 2
        for t in range(start, length):
            if self.order == 1:
                p = self.trans[out[t - 1]]
            else:
                p = 0.5 * self.t1[out[t - 1]] + 0.5 * self.t2[out[t - 2]]
            out[t] = rng.choice(n, p=p)
        if self.motif:
            m = len(self.motif_toks)
            for pos in range(8, length - m, 24):
                out[pos:pos + m] = self.motif_toks
        return out + C.TEXT_BASE

    def next_probs(self, prev1: int, prev2: int) -> np.ndarray:
        """True next-token distribution (text-range indices)."""
        if self.order == 1:
            return self.trans[prev1 - C.TEXT_BASE]
        return 0.5 * self.t1[prev1 - C.TEXT_BASE] + 0.5 * self.t2[prev2 - C.TEXT_BASE]


def corpora():
    """The three eval corpora; 'c4' also dominates the training mixture."""
    return {
        "c4": MarkovCorpus(seed=101, order=2, alpha=1.1),
        "ptb": MarkovCorpus(seed=202, order=1, alpha=1.6),
        "wikitext": MarkovCorpus(seed=303, order=2, alpha=0.9, motif=True),
    }


# ---------------------------------------------------------------------------
# Task sequence formats (shared by training mixture and eval suites)
# ---------------------------------------------------------------------------


def make_passkey(rng, corpus, seq_len: int, depth_frac: float):
    """[BOS] garbage... QRY KEY v1 v2 v3 garbage... QRY KEY -> v1 v2 v3 [EOS].

    The retrieval cue (QRY KEY) is *repeated* at query time, making the
    task a pure induction pattern (match the earlier cue, copy what
    followed) — the mechanism tiny transformers actually learn. Faithful
    to the paper's passkey task: the model must retrieve an exact value
    planted at a controlled depth inside distractor text."""
    vals = rng.integers(C.VAL_BASE, C.VAL_BASE + C.N_VALS, size=3)
    answer_len = 3
    tail = answer_len + 3  # QRY KEY + answer + EOS
    body_len = seq_len - 1 - tail
    garbage = corpus.sample(rng, body_len)
    key_pos = 1 + int(depth_frac * (body_len - 6))
    seq = np.empty(seq_len, dtype=np.int64)
    seq[0] = C.BOS
    seq[1:1 + body_len] = garbage
    seq[key_pos] = C.QRY
    seq[key_pos + 1] = C.KEY
    seq[key_pos + 2:key_pos + 5] = vals
    q = 1 + body_len
    seq[q] = C.QRY
    seq[q + 1] = C.KEY
    seq[q + 2:q + 5] = vals
    seq[q + 5] = C.EOS
    prompt_len = q + 2  # prompt ends after the repeated QRY KEY cue
    return seq, prompt_len, vals


def make_longqa(rng, corpus, seq_len: int, n_facts: int = 4):
    """Scattered FACT <name> <v1> <v2> pairs; ASK <name> -> ANS <v1> <v2>."""
    names = rng.choice(C.N_VALS, size=n_facts, replace=False) + C.VAL_BASE
    vals = rng.integers(C.VAL_BASE, C.VAL_BASE + C.N_VALS, size=(n_facts, 2))
    tail = 6  # ASK name ANS v1 v2 EOS
    body_len = seq_len - 1 - tail
    seq = np.empty(seq_len, dtype=np.int64)
    seq[0] = C.BOS
    seq[1:1 + body_len] = corpus.sample(rng, body_len)
    positions = np.sort(rng.choice(np.arange(2, body_len - 4), size=n_facts,
                                   replace=False))
    for i, p in enumerate(positions):
        seq[1 + p] = C.FACT
        seq[2 + p] = names[i]
        seq[3 + p:5 + p] = vals[i]
    qi = rng.integers(0, n_facts)
    q = 1 + body_len
    seq[q] = C.ASK
    seq[q + 1] = names[qi]
    seq[q + 2] = C.ANS
    seq[q + 3:q + 5] = vals[qi]
    seq[q + 5] = C.EOS
    prompt_len = q + 3  # prompt ends after ANS; model emits the 2 values
    return seq, prompt_len, vals[qi]


# --- the nine LM-Eval probe tasks -----------------------------------------
# Each returns (full_seq, prompt_len, candidates [4, clen], label).
# Candidates are scored by total log-prob of the continuation (exactly the
# lm-eval multiple-choice protocol); the "correct" candidate is the one the
# training distribution makes most likely / the task's ground truth.


def _mc_from_distribution(rng, probs, answer_len=1):
    """True answer = distribution mode; distractors = low-prob tokens."""
    order = np.argsort(-probs)
    true_tok = order[0]
    distract = order[len(order) // 2:]
    picks = rng.choice(distract, size=3, replace=False)
    cands = np.array([[true_tok], [picks[0]], [picks[1]], [picks[2]]]) + C.TEXT_BASE
    perm = rng.permutation(4)
    return cands[perm], int(np.where(perm == 0)[0][0])


def probe_bigram(rng, corpora_d, seq_len):
    c = corpora_d["c4"]
    ctx = c.sample(rng, seq_len - 1)
    seq = np.concatenate([[C.BOS], ctx])
    probs = c.next_probs(ctx[-1], ctx[-2])
    cands, label = _mc_from_distribution(rng, probs)
    return seq, len(seq), cands, label


def probe_peaked(rng, corpora_d, seq_len):
    c = corpora_d["ptb"]
    ctx = c.sample(rng, seq_len - 1)
    seq = np.concatenate([[C.BOS], ctx])
    probs = c.next_probs(ctx[-1], ctx[-1])
    cands, label = _mc_from_distribution(rng, probs)
    return seq, len(seq), cands, label


def probe_motif(rng, corpora_d, seq_len):
    """Complete the wikitext motif phrase."""
    c = corpora_d["wikitext"]
    ctx = c.sample(rng, seq_len - 1)
    # cut right before the last motif token
    m = c.motif_toks + C.TEXT_BASE
    # find last motif occurrence
    pos = None
    for p in range(len(ctx) - 6, 0, -1):
        if np.array_equal(ctx[p:p + 5], m[:5]):
            pos = p
            break
    if pos is None:  # fall back to bigram probe
        return probe_bigram(rng, corpora_d, seq_len)
    seq = np.concatenate([[C.BOS], ctx[:pos + 5]])
    true_tok = m[5]
    others = rng.choice(C.N_TEXT, size=3, replace=False) + C.TEXT_BASE
    others = np.where(others == true_tok, (others + 1 - C.TEXT_BASE) % C.N_TEXT + C.TEXT_BASE, others)
    cands = np.stack([[true_tok], [others[0]], [others[1]], [others[2]]])
    perm = rng.permutation(4)
    return seq, len(seq), cands[perm], int(np.where(perm == 0)[0][0])


def _copy_probe(rng, corpora_d, seq_len, pair_dist):
    """a b ... SEP a -> b  (induction-head copy at distance pair_dist)."""
    c = corpora_d["c4"]
    ctx = c.sample(rng, seq_len - 4)
    a = rng.integers(C.TEXT_BASE, C.TEXT_BASE + C.N_TEXT)
    b = rng.integers(C.TEXT_BASE, C.TEXT_BASE + C.N_TEXT)
    pos = max(1, len(ctx) - pair_dist)
    ctx[pos - 1] = a
    ctx[pos] = b
    seq = np.concatenate([[C.BOS], ctx, [C.SEP, a]])
    others = rng.choice(C.N_TEXT, size=3, replace=False) + C.TEXT_BASE
    others = np.where(others == b, (others + 1 - C.TEXT_BASE) % C.N_TEXT + C.TEXT_BASE, others)
    cands = np.stack([[b], [others[0]], [others[1]], [others[2]]])
    perm = rng.permutation(4)
    return seq, len(seq), cands[perm], int(np.where(perm == 0)[0][0])


def probe_copy_near(rng, d, n):
    return _copy_probe(rng, d, n, pair_dist=8)


def probe_copy_far(rng, d, n):
    return _copy_probe(rng, d, n, pair_dist=32)


def probe_induction(rng, d, n):
    return _copy_probe(rng, d, n, pair_dist=16)


def probe_retrieval(rng, corpora_d, seq_len):
    """Short passkey as MC: KEY v ... QRY -> v."""
    c = corpora_d["c4"]
    seq, plen, vals = make_passkey(rng, c, seq_len, rng.uniform(0.1, 0.9))
    seq = seq[:plen + 1]  # prompt + first answer token
    true_tok = vals[0]
    others = rng.choice(C.N_VALS, size=3, replace=False) + C.VAL_BASE
    others = np.where(others == true_tok, (others - C.VAL_BASE + 1) % C.N_VALS + C.VAL_BASE, others)
    cands = np.stack([[true_tok], [others[0]], [others[1]], [others[2]]])
    perm = rng.permutation(4)
    return seq[:plen], plen, cands[perm], int(np.where(perm == 0)[0][0])


def probe_factqa(rng, corpora_d, seq_len):
    c = corpora_d["c4"]
    seq, plen, vals = make_longqa(rng, c, seq_len)
    true_tok = vals[0]
    others = rng.choice(C.N_VALS, size=3, replace=False) + C.VAL_BASE
    others = np.where(others == true_tok, (others - C.VAL_BASE + 1) % C.N_VALS + C.VAL_BASE, others)
    cands = np.stack([[true_tok], [others[0]], [others[1]], [others[2]]])
    perm = rng.permutation(4)
    return seq[:plen], plen, cands[perm], int(np.where(perm == 0)[0][0])


def probe_trigram(rng, corpora_d, seq_len):
    c = corpora_d["c4"]
    ctx = c.sample(rng, seq_len - 1)
    seq = np.concatenate([[C.BOS], ctx])
    probs = c.next_probs(ctx[-1], ctx[-2])
    # two-token continuation: mode then mode-of-mode
    t1 = int(np.argmax(probs))
    p2 = c.next_probs(t1 + C.TEXT_BASE, ctx[-1])
    t2 = int(np.argmax(p2))
    true = np.array([t1, t2]) + C.TEXT_BASE
    cands = [true]
    for _ in range(3):
        cands.append(rng.choice(C.N_TEXT, size=2) + C.TEXT_BASE)
    cands = np.stack(cands)
    perm = rng.permutation(4)
    return seq, len(seq), cands[perm], int(np.where(perm == 0)[0][0])


# Names roughly paired with the paper's nine LM-Eval tasks.
PROBE_TASKS = {
    "arc_c": probe_trigram,      # multi-step completion
    "arc_e": probe_bigram,       # single-step completion
    "boolq": probe_peaked,       # peaked / low-entropy judgement
    "hellaswag": probe_motif,    # continuation of a seen pattern
    "mmlu": probe_factqa,        # knowledge lookup
    "obqa": probe_copy_near,     # short-range binding
    "rte": probe_induction,      # mid-range binding
    "winogrande": probe_copy_far,  # long-range binding
    "retrieval": probe_retrieval,  # precise value retrieval
}


# --- VLM probes (Fig. 8) ----------------------------------------------------
# "Image" = IMG + 16 patch tokens from the image range; question afterwards.


def vlm_majority(rng, seq_len):
    """'MME': which of 4 patch classes dominates the image."""
    classes = rng.choice(C.N_IMG // 4, size=4, replace=False)
    counts = np.array([7, 4, 3, 2])
    rng.shuffle(counts)
    label_cls = int(np.argmax(counts))
    patches = np.concatenate([
        np.full(c, C.IMG_BASE + classes[i] * 4) for i, c in enumerate(counts)
    ])
    rng.shuffle(patches)
    seq = np.concatenate([[C.BOS, C.IMG], patches, [C.ASK]])
    cands = np.stack([[C.IMG_BASE + classes[i] * 4] for i in range(4)])
    return seq, len(seq), cands, label_cls


def vlm_pattern(rng, seq_len):
    """'MMMU': alternating vs constant vs blockwise vs random pattern."""
    a, b = rng.choice(C.N_IMG, size=2, replace=False) + C.IMG_BASE
    kind = rng.integers(0, 4)
    if kind == 0:
        patches = np.tile([a, b], 8)
    elif kind == 1:
        patches = np.full(16, a)
    elif kind == 2:
        patches = np.concatenate([np.full(8, a), np.full(8, b)])
    else:
        patches = rng.choice(C.N_IMG, size=16) + C.IMG_BASE
    seq = np.concatenate([[C.BOS, C.IMG], patches, [C.ASK]])
    # answer encoded as a value token per pattern class
    cands = np.stack([[C.VAL_BASE + i] for i in range(4)])
    return seq, len(seq), cands, int(kind)


def vlm_count(rng, seq_len):
    """'ScienceQA': is the count of target patches above threshold (binary)."""
    target = C.IMG_BASE
    n = int(rng.integers(2, 15))
    patches = np.concatenate([
        np.full(n, target),
        rng.choice(np.arange(C.IMG_BASE + 4, C.IMG_BASE + C.N_IMG), size=16 - n),
    ])
    rng.shuffle(patches)
    seq = np.concatenate([[C.BOS, C.IMG], patches, [C.QRY]])
    label = int(n > 8)
    cands = np.stack([[C.VAL_BASE], [C.VAL_BASE + 1]])  # no / yes
    return seq, len(seq), cands, label


VLM_TASKS = {"mme": vlm_majority, "mmmu": vlm_pattern, "scienceqa": vlm_count}


# ---------------------------------------------------------------------------
# Training mixture + eval suite emission
# ---------------------------------------------------------------------------


def training_batch(rng, corpora_d, batch, seq_len, vlm: bool):
    """One [batch, seq_len] LM batch from the task mixture."""
    out = np.zeros((batch, seq_len), dtype=np.int64)
    for i in range(batch):
        r = rng.uniform()
        if vlm and r < 0.30:
            fn = list(VLM_TASKS.values())[rng.integers(0, 3)]
            seq, plen, cands, label = fn(rng, seq_len)
            full = np.concatenate([seq, cands[label], [C.EOS]])
            out[i, :min(len(full), seq_len)] = full[:seq_len]
        elif r < 0.18:
            seq, _, _ = make_passkey(rng, corpora_d["c4"], seq_len,
                                     rng.uniform(0.05, 0.95))
            out[i] = seq
        elif r < 0.32:
            seq, _, _ = make_longqa(rng, corpora_d["c4"], seq_len)
            out[i] = seq
        elif r < 0.44:
            name = list(PROBE_TASKS)[rng.integers(0, 9)]
            seq, plen, cands, label = PROBE_TASKS[name](rng, corpora_d, seq_len - 4)
            full = np.concatenate([seq, cands[label], [C.EOS]])
            out[i, :min(len(full), seq_len)] = full[:seq_len]
        else:
            name = ["c4", "c4", "c4", "ptb", "wikitext"][rng.integers(0, 5)]
            seq = corpora_d[name].sample(rng, seq_len - 1)
            out[i] = np.concatenate([[C.BOS], seq])
    return out


def _pad_to(arr_list, width, pad=0):
    out = np.full((len(arr_list), width), pad, dtype=np.int32)
    for i, a in enumerate(arr_list):
        out[i, :len(a)] = a[:width]
    return out


def build_eval_suite(seq_len: int, seed: int = 7,
                     n_ppl: int = 8, ppl_len: int = 96,
                     n_passkey: int = 16, n_longqa: int = 12,
                     n_probe: int = 16, n_vlm: int = 16):
    """All eval arrays (int32) + metadata dict, for npz + json emission."""
    rng = np.random.default_rng(seed)
    corp = corpora()
    arrays, meta = {}, {"tasks": {}}

    for name, c in corp.items():
        seqs = np.stack([np.concatenate([[C.BOS], c.sample(rng, ppl_len - 1)])
                         for _ in range(n_ppl)]).astype(np.int32)
        arrays[f"ppl_{name}"] = seqs
        meta["tasks"][f"ppl_{name}"] = {"kind": "perplexity", "n": n_ppl,
                                        "len": ppl_len}

    # Passkey across a depth grid (paper: varying depths, 100 iterations —
    # scaled down for one CPU core; n configurable at harness level).
    pk_seq, pk_plen, pk_ans, pk_depth = [], [], [], []
    depths = np.linspace(0.1, 0.9, 5)
    for d in depths:
        for _ in range(n_passkey // len(depths) + 1):
            seq, plen, vals = make_passkey(rng, corp["c4"], seq_len, d)
            pk_seq.append(seq[:plen])
            pk_plen.append(plen)
            pk_ans.append(vals)
            pk_depth.append(d)
    arrays["passkey_prompts"] = _pad_to(pk_seq, seq_len)
    arrays["passkey_plen"] = np.array(pk_plen, dtype=np.int32)
    arrays["passkey_answers"] = np.array(pk_ans, dtype=np.int32)
    arrays["passkey_depth_pct"] = (np.array(pk_depth) * 100).astype(np.int32)
    meta["tasks"]["passkey"] = {"kind": "generate_exact", "answer_len": 3,
                                "n": len(pk_seq)}

    lq_seq, lq_plen, lq_ans = [], [], []
    for _ in range(n_longqa):
        seq, plen, vals = make_longqa(rng, corp["c4"], seq_len)
        lq_seq.append(seq[:plen])
        lq_plen.append(plen)
        lq_ans.append(vals)
    arrays["longqa_prompts"] = _pad_to(lq_seq, seq_len)
    arrays["longqa_plen"] = np.array(lq_plen, dtype=np.int32)
    arrays["longqa_answers"] = np.array(lq_ans, dtype=np.int32)
    meta["tasks"]["longqa"] = {"kind": "generate_f1", "answer_len": 2,
                               "n": n_longqa}

    for tname, fn in PROBE_TASKS.items():
        p_seq, p_plen, p_cands, p_label = [], [], [], []
        for _ in range(n_probe):
            seq, plen, cands, label = fn(rng, corp, seq_len - 6)
            p_seq.append(seq[:plen])
            p_plen.append(plen)
            # pad candidates to uniform length 2
            cpad = np.zeros((4, 2), dtype=np.int32)
            clen = np.zeros(4, dtype=np.int32)
            for j in range(4):
                cc = np.atleast_1d(cands[j])
                cpad[j, :len(cc)] = cc
                clen[j] = len(cc)
            p_cands.append(cpad)
            p_label.append(label)
        arrays[f"probe_{tname}_prompts"] = _pad_to(p_seq, seq_len)
        arrays[f"probe_{tname}_plen"] = np.array(p_plen, dtype=np.int32)
        arrays[f"probe_{tname}_cands"] = np.stack(p_cands).astype(np.int32)
        arrays[f"probe_{tname}_labels"] = np.array(p_label, dtype=np.int32)
        meta["tasks"][f"probe_{tname}"] = {"kind": "multiple_choice",
                                           "n": n_probe, "n_cands": 4}

    for tname, fn in VLM_TASKS.items():
        v_seq, v_plen, v_cands, v_label = [], [], [], []
        for _ in range(n_vlm):
            seq, plen, cands, label = fn(rng, seq_len)
            v_seq.append(seq[:plen])
            v_plen.append(plen)
            ncand = cands.shape[0]
            cpad = np.zeros((4, 2), dtype=np.int32)
            clen = np.zeros(4, dtype=np.int32)
            for j in range(ncand):
                cpad[j, :cands.shape[1]] = cands[j]
                clen[j] = cands.shape[1]
            v_seq[-1] = seq[:plen]
            v_cands.append(cpad)
            v_label.append(label)
        arrays[f"vlm_{tname}_prompts"] = _pad_to(v_seq, seq_len)
        arrays[f"vlm_{tname}_plen"] = np.array(v_plen, dtype=np.int32)
        arrays[f"vlm_{tname}_cands"] = np.stack(v_cands).astype(np.int32)
        arrays[f"vlm_{tname}_labels"] = np.array(v_label, dtype=np.int32)
        n_c = 2 if tname == "scienceqa" else 4
        meta["tasks"][f"vlm_{tname}"] = {"kind": "multiple_choice",
                                         "n": n_vlm, "n_cands": n_c}

    meta["probe_tasks"] = list(PROBE_TASKS)
    meta["vlm_tasks"] = list(VLM_TASKS)
    meta["ppl_corpora"] = list(corp)
    meta["seq_len"] = seq_len
    return arrays, meta


def write_eval_suite(out_dir: str, seq_len: int, **kw):
    import os
    os.makedirs(out_dir, exist_ok=True)
    arrays, meta = build_eval_suite(seq_len, **kw)
    np.savez(os.path.join(out_dir, "eval_suite.npz"), **arrays)
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta
