"""Pallas kernel: weighted SwiGLU expert mixture — the MoE compute hot-spot.

Hardware adaptation (DESIGN.md §4): vLLM's FusedMoE assigns CUDA threadblocks
to (expert, token-tile) pairs reading expert panels from HBM through shared
memory. The TPU-shaped schedule below expresses the same thing with a Pallas
grid over (token-block, expert-block): each grid step holds one token block
[bt, H] and one expert panel W1/W3 [be, H, F] + W2 [be, F, H] in VMEM, runs
the SwiGLU contractions on the MXU, scales by the gate weights (zero for
non-routed experts), and *accumulates* into the revisited output block —
Pallas' sequential-grid revisiting plays the role of the CUDA atomics /
split-K reduction.

VMEM per grid step (f32 words): bt*H + 2*be*H*F + be*F*H + bt*be*F + bt*H.
The default blocks keep this under ~1 MiB for every Table-1 analogue; the
paper-scale estimate lives in DESIGN.md §Perf.

interpret=True: CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, wts_ref, o_ref):
    """Grid step (i=token block, j=expert block), accumulate into o_ref."""
    j = pl.program_id(1)
    x = x_ref[...]                        # [bt, H]
    w1 = w1_ref[...]                      # [be, H, F]
    w3 = w3_ref[...]
    w2 = w2_ref[...]                      # [be, F, H]
    wts = wts_ref[...]                    # [bt, be]
    # SwiGLU contractions over the expert panel (MXU-shaped matmuls).
    h1 = jnp.einsum("th,ehf->tef", x, w1)
    h3 = jnp.einsum("th,ehf->tef", x, w3)
    act = jax.nn.silu(h1) * h3            # [bt, be, F]
    act = act * wts[:, :, None]           # gate-scale (0 for unrouted)
    part = jnp.einsum("tef,efh->th", act, w2)

    # First expert block initializes the revisited output block; later
    # blocks accumulate (sequential grid => no write races).
    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_t", "block_e"))
def moe_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
            weights: jax.Array, block_t: int = 128,
            block_e: int = 8) -> jax.Array:
    """y[T, H] = sum_e weights[:, e] * SwiGLU_e(x).

    x: [T, H]; w1, w3: [E, H, F]; w2: [E, F, H]; weights: [T, E] dense gate
    (zeros for non-selected experts, produced by kernels.topk_gate).
    Block sizes are clamped to the largest divisors of T / E not above the
    requested values (Table-1 expert counts include 60).
    """
    T, H = x.shape
    E, _, F = w1.shape
    bt = min(block_t, T)
    while T % bt:
        bt -= 1
    be = min(block_e, E)
    while E % be:
        be -= 1
    assert T % bt == 0 and E % be == 0, (T, bt, E, be)
    grid = (T // bt, E // be)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, H), lambda i, j: (i, 0)),
            pl.BlockSpec((be, H, F), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((be, H, F), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((be, F, H), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bt, be), lambda i, j: (i, j)),
        ],
        # Output block revisited across j => accumulation schedule.
        out_specs=pl.BlockSpec((bt, H), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H), x.dtype),
        interpret=True,
    )(x, w1, w3, w2, weights)


def moe_block(x, gate_w, gate_bias, w1, w3, w2, k, k_base,
              block_t: int = 128, block_e: int = 8):
    """Full MoE module on the kernel path: router + weighted mixture.

    Mirrors ref.moe_block_ref; returns (y [T, H], weights [T, E]).
    """
    from .topk_gate import topk_gate
    scores = x @ gate_w + gate_bias[None, :]
    weights = topk_gate(scores, k, k_base=k_base, block_t=block_t)
    return moe_ffn(x, w1, w3, w2, weights,
                   block_t=block_t, block_e=block_e), weights
