"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref)."""
from . import ref
from .topk_gate import topk_gate
from .moe_ffn import moe_ffn, moe_block
