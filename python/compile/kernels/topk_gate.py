"""Pallas kernel: top-k gating with runtime k (LExI's per-layer active-expert
count is a *runtime* input so one compiled executable serves every allocation).

Hardware adaptation (DESIGN.md §4): instead of the CUDA warp-shuffle top-k
vLLM uses, the TPU-shaped formulation computes the full rank matrix with an
O(E^2) broadcast-compare on the VPU — E <= 64 in every Table-1 model, so the
[block_T, E, E] compare tensor stays comfortably in VMEM and needs no sort
network or cross-lane shuffles. Selection is rank < k, which makes the
selected sets nested in k (the monotonicity LExI Stage-1 relies on).

interpret=True: CPU PJRT cannot execute Mosaic custom-calls; numerics are
identical to the TPU lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _gate_kernel(k_ref, scores_ref, out_ref):
    """One token-block: scores [bt, E] -> dense softmax-top-k weights."""
    scores = scores_ref[...]
    bt, e = scores.shape
    k = k_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, e), 1)
    s_i = scores[:, :, None]          # candidate expert e
    s_j = scores[:, None, :]          # competitor expert j
    better = (s_j > s_i) | ((s_j == s_i) & (idx[:, None, :] < idx[:, :, None]))
    rank = jnp.sum(better.astype(jnp.int32), axis=-1)      # [bt, E]
    active = rank < k
    masked = jnp.where(active, scores, NEG_INF)
    # Numerically-stable softmax over the active set only.
    m = jnp.max(masked, axis=-1, keepdims=True)
    ex = jnp.exp(masked - m)
    ex = jnp.where(active, ex, 0.0)
    out_ref[...] = ex / jnp.sum(ex, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("k_base", "block_t"))
def topk_gate(scores: jax.Array, k: jax.Array, k_base: int = 8,
              block_t: int = 128) -> jax.Array:
    """Dense gate weights [T, E] from router logits [T, E] and runtime k.

    k_base is static and only bounds the search space (k <= k_base); the
    kernel itself is generic in k. block_t tiles the token axis so each grid
    step's [block_t, E, E] compare tensor fits VMEM.
    """
    T, E = scores.shape
    bt = min(block_t, T)
    assert T % bt == 0, f"token count {T} not divisible by block {bt}"
    k_arr = jnp.reshape(jnp.asarray(k, dtype=jnp.int32), (1,))
    return pl.pallas_call(
        _gate_kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),          # runtime k (scalar)
            pl.BlockSpec((bt, E), lambda i: (i, 0)),     # token block
        ],
        out_specs=pl.BlockSpec((bt, E), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, E), scores.dtype),
        interpret=True,
    )(k_arr, scores)
