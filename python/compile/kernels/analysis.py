"""L1 performance analysis: VMEM footprint + MXU-utilization *estimates*
for the Pallas kernels' BlockSpecs (DESIGN.md §Perf).

interpret=True wall-clock is CPU-numpy time, NOT a TPU proxy — so the L1
optimization loop reasons structurally: does each grid step fit VMEM
(~16 MiB/core on TPU v4), and what fraction of its time would the MXU be
busy (arithmetic intensity vs the 128x128 systolic array's balance point)?

Run as a module for the per-model table:
    python -m compile.kernels.analysis
"""

from dataclasses import dataclass

# TPU v4-ish envelope used for the estimates.
VMEM_BYTES = 16 * 1024 * 1024
MXU_FLOPS = 137e12          # BF16 peak per core
HBM_BW = 1.2e12             # B/s per core
F32 = 4


@dataclass
class KernelEstimate:
    name: str
    grid: tuple
    vmem_bytes: int
    flops_per_step: float
    hbm_bytes_per_step: float

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_step / max(self.hbm_bytes_per_step, 1)

    @property
    def mxu_utilization(self) -> float:
        """Roofline estimate: fraction of peak MXU the step can sustain
        given its HBM traffic (1.0 = compute-bound at peak)."""
        t_compute = self.flops_per_step / MXU_FLOPS
        t_memory = self.hbm_bytes_per_step / HBM_BW
        return t_compute / max(t_compute, t_memory)


def moe_ffn_estimate(t: int, h: int, f: int, e: int, block_t: int,
                     block_e: int, block_f: int | None = None,
                     dtype_bytes: int = F32) -> KernelEstimate:
    """Estimate one (token-block, expert-block[, ffn-block]) grid step of
    kernels.moe_ffn.

    VMEM residency per step: x block, W1/W3/W2 panels, gate block, the
    [bt, be, bf] activation scratch, and the output block. The exported
    analogue kernel keeps F unblocked (their panels are tiny); the
    paper-scale mapping tiles F as a third grid axis (Mixtral's
    4096x14336 panels are ~118 MiB each in BF16, far beyond VMEM).
    """
    bt, be = min(block_t, t), min(block_e, e)
    bf = min(block_f or f, f)
    vmem = (
        bt * h                      # x block
        + 2 * be * h * bf           # W1 + W3 panels
        + be * bf * h               # W2 panel
        + bt * be                   # gate weights block
        + bt * be * bf              # activation scratch
        + bt * h                    # output block
    ) * dtype_bytes
    flops = 3 * 2 * bt * be * h * bf + 2 * bt * be * bf
    # HBM per step: weight panels stream in; x/out blocks amortize over
    # the expert/ffn axes (revisited), gate block is tiny.
    hbm = (3 * be * h * bf + bt * be) * dtype_bytes \
        + (2 * bt * h * dtype_bytes) / max((e // be) * (f // bf), 1)
    name = f"moe_ffn[bt={bt},be={be}" + (f",bf={bf}]" if bf < f else "]")
    return KernelEstimate(
        name=name,
        grid=(max(t // bt, 1), max(e // be, 1), max(f // bf, 1)),
        vmem_bytes=int(vmem),
        flops_per_step=float(flops),
        hbm_bytes_per_step=float(hbm),
    )


def topk_gate_estimate(t: int, e: int, block_t: int,
                       dtype_bytes: int = F32) -> KernelEstimate:
    """One token-block step of kernels.topk_gate (VPU work, no MXU)."""
    bt = min(block_t, t)
    vmem = (bt * e          # scores block
            + bt * e * e    # rank compare tensor
            + bt * e        # output
            ) * dtype_bytes
    flops = bt * e * e * 2 + 4 * bt * e
    hbm = 2 * bt * e * dtype_bytes
    return KernelEstimate(
        name=f"topk_gate[bt={bt}]",
        grid=(max(t // bt, 1),),
        vmem_bytes=int(vmem),
        flops_per_step=float(flops),
        hbm_bytes_per_step=float(hbm),
    )


def sweep_block_sizes(t: int, h: int, f: int, e: int,
                      dtype_bytes: int = F32):
    """Best MoE-FFN block config: maximize MXU utilization subject to
    VMEM fit (the structural L1 optimization loop)."""
    best = None
    for bt in (32, 64, 128, 256):
        for be in (1, 2, 4, 8, 16):
            if e % min(be, e):
                continue
            for bf in (128, 256, 512, 1024, 2048, f):
                if bf > f:
                    continue
                est = moe_ffn_estimate(t, h, f, e, bt, be, block_f=bf,
                                       dtype_bytes=dtype_bytes)
                if not est.fits_vmem:
                    continue
                if best is None or est.mxu_utilization > best.mxu_utilization:
                    best = est
    return best


def paper_scale_table():
    """Estimates at the paper-scale dims of Table 1 (for DESIGN §Perf)."""
    rows = []
    paper = {
        "mixtral-8x7b": (4096, 14336, 8),
        "qwen1.5-moe-a2.7b": (2048, 1408, 60),
        "olmoe-1b-7b": (2048, 1024, 64),
        "deepseek-v2-lite": (2048, 1408, 64),
        "minicpm-moe-8x2b": (2304, 5760, 8),
        "deepseek-vl2-tiny": (1280, 896, 64),
    }
    for name, (h, f, e) in paper.items():
        best = sweep_block_sizes(t=1024, h=h, f=f, e=e, dtype_bytes=2)
        rows.append((name, best))
    return rows


def analogue_table():
    from .. import configs as C
    rows = []
    for name, cfg in C.MODELS.items():
        est = moe_ffn_estimate(cfg.batch * cfg.prefill_len, cfg.hidden,
                               cfg.ffn, cfg.n_experts, 128, 8)
        rows.append((name, est))
    return rows


def main():
    print(f"VMEM budget {VMEM_BYTES >> 20} MiB, MXU {MXU_FLOPS/1e12:.0f} TF, "
          f"HBM {HBM_BW/1e12:.1f} TB/s\n")
    print("== tiny analogues (as exported, f32, interpret) ==")
    for name, est in analogue_table():
        print(f"{name:<22} {est.name:<24} grid {str(est.grid):<10} "
              f"vmem {est.vmem_bytes/1024:8.0f} KiB  "
              f"AI {est.arithmetic_intensity:6.1f}  "
              f"mxu~{est.mxu_utilization*100:5.1f}%")
    print("\n== paper scale (bf16-ready), best block config by sweep ==")
    for name, est in paper_scale_table():
        print(f"{name:<22} {est.name:<24} grid {str(est.grid):<10} "
              f"vmem {est.vmem_bytes/1024:8.0f} KiB  "
              f"AI {est.arithmetic_intensity:6.1f}  "
              f"mxu~{est.mxu_utilization*100:5.1f}%")


if __name__ == "__main__":
    main()
