"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground-truth semantics; pytest/hypothesis asserts the Pallas
kernels (interpret=True) match them elementwise. The training path also uses
these (they trace to fewer HLO ops than interpret-mode Pallas, which matters
on a single CPU core), while the exported inference graphs use the kernels —
the equality tests make the two paths interchangeable.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def topk_gate_ref(scores: jax.Array, k, k_base: int) -> jax.Array:
    """Paper gating: G(x) = Softmax(TopK(x . Wg)) with *runtime* k.

    scores: [T, E] raw router logits (gate bias already added).
    k:      scalar i32, number of active experts, 1 <= k <= k_base.
    k_base: static baseline top-k (defines the nested selection order).

    Returns dense weights [T, E]: softmax over the top-k experts per token,
    zero elsewhere. Selection is by score rank with index tie-break, so the
    top-k sets are nested in k — the property LExI's Stage-1 monotonicity
    relies on.
    """
    T, E = scores.shape
    # rank[t, e] = number of experts strictly better than e for token t
    # (ties broken by lower expert index winning).
    s_i = scores[:, :, None]  # candidate e
    s_j = scores[:, None, :]  # competitor j
    better = (s_j > s_i) | (
        (s_j == s_i)
        & (jnp.arange(E)[None, None, :] < jnp.arange(E)[None, :, None])
    )
    rank = jnp.sum(better, axis=-1)  # [T, E]
    active = rank < jnp.asarray(k, dtype=rank.dtype)
    masked = jnp.where(active, scores, NEG_INF)
    w = jax.nn.softmax(masked, axis=-1)
    return jnp.where(active, w, 0.0)


def moe_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
                weights: jax.Array) -> jax.Array:
    """Weighted SwiGLU mixture: y = sum_e weights[:,e] * FFN_e(x).

    x: [T, H]; w1,w3: [E, H, F]; w2: [E, F, H]; weights: [T, E] (dense gate).
    Computed densely over experts as two big GEMMs so XLA hits the GEMM
    kernel; gate weights of non-selected experts are exactly zero.
    """
    T, H = x.shape
    E, _, F = w1.shape
    h1 = x @ jnp.transpose(w1, (1, 0, 2)).reshape(H, E * F)   # [T, E*F]
    h3 = x @ jnp.transpose(w3, (1, 0, 2)).reshape(H, E * F)
    act = jax.nn.silu(h1) * h3
    act = act.reshape(T, E, F) * weights[:, :, None]
    y = act.reshape(T, E * F) @ w2.reshape(E * F, H)
    return y


def moe_block_ref(x, gate_w, gate_bias, w1, w3, w2, k, k_base):
    """Full MoE module: router + weighted expert mixture. x: [T, H].

    Returns (y [T, H], weights [T, E])."""
    scores = x @ gate_w + gate_bias[None, :]
    weights = topk_gate_ref(scores, k, k_base)
    return moe_ffn_ref(x, w1, w3, w2, weights), weights
