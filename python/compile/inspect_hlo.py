"""L2 performance analysis: structural inspection of the exported HLO
(DESIGN.md §Perf). Counts op kinds, fusions, while-loops (scan bodies),
and flags the decode-graph properties that matter:

  * decode must be O(1) in sequence length per step (no quadratic
    attention recompute — KV in/out only);
  * the MoE mixture should be dominated by dot-generals (GEMM-bound),
    not gathers/scatters;
  * the rolled scan keeps code size O(1) in depth.

Usage:  python -m compile.inspect_hlo artifacts/<model>/decode.hlo.txt
        python -m compile.inspect_hlo --all artifacts
"""

import os
import re
import sys
from collections import Counter


# type may be a tuple "(f32[..], ...)" — allow parens and slashes (comments)
OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{}()/*=0-9, ]+\s+([a-z][\w-]*)\(")


def analyze(path: str) -> dict:
    counts = Counter()
    dot_shapes = []
    n_lines = 0
    with open(path) as f:
        for line in f:
            n_lines += 1
            m = OP_RE.match(line)
            if not m:
                continue
            op = m.group(1)
            counts[op] += 1
            if op == "dot":
                shape = line.split("=", 1)[1].strip().split(" ")[0]
                dot_shapes.append(shape)
    return {
        "path": path,
        "lines": n_lines,
        "counts": counts,
        "dot_shapes": dot_shapes,
    }


def report(info: dict) -> str:
    c = info["counts"]
    total = sum(c.values())
    top = ", ".join(f"{op}:{n}" for op, n in c.most_common(10))
    lines = [
        f"{info['path']}",
        f"  {info['lines']} lines, {total} instructions",
        f"  top ops: {top}",
        f"  dot={c.get('dot', 0)} gather={c.get('gather', 0)} "
        f"scatter={c.get('scatter', 0)} while={c.get('while', 0)} "
        f"fusion={c.get('fusion', 0)}",
    ]
    return "\n".join(lines)


def check_decode_invariants(info: dict) -> list:
    """Structural red flags for the decode hot path."""
    problems = []
    c = info["counts"]
    if c.get("while", 0) < 1:
        problems.append("decode graph lost its rolled scan (depth unrolled?)")
    if c.get("gather", 0) > c.get("dot", 0) * 4:
        problems.append(
            f"gather-heavy graph ({c.get('gather')} gathers vs {c.get('dot')} dots)")
    # quadratic attention would show as a dot with ctx x ctx output
    return problems


def main():
    args = sys.argv[1:]
    if args and args[0] == "--all":
        root = args[1] if len(args) > 1 else "artifacts"
        paths = []
        for d in sorted(os.listdir(root)):
            for g in ("prefill.hlo.txt", "decode.hlo.txt", "moe_layer.hlo.txt"):
                p = os.path.join(root, d, g)
                if os.path.exists(p):
                    paths.append(p)
    else:
        paths = args or ["artifacts/mixtral-8x7b/decode.hlo.txt"]

    for p in paths:
        info = analyze(p)
        print(report(info))
        if "decode" in p:
            for prob in check_decode_invariants(info):
                print(f"  !! {prob}")
        print()


if __name__ == "__main__":
    main()
