"""Tiny analogue configs of the paper's Table-1 MoE models.

Each analogue preserves the *structural* quantities LExI depends on —
layer count, expert count, baseline top-k — while shrinking hidden/FFN
dims so the models can be trained and evaluated on a single CPU core.
The paper-scale dims (for the H100 performance model on the Rust side)
live in rust/src/config/model.rs; the two sides share `name` keys.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    # Identity (matches rust/src/config/model.rs keys)
    name: str
    # Structure copied from the paper's Table 1
    n_layers: int
    n_experts: int
    top_k: int  # baseline pretrained top-k (k_base)
    # Tiny-analogue dims (paper-scale dims live on the Rust side)
    hidden: int = 32
    ffn: int = 64
    n_heads: int = 4
    vocab: int = 256
    # Sequence geometry shared with the Rust engine
    max_seq: int = 128          # KV-cache capacity
    prefill_len: int = 96       # static prefill graph length
    batch: int = 8              # static batch (shared by prefill + decode)
    # Build-time training
    train_seq: int = 96
    train_batch: int = 2
    train_steps: int = 500
    lr: float = 3e-3
    is_vlm: bool = False

    @property
    def head_dim(self) -> int:
        return self.hidden // self.n_heads

    def to_dict(self):
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


# Table 1 of the paper (layer / expert / top-k structure preserved):
#   Model                       #Layers  #Experts  TopK
#   DeepSeek VL2-Tiny              12       64       6
#   OLMoE-1B-7B-0125-Instruct      16       64       8
#   Qwen1.5-MoE-A2.7B-Chat         24       60       4
#   DeepSeek-V2-Lite-Chat          27       64       6
#   MiniCPM-MoE-8x2B               40        8       2
#   Mixtral-8x7B-Instruct-v0.1     32        8       2
MODELS = {
    "deepseek-vl2-tiny": ModelConfig(
        name="deepseek-vl2-tiny", n_layers=12, n_experts=64, top_k=6,
        is_vlm=True,
    ),
    "olmoe-1b-7b": ModelConfig(
        name="olmoe-1b-7b", n_layers=16, n_experts=64, top_k=8,
    ),
    "qwen1.5-moe-a2.7b": ModelConfig(
        name="qwen1.5-moe-a2.7b", n_layers=24, n_experts=60, top_k=4,
    ),
    "deepseek-v2-lite": ModelConfig(
        name="deepseek-v2-lite", n_layers=27, n_experts=64, top_k=6,
    ),
    "minicpm-moe-8x2b": ModelConfig(
        name="minicpm-moe-8x2b", n_layers=40, n_experts=8, top_k=2, ffn=96,
    ),
    "mixtral-8x7b": ModelConfig(
        name="mixtral-8x7b", n_layers=32, n_experts=8, top_k=2, ffn=96,
    ),
}

# The five LLMs used in Figs. 4-7 (the VLM is Fig. 8).
LLM_NAMES = [
    "olmoe-1b-7b",
    "qwen1.5-moe-a2.7b",
    "deepseek-v2-lite",
    "minicpm-moe-8x2b",
    "mixtral-8x7b",
]
VLM_NAME = "deepseek-vl2-tiny"
ALL_NAMES = LLM_NAMES + [VLM_NAME]


# ---------------------------------------------------------------------------
# Shared vocabulary layout (mirrored in rust/src/engine/tokenizer.rs)
# ---------------------------------------------------------------------------
PAD, BOS, EOS = 0, 1, 2
KEY, QRY, FACT, ASK, ANS, SEP, IMG = 3, 4, 5, 6, 7, 8, 9
VAL_BASE, N_VALS = 10, 32          # "digit"/value tokens 10..41
TEXT_BASE, N_TEXT = 42, 128        # Markov text tokens 42..169
IMG_BASE, N_IMG = 170, 64          # image patch tokens 170..233
VOCAB = 256
