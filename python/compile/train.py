"""Build-time training of the tiny analogue models (DESIGN.md §3).

Runs ONCE under `make artifacts`; never on the request path. Each analogue
is trained with Adam on the synthetic task mixture until the loss curve is
clearly descending (a few hundred steps — the point is real, structured
weights whose routers have learned token-dependent expert preferences, not
SOTA quality). The loss curve is logged to train_log.json and summarized
in EXPERIMENTS.md.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs as C
from . import data as D
from . import model as M


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                clip=1.0):
    """Adam with global-norm gradient clipping."""
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_sc = 1.0 / (1 - b1 ** tf)
    vhat_sc = 1.0 / (1 - b2 ** tf)
    new_p = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_sc) / (jnp.sqrt(v_ * vhat_sc) + eps),
        params, m, v)
    return new_p, {"m": m, "v": v, "t": t}


def train_model(cfg: C.ModelConfig, seed: int = 0, steps: int | None = None,
                log_every: int = 10, progress: bool = True):
    """Train one analogue; returns (params, log dict)."""
    steps = steps or cfg.train_steps
    rng = np.random.default_rng(seed + 17)
    corp = D.corpora()
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, tokens):
        (loss, (ce, bal)), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, tokens, cfg)
        params, opt = adam_update(params, grads, opt, cfg.lr)
        return params, opt, loss, ce, bal

    log = {"model": cfg.name, "steps": steps, "loss": [], "ce": [],
           "balance": [], "step_ids": []}
    t0 = time.time()
    for i in range(steps):
        batch = D.training_batch(rng, corp, cfg.train_batch, cfg.train_seq,
                                 vlm=cfg.is_vlm)
        params, opt, loss, ce, bal = step(params, opt, jnp.asarray(batch))
        if i % log_every == 0 or i == steps - 1:
            log["loss"].append(float(loss))
            log["ce"].append(float(ce))
            log["balance"].append(float(bal))
            log["step_ids"].append(i)
            if progress:
                print(f"[{cfg.name}] step {i:4d} loss {float(loss):.4f} "
                      f"ce {float(ce):.4f} bal {float(bal):.3f}", flush=True)
    log["wall_s"] = time.time() - t0
    return params, log


def calibration_stats(params, cfg: C.ModelConfig, n_batches: int = 4,
                      seed: int = 1234):
    """Per-layer expert stats on sampled data for the *baseline* methods.

    This is exactly the calibration-set dependence LExI avoids: NAEE-style
    inter-pruning ranks experts by how much router mass / selection
    frequency they receive on real data. Returns dict of [L, E] arrays.
    """
    rng = np.random.default_rng(seed)
    corp = D.corpora()
    k_vec = jnp.full((cfg.n_layers,), cfg.top_k, dtype=jnp.int32)
    bias = jnp.zeros((cfg.n_layers, cfg.n_experts))

    fwd = jax.jit(lambda p, t: M.forward_prefill(
        p, t, k_vec, bias, cfg, use_kernels=False, collect_router=True)[2])
    mean_p = np.zeros((cfg.n_layers, cfg.n_experts))
    sel_freq = np.zeros_like(mean_p)
    gate_mass = np.zeros_like(mean_p)
    for _ in range(n_batches):
        batch = D.training_batch(rng, corp, cfg.train_batch, cfg.train_seq,
                                 vlm=cfg.is_vlm)
        p, f, g = fwd(params, jnp.asarray(batch))
        mean_p += np.asarray(p) / n_batches
        sel_freq += np.asarray(f) / n_batches
        gate_mass += np.asarray(g) / n_batches
    return {"mean_prob": mean_p.astype(np.float32),
            "sel_freq": sel_freq.astype(np.float32),
            "gate_mass": gate_mass.astype(np.float32)}


def save_params_npz(params, path: str):
    """Flatten the pytree to name->array and save; names match manifest."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    arrays = {}
    for p, leaf in flat:
        name = "/".join(str(k.key) for k in p)
        arrays[name] = np.asarray(leaf, dtype=np.float32)
    np.savez(path, **arrays)


def save_log(log: dict, path: str):
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
