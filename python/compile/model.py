"""L2: MoE transformer LM in JAX with *runtime* per-layer top-k.

Architecture (per analogue config): tied-embedding decoder with RMSNorm,
RoPE multi-head attention, and a softmax-top-k MoE SwiGLU FFN in every
layer. The per-layer active-expert counts `k_vec[L]` and router biases
`gate_bias[L, E]` are *runtime inputs*, so a single AOT-compiled executable
serves the baseline model, every LExI allocation, and every pruning
baseline (inter-pruning = -1e9 gate bias; intra-pruning = zeroed FFN
columns in the weights).

Three graphs are exported by aot.py:
  prefill: tokens[B,T] -> logits[B,T,V] + KV cache
  decode:  kv, token[B], pos[B]  -> logits[B,V] + kv'   (O(1) per step)
  moe_layer: x[T,H] + one layer's weights + k -> y[T,H] (Stage-1 profiling)

The exported graphs run the Pallas kernel path (kernels.moe_block); the
build-time training path runs the pure-jnp oracle (kernels.ref) — pytest
asserts the two are numerically interchangeable.
"""

import os

import jax
import jax.numpy as jnp

from . import configs as C
from .kernels import ref as kref
from .kernels.moe_ffn import moe_block as _kernel_moe_block


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: C.ModelConfig, key: jax.Array):
    """Stacked-layer parameter pytree (leading axis = layer) for lax.scan."""
    L, H, F, E, V = cfg.n_layers, cfg.hidden, cfg.ffn, cfg.n_experts, cfg.vocab
    ks = jax.random.split(key, 10)

    def norm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    h_sc = H ** -0.5
    f_sc = F ** -0.5
    return {
        "embed": norm(ks[0], (V, H), 0.05),
        "ln_f": jnp.ones((H,)),
        "layers": {
            "ln1": jnp.ones((L, H)),
            "wq": norm(ks[1], (L, H, H), h_sc),
            "wk": norm(ks[2], (L, H, H), h_sc),
            "wv": norm(ks[3], (L, H, H), h_sc),
            "wo": norm(ks[4], (L, H, H), h_sc),
            "ln2": jnp.ones((L, H)),
            "gate": norm(ks[5], (L, H, E), h_sc),
            "w1": norm(ks[6], (L, E, H, F), h_sc),
            "w3": norm(ks[7], (L, E, H, F), h_sc),
            "w2": norm(ks[8], (L, E, F, H), f_sc),
        },
    }


def param_leaf_names(params):
    """Flattened leaf names in jax's traversal order (manifest / Rust I/O)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return ["/".join(str(p.key) for p in path) for path, _ in flat]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, pos):
    """Rotary embedding. x: [..., T, nh, hd]; pos: [..., T] absolute."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 10000.0 ** (-jnp.arange(half) / half)           # [half]
    ang = pos[..., None] * freqs                            # [..., T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _block_e(cfg) -> int:
    """Expert-block size of the exported kernels.

    §Perf L1 iteration: at analogue scale the full expert panel fits VMEM
    (kernels/analysis.py: <= 708 KiB of the 16 MiB budget), so the default
    is be = E — one grid step per token block instead of E/8, which cuts
    the interpret-mode grid overhead ~8x on the decode hot path. Paper-
    scale panels would NOT fit; set LEXI_BLOCK_E=8 to export the tiled
    schedule the analysis sweep selects for real hardware.
    """
    want = os.environ.get("LEXI_BLOCK_E", "")
    if want:
        be = int(want)
        while cfg.n_experts % be:
            be -= 1
        return be
    return cfg.n_experts


def _moe(x2d, lp, k, bias_row, cfg, use_kernels):
    """MoE FFN on flattened tokens x2d [N, H] -> ([N, H], weights [N, E])."""
    if use_kernels:
        # Pallas path (exported inference graphs). Block sizes: largest
        # power-of-two token block <= 128 dividing N; expert block from
        # the §Perf policy above.
        n = x2d.shape[0]
        bt = 128
        while n % bt:
            bt //= 2
        return _kernel_moe_block(x2d, lp["gate"], bias_row, lp["w1"],
                                 lp["w3"], lp["w2"], k, cfg.top_k,
                                 block_t=bt, block_e=_block_e(cfg))
    return kref.moe_block_ref(x2d, lp["gate"], bias_row, lp["w1"], lp["w3"],
                              lp["w2"], k, cfg.top_k)


def _attn_prefill(x, lp, cfg):
    """Causal self-attention over [B, T, H]; returns (y, k_cache, v_cache)."""
    B, T, H = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = (x @ lp["wq"]).reshape(B, T, nh, hd)
    k = (x @ lp["wk"]).reshape(B, T, nh, hd)
    v = (x @ lp["wv"]).reshape(B, T, nh, hd)
    pos = jnp.arange(T)[None, :].astype(jnp.float32)
    q, k = rope(q, pos), rope(k, pos)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / hd ** 0.5
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B, T, H)
    return y @ lp["wo"], k, v


def _attn_decode(x, lp, kc, vc, pos, cfg):
    """One-token attention. x: [B, H]; kc/vc: [B, maxT, nh, hd]; pos: [B]."""
    B, H = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    maxT = kc.shape[1]
    q = (x @ lp["wq"]).reshape(B, 1, nh, hd)
    k = (x @ lp["wk"]).reshape(B, 1, nh, hd)
    v = (x @ lp["wv"]).reshape(B, 1, nh, hd)
    posf = pos.astype(jnp.float32)[:, None]
    q, k = rope(q, posf), rope(k, posf)
    # Write this step's K/V at index pos[b] (one-hot blend keeps the graph
    # free of per-batch dynamic slices).
    onehot = (jnp.arange(maxT)[None, :] == pos[:, None]).astype(kc.dtype)
    kc = kc * (1 - onehot)[..., None, None] + onehot[..., None, None] * k
    vc = vc * (1 - onehot)[..., None, None] + onehot[..., None, None] * v
    att = jnp.einsum("bqhd,bkhd->bhqk", q, kc)[:, :, 0] / hd ** 0.5  # [B,nh,maxT]
    valid = jnp.arange(maxT)[None, :] <= pos[:, None]
    att = jnp.where(valid[:, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhk,bkhd->bhd", att, vc).reshape(B, H)
    return y @ lp["wo"], kc, vc


# ---------------------------------------------------------------------------
# Full-model graphs
# ---------------------------------------------------------------------------


def forward_prefill(params, tokens, k_vec, gate_bias, cfg: C.ModelConfig,
                    use_kernels: bool = True, collect_router: bool = False):
    """tokens [B, T] -> (logits [B, T, V], kv [L, 2, B, maxT, nh, hd]).

    Router stats (mean full-softmax prob, top-k selection frequency and
    gate mass per expert) are additionally returned when
    collect_router=True (training aux loss + NAEE calibration stats).
    """
    B, T = tokens.shape
    x = params["embed"][tokens]

    def body(x, xs):
        lp, kj, bj = xs
        a, kc, vc = _attn_prefill(rmsnorm(x, lp["ln1"]), lp, cfg)
        x = x + a
        h = rmsnorm(x, lp["ln2"]).reshape(B * T, cfg.hidden)
        y, w = _moe(h, lp, kj, bj, cfg, use_kernels)
        x = x + y.reshape(B, T, cfg.hidden)
        if collect_router:
            scores = h @ lp["gate"] + bj[None, :]
            full_p = jax.nn.softmax(scores, axis=-1)
            aux = (jnp.mean(full_p, axis=0),
                   jnp.mean((w > 0).astype(jnp.float32), axis=0),
                   jnp.sum(w, axis=0))
        else:
            aux = jnp.zeros((0,))
        return x, (kc, vc, aux)

    xs = (params["layers"], k_vec, gate_bias)
    x, (kcs, vcs, aux) = jax.lax.scan(body, x, xs)
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T
    # Pad caches T -> max_seq so prefill and decode share the cache shape.
    pad = cfg.max_seq - T
    kcs = jnp.pad(kcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vcs = jnp.pad(vcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kv = jnp.stack([kcs, vcs], axis=1)  # [L, 2, B, maxT, nh, hd]
    return (logits, kv, aux) if collect_router else (logits, kv)


def forward_decode(params, kv, tokens, pos, k_vec, gate_bias,
                   cfg: C.ModelConfig, use_kernels: bool = True):
    """One decode step. tokens [B], pos [B] -> (logits [B, V], kv')."""
    x = params["embed"][tokens]

    def body(x, xs):
        lp, kvj, kj, bj = xs
        a, kc, vc = _attn_decode(rmsnorm(x, lp["ln1"]), lp, kvj[0], kvj[1],
                                 pos, cfg)
        x = x + a
        h = rmsnorm(x, lp["ln2"])
        y, _ = _moe(h, lp, kj, bj, cfg, use_kernels)
        return x + y, jnp.stack([kc, vc])

    xs = (params["layers"], kv, k_vec, gate_bias)
    x, kv2 = jax.lax.scan(body, x, xs)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T, kv2


def moe_layer_forward(x, gate_w, gate_bias, w1, w3, w2, k,
                      cfg: C.ModelConfig, use_kernels: bool = True):
    """Standalone MoE module for Stage-1 sensitivity profiling. x: [T, H]."""
    lp = {"gate": gate_w, "w1": w1, "w3": w3, "w2": w2}
    y, _ = _moe(x, lp, k, gate_bias, cfg, use_kernels)
    return y


# ---------------------------------------------------------------------------
# Training objective (build-time only)
# ---------------------------------------------------------------------------


def loss_fn(params, tokens, cfg: C.ModelConfig, aux_coef: float = 0.01):
    """Next-token CE over non-PAD targets + Switch-style load-balance aux."""
    k_vec = jnp.full((cfg.n_layers,), cfg.top_k, dtype=jnp.int32)
    gate_bias = jnp.zeros((cfg.n_layers, cfg.n_experts))
    logits, _, aux = forward_prefill(params, tokens, k_vec, gate_bias, cfg,
                                     use_kernels=False, collect_router=True)
    mean_p, sel_freq, _ = aux  # each [L, E]
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    mask = (tgt != C.PAD).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    # Load balance: E * sum_e f_e p_e per layer (Switch Transformer eq. 4);
    # f_e normalized by top_k so a perfectly uniform router scores 1.
    balance = cfg.n_experts * jnp.mean(jnp.sum(sel_freq / cfg.top_k * mean_p,
                                               axis=-1))
    return ce + aux_coef * balance, (ce, balance)
