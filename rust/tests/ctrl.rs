//! Integration tests for the elastic control plane (`ctrl`): class-aware
//! shedding protects interactive admissions under a flash crowd, the
//! autoscaler grows the pool under pressure without losing a request,
//! speed-weighted routing prefers fast replicas in a heterogeneous
//! cluster, and an inert control plane reproduces the default run
//! exactly (the byte-identity regression).

use std::rc::Rc;

use lexi_moe::config::server::{PolicyKind, ScenarioKind};
use lexi_moe::ctrl::{AutoscalePolicy, Autoscaler, ShedPolicy, Shedder};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::server::workload::{
    ArrivalProcess, RequestProfile, Scenario, Trace, TraceRequest,
};
use lexi_moe::server::{
    Cluster, QualityLadder, Replica, ReplicaBackend, RunResult, ServiceModel,
};

// ---------------------------------------------------------------------
// fixtures
// ---------------------------------------------------------------------

fn ladder(step_s: f64, slots: usize) -> QualityLadder {
    QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-5, step_s, slots),
    )
}

/// Interactive (priority 0) + batch (priority 1) classes.
fn two_class_scenario() -> Scenario {
    let mut s = Scenario {
        name: "flash",
        kind: ScenarioKind::FlashCrowd,
        arrivals: ArrivalProcess::Poisson { rate: 1.0 },
        profiles: vec![
            RequestProfile {
                name: "chat",
                prompt_lo: 64,
                prompt_hi: 64,
                gen_lo: 32,
                gen_hi: 32,
                priority: 0,
                weight: 0.5,
                ttft_mult: 50.0,
                tpot_mult: 10.0,
            },
            RequestProfile {
                name: "batch",
                prompt_lo: 64,
                prompt_hi: 64,
                gen_lo: 32,
                gen_hi: 32,
                priority: 1,
                weight: 0.5,
                ttft_mult: 50.0,
                tpot_mult: 10.0,
            },
        ],
        slos: Vec::new(),
    };
    s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
    s
}

/// `n` alternating interactive/batch requests, effectively simultaneous.
fn flash_trace(n: usize) -> Trace {
    Trace {
        scenario: "flash",
        requests: (0..n as u64)
            .map(|id| TraceRequest {
                id,
                class: (id % 2) as usize,
                arrival_s: 1e-6 * id as f64,
                prompt_len: 64,
                new_tokens: 32,
            })
            .collect(),
        closed_loop: None,
    }
}

/// One-class trace with arrivals spaced `gap_s` apart.
fn paced_trace(n: usize, gap_s: f64) -> Trace {
    Trace {
        scenario: "flash",
        requests: (0..n as u64)
            .map(|id| TraceRequest {
                id,
                class: 0,
                arrival_s: gap_s * id as f64,
                prompt_len: 64,
                new_tokens: 16,
            })
            .collect(),
        closed_loop: None,
    }
}

fn count_rejected(res: &RunResult, class: usize) -> u64 {
    res.rejected_by_class[class]
}

// ---------------------------------------------------------------------
// class-aware shedding
// ---------------------------------------------------------------------

/// Under a flash crowd, the shedder drops batch traffic before the hard
/// cap would turn interactive work away: batch is policy-shed,
/// interactive never is, and interactive rejections go DOWN relative to
/// the cap-only cluster.
#[test]
fn flash_crowd_sheds_batch_before_interactive() {
    let s = two_class_scenario();
    let trace = flash_trace(60);
    let cap = 16usize;
    let mk = || Cluster::new(2, 2, PolicyKind::Jsq, ladder(0.01, 2), None, cap, 2, 0.0, 1);

    let plain = mk().run(&s, &trace);
    let shed = mk()
        .with_shedding(Shedder::new(
            ShedPolicy {
                cap,
                queue_frac: 0.85,
                // disable the slack trigger: this test isolates the
                // queue-pressure path deterministically
                slack_frac: 0.0,
            },
            2,
        ))
        .run(&s, &trace);

    // conservation on both sides of the comparison
    for res in [&plain, &shed] {
        assert_eq!(
            res.completed.len() as u64 + res.rejected_by_class.iter().sum::<u64>(),
            60,
            "requests lost"
        );
    }
    assert!(plain.shed_by_class.is_none(), "default run grew shed fields");

    let by_class = shed.shed_by_class.as_ref().expect("shedding was enabled");
    assert_eq!(by_class[0], 0, "interactive traffic was policy-shed");
    assert!(by_class[1] > 0, "flash crowd shed no batch traffic");
    // sheds are a subset of the rejections (they count toward both)
    assert!(count_rejected(&shed, 1) >= by_class[1]);
    // the whole point: shedding batch early leaves the cap's headroom
    // for interactive admissions
    assert!(
        count_rejected(&shed, 0) < count_rejected(&plain, 0),
        "interactive rejections did not improve: {} (shed) vs {} (cap only)",
        count_rejected(&shed, 0),
        count_rejected(&plain, 0)
    );
}

// ---------------------------------------------------------------------
// autoscaling
// ---------------------------------------------------------------------

/// A flash crowd against a 1-live / 4-slot pool: the autoscaler grows
/// the live set, every request still completes exactly once, and the
/// provisioned replica-seconds stay below the fixed-pool cost.
#[test]
fn autoscaler_grows_under_pressure_and_conserves_requests() {
    let s = two_class_scenario();
    let trace = flash_trace(80);
    let pool = 4usize;
    let backends: Vec<Box<dyn ReplicaBackend>> = (0..pool)
        .map(|i| {
            Box::new(Replica::new(i, 2, Rc::new(ladder(0.01, 2)))) as Box<dyn ReplicaBackend>
        })
        .collect();
    let policy = AutoscalePolicy {
        min: 1,
        max: pool,
        warmup_s: 0.05,
        // depth pressure only: 80 outstanding >> 1.5 * live * 2 slots
        up_slack_frac: 0.0,
        up_outstanding_per_slot: 1.5,
        down_outstanding_per_slot: 0.5,
        sustain_up_s: 0.02,
        sustain_down_s: 0.5,
        cooldown_s: 0.05,
        slots_per_replica: 2,
    };
    let res = Cluster::from_backends(
        backends,
        PolicyKind::Jsq,
        Rc::new(ladder(0.01, 2)),
        None,
        100_000,
        2,
        0.0,
        1,
    )
    .with_autoscale(Autoscaler::new(policy, pool, 1))
    .run(&s, &trace);

    assert_eq!(res.completed.len(), 80, "autoscaling lost requests");
    assert_eq!(res.rejected_by_class.iter().sum::<u64>(), 0);
    let mut ids: Vec<u64> = res.completed.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 80, "autoscaling duplicated a request");

    let events = res.scale_events.as_ref().expect("autoscaling was enabled");
    let ups = events.iter().filter(|&&(_, _, up)| up).count();
    assert!(ups >= 1, "sustained backlog never triggered a scale-up");
    assert!(events.iter().all(|&(_, r, _)| r < pool));
    // scaled-up replicas actually served work
    assert!(
        res.completed.iter().any(|c| c.replica > 0),
        "no completion ever landed on a scaled-up replica"
    );
    let rs = res.replica_seconds.expect("autoscaling was enabled");
    assert!(rs > 0.0);
    assert!(
        rs < pool as f64 * res.makespan_s,
        "elastic provisioning cost {rs:.3} replica-s not below the fixed \
         pool's {:.3}",
        pool as f64 * res.makespan_s
    );
}

// ---------------------------------------------------------------------
// heterogeneous tiers: speed-weighted routing
// ---------------------------------------------------------------------

/// Fast + slow replica under JSQ: weighing backlog by measured step
/// speed shifts share toward the fast replica relative to raw
/// token-count balancing.
#[test]
fn speed_weighted_routing_prefers_the_fast_replica() {
    let s = two_class_scenario();
    let trace = paced_trace(60, 0.02);
    let mk = |speed_weighted: bool| {
        let backends: Vec<Box<dyn ReplicaBackend>> = vec![
            Box::new(Replica::new(0, 2, Rc::new(ladder(0.004, 2)))), // fast tier
            Box::new(Replica::new(1, 2, Rc::new(ladder(0.020, 2)))), // slow tier
        ];
        let c = Cluster::from_backends(
            backends,
            PolicyKind::Jsq,
            Rc::new(ladder(0.004, 2)),
            None,
            100_000,
            2,
            0.0,
            1,
        );
        if speed_weighted {
            c.with_speed_weighted_routing()
        } else {
            c
        }
    };

    let plain = mk(false).run(&s, &trace);
    let weighted = mk(true).run(&s, &trace);
    assert_eq!(plain.completed.len(), 60);
    assert_eq!(weighted.completed.len(), 60);

    let fast_share = |res: &RunResult| {
        res.completed.iter().filter(|c| c.replica == 0).count() as f64
            / res.completed.len() as f64
    };
    assert!(
        fast_share(&weighted) > 0.5,
        "fast replica served only {:.0}% under speed weighting",
        fast_share(&weighted) * 100.0
    );
    assert!(
        fast_share(&weighted) >= fast_share(&plain),
        "speed weighting moved share AWAY from the fast replica: \
         {:.2} vs {:.2}",
        fast_share(&weighted),
        fast_share(&plain)
    );
}

// ---------------------------------------------------------------------
// byte-identity regression: an inert control plane changes nothing
// ---------------------------------------------------------------------

/// A calm workload through a shedder that never fires and an autoscaler
/// pinned at min == max must reproduce the default cluster's completions
/// exactly — the control plane only reads telemetry, it never perturbs
/// the schedule or the seeded rng.
#[test]
fn inert_control_plane_reproduces_the_default_run() {
    let s = two_class_scenario();
    let trace = paced_trace(24, 0.05);
    let mk = || Cluster::new(2, 2, PolicyKind::Jsq, ladder(0.01, 2), None, 100_000, 2, 0.0, 7);

    let default = mk().run(&s, &trace);
    let policy = AutoscalePolicy {
        min: 2,
        max: 2,
        warmup_s: 0.1,
        up_slack_frac: 0.0,
        up_outstanding_per_slot: 1.5,
        down_outstanding_per_slot: 0.5,
        sustain_up_s: 0.02,
        sustain_down_s: 0.5,
        cooldown_s: 0.05,
        slots_per_replica: 2,
    };
    let elastic = mk()
        .with_shedding(Shedder::new(
            ShedPolicy {
                cap: 100_000,
                queue_frac: 0.85,
                slack_frac: 0.0,
            },
            2,
        ))
        .with_autoscale(Autoscaler::new(policy, 2, 2))
        .run(&s, &trace);

    // identical request-by-request outcome...
    assert_eq!(elastic.completed, default.completed);
    assert_eq!(elastic.rejected_by_class, default.rejected_by_class);
    // ...while the elastic fields light up (and record inactivity)
    assert!(default.shed_by_class.is_none() && default.scale_events.is_none());
    assert_eq!(elastic.shed_by_class, Some(vec![0, 0]));
    assert_eq!(elastic.scale_events, Some(Vec::new()));
    let rs = elastic.replica_seconds.expect("autoscaling was enabled");
    assert!(
        (rs - 2.0 * elastic.makespan_s).abs() < 1e-6,
        "a pinned pool must bill exactly pool x makespan: {rs} vs {}",
        2.0 * elastic.makespan_s
    );
}
