//! End-to-end tests over the REAL artifacts (three-layer composition):
//! HLO compile, weight upload, prefill/decode consistency, Stage-1
//! monotonicity on trained weights, eval + engine smoke.
//!
//! Skipped (with a notice) when `make artifacts` has not run.

use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::{Engine, SamplingParams};
use lexi_moe::eval::{EvalSuite, RunConfig};
use lexi_moe::lexi::sensitivity::{profile_model, verify_table};
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};
use lexi_moe::util::Pcg32;

const MODEL: &str = "deepseek-vl2-tiny"; // smallest analogue -> fastest

fn setup() -> Option<(Runtime, Manifest)> {
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some((Runtime::cpu().expect("pjrt cpu"), m)),
        Err(_) => {
            eprintln!("SKIP runtime_e2e: no artifacts at {dir:?} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn prefill_decode_consistency() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let rc = RunConfig::baseline(&e);

    // random prompt in the text range
    let mut rng = Pcg32::seeded(1);
    let plen = 24usize;
    let mut tokens = vec![0i32; e.batch * e.prefill_len];
    for b in 0..e.batch {
        for p in 0..plen {
            tokens[b * e.prefill_len + p] = 42 + rng.gen_range(128) as i32;
        }
    }
    let full = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias).unwrap();

    // teacher-forced decode from a shorter prefill must reproduce the
    // prefill logits at each step (cache correctness across the stack)
    let cut = plen - 2;
    let mut short = tokens.clone();
    for b in 0..e.batch {
        for p in cut..e.prefill_len {
            short[b * e.prefill_len + p] = 0;
        }
    }
    let pre = model.prefill(&short, &rc.k_vec, &rc.gate_bias).unwrap();
    // zero cache rows at positions >= cut (prefill wrote pad-token k/v)
    let mut kv = pre.kv.to_host().unwrap();
    let row = e.n_heads * e.head_dim;
    for lane in 0..e.n_layers * 2 {
        for b in 0..e.batch {
            let base = ((lane * e.batch) + b) * e.max_seq * row;
            for t in cut..e.max_seq {
                kv.data[base + t * row..base + (t + 1) * row].fill(0.0);
            }
        }
    }
    let mut kv_state = lexi_moe::runtime::executable::KvState::Host(kv.to_literal().unwrap());

    for step in 0..2 {
        let toks: Vec<i32> = (0..e.batch)
            .map(|b| tokens[b * e.prefill_len + cut + step])
            .collect();
        let pos = vec![(cut + step) as i32; e.batch];
        let out = model
            .decode(&kv_state, &toks, &pos, &rc.k_vec, &rc.gate_bias)
            .unwrap();
        for b in 0..e.batch {
            let want =
                &full.logits[(b * e.prefill_len + cut + step) * e.vocab..][..e.vocab];
            let got = &out.logits[b * e.vocab..(b + 1) * e.vocab];
            for (g, w) in got.iter().zip(want) {
                assert!(
                    (g - w).abs() < 2e-3 * w.abs().max(1.0),
                    "slot {b} step {step}: {g} vs {w}"
                );
            }
        }
        kv_state = out.kv;
    }
}

#[test]
fn stage1_monotone_on_trained_weights() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let cfg = ExperimentConfig {
        sensitivity_iters: 2,
        ..Default::default()
    };
    let table = profile_model(&model, &cfg, None).unwrap();
    verify_table(&table).unwrap();
    // every layer must show a real deviation at k=1 (trained routers are
    // not degenerate)
    for (j, row) in table.loss.iter().enumerate() {
        assert!(row[0] > 0.0, "layer {j} has zero k=1 deviation");
    }
}

#[test]
fn runtime_k_vector_changes_outputs() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let mut rng = Pcg32::seeded(3);
    let tokens: Vec<i32> = (0..e.batch * e.prefill_len)
        .map(|_| 42 + rng.gen_range(128) as i32)
        .collect();
    let base_rc = RunConfig::baseline(&e);
    let a = model.prefill(&tokens, &base_rc.k_vec, &base_rc.gate_bias).unwrap();
    let mut k1 = base_rc.k_vec.clone();
    for k in k1.iter_mut() {
        *k = 1;
    }
    let b = model.prefill(&tokens, &k1, &base_rc.gate_bias).unwrap();
    let diff: f64 = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| ((x - y) as f64).abs())
        .sum();
    assert!(diff > 1.0, "k vector had no effect (diff {diff})");
    // determinism: same inputs -> same outputs
    let c = model.prefill(&tokens, &base_rc.k_vec, &base_rc.gate_bias).unwrap();
    assert_eq!(a.logits, c.logits);
}

#[test]
fn gate_bias_prunes_experts_at_runtime() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let mut rng = Pcg32::seeded(4);
    let tokens: Vec<i32> = (0..e.batch * e.prefill_len)
        .map(|_| 42 + rng.gen_range(128) as i32)
        .collect();
    let rc = RunConfig::baseline(&e);
    let base = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias).unwrap();
    // prune half the experts everywhere
    let mut bias = rc.gate_bias.clone();
    for l in 0..e.n_layers {
        for ex in 0..e.n_experts / 2 {
            bias[l * e.n_experts + ex] = -1e9;
        }
    }
    let pruned = model.prefill(&tokens, &rc.k_vec, &bias).unwrap();
    assert!(pruned.logits.iter().all(|v| v.is_finite()));
    assert_ne!(base.logits, pruned.logits);
}

#[test]
fn engine_serves_mixed_lengths_with_continuous_batching() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let scfg = ServingConfig {
        batch: e.batch,
        max_seq: e.max_seq,
        prefill_len: e.prefill_len,
        ..Default::default()
    };
    let rc = RunConfig::baseline(&e);
    let mut engine = Engine::new(&model, scfg, rc.k_vec, rc.gate_bias).unwrap();
    let mut rng = Pcg32::seeded(5);
    let n = e.batch + 4; // force a second admission wave
    for i in 0..n {
        let plen = 8 + rng.gen_usize(32);
        let prompt: Vec<i32> = (0..plen).map(|_| 42 + rng.gen_range(128) as i32).collect();
        engine
            .submit(
                prompt,
                SamplingParams {
                    max_new_tokens: 2 + (i % 5),
                    stop_on_eos: false,
                    ..Default::default()
                },
            )
            .unwrap();
    }
    let outs = engine.run_until_complete().unwrap();
    assert_eq!(outs.len(), n);
    for o in &outs {
        assert!(!o.tokens.is_empty());
        assert!(o.e2e_s >= o.ttft_s);
    }
    let s = engine.metrics.summary();
    assert!(s.prefill_calls >= 2, "expected a second admission wave");
    assert!(s.total_tok_s > 0.0);
}

#[test]
fn eval_suite_and_perplexity_sane() {
    let Some((rt, manifest)) = setup() else { return };
    let suite = EvalSuite::load(&manifest).unwrap();
    assert_eq!(suite.probe_tasks.len(), 9, "paper uses nine LM-Eval tasks");
    assert_eq!(suite.vlm_tasks.len(), 3);
    assert_eq!(suite.ppl_corpora.len(), 3);

    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let rc = RunConfig::baseline(&model.entry);
    let ppl =
        lexi_moe::eval::perplexity::perplexity(&model, &suite, "c4", &rc).unwrap();
    // trained model must beat the uniform bound (= vocab size)
    assert!(ppl < 256.0, "ppl {ppl} not better than random");
    assert!(ppl > 1.0);
}

#[test]
fn intra_pruned_weights_change_outputs_but_stay_finite() {
    let Some((rt, manifest)) = setup() else { return };
    let entry = manifest.model(MODEL).unwrap().clone();
    let mut params = lexi_moe::runtime::weights::HostParams::load_npz(
        manifest.model_dir(MODEL).join(&entry.files.params),
        &entry,
    )
    .unwrap();
    let zeroed = lexi_moe::pruning::intra_prune_params(&mut params, 0.25).unwrap();
    assert!(zeroed > 0);
    let model = ModelRuntime::with_params(&rt, &manifest, MODEL, params).unwrap();
    let rc = RunConfig::baseline(&model.entry);
    let mut rng = Pcg32::seeded(6);
    let tokens: Vec<i32> = (0..entry.batch * entry.prefill_len)
        .map(|_| 42 + rng.gen_range(128) as i32)
        .collect();
    let out = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias).unwrap();
    assert!(out.logits.iter().all(|v| v.is_finite()));
}

#[test]
fn engine_truncates_at_kv_capacity() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let scfg = ServingConfig {
        batch: e.batch,
        max_seq: e.max_seq,
        prefill_len: e.prefill_len,
        ..Default::default()
    };
    let rc = RunConfig::baseline(&e);
    let mut engine = Engine::new(&model, scfg, rc.k_vec, rc.gate_bias).unwrap();
    // prompt nearly filling the cache + unbounded generation demand
    let prompt: Vec<i32> = (0..e.prefill_len).map(|i| 42 + (i as i32 % 128)).collect();
    engine
        .submit(
            prompt,
            SamplingParams {
                max_new_tokens: 10_000,
                stop_on_eos: false,
                ..Default::default()
            },
        )
        .unwrap();
    let outs = engine.run_until_complete().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(
        outs[0].finish,
        lexi_moe::engine::FinishReason::CapacityTruncated
    );
    // generated exactly up to the cache boundary
    assert!(outs[0].tokens.len() <= e.max_seq - e.prefill_len + 1);
}

#[test]
fn engine_rejects_when_queue_full() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let e = model.entry.clone();
    let scfg = ServingConfig {
        batch: e.batch,
        max_seq: e.max_seq,
        prefill_len: e.prefill_len,
        queue_cap: 2,
        ..Default::default()
    };
    let rc = RunConfig::baseline(&e);
    let mut engine = Engine::new(&model, scfg, rc.k_vec, rc.gate_bias).unwrap();
    engine.submit(vec![1, 50, 51], SamplingParams::default()).unwrap();
    engine.submit(vec![1, 50, 52], SamplingParams::default()).unwrap();
    assert!(engine
        .submit(vec![1, 50, 53], SamplingParams::default())
        .is_err());
}

#[test]
fn lexi_allocation_beats_uniform_fitness_on_real_table() {
    let Some((rt, manifest)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &manifest, MODEL).unwrap();
    let cfg = ExperimentConfig {
        sensitivity_iters: 2,
        ..Default::default()
    };
    let table = profile_model(&model, &cfg, None).unwrap();
    let l = table.n_layers() as u32;
    let budget = l * table.k_base * 2 / 3;
    let res = lexi_moe::lexi::pipeline::stage2(&table, budget, &cfg).unwrap();
    // uniform at the same (floored) budget
    let uni = lexi_moe::moe::allocation::Allocation::uniform(
        l as usize,
        (budget as f64 / l as f64).floor() as u32,
    );
    let uni_fit = table.fitness(&uni.k) - (budget - uni.budget()) as f64 * 0.0;
    assert!(
        res.best_fitness <= table.fitness(&uni.k) + 1e-9,
        "GA {} vs uniform {} (uniform uses {} fewer experts)",
        res.best_fitness,
        uni_fit,
        budget - uni.budget()
    );
}
