//! Cross-module integration tests that need no artifacts: perf model vs
//! the paper's Fig. 2 reading, LExI pipeline over synthetic tables,
//! pruning baselines, figure emission.

use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::model::{registry, spec};
use lexi_moe::figures::fig2;
use lexi_moe::lexi::evolution::{evolve, EvolutionParams};
use lexi_moe::lexi::SensitivityTable;
use lexi_moe::moe::allocation::{Allocation, Bounds};
use lexi_moe::moe::transform::Transform;
use lexi_moe::perfmodel::PerfModel;
use lexi_moe::pruning::calibration::{expert_importance, keep_masks};
use lexi_moe::runtime::weights::CalibStats;

// ---------------------------------------------------------------------
// Fig. 2 shape: the paper's central motivation
// ---------------------------------------------------------------------

#[test]
fn fig2_shape_holds_for_every_model() {
    let cfg = ExperimentConfig {
        routing_trials: 4,
        ..Default::default()
    };
    for m in registry() {
        let rows = fig2::sweep_model(&m, &cfg).unwrap();
        fig2::check_shape(&rows, m.top_k as u32, m.n_experts)
            .unwrap_or_else(|e| panic!("{}: {e}", m.name));
    }
}

#[test]
fn pruning_never_buys_proportional_speedup() {
    // 50% inter-pruning removes half the weights; if it bought >1.5x
    // throughput the paper's premise would not reproduce.
    for name in ["olmoe-1b-7b", "qwen1.5-moe-a2.7b", "mixtral-8x7b"] {
        let pm = PerfModel::new(spec(name).unwrap(), 0);
        let base = pm.throughput(&Transform::Baseline, 16, 1024, 512);
        let inter = pm.throughput(&Transform::InterPrune { frac: 0.5 }, 16, 1024, 512);
        let ratio = inter.throughput_tok_s / base.throughput_tok_s;
        assert!(ratio < 1.5, "{name}: inter-50% gave {ratio:.2}x");
    }
}

#[test]
fn lexi_dominates_pruning_at_matched_budget() {
    // The Fig. 4 geometry for the high-expert models: LExI at ~half the
    // active experts clearly beats the baseline and matches-or-beats the
    // 50% pruning points' throughput (while keeping accuracy — the eval
    // side of the figure harness).
    for name in ["olmoe-1b-7b", "deepseek-v2-lite", "qwen1.5-moe-a2.7b"] {
        let m = spec(name).unwrap();
        let pm = PerfModel::new(m.clone(), 0);
        let lexi = Transform::Lexi {
            allocation: Allocation::uniform(m.n_layers, (m.top_k / 2).max(1) as u32),
        };
        let tb = pm.throughput(&Transform::Baseline, 16, 1024, 512).throughput_tok_s;
        let tl = pm.throughput(&lexi, 16, 1024, 512).throughput_tok_s;
        let tp = pm
            .throughput(&Transform::InterPrune { frac: 0.5 }, 16, 1024, 512)
            .throughput_tok_s;
        let ta = pm
            .throughput(&Transform::IntraPrune { frac: 0.25 }, 16, 1024, 512)
            .throughput_tok_s;
        assert!(tl > tb * 1.08, "{name}: lexi {tl:.0} not above baseline {tb:.0}");
        assert!(tl > tp * 0.93, "{name}: lexi {tl:.0} far below inter {tp:.0}");
        assert!(tl > ta * 0.95, "{name}: lexi {tl:.0} far below intra {ta:.0}");
    }
}

#[test]
fn decode_is_memory_bound_at_paper_scale() {
    let pm = PerfModel::new(spec("mixtral-8x7b").unwrap(), 0);
    let b = pm.throughput(&Transform::Baseline, 16, 1024, 512);
    // decoding 512 tokens should dominate the single prefill pass
    assert!(b.decode_s > b.prefill_s, "{b:?}");
}

// ---------------------------------------------------------------------
// LExI pipeline over synthetic sensitivity tables
// ---------------------------------------------------------------------

#[test]
fn pipeline_allocates_by_depth_profile() {
    // Qwen-like profile: early layers sensitive -> early layers keep k.
    let t = SensitivityTable::synthetic("qwen-like", 24, 4, |x| 3.0 - 2.5 * x, 11);
    let res = evolve(&t, 60, Bounds::paper(4), &EvolutionParams::default()).unwrap();
    let front: u32 = res.best.k[..8].iter().sum();
    let back: u32 = res.best.k[16..].iter().sum();
    assert!(front > back, "front {front} back {back}: {}", res.best);

    // Mixtral-like: deep layers sensitive -> reversed.
    let t = SensitivityTable::synthetic("mixtral-like", 32, 2, |x| 0.5 + 2.5 * x, 12);
    let res = evolve(&t, 48, Bounds::paper(2), &EvolutionParams::default()).unwrap();
    let front: u32 = res.best.k[..10].iter().sum();
    let back: u32 = res.best.k[22..].iter().sum();
    assert!(back > front, "{}", res.best);
}

#[test]
fn budget_sweep_monotone_fitness() {
    let t = SensitivityTable::synthetic("m", 16, 8, |x| 1.0 + x, 5);
    let mut last = f64::INFINITY;
    for budget in [32u32, 64, 96, 128] {
        let res = evolve(&t, budget, Bounds::paper(8), &EvolutionParams::default()).unwrap();
        assert!(
            res.best_fitness <= last + 1e-9,
            "larger budget must not hurt fitness"
        );
        last = res.best_fitness;
    }
}

// ---------------------------------------------------------------------
// Pruning baselines
// ---------------------------------------------------------------------

fn fake_calib(l: usize, e: usize) -> CalibStats {
    let freq: Vec<Vec<f32>> = (0..l)
        .map(|li| (0..e).map(|ei| ((li + ei * 7) % e) as f32 / e as f32 + 0.01).collect())
        .collect();
    CalibStats {
        mean_prob: freq.clone(),
        sel_freq: freq.clone(),
        gate_mass: freq,
    }
}

#[test]
fn inter_prune_bias_matches_importance_ranking() {
    let calib = fake_calib(4, 8);
    let bias = lexi_moe::pruning::inter_prune_bias(&calib, 0.25);
    let importance = expert_importance(&calib);
    let masks = keep_masks(&importance, 0.25);
    for (l, mask) in masks.iter().enumerate() {
        for (e, &keep) in mask.iter().enumerate() {
            let b = bias[l * 8 + e];
            assert_eq!(keep, b == 0.0, "layer {l} expert {e}");
        }
    }
}

#[test]
fn transforms_compose_with_perfmodel() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    let pm = PerfModel::new(m.clone(), 3);
    for t in [
        Transform::Baseline,
        Transform::InterPrune { frac: 0.125 },
        Transform::IntraPrune { frac: 0.25 },
        Transform::DynamicSkip { threshold: 0.4 },
        Transform::Lexi {
            allocation: Allocation::uniform(40, 1),
        },
    ] {
        let b = pm.throughput(&t, 16, 512, 256);
        assert!(
            b.throughput_tok_s.is_finite() && b.throughput_tok_s > 0.0,
            "{t:?}"
        );
    }
}

// ---------------------------------------------------------------------
// Figure emission plumbing
// ---------------------------------------------------------------------

#[test]
fn figures_emit_csvs() {
    let out = std::env::temp_dir().join("lexi_integration_figs");
    let _ = std::fs::remove_dir_all(&out);
    lexi_moe::figures::table1::run(&out).unwrap();
    let cfg = ExperimentConfig {
        routing_trials: 2,
        ..Default::default()
    };
    lexi_moe::figures::fig2::run(&out, &cfg).unwrap();
    for f in ["table1_models.csv", "fig2_pruning_throughput.csv"] {
        let text = std::fs::read_to_string(out.join(f)).unwrap();
        assert!(text.lines().count() > 5, "{f} nearly empty");
    }
    // fig2 covers all 6 models x (1 + 2*3 prune) configs
    let fig2_text = std::fs::read_to_string(out.join("fig2_pruning_throughput.csv")).unwrap();
    for m in registry() {
        assert!(fig2_text.contains(m.name), "fig2 missing {}", m.name);
    }
}

#[test]
fn sensitivity_table_normalization() {
    let t = SensitivityTable::synthetic("m", 6, 4, |x| 1.0 + 9.0 * x, 1);
    let norm = t.normalized();
    for row in &norm {
        let max = row.iter().cloned().fold(f64::MIN, f64::max);
        assert!((max - 1.0).abs() < 1e-9 || max == 0.0);
    }
}
