//! Integration tests for the observability layer: tracing stays off by
//! default and changes no serving artifact byte, traced runs emit
//! checker-clean Perfetto/Prometheus/critical-path files over the
//! recorded trace-replay fixture, span conservation holds under
//! admission rejects, and the self-profiler records the event loop's
//! instrumented sections.

use lexi_moe::config::model::spec;
use lexi_moe::config::server::{PolicyKind, ScenarioKind, ServerConfig};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::obs::{self, EventKind};
use lexi_moe::server;
use lexi_moe::server::ladder::QualityLadder;
use lexi_moe::server::replica::ServiceModel;
use lexi_moe::server::router::Cluster;
use lexi_moe::server::workload::{
    ArrivalProcess, RequestProfile, Scenario, Trace, TraceRequest,
};
use lexi_moe::util::json;

// ---------------------------------------------------------------------
// fixtures
// ---------------------------------------------------------------------

fn replay_cfg() -> ServerConfig {
    let fixture = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/trace_fixture.jsonl");
    ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        scenario: ScenarioKind::TraceReplay,
        trace_file: Some(fixture),
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    }
}

fn obs_artifact_names(out: &std::path::Path) -> Vec<String> {
    std::fs::read_dir(out)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| {
            n.starts_with("trace_")
                || n.starts_with("critical_path_")
                || n.starts_with("metrics_")
        })
        .collect()
}

/// One-class burst scenario whose trace slams every request into the
/// cluster at once — with a tiny admission queue, some must be rejected.
fn burst_scenario() -> Scenario {
    let mut s = Scenario {
        name: "obs-burst",
        kind: ScenarioKind::Poisson,
        arrivals: ArrivalProcess::Poisson { rate: 1.0 },
        profiles: vec![RequestProfile {
            name: "burst",
            prompt_lo: 64,
            prompt_hi: 64,
            gen_lo: 16,
            gen_hi: 16,
            priority: 0,
            weight: 1.0,
            ttft_mult: 4.0,
            tpot_mult: 2.0,
        }],
        slos: Vec::new(),
    };
    s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.01);
    s
}

fn burst_trace(n: usize) -> Trace {
    Trace {
        scenario: "obs-burst",
        requests: (0..n)
            .map(|i| TraceRequest {
                id: i as u64,
                class: 0,
                arrival_s: 1e-6 * i as f64,
                prompt_len: 64,
                new_tokens: 16,
            })
            .collect(),
        closed_loop: None,
    }
}

fn traced_burst_cluster(queue_cap: usize) -> Cluster<'static> {
    let ladder = QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-5, 0.01, 2),
    );
    Cluster::new(2, 2, PolicyKind::Jsq, ladder, None, queue_cap, 1, 0.0, 1)
        .with_tracing(1 << 16)
}

// ---------------------------------------------------------------------
// tracing off by default: no artifacts, byte-identical reports
// ---------------------------------------------------------------------

/// Turning `--trace` on must not move a single byte of the serving
/// reports (tracing draws nothing from the seeded rng), and turning it
/// off must emit no observability artifact at all.
#[test]
fn tracing_changes_no_report_byte_and_off_emits_no_artifacts() {
    let m = spec("olmoe-1b-7b").unwrap();
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 48,
        scenario: ScenarioKind::Poisson,
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    };
    let out_off = std::env::temp_dir().join("lexi_obs_off_test");
    let out_on = std::env::temp_dir().join("lexi_obs_on_test");
    let _ = std::fs::remove_dir_all(&out_off);
    let _ = std::fs::remove_dir_all(&out_on);
    server::bench_serve(&m, &cfg, None, &out_off).unwrap();
    let traced = ServerConfig {
        trace: true,
        ..cfg
    };
    server::bench_serve(&m, &traced, None, &out_on).unwrap();
    for name in [
        "bench_serve_olmoe-1b-7b_poisson.csv",
        "bench_serve_olmoe-1b-7b_poisson.json",
    ] {
        let off = std::fs::read(out_off.join(name)).unwrap();
        let on = std::fs::read(out_on.join(name)).unwrap();
        assert_eq!(off, on, "{name} differs once tracing is enabled");
    }
    assert!(
        obs_artifact_names(&out_off).is_empty(),
        "untraced run emitted observability artifacts"
    );
    assert!(
        !obs_artifact_names(&out_on).is_empty(),
        "traced run emitted no observability artifacts"
    );
}

// ---------------------------------------------------------------------
// traced replay: artifacts exist, pass checkers, components reconstruct
// ---------------------------------------------------------------------

/// The acceptance path: replay the recorded fixture with `--trace`, then
/// hold every artifact to the same bar `lexi trace --check` applies, and
/// verify the critical-path components reconstruct the reported totals
/// bit-exactly after the CSV round trip.
#[test]
fn traced_replay_artifacts_pass_checkers_and_reconstruct_totals() {
    let m = spec("olmoe-1b-7b").unwrap();
    let cfg = ServerConfig {
        trace: true,
        ..replay_cfg()
    };
    let out = std::env::temp_dir().join("lexi_obs_replay_test");
    let _ = std::fs::remove_dir_all(&out);
    let reports = server::bench_serve(&m, &cfg, None, &out).unwrap();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        let stem = format!("olmoe-1b-7b_trace-replay_{}", r.transform);

        let doc = json::parse_file(&out.join(format!("trace_{stem}.json"))).unwrap();
        let perfetto = obs::check_perfetto(&doc).unwrap();
        assert!(perfetto.spans > 0, "{stem}: no spans");

        let prom = std::fs::read_to_string(out.join(format!("metrics_{stem}.prom"))).unwrap();
        let summary = obs::check_prometheus(&prom).unwrap();
        assert!(summary.families >= 4, "{stem}: {summary:?}");

        let jsonl = std::fs::read_to_string(out.join(format!("metrics_{stem}.jsonl"))).unwrap();
        assert!(!jsonl.trim().is_empty(), "{stem}: empty metrics snapshots");
        for line in jsonl.lines() {
            json::parse(line).unwrap_or_else(|e| panic!("{stem}: bad snapshot: {e}"));
        }

        let csv =
            std::fs::read_to_string(out.join(format!("critical_path_{stem}.csv"))).unwrap();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), obs::export::CRITICAL_PATH_HEADER.join(","));
        let mut rows = 0usize;
        for line in lines {
            let row: Vec<&str> = line.split(',').collect();
            assert_eq!(row.len(), obs::export::CRITICAL_PATH_HEADER.len());
            let queue: f64 = row[3].parse().unwrap();
            let prefill: f64 = row[4].parse().unwrap();
            let decode: f64 = row[5].parse().unwrap();
            let ttft: f64 = row[8].parse().unwrap();
            let e2e: f64 = row[9].parse().unwrap();
            // shortest round-trip formatting: the decomposition written
            // by the exporter survives the file bit-exactly
            assert_eq!(prefill, ttft - queue, "{stem}: prefill != ttft-queue");
            assert_eq!(decode, e2e - ttft, "{stem}: decode != e2e-ttft");
            assert!(queue >= 0.0 && prefill >= 0.0 && decode >= 0.0, "{stem}: {line}");
            rows += 1;
        }
        assert_eq!(rows, r.n_completed, "{stem}: one CSV row per completion");
    }
}

// ---------------------------------------------------------------------
// span conservation under admission rejects
// ---------------------------------------------------------------------

/// With a queue small enough to force rejects, every arrival must still
/// terminate exactly once (finish or reject), and the trace-derived
/// queue wait must bound each completion's reported TTFT.
#[test]
fn span_conservation_holds_under_admission_rejects() {
    let scenario = burst_scenario();
    let trace = burst_trace(64);
    let res = traced_burst_cluster(4).run(&scenario, &trace);
    let rejected: u64 = res.rejected_by_class.iter().sum();
    assert!(rejected > 0, "fixture failed to overflow the admission queue");
    let log = res.trace.as_ref().expect("traced run returned no span log");
    assert_eq!(log.dropped, 0, "ring too small for fixture");
    log.check_conservation().unwrap();
    assert_eq!(
        log.count(|k| matches!(k, EventKind::Arrival { .. })),
        64,
        "one arrival span per fixture request"
    );
    assert_eq!(
        log.count(|k| matches!(k, EventKind::Reject { .. })) as u64,
        rejected
    );
    assert_eq!(
        log.count(|k| matches!(k, EventKind::Finish { .. })),
        res.completed.len()
    );
    for c in &res.completed {
        let t_prefill = log
            .prefill_start(c.id)
            .unwrap_or_else(|| panic!("request {} has no prefill span", c.id));
        let queue_s = t_prefill - c.arrival_s;
        assert!(
            queue_s >= 0.0 && queue_s <= c.ttft_s,
            "request {}: queue {queue_s} outside [0, ttft {}]",
            c.id,
            c.ttft_s
        );
        assert!(log.finish_time(c.id).is_some(), "request {} never finished", c.id);
    }
}

// ---------------------------------------------------------------------
// self-profiler
// ---------------------------------------------------------------------

/// Enabling the self-profiler around a sim run collects the event
/// loop's instrumented sections without perturbing the sim (virtual
/// time only sees wall clocks through `BENCH_selfprof.json`).
#[test]
fn selfprof_records_event_loop_sections_around_a_run() {
    let scenario = burst_scenario();
    let trace = burst_trace(16);
    obs::selfprof::enable();
    let res = traced_burst_cluster(100_000).run(&scenario, &trace);
    let prof = obs::selfprof::disable_and_collect();
    assert!(!res.completed.is_empty());
    assert!(!prof.is_empty(), "no sections recorded");
    for key in ["cluster.route", "edf.push", "edf.pop"] {
        let (_, stat) = prof
            .sections
            .iter()
            .find(|(n, _)| *n == key)
            .unwrap_or_else(|| panic!("section {key} missing from {prof:?}"));
        assert!(stat.calls > 0, "{key}: zero calls");
    }
    let entry = prof.to_json("integration");
    assert_eq!(entry.get("label").unwrap().as_str().unwrap(), "integration");
}

// ---------------------------------------------------------------------
// SLO health engine: off by default, additive when on, bundles check
// ---------------------------------------------------------------------

/// `--health` is a pure observer: enabling it must not move a single
/// byte of the serving CSV (the schedule is untouched), and the JSON
/// report only *gains* the health digest.
#[test]
fn health_off_is_byte_identical_and_health_digest_is_additive() {
    let m = spec("olmoe-1b-7b").unwrap();
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 48,
        scenario: ScenarioKind::Poisson,
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    };
    let out_off = std::env::temp_dir().join("lexi_obs_health_off_test");
    let out_on = std::env::temp_dir().join("lexi_obs_health_on_test");
    let _ = std::fs::remove_dir_all(&out_off);
    let _ = std::fs::remove_dir_all(&out_on);
    let reports_off = server::bench_serve(&m, &cfg, None, &out_off).unwrap();
    let healthy = ServerConfig {
        health: true,
        ..cfg
    };
    let reports_on = server::bench_serve(&m, &healthy, None, &out_on).unwrap();

    let name = "bench_serve_olmoe-1b-7b_poisson.csv";
    let off = std::fs::read(out_off.join(name)).unwrap();
    let on = std::fs::read(out_on.join(name)).unwrap();
    assert_eq!(off, on, "{name} differs once the health engine is on");

    for (r_off, r_on) in reports_off.iter().zip(&reports_on) {
        assert!(r_off.health.is_none(), "{}: health digest leaked", r_off.transform);
        let h = r_on
            .health
            .as_ref()
            .unwrap_or_else(|| panic!("{}: no health digest", r_on.transform));
        assert_eq!(
            h.classes.iter().map(|c| c.n).sum::<u64>(),
            r_on.n_completed as u64 + r_on.n_rejected,
            "{}: health digest lost outcomes",
            r_on.transform
        );
        // the digest is additive: every schedule-derived number is
        // unchanged by observation
        assert_eq!(r_off.n_completed, r_on.n_completed);
        assert_eq!(r_off.goodput_rps, r_on.goodput_rps);
        assert_eq!(r_off.makespan_s, r_on.makespan_s);
        assert_eq!(r_off.ttft_p99_s, r_on.ttft_p99_s);
    }
    let doc_off = json::parse_file(&out_off.join("bench_serve_olmoe-1b-7b_poisson.json")).unwrap();
    let doc_on = json::parse_file(&out_on.join("bench_serve_olmoe-1b-7b_poisson.json")).unwrap();
    let reports_key = |d: &json::Json, has_health: bool| {
        let arr = d.as_arr().unwrap();
        assert!(!arr.is_empty());
        for r in arr {
            assert_eq!(r.opt("health").is_some(), has_health);
        }
    };
    reports_key(&doc_off, false);
    reports_key(&doc_on, true);
}

/// A debug bundle frozen by the engine survives serialization to disk
/// and re-validation — the exact `lexi bundle --check` code path.
#[test]
fn written_debug_bundle_round_trips_through_the_bundle_checker() {
    use lexi_moe::obs::{check_bundle, HealthConfig, HealthEngine};
    use lexi_moe::server::workload::SloTarget;
    use lexi_moe::util::json::Json;

    // sustained 25%-overload trace with a tight deadline: violations
    // push a class critical and freeze a bundle
    let mut scenario = burst_scenario();
    scenario.slos = vec![
        SloTarget {
            ttft_s: 0.2,
            tpot_s: 0.05,
        };
        scenario.profiles.len()
    ];
    let requests = (0..240)
        .map(|i| TraceRequest {
            id: i,
            class: 0,
            arrival_s: 0.1 * i as f64,
            prompt_len: 32,
            new_tokens: 50,
        })
        .collect();
    let trace = Trace {
        scenario: "obs-burst",
        requests,
        closed_loop: None,
    };
    let ladder = QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-5, 0.01, 2),
    );
    let engine = HealthEngine::new(
        HealthConfig::default(),
        scenario.profiles.len(),
        Json::obj(vec![("seed", Json::Num(0.0))]),
    );
    let res = Cluster::new(2, 2, PolicyKind::Jsq, ladder, None, 25, 1, 0.0, 1)
        .with_health(engine)
        .run(&scenario, &trace);
    let h = res.health.as_ref().unwrap();
    assert!(!h.bundles.is_empty(), "overload froze no bundle");

    let dir = std::env::temp_dir().join("lexi_obs_bundle_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("debug_bundle_roundtrip.json");
    std::fs::write(&path, h.bundles[0].to_string_pretty()).unwrap();

    let doc = json::parse_file(&path).unwrap();
    let from_disk = check_bundle(&doc).unwrap();
    let in_memory = check_bundle(&h.bundles[0]).unwrap();
    assert_eq!(from_disk, in_memory, "bundle changed across the disk round trip");
    assert_eq!(from_disk.n_replicas, 2);
    assert!(from_disk.trigger.starts_with("burn_critical"), "{}", from_disk.trigger);
}
