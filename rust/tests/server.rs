//! Integration tests for the multi-replica serving front-end: routing
//! policies on skewed traffic, EDF scheduling under mixed classes,
//! seeded end-to-end determinism, and the adaptive quality ladder's
//! goodput advantage under bursty overload (the subsystem's acceptance
//! criterion). Artifact-free: service times come from the perf model or
//! synthetic fixtures.

use lexi_moe::config::model::spec;
use lexi_moe::config::server::{
    LadderScope, PolicyKind, PressureMode, ScenarioKind, ServerConfig,
};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::server::ladder::{LadderPolicy, QualityLadder, Rung};
use lexi_moe::server::replica::ServiceModel;
use lexi_moe::server::router::Cluster;
use lexi_moe::server::workload::{
    ArrivalProcess, RequestProfile, Scenario, Trace, TraceRequest,
};
use lexi_moe::server::{self, report};

// ---------------------------------------------------------------------
// fixtures
// ---------------------------------------------------------------------

/// Two-class scenario: tiny interactive requests + huge batch requests.
fn skewed_scenario() -> Scenario {
    let mut s = Scenario {
        name: "skewed",
        kind: ScenarioKind::Poisson,
        arrivals: ArrivalProcess::Poisson { rate: 1.0 },
        profiles: vec![
            RequestProfile {
                name: "tiny",
                prompt_lo: 32,
                prompt_hi: 32,
                gen_lo: 4,
                gen_hi: 4,
                priority: 0,
                weight: 0.5,
                ttft_mult: 4.0,
                tpot_mult: 2.0,
            },
            RequestProfile {
                name: "huge",
                prompt_lo: 512,
                prompt_hi: 512,
                gen_lo: 400,
                gen_hi: 400,
                priority: 1,
                weight: 0.5,
                ttft_mult: 50.0,
                tpot_mult: 10.0,
            },
        ],
        slos: Vec::new(),
    };
    s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.01);
    s
}

/// Alternating huge/tiny requests, all effectively arriving at once —
/// round-robin deterministically dumps every huge request on the same
/// replica; load-aware policies spread them.
fn skewed_trace(n_pairs: usize) -> Trace {
    let mut requests = Vec::new();
    for i in 0..n_pairs {
        for (j, class) in [(0usize, 1usize), (1, 0)] {
            let id = (2 * i + j) as u64;
            requests.push(TraceRequest {
                id,
                class,
                arrival_s: 1e-6 * id as f64,
                prompt_len: if class == 1 { 512 } else { 32 },
                new_tokens: if class == 1 { 400 } else { 4 },
            });
        }
    }
    Trace {
        scenario: "skewed",
        requests,
        closed_loop: None,
    }
}

fn fixed_cluster(policy: PolicyKind, n_replicas: usize, slots: usize) -> Cluster<'static> {
    let ladder = QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-5, 0.01, slots),
    );
    Cluster::new(n_replicas, slots, policy, ladder, None, 100_000, 2, 0.0, 1)
}

fn run_policy(policy: PolicyKind) -> server::RunResult {
    let s = skewed_scenario();
    let trace = skewed_trace(4);
    fixed_cluster(policy, 2, 2).run(&s, &trace)
}

// ---------------------------------------------------------------------
// routing policies
// ---------------------------------------------------------------------

#[test]
fn jsq_beats_round_robin_on_skewed_trace() {
    let rr = run_policy(PolicyKind::RoundRobin);
    let jsq = run_policy(PolicyKind::Jsq);
    assert_eq!(rr.completed.len(), 8);
    assert_eq!(jsq.completed.len(), 8);
    let mean_e2e = |r: &server::RunResult| {
        r.completed.iter().map(|c| c.e2e_s).sum::<f64>() / r.completed.len() as f64
    };
    // RR piles all 4 huge requests on replica 0 while replica 1 idles;
    // JSQ's token-weighted backlog spreads them 2/2.
    assert!(
        mean_e2e(&jsq) < mean_e2e(&rr),
        "JSQ mean e2e {:.3}s not better than RR {:.3}s",
        mean_e2e(&jsq),
        mean_e2e(&rr)
    );
    assert!(jsq.makespan_s < rr.makespan_s);
    // and the load split is visibly more even
    let spread = |r: &server::RunResult| {
        (r.replica_busy_s[0] - r.replica_busy_s[1]).abs()
            / (r.replica_busy_s[0] + r.replica_busy_s[1])
    };
    assert!(spread(&jsq) < spread(&rr));
}

#[test]
fn power_of_two_is_load_aware_too() {
    let rr = run_policy(PolicyKind::RoundRobin);
    let p2c = run_policy(PolicyKind::PowerOfTwo);
    assert_eq!(p2c.completed.len(), 8);
    let makespan_gain = rr.makespan_s / p2c.makespan_s;
    assert!(
        makespan_gain > 1.0,
        "p2c makespan {:.3}s vs rr {:.3}s",
        p2c.makespan_s,
        rr.makespan_s
    );
}

// ---------------------------------------------------------------------
// EDF scheduling
// ---------------------------------------------------------------------

#[test]
fn interactive_class_preempts_batch_in_queue() {
    // One replica, one slot: service order is pure queue order. Submit
    // a batch request first, then an interactive one — EDF must serve
    // the interactive request's prefill before the earlier-arrived
    // batch request whenever both are waiting.
    let s = skewed_scenario();
    let trace = Trace {
        scenario: "skewed",
        requests: vec![
            // occupies the slot first
            TraceRequest { id: 0, class: 0, arrival_s: 0.0, prompt_len: 32, new_tokens: 4 },
            // batch arrives before interactive, both queue behind id 0
            TraceRequest { id: 1, class: 1, arrival_s: 0.001, prompt_len: 512, new_tokens: 400 },
            TraceRequest { id: 2, class: 0, arrival_s: 0.002, prompt_len: 32, new_tokens: 4 },
        ],
        closed_loop: None,
    };
    let res = fixed_cluster(PolicyKind::RoundRobin, 1, 1).run(&s, &trace);
    assert_eq!(res.completed.len(), 3);
    let finish = |id: u64| res.completed.iter().find(|c| c.id == id).unwrap().finish_s;
    assert!(
        finish(2) < finish(1),
        "interactive id 2 finished at {:.3}s, after batch id 1 at {:.3}s",
        finish(2),
        finish(1)
    );
}

// ---------------------------------------------------------------------
// determinism
// ---------------------------------------------------------------------

#[test]
fn bench_serve_is_bit_deterministic_across_runs() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 64,
        scenario: ScenarioKind::Bursty,
        service_in_len: 256,
        service_out_len: 32,
        seed: 9,
        ..Default::default()
    };
    let out_a = std::env::temp_dir().join("lexi_server_det_a");
    let out_b = std::env::temp_dir().join("lexi_server_det_b");
    let _ = std::fs::remove_dir_all(&out_a);
    let _ = std::fs::remove_dir_all(&out_b);
    let a = server::bench_serve(&m, &cfg, None, &out_a).unwrap();
    let b = server::bench_serve(&m, &cfg, None, &out_b).unwrap();
    assert_eq!(a, b, "identical config + seed must reproduce bit-for-bit");
    // the emitted artifacts agree byte-for-byte too
    for f in [
        "bench_serve_minicpm-moe-8x2b_bursty.csv",
        "bench_serve_minicpm-moe-8x2b_bursty.json",
    ] {
        let x = std::fs::read(out_a.join(f)).unwrap();
        let y = std::fs::read(out_b.join(f)).unwrap();
        assert_eq!(x, y, "{f} differs between identical runs");
    }
    // and a different seed produces a different trace
    let c = server::bench_serve(
        &m,
        &ServerConfig { seed: 10, ..cfg },
        None,
        &out_b,
    )
    .unwrap();
    assert_ne!(a, c, "seed is ignored");
}

// ---------------------------------------------------------------------
// adaptive quality ladder (acceptance criterion)
// ---------------------------------------------------------------------

#[test]
fn ladder_beats_fixed_baseline_goodput_under_bursty_load() {
    let m = spec("qwen1.5-moe-a2.7b").unwrap();
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 8,
        n_requests: 400,
        scenario: ScenarioKind::Bursty,
        policy: PolicyKind::Jsq,
        degrade_above: 8,
        upgrade_below: 2,
        service_in_len: 256,
        service_out_len: 32,
        seed: 3,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("lexi_server_ladder_test");
    let _ = std::fs::remove_dir_all(&out);
    let reports = server::bench_serve(&m, &cfg, None, &out).unwrap();
    let get = |name: &str| reports.iter().find(|r| r.transform == name).unwrap();
    let base = get("baseline");
    let ladder = get("lexi-ladder");

    // the controller actually adapted...
    assert!(ladder.rung_switches > 0, "ladder never switched rungs");
    let frac = ladder.full_quality_frac.expect("ladder rung 0 is the baseline");
    assert!(
        frac < 1.0 && frac > 0.0,
        "ladder spent {}% at full quality — no adaptation observed",
        frac * 100.0
    );
    // ...and bought strictly more goodput than the fixed-budget baseline
    assert!(
        ladder.goodput_rps > base.goodput_rps,
        "ladder goodput {:.4} rps <= baseline {:.4} rps",
        ladder.goodput_rps,
        base.goodput_rps
    );
    // throughput ordering sanity: adaptively shedding budget can't be
    // slower than never shedding it
    assert!(ladder.throughput_tok_s >= base.throughput_tok_s * 0.98);
}

// ---------------------------------------------------------------------
// cluster-global ladder controller (no synchronized flapping)
// ---------------------------------------------------------------------

/// Three synthetic rungs: deeper = faster decode, higher proxy loss.
fn three_rung_ladder(slots: usize) -> QualityLadder {
    let rung = |label: &str, step_s: f64, loss: f64| {
        Rung::k_only(
            label,
            Allocation::uniform(4, 2),
            ServiceModel::synthetic(label, 1e-5, step_s, slots),
            loss,
        )
    };
    QualityLadder::from_points_1d(vec![
        rung("r0", 0.020, 0.0),
        rung("r1", 0.012, 1.0),
        rung("r2", 0.008, 2.0),
    ])
}

fn burst_scenario() -> Scenario {
    let mut s = Scenario {
        name: "burst",
        kind: ScenarioKind::Poisson,
        arrivals: ArrivalProcess::Poisson { rate: 1.0 },
        profiles: vec![RequestProfile {
            name: "chat",
            prompt_lo: 64,
            prompt_hi: 64,
            gen_lo: 32,
            gen_hi: 32,
            priority: 0,
            weight: 1.0,
            ttft_mult: 50.0,
            tpot_mult: 10.0,
        }],
        slos: Vec::new(),
    };
    s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
    s
}

/// Every request lands at t=0: both rr-routed replicas cross the
/// degrade threshold in the same event-loop instant.
fn burst_trace(n: usize) -> Trace {
    Trace {
        scenario: "burst",
        requests: (0..n as u64)
            .map(|id| TraceRequest {
                id,
                class: 0,
                arrival_s: 0.0,
                prompt_len: 64,
                new_tokens: 32,
            })
            .collect(),
        closed_loop: None,
    }
}

/// Largest number of rung switches sharing one event-loop instant.
fn max_switches_at_one_instant(events: &[(u64, usize)]) -> usize {
    let mut best = 0usize;
    let mut i = 0;
    while i < events.len() {
        let t = events[i].0;
        let n = events[i..].iter().take_while(|(tt, _)| *tt == t).count();
        best = best.max(n);
        i += n;
    }
    best
}

#[test]
fn cluster_scope_staggers_rung_switches_under_bursty_load() {
    let s = burst_scenario();
    let trace = burst_trace(40);
    let mk = |scope: LadderScope| {
        let policy = LadderPolicy {
            degrade_above: 8,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope,
            max_switches_per_instant: 1,
            ..Default::default()
        };
        Cluster::new(
            2,
            2,
            PolicyKind::RoundRobin,
            three_rung_ladder(2),
            Some(policy),
            100_000,
            1,
            0.0,
            0,
        )
    };

    // the per-replica rule reacts to the synchronized burst by flapping
    // both replicas in the same instant...
    let res = mk(LadderScope::PerReplica).run(&s, &trace);
    assert_eq!(res.completed.len(), 40);
    assert!(res.rung_switches > 0);
    assert!(
        max_switches_at_one_instant(&res.rung_switch_events) >= 2,
        "per-replica controller never switched in sync: {:?}",
        res.rung_switch_events
    );

    // ...the cluster-global controller adapts to the SAME burst but
    // staggers: never more than one switch per instant
    let res = mk(LadderScope::Cluster).run(&s, &trace);
    assert_eq!(res.completed.len(), 40);
    assert!(res.rung_switches > 0, "cluster controller never adapted");
    assert_eq!(
        max_switches_at_one_instant(&res.rung_switch_events),
        1,
        "synchronized flap under cluster scope: {:?}",
        res.rung_switch_events
    );
}

// ---------------------------------------------------------------------
// telemetry-driven control plane: work stealing, class-aware routing,
// EDF-slack ladder pressure, trace replay
// ---------------------------------------------------------------------

/// Work stealing must move work (idle replica helps a drowning one)
/// without losing or duplicating a single request.
#[test]
fn work_stealing_conserves_requests_on_skewed_traffic() {
    let s = skewed_scenario();
    let trace = skewed_trace(6); // 12 requests: rr piles 6 huge on r0
    let base = fixed_cluster(PolicyKind::RoundRobin, 2, 2).run(&s, &trace);
    let mut c = fixed_cluster(PolicyKind::RoundRobin, 2, 2).with_stealing(1);
    let stolen = c.run(&s, &trace);

    // conservation: same request population, nothing lost or duplicated
    assert_eq!(base.completed.len(), 12);
    assert_eq!(stolen.completed.len(), 12, "stealing lost requests");
    let mut ids: Vec<u64> = stolen.completed.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 12, "stealing duplicated a request");
    assert_eq!(stolen.rejected_by_class.iter().sum::<u64>(), 0);

    // ...and stealing actually happened, recorded move by move
    let steals = stolen.steals.expect("stealing was enabled");
    assert!(steals > 0, "idle replica never stole from the drowning one");
    assert_eq!(steals as usize, stolen.steal_events.len());
    for &(_, victim, thief) in &stolen.steal_events {
        assert_ne!(victim, thief);
    }
    // rebalancing the huge requests must shorten the run
    assert!(
        stolen.makespan_s < base.makespan_s,
        "stealing did not help: {:.3}s vs {:.3}s",
        stolen.makespan_s,
        base.makespan_s
    );
}

/// Class-aware routing steers batch-priority traffic to degraded
/// replicas (which sell quality for speed) while interactive classes
/// keep the full-quality replicas; JSQ mixes classes across both.
#[test]
fn classaware_sends_more_batch_share_to_degraded_replicas_than_jsq() {
    let s = {
        let mut s = Scenario::from_kind(ScenarioKind::Bursty, 10.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        s
    };
    let trace = s.generate(250, 11);
    let run = |policy: PolicyKind| {
        let mut c = Cluster::new(
            2,
            4,
            policy,
            three_rung_ladder(4),
            None, // rungs held fixed: replica 1 stays degraded
            100_000,
            s.profiles.len(),
            0.0,
            1,
        );
        c.backends[1].set_rung(2, 0.0, 0.0);
        c.run(&s, &trace)
    };
    let batch_share_on_degraded = |res: &server::RunResult| {
        let batch: Vec<_> = res
            .completed
            .iter()
            .filter(|c| s.profiles[c.class].priority >= 1)
            .collect();
        assert!(!batch.is_empty(), "trace has no batch traffic");
        batch.iter().filter(|c| c.replica == 1).count() as f64 / batch.len() as f64
    };

    let jsq = run(PolicyKind::Jsq);
    let ca = run(PolicyKind::ClassAware);
    assert_eq!(jsq.completed.len(), 250);
    assert_eq!(ca.completed.len(), 250);
    let jsq_share = batch_share_on_degraded(&jsq);
    let ca_share = batch_share_on_degraded(&ca);
    assert!(
        ca_share > jsq_share,
        "classaware batch share on the degraded replica ({ca_share:.2}) \
         not above jsq ({jsq_share:.2})"
    );
    // with fixed rungs, classaware keeps the degraded replica free of
    // interactive traffic entirely
    for c in ca.completed.iter().filter(|c| c.replica == 1) {
        assert!(
            s.profiles[c.class].priority >= 1,
            "interactive request {} served by the degraded replica",
            c.id
        );
    }
}

/// The EDF-slack pressure signal reacts to deadline collapse directly,
/// so under a flash crowd it must do at least as well as the sluggish
/// queue-depth rule (the ROADMAP's deadline-aware ladder claim).
#[test]
fn slack_pressure_ladder_matches_or_beats_queue_ladder_on_flash_crowd() {
    let m = spec("qwen1.5-moe-a2.7b").unwrap();
    let base_cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 8,
        n_requests: 350,
        scenario: ScenarioKind::FlashCrowd,
        policy: PolicyKind::Jsq,
        // deliberately sluggish depth thresholds: the queue rule only
        // reacts once the backlog is already deep
        degrade_above: 64,
        upgrade_below: 4,
        service_in_len: 256,
        service_out_len: 32,
        seed: 5,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("lexi_server_slack_ladder_test");
    let _ = std::fs::remove_dir_all(&out);
    let queue_reports = server::bench_serve(&m, &base_cfg, None, &out).unwrap();
    let slack_cfg = ServerConfig {
        pressure: PressureMode::Slack,
        ..base_cfg
    };
    let out2 = std::env::temp_dir().join("lexi_server_slack_ladder_test2");
    let _ = std::fs::remove_dir_all(&out2);
    let slack_reports = server::bench_serve(&m, &slack_cfg, None, &out2).unwrap();

    let ladder_of = |rs: &[server::TransformReport]| {
        rs.iter()
            .find(|r| r.transform == "lexi-ladder")
            .unwrap()
            .clone()
    };
    let q = ladder_of(&queue_reports);
    let s = ladder_of(&slack_reports);
    // the slack controller adapted, and its report carries the new
    // slack telemetry fields; the queue run keeps the legacy shape
    assert!(s.rung_switches > 0, "slack ladder never adapted");
    assert!(s.min_slack_s.is_some(), "slack field not populated");
    assert_eq!(s.steals, Some(0)); // extended run, stealing off
    assert!(q.min_slack_s.is_none() && q.steals.is_none());
    assert!(
        s.goodput_rps >= q.goodput_rps * 0.999,
        "slack-pressure goodput {:.4} rps below queue-pressure {:.4} rps",
        s.goodput_rps,
        q.goodput_rps
    );
}

/// A recorded JSONL log replays end-to-end through bench-serve.
#[test]
fn trace_replay_runs_through_bench_serve() {
    let m = spec("olmoe-1b-7b").unwrap();
    let fixture = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/trace_fixture.jsonl");
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        scenario: ScenarioKind::TraceReplay,
        trace_file: Some(fixture),
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("lexi_server_replay_test");
    let _ = std::fs::remove_dir_all(&out);
    let reports = server::bench_serve(&m, &cfg, None, &out).unwrap();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert_eq!(r.scenario, "trace-replay");
        assert_eq!(
            r.n_completed as u64 + r.n_rejected,
            24,
            "{}: fixture rows lost",
            r.transform
        );
    }
    assert!(out.join("bench_serve_olmoe-1b-7b_trace-replay.csv").exists());
    assert!(out.join("bench_serve_olmoe-1b-7b_trace-replay.json").exists());
}

#[test]
fn every_scenario_completes_with_all_transforms() {
    let m = spec("olmoe-1b-7b").unwrap();
    let out = std::env::temp_dir().join("lexi_server_scenarios_test");
    let _ = std::fs::remove_dir_all(&out);
    for kind in ScenarioKind::all() {
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 48,
            scenario: kind,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let reports = server::bench_serve(&m, &cfg, None, &out).unwrap();
        assert_eq!(reports.len(), 4, "{kind:?}");
        for r in &reports {
            assert!(r.n_completed > 0, "{kind:?}/{}: nothing completed", r.transform);
            assert!(
                r.n_completed as u64 + r.n_rejected <= 48,
                "{kind:?}/{}: conservation violated",
                r.transform
            );
            assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
            assert!(r.ttft_p99_s >= r.ttft_p50_s);
            assert!(r.tpot_p99_s >= r.tpot_p50_s);
        }
        let csv = out.join(format!("bench_serve_olmoe-1b-7b_{}.csv", kind.label()));
        let text = std::fs::read_to_string(&csv).unwrap();
        assert_eq!(text.lines().count(), 5, "{kind:?}: header + 4 transforms");
        assert_eq!(text.lines().next().unwrap(), report::CSV_HEADER.join(","));
    }
}

/// `--pressure burn` degrades on SLO error-budget burn directly, so on
/// the same flash crowd it must do at least as well as the EDF-slack
/// rule (the health engine's closed-loop acceptance criterion), and
/// only the burn run carries the health digest in its report.
#[test]
fn burn_pressure_ladder_matches_or_beats_slack_ladder_on_flash_crowd() {
    let m = spec("qwen1.5-moe-a2.7b").unwrap();
    let base_cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 8,
        n_requests: 350,
        scenario: ScenarioKind::FlashCrowd,
        policy: PolicyKind::Jsq,
        degrade_above: 64,
        upgrade_below: 4,
        service_in_len: 256,
        service_out_len: 32,
        seed: 5,
        pressure: PressureMode::Slack,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("lexi_server_burn_ladder_slack");
    let _ = std::fs::remove_dir_all(&out);
    let slack_reports = server::bench_serve(&m, &base_cfg, None, &out).unwrap();
    let burn_cfg = ServerConfig {
        pressure: PressureMode::Burn,
        ..base_cfg
    };
    let out2 = std::env::temp_dir().join("lexi_server_burn_ladder_burn");
    let _ = std::fs::remove_dir_all(&out2);
    let burn_reports = server::bench_serve(&m, &burn_cfg, None, &out2).unwrap();

    let ladder_of = |rs: &[server::TransformReport]| {
        rs.iter()
            .find(|r| r.transform == "lexi-ladder")
            .unwrap()
            .clone()
    };
    let s = ladder_of(&slack_reports);
    let b = ladder_of(&burn_reports);
    assert!(s.health.is_none(), "slack run must stay health-free");
    let bh = b.health.as_ref().expect("burn run carries no health digest");
    assert!(
        bh.peak_fast_burn > 0.0,
        "flash crowd never burned any error budget"
    );
    assert!(
        b.goodput_rps >= s.goodput_rps * 0.999,
        "burn-pressure goodput {:.4} rps below slack-pressure {:.4} rps",
        b.goodput_rps,
        s.goodput_rps
    );
}

/// The health engine raises BurnCritical (and freezes a debug bundle)
/// while sustained overload is still only blowing deadlines — strictly
/// before the queue cap produces its first hard reject. The bundle
/// must survive the `lexi bundle --check` validator.
#[test]
fn burn_critical_fires_before_the_first_hard_cap_reject() {
    use lexi_moe::obs::{check_bundle, HealthConfig, HealthEngine, HealthEvent};
    use lexi_moe::server::workload::SloTarget;
    use lexi_moe::util::json::Json;

    // one class with a tight deadline, arriving ~25% above capacity:
    // the queue grows a couple of requests per second, so deadline
    // violations accumulate long before the cap fills
    let mut s = skewed_scenario();
    let tight = SloTarget {
        ttft_s: 0.2,
        tpot_s: 0.05,
    };
    s.slos = vec![tight; s.profiles.len()];
    let requests = (0..240)
        .map(|i| TraceRequest {
            id: i,
            class: 0,
            arrival_s: 0.1 * i as f64,
            prompt_len: 32,
            new_tokens: 50,
        })
        .collect();
    let trace = Trace {
        scenario: "skewed",
        requests,
        closed_loop: None,
    };

    let ladder = QualityLadder::fixed(
        "base",
        Allocation::uniform(4, 2),
        ServiceModel::synthetic("base", 1e-5, 0.01, 2),
    );
    let hcfg = HealthConfig {
        recorder_horizon_s: 0.0, // bundles carry the whole recorder ring
        ..HealthConfig::default()
    };
    let engine = HealthEngine::new(hcfg, s.profiles.len(), Json::obj(vec![]));
    let res = Cluster::new(2, 2, PolicyKind::Jsq, ladder, None, 25, 2, 0.0, 1)
        .with_health(engine)
        .run(&s, &trace);

    assert!(
        res.rejected_by_class.iter().sum::<u64>() > 0,
        "cap never rejected: overload too mild for this fixture"
    );
    let h = res.health.as_ref().unwrap();
    let critical = h
        .events
        .iter()
        .find(|e| matches!(e.event, HealthEvent::BurnCritical { .. }))
        .expect("no BurnCritical raised under sustained overload");
    assert!(critical.t_s < res.makespan_s);

    // the bundle frozen at the first critical carries every recorder
    // entry so far (horizon 0 = unbounded), and none of them is a
    // reject: the burn signal led the hard cap
    assert!(!h.bundles.is_empty(), "critical event froze no bundle");
    let bundle = &h.bundles[0];
    let sum = check_bundle(bundle).expect("bundle fails `lexi bundle --check` validation");
    assert!(sum.trigger.starts_with("burn_critical"), "{}", sum.trigger);
    assert_eq!(sum.n_replicas, 2);
    let entries = bundle.get("events").unwrap().as_arr().unwrap();
    assert!(
        entries
            .iter()
            .all(|e| e.get("kind").unwrap().as_str().unwrap() != "reject"),
        "a hard-cap reject preceded the first BurnCritical"
    );
}

// ---------------------------------------------------------------------
// 2-D quality lattice (active experts x intra-expert sparsity)
// ---------------------------------------------------------------------

/// The lattice refactor must not perturb a single byte of the default
/// single-axis path: same config + seed reproduce the full report set
/// and the emitted CSV/JSON artifacts bit-for-bit, across scenario
/// shapes and seeds.
#[test]
fn one_d_ladder_stays_bit_identical_across_scenarios_and_seeds() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    for kind in [
        ScenarioKind::Poisson,
        ScenarioKind::Bursty,
        ScenarioKind::FlashCrowd,
    ] {
        for seed in [7, 11] {
            let cfg = ServerConfig {
                replicas: 2,
                slots_per_replica: 4,
                n_requests: 48,
                scenario: kind,
                service_in_len: 256,
                service_out_len: 32,
                seed,
                ..Default::default()
            };
            let out_a =
                std::env::temp_dir().join(format!("lexi_1d_parity_a_{}_{seed}", kind.label()));
            let out_b =
                std::env::temp_dir().join(format!("lexi_1d_parity_b_{}_{seed}", kind.label()));
            let _ = std::fs::remove_dir_all(&out_a);
            let _ = std::fs::remove_dir_all(&out_b);
            let a = server::bench_serve(&m, &cfg, None, &out_a).unwrap();
            let b = server::bench_serve(&m, &cfg, None, &out_b).unwrap();
            assert_eq!(a, b, "{} seed {seed} diverged", kind.label());
            for ext in ["csv", "json"] {
                let f = format!("bench_serve_minicpm-moe-8x2b_{}.{ext}", kind.label());
                let x = std::fs::read(out_a.join(&f)).unwrap();
                let y = std::fs::read(out_b.join(&f)).unwrap();
                assert_eq!(x, y, "{f} differs between identical runs (seed {seed})");
            }
        }
    }
}

/// On a flash crowd, the 2-D controller has strictly more legal moves
/// than the 1-D walk (the intra axis sells quality cheaper per latency
/// step on shallow rungs), so adaptive goodput must not regress, and
/// the lattice itself must be a real grid.
#[test]
fn two_d_intra_lattice_matches_or_beats_one_d_on_flash_crowd() {
    use lexi_moe::config::server::LadderAxes;

    let m = spec("qwen1.5-moe-a2.7b").unwrap();
    let base_cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 8,
        n_requests: 350,
        scenario: ScenarioKind::FlashCrowd,
        policy: PolicyKind::Jsq,
        degrade_above: 8,
        upgrade_below: 2,
        service_in_len: 256,
        service_out_len: 32,
        seed: 5,
        ..Default::default()
    };
    let out1 = std::env::temp_dir().join("lexi_2d_vs_1d_flash_k");
    let out2 = std::env::temp_dir().join("lexi_2d_vs_1d_flash_kintra");
    let _ = std::fs::remove_dir_all(&out1);
    let _ = std::fs::remove_dir_all(&out2);
    let one_d = server::bench_serve(&m, &base_cfg, None, &out1).unwrap();
    let two_d_cfg = ServerConfig {
        ladder_axes: LadderAxes::KIntra,
        ..base_cfg
    };
    let two_d = server::bench_serve(&m, &two_d_cfg, None, &out2).unwrap();

    let ladder_of = |rs: &[server::TransformReport]| {
        rs.iter()
            .find(|r| r.transform == "lexi-ladder")
            .unwrap()
            .clone()
    };
    let a = ladder_of(&one_d);
    let b = ladder_of(&two_d);
    assert!(b.rung_switches > 0, "2-D controller never adapted");
    assert!(
        b.goodput_rps >= a.goodput_rps * 0.999,
        "2-D lattice goodput {:.4} rps below 1-D {:.4} rps",
        b.goodput_rps,
        a.goodput_rps
    );
}
