//! Backend parity: the simulated virtual-time replica and the real
//! `engine::Engine` replica (over the synthetic host model) sit behind
//! the SAME cluster front door and agree on what was served — completion
//! counts and per-request generated-token totals over one seeded trace.
//! Latencies legitimately differ (perf-model time vs. wall-clock-mapped
//! phases), so they are checked only for causal ordering.

use std::collections::BTreeMap;
use std::rc::Rc;

use lexi_moe::config::model::spec;
use lexi_moe::config::server::{BackendKind, PolicyKind, ScenarioKind, ServerConfig};
use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::Engine;
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::runtime::SyntheticModel;
use lexi_moe::server::workload::{ArrivalProcess, RequestProfile, Scenario, Trace};
use lexi_moe::server::{
    self, Cluster, EngineReplica, QualityLadder, ReplicaBackend, RunResult, ServiceModel,
};

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 8;
const SLOTS: usize = 4;
const N_REQUESTS: usize = 40;

/// One chat-shaped class whose largest request fits the engine graph
/// without truncation (prompt <= 48 < prefill 64; prompt+gen < max_seq).
fn parity_scenario() -> Scenario {
    let mut s = Scenario {
        name: "parity",
        kind: ScenarioKind::Poisson,
        arrivals: ArrivalProcess::Poisson { rate: 5.0 },
        profiles: vec![RequestProfile {
            name: "chat",
            prompt_lo: 16,
            prompt_hi: 48,
            gen_lo: 4,
            gen_hi: 12,
            priority: 0,
            weight: 1.0,
            ttft_mult: 50.0,
            tpot_mult: 10.0,
        }],
        slos: Vec::new(),
    };
    s.resolve_slos(|tokens| 1e-3 * tokens as f64, 0.05);
    s
}

fn fixed_ladder() -> QualityLadder {
    QualityLadder::fixed(
        "base",
        Allocation::uniform(N_LAYERS, 2),
        ServiceModel::synthetic("base", 1e-5, 0.01, SLOTS),
    )
}

fn run_sim(s: &Scenario, trace: &Trace) -> RunResult {
    let mut c = Cluster::new(
        2,
        SLOTS,
        PolicyKind::Jsq,
        fixed_ladder(),
        None,
        10_000,
        1,
        0.0,
        7,
    );
    c.run(s, trace)
}

fn run_engine(s: &Scenario, trace: &Trace) -> RunResult {
    let model = SyntheticModel::new("parity", N_LAYERS, N_EXPERTS, 2, SLOTS, 64, 128);
    let ladder = Rc::new(fixed_ladder());
    let scfg = ServingConfig {
        batch: SLOTS,
        max_seq: 128,
        prefill_len: 64,
        kv_block: 16,
        kv_blocks_total: SLOTS * 8,
        queue_cap: 1024,
        max_new_tokens: 16,
        decode_burst: 8,
    };
    let mut backends: Vec<Box<dyn ReplicaBackend + '_>> = Vec::new();
    for i in 0..2 {
        let engine = Engine::new(
            &model,
            scfg.clone(),
            ladder.k_vec(0).unwrap(),
            vec![0.0f32; N_LAYERS * N_EXPERTS],
        )
        .unwrap();
        backends.push(Box::new(
            EngineReplica::new(i, engine, Rc::clone(&ladder)).unwrap(),
        ));
    }
    let mut c = Cluster::from_backends(
        backends,
        PolicyKind::Jsq,
        Rc::clone(&ladder),
        None,
        10_000,
        1,
        0.0,
        7,
    );
    c.run(s, trace)
}

fn token_map(res: &RunResult) -> BTreeMap<u64, usize> {
    res.completed.iter().map(|c| (c.id, c.tokens)).collect()
}

#[test]
fn an_undersized_engine_queue_is_rejected_at_construction() {
    let model = SyntheticModel::new("parity", N_LAYERS, N_EXPERTS, 2, SLOTS, 64, 128);
    let ladder = Rc::new(fixed_ladder());
    let scfg = ServingConfig {
        batch: SLOTS,
        max_seq: 128,
        prefill_len: 64,
        kv_block: 16,
        kv_blocks_total: SLOTS * 8,
        queue_cap: SLOTS - 1, // below the batch width the replica tops up to
        max_new_tokens: 16,
        decode_burst: 8,
    };
    let engine = Engine::new(
        &model,
        scfg,
        ladder.k_vec(0).unwrap(),
        vec![0.0f32; N_LAYERS * N_EXPERTS],
    )
    .unwrap();
    let err = EngineReplica::new(0, engine, Rc::clone(&ladder)).unwrap_err();
    assert!(err.to_string().contains("queue capacity"), "{err:#}");
}

#[test]
fn sim_and_engine_backends_agree_on_the_served_trace() {
    let s = parity_scenario();
    let trace = s.generate(N_REQUESTS, 11);
    let sim = run_sim(&s, &trace);
    let eng = run_engine(&s, &trace);

    // both backends drain the identical trace completely
    assert_eq!(sim.completed.len(), N_REQUESTS);
    assert_eq!(eng.completed.len(), N_REQUESTS);
    assert_eq!(sim.rejected_by_class.iter().sum::<u64>(), 0);
    assert_eq!(eng.rejected_by_class.iter().sum::<u64>(), 0);

    // ...and agree per request id on how many tokens were generated
    assert_eq!(token_map(&sim), token_map(&eng));

    // engine timelines are causally ordered on the event-loop clock
    for c in &eng.completed {
        assert!(c.ttft_s > 0.0, "request {} ttft {}", c.id, c.ttft_s);
        assert!(c.e2e_s >= c.ttft_s - 1e-12);
        assert!(c.finish_s >= c.arrival_s);
    }
    assert!(eng.makespan_s > 0.0);
    assert!(eng.prefill_calls > 0 && eng.decode_steps > 0);
}

#[test]
fn engine_backend_replays_are_count_deterministic() {
    // wall-clock phase lengths vary run to run, but WHAT is served must
    // not: same trace -> same completions and token totals
    let s = parity_scenario();
    let trace = s.generate(N_REQUESTS, 13);
    let a = run_engine(&s, &trace);
    let b = run_engine(&s, &trace);
    assert_eq!(token_map(&a), token_map(&b));
    assert!(a.prefill_calls > 0 && b.prefill_calls > 0);
}

#[test]
fn bench_serve_engine_backend_end_to_end() {
    // the full `lexi bench-serve --backend engine` path: real Engine
    // replicas (synthetic host model), same report pipeline as sim
    let m = spec("olmoe-1b-7b").unwrap();
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 24,
        scenario: ScenarioKind::Poisson,
        backend: BackendKind::Engine,
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("lexi_engine_backend_test");
    let _ = std::fs::remove_dir_all(&out);
    let reports = server::bench_serve(&m, &cfg, None, &out).unwrap();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert_eq!(r.n_completed as u64 + r.n_rejected, 24, "{}", r.transform);
        assert!(r.throughput_tok_s > 0.0, "{}", r.transform);
        assert!(r.makespan_s > 0.0);
        assert!(r.mean_utilization > 0.0);
    }
    // engine runs get their own stem so they never clobber sim results
    assert!(out.join("bench_serve_olmoe-1b-7b_poisson_engine.csv").exists());
    assert!(out.join("bench_serve_olmoe-1b-7b_poisson_engine.json").exists());
}

#[test]
fn engine_take_outputs_drains_non_blockingly() {
    use lexi_moe::engine::{SamplingParams, StepKind};

    let model = SyntheticModel::new("drain", N_LAYERS, N_EXPERTS, 2, 2, 32, 64);
    let scfg = ServingConfig {
        batch: 2,
        max_seq: 64,
        prefill_len: 32,
        kv_block: 16,
        kv_blocks_total: 8,
        queue_cap: 16,
        max_new_tokens: 4,
        decode_burst: 8,
    };
    let mut engine = Engine::new(
        &model,
        scfg,
        vec![2i32; N_LAYERS],
        vec![0.0f32; N_LAYERS * N_EXPERTS],
    )
    .unwrap();
    let sampling = SamplingParams {
        temperature: 0.0,
        max_new_tokens: 3,
        stop_on_eos: false,
        ..Default::default()
    };
    let a = engine.submit(vec![5, 6, 7], sampling).unwrap();
    let b = engine.submit(vec![9, 10], sampling).unwrap();

    // prefill step: both requests get their first token, none finished
    let out = engine.step_detail().unwrap();
    assert_eq!(out.kind, StepKind::Prefill);
    assert_eq!(out.first_tokens, vec![a, b]);
    assert!(out.finished.is_empty());
    assert!(engine.take_outputs().is_empty());

    // two decode steps finish both 3-token requests
    let mut finished = Vec::new();
    for _ in 0..2 {
        let out = engine.step_detail().unwrap();
        assert_eq!(out.kind, StepKind::Decode);
        finished.extend(out.finished);
    }
    assert_eq!(finished.len(), 2);
    assert!(finished.iter().all(|o| o.tokens.len() == 3));
    assert!(engine.idle());

    // the blocking drain path stays consistent: step() retains outputs
    // until take_outputs / run_until_complete hands them over
    let c = engine.submit(vec![4], sampling).unwrap();
    while engine.step().unwrap() {}
    let outs = engine.take_outputs();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].id, c);
}
