//! Calibration round trip: fit a sim `ServiceModel` from a real
//! engine-backend run (synthetic host model), cross-validate the two
//! backends on the same seeded trace, and check that supplying the
//! artifact changes bench-serve's sim outputs while the default stays
//! byte-identical.

use lexi_moe::calibrate::{self, CalibrationArtifact};
use lexi_moe::config::model::spec;
use lexi_moe::config::server::{ScenarioKind, ServerConfig};
use lexi_moe::server;

fn small_cfg(seed: u64) -> ServerConfig {
    ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 32,
        scenario: ScenarioKind::Poisson,
        service_in_len: 256,
        service_out_len: 32,
        seed,
        ..Default::default()
    }
}

#[test]
fn calibrate_then_cross_validate_round_trip() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    let cfg = small_cfg(9);
    let out = std::env::temp_dir().join("lexi_calibrate_roundtrip_test");
    let _ = std::fs::remove_dir_all(&out);

    // measure + fit + write the artifact from an engine-backend run
    let (art, path) = calibrate::calibrate(&m, &cfg, None, &out).unwrap();
    assert!(path.exists());
    assert!(art.n_samples() > 0, "engine run recorded no step samples");
    assert_eq!(art.model, "minicpm-moe-8x2b");
    assert_eq!(art.slots, 4);
    assert_eq!(art.source, "engine-synthetic");
    // rung 0 (the gate's rung) must be observed by both contenders
    assert!(art.observed_rungs().contains(&0));
    assert_eq!(CalibrationArtifact::load(&path).unwrap(), art);

    // replay the same seeded scenario on engine + raw sim + calibrated
    // sim, reusing the saved artifact; generous tolerance because tests
    // share the machine with the rest of the suite (CI gates at 0.5)
    let cv = calibrate::cross_validate(&m, &cfg, None, Some(&path), 0.9, &out).unwrap();
    assert_eq!(cv.contenders.len(), 2);
    assert_eq!(cv.contenders[0].label, "baseline");
    assert_eq!(cv.contenders[1].label, "lexi-ladder");
    for c in &cv.contenders {
        assert!(c.token_parity, "{}: backends served different tokens", c.label);
        assert_eq!(c.engine.n_completed, 32);
        assert_eq!(c.engine.served_tokens, c.sim_calibrated.served_tokens);
    }
    assert!(
        cv.pass,
        "calibrated divergence {:.2} exceeded tolerance (raw was {:.2})",
        cv.contenders[0].calibrated.max_gated(),
        cv.contenders[0].raw.max_gated()
    );
    // artifacts of the gate: full report, CI perf summary, figure CSV
    assert!(out.join("cross_validate_minicpm-moe-8x2b_poisson.json").exists());
    assert!(out.join("BENCH_serve.json").exists());
    assert!(out
        .join("fig_cross_validation_minicpm-moe-8x2b_poisson.csv")
        .exists());
    let bench = lexi_moe::util::json::parse_file(&out.join("BENCH_serve.json")).unwrap();
    assert!(bench.get("pass").unwrap().as_bool().unwrap());
    // summary carries the perf-trajectory numbers CI tracks over time
    assert!(bench.get("max_divergence_calibrated").unwrap().as_f64().unwrap() >= 0.0);
    let contenders = bench.get("contenders").unwrap().as_arr().unwrap();
    assert_eq!(contenders.len(), 2);
    assert!(contenders[0]
        .get("engine")
        .unwrap()
        .get("goodput_rps")
        .unwrap()
        .as_f64()
        .unwrap()
        >= 0.0);
}

#[test]
fn bench_serve_default_sim_outputs_stay_byte_identical_without_an_artifact() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    let cfg = small_cfg(3);
    let base = std::env::temp_dir().join("lexi_calibration_byte_identity_test");
    let _ = std::fs::remove_dir_all(&base);

    // two default runs must agree byte for byte
    let dir_a = base.join("a");
    let dir_b = base.join("b");
    server::bench_serve(&m, &cfg, None, &dir_a).unwrap();
    server::bench_serve(&m, &cfg, None, &dir_b).unwrap();
    for name in [
        "bench_serve_minicpm-moe-8x2b_poisson.csv",
        "bench_serve_minicpm-moe-8x2b_poisson.json",
    ] {
        let a = std::fs::read(dir_a.join(name)).unwrap();
        let b = std::fs::read(dir_b.join(name)).unwrap();
        assert_eq!(a, b, "{name} not byte-identical across default runs");
    }

    // a calibration artifact swaps the service models -> different sim
    let (_, art_path) = calibrate::calibrate(&m, &cfg, None, &base.join("cal")).unwrap();
    let mut calibrated = cfg.clone();
    calibrated.calibration_file = Some(art_path);
    let dir_c = base.join("c");
    let reports = server::bench_serve(&m, &calibrated, None, &dir_c).unwrap();
    assert_eq!(reports.len(), 4);
    let a = std::fs::read_to_string(dir_a.join("bench_serve_minicpm-moe-8x2b_poisson.json"))
        .unwrap();
    let c = std::fs::read_to_string(dir_c.join("bench_serve_minicpm-moe-8x2b_poisson.json"))
        .unwrap();
    assert_ne!(a, c, "calibrated run should change sim latencies");
}

#[test]
fn mismatched_artifacts_are_refused() {
    let m = spec("minicpm-moe-8x2b").unwrap();
    let cfg = small_cfg(5);
    let out = std::env::temp_dir().join("lexi_calibration_mismatch_test");
    let _ = std::fs::remove_dir_all(&out);
    let (art, _) = calibrate::calibrate(&m, &cfg, None, &out).unwrap();

    // wrong model name
    let mut wrong_model = art.clone();
    wrong_model.model = "someone-else".into();
    let p1 = out.join("wrong_model.json");
    wrong_model.save(&p1).unwrap();
    let mut c1 = cfg.clone();
    c1.calibration_file = Some(p1);
    assert!(server::bench_serve(&m, &c1, None, &out.join("x")).is_err());

    // wrong slot count
    let mut wrong_slots = art;
    wrong_slots.slots = 16;
    let p2 = out.join("wrong_slots.json");
    wrong_slots.save(&p2).unwrap();
    let mut c2 = cfg.clone();
    c2.calibration_file = Some(p2);
    assert!(server::bench_serve(&m, &c2, None, &out.join("y")).is_err());
}
