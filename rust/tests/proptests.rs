//! Property-based tests (hand-rolled randomized harness; proptest is
//! unavailable offline). Each property runs against a few hundred random
//! cases from a seeded PCG stream — failures print the offending seed.

use lexi_moe::engine::kv_manager::KvBlockManager;
use lexi_moe::lexi::evolution::{evolve, exact_dp, EvolutionParams};
use lexi_moe::lexi::SensitivityTable;
use lexi_moe::moe::allocation::{Allocation, Bounds};
use lexi_moe::moe::routing::RoutingSim;
use lexi_moe::util::json;
use lexi_moe::util::stats::token_f1;
use lexi_moe::util::Pcg32;

/// Run `f` on `cases` seeded random cases.
fn property<F: FnMut(u64, &mut Pcg32)>(name: &str, cases: u64, mut f: F) {
    for seed in 0..cases {
        let mut rng = Pcg32::seeded(seed * 7919 + 13);
        f(seed, &mut rng);
    }
    println!("property '{name}' held over {cases} cases");
}

// ---------------------------------------------------------------------
// Allocation / GA invariants
// ---------------------------------------------------------------------

#[test]
fn prop_random_feasible_always_satisfies() {
    property("random_feasible_satisfies", 300, |seed, rng| {
        let n_layers = 1 + rng.gen_usize(48);
        let k_max = 1 + rng.gen_range(8);
        let bounds = Bounds::paper(k_max);
        let lo = n_layers as u32;
        let hi = k_max * n_layers as u32;
        let budget = lo + rng.gen_range(hi - lo + 1);
        let a = Allocation::random_feasible(n_layers, bounds, budget, rng)
            .unwrap_or_else(|| panic!("seed {seed}: feasible budget rejected"));
        assert!(a.satisfies(bounds, budget), "seed {seed}");
    });
}

#[test]
fn prop_projection_repairs_and_is_idempotent() {
    property("projection", 300, |seed, rng| {
        let n_layers = 2 + rng.gen_usize(40);
        let k_max = 1 + rng.gen_range(8);
        let bounds = Bounds::paper(k_max);
        let budget = n_layers as u32 + rng.gen_range((k_max - 1) * n_layers as u32 + 1);
        // arbitrary garbage vector (possibly wildly out of bounds)
        let mut a = Allocation::new(
            (0..n_layers).map(|_| rng.gen_range(k_max * 3 + 1)).collect(),
        );
        a.project(bounds, budget, rng);
        assert!(a.satisfies(bounds, budget), "seed {seed}: {a:?}");
        let before = a.clone();
        a.project(bounds, budget, rng);
        assert_eq!(a, before, "seed {seed}: projection not idempotent");
    });
}

#[test]
fn prop_ga_never_returns_infeasible_and_beats_init() {
    property("ga_feasible_and_improving", 25, |seed, rng| {
        let n_layers = 4 + rng.gen_usize(28);
        let k_base = 2 + rng.gen_range(7);
        let table = SensitivityTable::synthetic(
            "p",
            n_layers,
            k_base,
            |x| 0.5 + 2.0 * x,
            seed,
        );
        let bounds = Bounds::paper(k_base);
        let budget = n_layers as u32 + rng.gen_range((k_base - 1) * n_layers as u32 + 1);
        let params = EvolutionParams {
            population: 16,
            generations: 80,
            seed,
            ..Default::default()
        };
        let res = evolve(&table, budget, bounds, &params).unwrap();
        assert!(res.best.satisfies(bounds, budget), "seed {seed}");
        // monotone convergence curve
        for w in res.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "seed {seed}: fitness regressed");
        }
        // uniform-feasible baseline is never better than the GA best by >5%
        if budget % n_layers as u32 == 0 {
            let uni = Allocation::uniform(n_layers, budget / n_layers as u32);
            assert!(
                res.best_fitness <= table.fitness(&uni.k) + 1e-9,
                "seed {seed}: GA worse than uniform"
            );
        }
    });
}

#[test]
fn prop_ga_matches_dp_within_tolerance() {
    property("ga_vs_dp", 10, |seed, _rng| {
        let table = SensitivityTable::synthetic("p", 12, 6, |x| 1.0 + 3.0 * (1.0 - x), seed);
        let bounds = Bounds::paper(6);
        let budget = 40;
        let params = EvolutionParams {
            generations: 1500,
            seed,
            ..Default::default()
        };
        let ga = evolve(&table, budget, bounds, &params).unwrap();
        let dp = exact_dp(&table, budget, bounds).unwrap();
        let opt = table.fitness(&dp.k);
        assert!(
            ga.best_fitness <= opt * 1.10 + 1e-9,
            "seed {seed}: GA {} vs optimum {}",
            ga.best_fitness,
            opt
        );
    });
}

// ---------------------------------------------------------------------
// Routing invariants
// ---------------------------------------------------------------------

#[test]
fn prop_routing_loads_conserve_mass() {
    property("routing_mass", 60, |seed, rng| {
        let e = 2 + rng.gen_usize(62);
        let k = 1 + rng.gen_usize(e.min(8));
        let tokens = 1 + rng.gen_usize(256);
        let sim = RoutingSim::new(e, rng.gen_f64() * 2.0, rng);
        let loads = sim.sample_loads(tokens, k, rng);
        assert_eq!(
            loads.iter().sum::<u64>(),
            (tokens * k) as u64,
            "seed {seed}"
        );
        // popularity stays a distribution after pruning
        let mut keep = vec![true; e];
        keep[rng.gen_usize(e)] = e > 1;
        let pruned = sim.pruned(&keep);
        let z: f64 = pruned.popularity.iter().sum();
        assert!((z - 1.0).abs() < 1e-9, "seed {seed}: mass {z}");
    });
}

#[test]
fn prop_imbalance_at_least_one() {
    property("imbalance_ge_1", 40, |seed, rng| {
        let e = 2 + rng.gen_usize(30);
        let sim = RoutingSim::new(e, rng.gen_f64() * 3.0, rng);
        let s = sim.load_stats(64 + rng.gen_usize(256), 1 + rng.gen_usize(4), 4, seed);
        assert!(s.imbalance >= 1.0 - 1e-9, "seed {seed}: {}", s.imbalance);
        assert!(s.expected_active_experts <= e as f64 + 1e-9, "seed {seed}");
    });
}

// ---------------------------------------------------------------------
// KV allocator invariants under random op sequences
// ---------------------------------------------------------------------

#[test]
fn prop_kv_manager_never_leaks() {
    property("kv_no_leak", 120, |seed, rng| {
        let total = 4 + rng.gen_usize(60);
        let block = 1 + rng.gen_usize(31);
        let mut m = KvBlockManager::new(total, block);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            match rng.gen_range(4) {
                0 => {
                    let demand = 1 + rng.gen_usize(block * 6);
                    if m.admit(next_id, demand).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 => {
                    if !live.is_empty() {
                        let idx = rng.gen_usize(live.len());
                        let id = live.swap_remove(idx);
                        m.release(id);
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let id = live[rng.gen_usize(live.len())];
                        let _ = m.extend(id, 1 + rng.gen_usize(block * 8));
                    }
                }
                _ => {
                    // double admit of a live id must fail
                    if !live.is_empty() {
                        let id = live[rng.gen_usize(live.len())];
                        assert!(m.admit(id, 1).is_err(), "seed {seed}: double admit");
                    }
                }
            }
            m.check_invariant()
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
        for id in live {
            m.release(id);
        }
        m.check_invariant().unwrap();
        assert_eq!(m.free_blocks(), total, "seed {seed}: blocks lost");
    });
}

// ---------------------------------------------------------------------
// Scoring + JSON fuzz
// ---------------------------------------------------------------------

#[test]
fn prop_token_f1_bounds_and_symmetry() {
    property("token_f1", 200, |seed, rng| {
        let n = rng.gen_usize(6);
        let m = rng.gen_usize(6);
        let a: Vec<i32> = (0..n).map(|_| rng.gen_range(8) as i32).collect();
        let b: Vec<i32> = (0..m).map(|_| rng.gen_range(8) as i32).collect();
        let f = token_f1(&a, &b);
        assert!((0.0..=1.0).contains(&f), "seed {seed}: f1 {f}");
        assert!(
            (token_f1(&a, &b) - token_f1(&b, &a)).abs() < 1e-12,
            "seed {seed}: asymmetric"
        );
        assert!((token_f1(&a, &a) - if a.is_empty() { 1.0 } else { 1.0 }).abs() < 1e-12);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> json::Json {
        match if depth > 2 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.gen_f64() < 0.5),
            2 => json::Json::Num((rng.gen_f64() * 2e6).round() / 4.0 - 1e5),
            3 => json::Json::Str(format!("s{}-\"q\"\n{}", rng.next_u32(), rng.gen_range(100))),
            4 => json::Json::Arr((0..rng.gen_usize(5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.gen_usize(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    property("json_roundtrip", 200, |seed, rng| {
        let v = random_json(rng, 0);
        let pretty = json::parse(&v.to_string_pretty())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(v, pretty, "seed {seed}");
        let compact = json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, compact, "seed {seed}");
    });
}

// ---------------------------------------------------------------------
// Sensitivity-table invariants feeding Stage 2
// ---------------------------------------------------------------------

#[test]
fn prop_fitness_additive_and_monotone() {
    property("fitness_monotone", 60, |seed, rng| {
        let l = 2 + rng.gen_usize(30);
        let kb = 2 + rng.gen_range(7);
        let t = SensitivityTable::synthetic("p", l, kb, |x| 0.2 + x, seed);
        // raising any single layer's k never increases fitness
        let mut alloc: Vec<u32> = (0..l).map(|_| 1 + rng.gen_range(kb)).collect();
        let base = t.fitness(&alloc);
        let j = rng.gen_usize(l);
        if alloc[j] < kb {
            alloc[j] += 1;
            assert!(
                t.fitness(&alloc) <= base + 1e-9,
                "seed {seed}: fitness rose with more experts"
            );
        }
    });
}
