//! Integration tests for the expert residency subsystem: seeded
//! determinism per eviction policy, predictive prefetch beating plain
//! LRU on skewed routing, stall-time conservation, and the subsystem's
//! acceptance criterion — k_vec-aware pinning achieving strictly higher
//! goodput than LRU under a tight HBM budget on the bursty scenario.

use std::rc::Rc;

use lexi_moe::config::server::{EvictKind, PolicyKind, ScenarioKind};
use lexi_moe::experts::{ExpertResidency, LinkModel, ResidencyConfig};
use lexi_moe::moe::allocation::Allocation;
use lexi_moe::moe::routing::RoutingSim;
use lexi_moe::server::ladder::QualityLadder;
use lexi_moe::server::replica::{Replica, ServiceModel};
use lexi_moe::server::report::TransformReport;
use lexi_moe::server::router::Cluster;
use lexi_moe::server::workload::Scenario;
use lexi_moe::server::ReplicaBackend;

const N_LAYERS: usize = 4;
const N_EXPERTS: usize = 16;
const EXPERT_BYTES: u64 = 1 << 20;

/// Residency over `budget_experts` HBM slots with a 5 ms expert fetch.
fn cfg(budget_experts: f64, policy: EvictKind, prefetch: bool) -> ResidencyConfig {
    let mut c = ResidencyConfig::for_dims(
        N_LAYERS,
        N_EXPERTS,
        EXPERT_BYTES,
        budget_experts / (N_LAYERS * N_EXPERTS) as f64,
        policy,
        9,
    );
    c.prefetch = prefetch;
    c.link = LinkModel {
        bw_bytes_per_s: EXPERT_BYTES as f64 / 4e-3,
        latency_s: 1e-3,
    };
    c.overlap_s_per_step = 2e-3;
    c
}

/// Extreme two-hot-experts-per-layer router: the top-2 carry ~98.6% of
/// the mass, so each layer's LExI hot set (k=2) covers nearly every
/// token and the 14 tail experts appear only occasionally.
fn two_hot_routing() -> Vec<RoutingSim> {
    let mut freq = vec![1.0f32; N_EXPERTS];
    freq[0] = 493.0;
    freq[1] = 493.0;
    (0..N_LAYERS)
        .map(|_| RoutingSim::from_frequencies(&freq))
        .collect()
}

#[test]
fn eviction_policies_are_deterministic_under_fixed_seed() {
    for policy in EvictKind::all() {
        let run = || {
            let mut r = ExpertResidency::with_routing(
                &cfg(10.0, policy, true),
                vec![2; N_LAYERS],
                3,
                two_hot_routing(),
            );
            let steps: Vec<_> = (0..96)
                .map(|i| r.step(if i % 8 == 0 { 64 } else { 4 }))
                .collect();
            (steps, r.stats())
        };
        let (steps_a, stats_a) = run();
        let (steps_b, stats_b) = run();
        assert_eq!(steps_a, steps_b, "{policy:?} step stream not deterministic");
        assert_eq!(stats_a, stats_b, "{policy:?} stats not deterministic");
        assert!(stats_a.hits + stats_a.misses > 0);
    }
}

#[test]
fn predictive_prefetch_beats_plain_lru_hit_rate_on_skewed_routing() {
    // generous budget (48 of 64 experts) + an overlap window that fits
    // several transfers: prediction should convert cold misses of the
    // popular experts into hits
    let run = |prefetch: bool| {
        let mut c = cfg(48.0, EvictKind::Lru, prefetch);
        c.overlap_s_per_step = 50e-3; // fetch = 5ms: deep overlap
        let mut r = ExpertResidency::with_routing(&c, vec![2; N_LAYERS], 5, two_hot_routing());
        for _ in 0..256 {
            r.step(4);
        }
        r.stats()
    };
    let plain = run(false);
    let prefetched = run(true);
    assert!(prefetched.prefetch_issued > 0 && prefetched.prefetch_hits > 0);
    assert_eq!(plain.prefetch_issued, 0, "LRU without prefetch issued transfers");
    assert!(
        prefetched.hit_rate() >= plain.hit_rate() - 1e-9,
        "prefetch hit rate {} < plain LRU {}",
        prefetched.hit_rate(),
        plain.hit_rate()
    );
    assert!(
        prefetched.stall_s <= plain.stall_s + 1e-9,
        "prefetch stalled longer ({} s) than plain LRU ({} s)",
        prefetched.stall_s,
        plain.stall_s
    );
}

#[test]
fn stall_time_is_conserved_across_steps() {
    let mut r = ExpertResidency::with_routing(
        &cfg(7.0, EvictKind::Lru, false),
        vec![2; N_LAYERS],
        1,
        two_hot_routing(),
    );
    let mut total = 0.0;
    let mut max_step = 0.0f64;
    for i in 0..200 {
        let s = r.step(if i % 10 == 0 { 64 } else { 4 });
        total += s.stall_s;
        max_step = max_step.max(s.stall_s);
    }
    let stats = r.stats();
    // the report total is exactly the sum of the per-step stalls
    assert!(
        (stats.stall_s - total).abs() <= 1e-9 * total.max(1.0),
        "sum of per-step stalls {total} != reported {}",
        stats.stall_s
    );
    assert!(stats.stall_s > 0.0, "tight budget never stalled");
    // percentiles live inside the observed per-step range
    assert!(stats.stall_p50_s <= stats.stall_p95_s + 1e-12);
    assert!(stats.stall_p95_s <= max_step + 1e-12);
}

/// The acceptance criterion: under a 7-expert HBM budget whose hot set
/// is 8 experts (4 layers x k=2), plain LRU degenerates into the classic
/// cyclic-scan thrash (every hot access evicts the next hot expert),
/// while k_vec-aware pinning keeps 6 hot experts locked in HBM. Run both
/// through the full serving cluster on the bursty scenario: pinning must
/// win goodput outright.
#[test]
fn kvec_pinning_beats_lru_goodput_under_tight_hbm_budget() {
    let slots = 4;
    let svc = ServiceModel::synthetic("base", 1e-5, 0.02, slots);
    let ladder = QualityLadder::fixed("base", Allocation::uniform(N_LAYERS, 2), svc.clone());

    let mut scenario = Scenario::from_kind(
        ScenarioKind::Bursty,
        2.0 * svc.capacity_rps(480.0, 120.0),
    );
    // generous fixed TTFT references, TPOT from a 0.05 s step budget:
    // healthy replicas pass easily, stall-inflated cadence + queue
    // collapse bust the SLOs
    scenario.resolve_slos(|_| 2.0, 0.05);
    let trace = scenario.generate(120, 11);

    let run = |policy: EvictKind| {
        let ladder = Rc::new(ladder.clone());
        let backends: Vec<Box<dyn ReplicaBackend>> = (0..2)
            .map(|i| {
                let residency = ExpertResidency::with_routing(
                    &cfg(7.0, policy, false),
                    ladder.k_vec(0).unwrap(),
                    i as u64,
                    two_hot_routing(),
                );
                let replica = Replica::new(i, slots, Rc::clone(&ladder)).with_residency(residency);
                Box::new(replica) as Box<dyn ReplicaBackend>
            })
            .collect();
        let mut cluster = Cluster::from_backends(
            backends,
            PolicyKind::Jsq,
            Rc::clone(&ladder),
            None,
            10_000,
            scenario.profiles.len(),
            0.0,
            1,
        );
        let res = cluster.run(&scenario, &trace);
        assert_eq!(res.completed.len(), 120, "{policy:?} lost requests");
        TransformReport::from_run(&scenario, policy.label(), "jsq", &res, &[0.0])
    };

    let lru = run(EvictKind::Lru);
    let kvec = run(EvictKind::KvecAware);

    let lru_res = lru.residency_aggregate().unwrap();
    let kvec_res = kvec.residency_aggregate().unwrap();
    // mechanism: pinning keeps the hot set resident, LRU thrashes it
    assert!(
        kvec_res.hit_rate() > lru_res.hit_rate() + 0.2,
        "kvec hit rate {:.3} not clearly above LRU {:.3}",
        kvec_res.hit_rate(),
        lru_res.hit_rate()
    );
    assert!(
        kvec_res.stall_s < lru_res.stall_s,
        "kvec stalled {} s >= LRU {} s",
        kvec_res.stall_s,
        lru_res.stall_s
    );
    // outcome: strictly higher goodput under the same workload contract
    assert!(
        kvec.goodput_rps > lru.goodput_rps,
        "kvec goodput {:.4} rps not strictly above LRU {:.4} rps \
         (kvec: {}/{} in SLO over {:.1}s; lru: {}/{} over {:.1}s)",
        kvec.goodput_rps,
        lru.goodput_rps,
        kvec.n_slo_met,
        kvec.n_completed,
        kvec.makespan_s,
        lru.n_slo_met,
        lru.n_completed,
        lru.makespan_s
    );
}
