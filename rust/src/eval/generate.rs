//! Generation tasks: passkey retrieval (exact match, Fig. 6) and
//! long-context QA (token F1, Fig. 5) via greedy decoding through the
//! prefill+decode graphs.

use anyhow::Result;

use crate::engine::engine::Engine;
use crate::runtime::ModelRuntime;
use crate::util::stats::token_f1;

use super::suite::EvalSuite;
use super::RunConfig;

/// Passkey retrieval: greedy-decode `answer_len` tokens after the QRY
/// marker; exact match on all positions. Returns (accuracy, per-depth
/// accuracy pairs (depth_pct, acc)).
pub fn passkey(
    model: &ModelRuntime,
    suite: &EvalSuite,
    rc: &RunConfig,
) -> Result<(f64, Vec<(i32, f64)>)> {
    let t = suite.gen_task("passkey")?;
    let depth = suite.array("passkey_depth_pct")?;
    let alen = t.answer_len();
    let n = t.n();
    let e = &model.entry;

    let mut hits = vec![false; n];
    let mut start = 0;
    while start < n {
        let group = (n - start).min(e.batch);
        let prompts: Vec<&[i32]> = (0..group)
            .map(|i| {
                let q = start + i;
                let plen = t.plen.scalar(q) as usize;
                &t.prompts.row(q)[..plen]
            })
            .collect();
        let gen = Engine::generate_batch(model, &prompts, alen, &rc.k_vec, &rc.gate_bias)?;
        for i in 0..group {
            let q = start + i;
            hits[q] = gen[i] == t.answers.row(q);
        }
        start += group;
    }

    let acc = hits.iter().filter(|&&h| h).count() as f64 / n as f64;
    // group by depth percentage
    let mut depths: Vec<i32> = (0..n).map(|i| depth.scalar(i)).collect();
    depths.sort_unstable();
    depths.dedup();
    let per_depth = depths
        .into_iter()
        .map(|d| {
            let idx: Vec<usize> = (0..n).filter(|&i| depth.scalar(i) == d).collect();
            let a = idx.iter().filter(|&&i| hits[i]).count() as f64 / idx.len() as f64;
            (d, a)
        })
        .collect();
    Ok((acc, per_depth))
}

/// Long-context QA: greedy-decode the answer and score token-level F1
/// (the Qasper/LongBench metric).
pub fn longqa_f1(model: &ModelRuntime, suite: &EvalSuite, rc: &RunConfig) -> Result<f64> {
    let t = suite.gen_task("longqa")?;
    let alen = t.answer_len();
    let n = t.n();
    let e = &model.entry;

    let mut total = 0.0f64;
    let mut start = 0;
    while start < n {
        let group = (n - start).min(e.batch);
        let prompts: Vec<&[i32]> = (0..group)
            .map(|i| {
                let q = start + i;
                let plen = t.plen.scalar(q) as usize;
                &t.prompts.row(q)[..plen]
            })
            .collect();
        let gen = Engine::generate_batch(model, &prompts, alen, &rc.k_vec, &rc.gate_bias)?;
        for i in 0..group {
            let q = start + i;
            total += token_f1(&gen[i], t.answers.row(q));
        }
        start += group;
    }
    Ok(total / n as f64)
}
