//! Perplexity on the held-out synthetic corpora (Fig. 7's y-axis; the
//! C4 / PTB / WikiText substitution of DESIGN.md §3).

use anyhow::Result;

use crate::runtime::ModelRuntime;

use super::scoring;
use super::suite::EvalSuite;
use super::RunConfig;

/// Perplexity of the model on one corpus' held-out sequences.
pub fn perplexity(
    model: &ModelRuntime,
    suite: &EvalSuite,
    corpus: &str,
    rc: &RunConfig,
) -> Result<f64> {
    let e = &model.entry;
    let seqs = suite.ppl_seqs(corpus)?;
    let (n, len) = (seqs.n_rows(), seqs.shape[1]);
    anyhow::ensure!(len <= e.prefill_len, "ppl seq longer than prefill graph");

    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let mut start = 0;
    while start < n {
        let group = (n - start).min(e.batch);
        let mut tokens = vec![0i32; e.batch * e.prefill_len];
        for i in 0..group {
            tokens[i * e.prefill_len..i * e.prefill_len + len]
                .copy_from_slice(seqs.row(start + i));
        }
        let out = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias)?;
        for i in 0..group {
            let row_seq = seqs.row(start + i);
            for pos in 0..len - 1 {
                let target = row_seq[pos + 1];
                if target == 0 {
                    break; // padding
                }
                let row =
                    scoring::prefill_row(&out.logits, i, pos, e.prefill_len, e.vocab);
                total_nll += -scoring::log_prob(row, target);
                total_tok += 1;
            }
        }
        start += group;
    }
    Ok((total_nll / total_tok.max(1) as f64).exp())
}

/// All corpora at once (Fig. 7 row for one model+transform).
pub fn all_corpora(
    model: &ModelRuntime,
    suite: &EvalSuite,
    rc: &RunConfig,
) -> Result<Vec<(String, f64)>> {
    suite
        .ppl_corpora
        .iter()
        .map(|c| Ok((c.clone(), perplexity(model, suite, c, rc)?)))
        .collect()
}
