//! Multiple-choice scoring (the lm-eval protocol): each candidate
//! continuation is scored by total log-probability given the prompt;
//! accuracy = fraction of questions where the gold candidate wins.
//! Used for the nine probe tasks (Fig. 4) and the VLM tasks (Fig. 8).

use anyhow::Result;

use crate::runtime::ModelRuntime;

use super::scoring;
use super::suite::EvalSuite;
use super::RunConfig;

/// Accuracy on one multiple-choice task.
pub fn accuracy(
    model: &ModelRuntime,
    suite: &EvalSuite,
    task: &str,
    rc: &RunConfig,
) -> Result<f64> {
    let t = suite.mc_task(task)?;
    let e = &model.entry;
    let n = t.n();
    let mut correct = 0usize;

    let mut start = 0;
    while start < n {
        let group = (n - start).min(e.batch);
        // one prefill for the whole group of questions
        let mut tokens = vec![0i32; e.batch * e.prefill_len];
        for i in 0..group {
            let q = start + i;
            let plen = t.plen.scalar(q) as usize;
            tokens[i * e.prefill_len..i * e.prefill_len + plen]
                .copy_from_slice(&t.prompts.row(q)[..plen]);
        }
        let out = model.prefill(&tokens, &rc.k_vec, &rc.gate_bias)?;

        // score candidates; first token from prefill logits, second token
        // (when present) from one decode step per candidate index
        let n_cands = t.n_cands();
        let mut scores = vec![vec![f64::NEG_INFINITY; n_cands]; group];
        for c in 0..n_cands {
            // first-token log-probs
            let mut needs_second = false;
            for i in 0..group {
                let q = start + i;
                let cand = t.cand(q, c);
                if cand[0] == 0 {
                    continue; // candidate slot unused (binary tasks)
                }
                let plen = t.plen.scalar(q) as usize;
                let row = scoring::prefill_row(&out.logits, i, plen - 1, e.prefill_len, e.vocab);
                scores[i][c] = scoring::log_prob(row, cand[0]);
                if cand.len() > 1 && cand[1] != 0 {
                    needs_second = true;
                }
            }
            if needs_second {
                // decode step: feed candidate token c at each slot's plen
                let mut toks = vec![0i32; e.batch];
                let mut pos = vec![(e.max_seq - 1) as i32; e.batch];
                for i in 0..group {
                    let q = start + i;
                    let cand = t.cand(q, c);
                    if cand[0] != 0 {
                        toks[i] = cand[0];
                        pos[i] = t.plen.scalar(q);
                    }
                }
                let d = model.decode(&out.kv, &toks, &pos, &rc.k_vec, &rc.gate_bias)?;
                for i in 0..group {
                    let q = start + i;
                    let cand = t.cand(q, c);
                    if cand[0] != 0 && cand.len() > 1 && cand[1] != 0 {
                        let row = scoring::decode_row(&d.logits, i, e.vocab);
                        scores[i][c] += scoring::log_prob(row, cand[1]);
                    }
                }
            }
        }

        for i in 0..group {
            let q = start + i;
            let best = (0..n_cands)
                .max_by(|&a, &b| scores[i][a].partial_cmp(&scores[i][b]).unwrap())
                .unwrap();
            if best as i32 == t.labels.scalar(q) {
                correct += 1;
            }
        }
        start += group;
    }
    Ok(correct as f64 / n as f64)
}

/// Mean accuracy over a list of MC tasks (prefixed names in the suite).
pub fn task_suite(
    model: &ModelRuntime,
    suite: &EvalSuite,
    tasks: &[(String, String)],
    rc: &RunConfig,
) -> Result<Vec<(String, f64)>> {
    tasks
        .iter()
        .map(|(short, full)| Ok((short.clone(), accuracy(model, suite, full, rc)?)))
        .collect()
}

/// The nine lm-eval probe tasks (Fig. 4).
pub fn lmeval_tasks(suite: &EvalSuite) -> Vec<(String, String)> {
    suite
        .probe_tasks
        .iter()
        .map(|t| (t.clone(), format!("probe_{t}")))
        .collect()
}

/// The VLM tasks (Fig. 8).
pub fn vlm_tasks(suite: &EvalSuite) -> Vec<(String, String)> {
    suite
        .vlm_tasks
        .iter()
        .map(|t| (t.clone(), format!("vlm_{t}")))
        .collect()
}

/// Convenience: mean of per-task accuracies.
pub fn mean_accuracy(scores: &[(String, f64)]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().map(|(_, a)| a).sum::<f64>() / scores.len() as f64
}
