//! Loader for the synthetic eval suite (artifacts/corpora/eval_suite.npz
//! + meta.json) written by python/compile/data.py.

use std::collections::HashMap;

use anyhow::{Context, Result};
use xla::FromRawBytes;

use crate::runtime::Manifest;
use crate::util::json::parse_file;

/// An int32 array with shape (all eval data is token ids / labels).
#[derive(Clone, Debug)]
pub struct I32Array {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl I32Array {
    pub fn row(&self, i: usize) -> &[i32] {
        let w: usize = self.shape[1..].iter().product();
        &self.data[i * w..(i + 1) * w]
    }

    pub fn n_rows(&self) -> usize {
        self.shape[0]
    }

    pub fn scalar(&self, i: usize) -> i32 {
        self.data[i]
    }
}

/// The full task suite.
pub struct EvalSuite {
    arrays: HashMap<String, I32Array>,
    pub seq_len: usize,
    pub ppl_corpora: Vec<String>,
    pub probe_tasks: Vec<String>,
    pub vlm_tasks: Vec<String>,
}

impl EvalSuite {
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let npz = manifest.corpora_path("eval_suite.npz");
        let raw = xla::Literal::read_npz(&npz, &())
            .map_err(|e| anyhow::anyhow!("reading {npz:?}: {e:?}"))?;
        let mut arrays = HashMap::new();
        for (name, lit) in raw {
            let shape: Vec<usize> = lit
                .array_shape()
                .map_err(|e| anyhow::anyhow!("{e:?}"))?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            let data = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("array '{name}': {e:?}"))?;
            arrays.insert(name, I32Array { shape, data });
        }
        let meta = parse_file(&manifest.corpora_path("meta.json"))?;
        Ok(EvalSuite {
            arrays,
            seq_len: meta.get("seq_len")?.as_usize()?,
            ppl_corpora: meta.get("ppl_corpora")?.str_vec()?,
            probe_tasks: meta.get("probe_tasks")?.str_vec()?,
            vlm_tasks: meta.get("vlm_tasks")?.str_vec()?,
        })
    }

    pub fn array(&self, name: &str) -> Result<&I32Array> {
        self.arrays
            .get(name)
            .with_context(|| format!("eval array '{name}' missing"))
    }

    /// Held-out LM sequences for one perplexity corpus.
    pub fn ppl_seqs(&self, corpus: &str) -> Result<&I32Array> {
        self.array(&format!("ppl_{corpus}"))
    }

    /// Multiple-choice task view (probe_* and vlm_* tasks).
    pub fn mc_task(&self, task: &str) -> Result<McTask<'_>> {
        Ok(McTask {
            prompts: self.array(&format!("{task}_prompts"))?,
            plen: self.array(&format!("{task}_plen"))?,
            cands: self.array(&format!("{task}_cands"))?,
            labels: self.array(&format!("{task}_labels"))?,
        })
    }

    /// Generation task view (passkey / longqa).
    pub fn gen_task(&self, task: &str) -> Result<GenTask<'_>> {
        Ok(GenTask {
            prompts: self.array(&format!("{task}_prompts"))?,
            plen: self.array(&format!("{task}_plen"))?,
            answers: self.array(&format!("{task}_answers"))?,
        })
    }
}

/// Multiple-choice task data: prompts [n, T], plen [n], cands [n, 4, clen],
/// labels [n].
pub struct McTask<'a> {
    pub prompts: &'a I32Array,
    pub plen: &'a I32Array,
    pub cands: &'a I32Array,
    pub labels: &'a I32Array,
}

impl McTask<'_> {
    pub fn n(&self) -> usize {
        self.prompts.n_rows()
    }

    /// Candidate tokens for question i, candidate c (0-padded tail).
    pub fn cand(&self, i: usize, c: usize) -> &[i32] {
        let (nc, cl) = (self.cands.shape[1], self.cands.shape[2]);
        let base = (i * nc + c) * cl;
        &self.cands.data[base..base + cl]
    }

    pub fn n_cands(&self) -> usize {
        self.cands.shape[1]
    }
}

/// Generation task data: prompts [n, T], plen [n], answers [n, alen].
pub struct GenTask<'a> {
    pub prompts: &'a I32Array,
    pub plen: &'a I32Array,
    pub answers: &'a I32Array,
}

impl GenTask<'_> {
    pub fn n(&self) -> usize {
        self.prompts.n_rows()
    }

    pub fn answer_len(&self) -> usize {
        self.answers.shape[1]
    }
}
