//! Logit-scoring helpers shared by the eval tasks.

/// Log-softmax probability of `token` in a logits row.
pub fn log_prob(logits: &[f32], token: i32) -> f64 {
    crate::engine::sampler::log_prob(logits, token)
}

/// View one position's logits row out of a flattened prefill output
/// [B, T, V].
pub fn prefill_row<'a>(
    logits: &'a [f32],
    slot: usize,
    pos: usize,
    t: usize,
    v: usize,
) -> &'a [f32] {
    &logits[(slot * t + pos) * v..(slot * t + pos + 1) * v]
}

/// View one slot's logits row out of a flattened decode output [B, V].
pub fn decode_row<'a>(logits: &'a [f32], slot: usize, v: usize) -> &'a [f32] {
    &logits[slot * v..(slot + 1) * v]
}

/// Mean negative log-likelihood of `targets[i]` at `rows[i]`; used by the
/// perplexity task.
pub fn mean_nll(pairs: &[(f64, usize)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    let (sum, n) = pairs
        .iter()
        .fold((0.0, 0usize), |(s, n), &(nll, c)| (s + nll, n + c));
    sum / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_views() {
        // B=2, T=2, V=3
        let logits: Vec<f32> = (0..12).map(|x| x as f32).collect();
        assert_eq!(prefill_row(&logits, 1, 0, 2, 3), &[6.0, 7.0, 8.0]);
        let dec: Vec<f32> = (0..6).map(|x| x as f32).collect();
        assert_eq!(decode_row(&dec, 1, 3), &[3.0, 4.0, 5.0]);
    }
}
