//! Evaluation harness over the artifacts' synthetic task suites
//! (DESIGN.md §3: each task is the capability-axis proxy for one of the
//! paper's benchmarks).

pub mod generate;
pub mod multiple_choice;
pub mod perplexity;
pub mod scoring;
pub mod suite;

pub use suite::EvalSuite;

use anyhow::Result;

use crate::moe::transform::Transform;
use crate::runtime::weights::CalibStats;
use crate::runtime::ManifestModel;

/// Runtime inputs realizing one [`Transform`] on the compiled graphs:
/// the per-layer k vector and per-expert gate bias. (Intra-pruning's
/// weight edit happens separately via `pruning::intra_prune_params`.)
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub k_vec: Vec<i32>,
    pub gate_bias: Vec<f32>,
    pub label: String,
}

impl RunConfig {
    pub fn baseline(entry: &ManifestModel) -> Self {
        RunConfig {
            k_vec: vec![entry.top_k as i32; entry.n_layers],
            gate_bias: vec![0.0; entry.n_layers * entry.n_experts],
            label: "base".into(),
        }
    }

    /// Build the runtime inputs for a transform. `calib` is required for
    /// inter-pruning (its expert ranking is calibration-dependent).
    pub fn for_transform(
        entry: &ManifestModel,
        t: &Transform,
        calib: Option<&CalibStats>,
    ) -> Result<Self> {
        let mut rc = Self::baseline(entry);
        rc.label = t.label();
        match t {
            Transform::Baseline | Transform::IntraPrune { .. } => {}
            Transform::Lexi { allocation } => {
                anyhow::ensure!(allocation.k.len() == entry.n_layers);
                rc.k_vec = allocation.to_i32();
            }
            Transform::InterPrune { frac } => {
                let calib =
                    calib.ok_or_else(|| anyhow::anyhow!("inter-pruning needs calib stats"))?;
                rc.gate_bias = crate::pruning::inter_prune_bias(calib, *frac);
                // top-k may saturate if fewer experts survive than k_base
                let kept = entry.n_experts
                    - ((entry.n_experts as f64 * frac).round() as usize).min(entry.n_experts - 1);
                let k = entry.top_k.min(kept) as i32;
                rc.k_vec = vec![k; entry.n_layers];
            }
            Transform::LexiPlusInter { allocation, frac } => {
                let calib = calib
                    .ok_or_else(|| anyhow::anyhow!("combined transform needs calib stats"))?;
                anyhow::ensure!(allocation.k.len() == entry.n_layers);
                rc.gate_bias = crate::pruning::inter_prune_bias(calib, *frac);
                let kept = entry.n_experts
                    - ((entry.n_experts as f64 * frac).round() as usize).min(entry.n_experts - 1);
                rc.k_vec = allocation
                    .k
                    .iter()
                    .map(|&k| (k as usize).min(kept) as i32)
                    .collect();
            }
            Transform::DynamicSkip { .. } => {
                anyhow::bail!("dynamic skipping is token-adaptive; not expressible as RunConfig")
            }
        }
        Ok(rc)
    }
}

/// Scores of one (model, transform) evaluation — the accuracy axis of
/// Figs. 4-8.
#[derive(Clone, Debug, Default)]
pub struct EvalScores {
    /// Mean accuracy over the nine probe tasks (Fig. 4 y-axis).
    pub lmeval_avg: f64,
    /// Per-task accuracies.
    pub lmeval: Vec<(String, f64)>,
    /// Token-F1 on the long-context QA task (Fig. 5).
    pub longqa_f1: f64,
    /// Passkey exact-match accuracy (Fig. 6).
    pub passkey_acc: f64,
    /// Perplexity per corpus (Fig. 7).
    pub perplexity: Vec<(String, f64)>,
    /// Mean accuracy over the VLM tasks (Fig. 8).
    pub vlm_avg: f64,
    pub vlm: Vec<(String, f64)>,
}
