//! Figures 4-8: measured accuracy (tiny analogues through the PJRT stack)
//! vs modeled H100 throughput, for baseline / inter / intra / LExI.
//!
//! Shared harness: each (model, transform) pair is evaluated once and its
//! scores reused across the per-figure CSVs (Fig. 4 probes, Fig. 5 longqa
//! F1, Fig. 6 passkey, Fig. 7 perplexity, Fig. 8 VLM).

use std::path::Path;

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::config::model::{spec, LLM_NAMES};
use crate::eval::{generate, multiple_choice as mc, perplexity, EvalScores, EvalSuite, RunConfig};
use crate::lexi::pipeline::{stage1, stage2, table_path};
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;
use crate::pruning;
use crate::runtime::weights::CalibStats;
use crate::runtime::{Manifest, ModelRuntime, Runtime};

use super::series::{f, FigureOutput};

/// One evaluated configuration.
pub struct ConfigResult {
    pub model: String,
    pub transform: Transform,
    pub label: String,
    pub throughput_tok_s: f64,
    pub scores: EvalScores,
}

/// Evaluate every transform for one model. `sel` selects the score
/// groups to compute (saves wall-clock for single-figure runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScoreSel {
    pub lmeval: bool,
    pub longqa: bool,
    pub passkey: bool,
    pub ppl: bool,
    pub vlm: bool,
}

impl ScoreSel {
    pub fn all() -> Self {
        ScoreSel {
            lmeval: true,
            longqa: true,
            passkey: true,
            ppl: true,
            vlm: false,
        }
    }
}

pub fn evaluate_model(
    rt: &Runtime,
    manifest: &Manifest,
    suite: &EvalSuite,
    model_name: &str,
    cfg: &ExperimentConfig,
    sel: ScoreSel,
) -> Result<Vec<ConfigResult>> {
    let mspec = spec(model_name)?;
    let entry = manifest.model(model_name)?.clone();
    let calib = CalibStats::load_npz(
        manifest.model_dir(model_name).join(&entry.files.calib),
        entry.n_layers,
        entry.n_experts,
    )?;
    let model = ModelRuntime::load(rt, manifest, model_name)?;
    let pm = PerfModel::new(mspec.clone(), cfg.seed).with_calibration(&calib.sel_freq);

    // Stage 1 once per model; Stage 2 per budget.
    let cache = table_path(&manifest.root, model_name);
    let table = stage1(&model, cfg, Some(&cache), false)?;

    let mut results = Vec::new();

    // baseline + pruning transforms
    let mut transforms: Vec<Transform> = vec![Transform::Baseline];
    for &frac in &cfg.prune_fracs {
        transforms.push(Transform::InterPrune { frac });
        transforms.push(Transform::IntraPrune { frac });
    }
    for b in mspec.budget_sweep() {
        let alloc = stage2(&table, b as u32, cfg)?.best;
        transforms.push(Transform::Lexi { allocation: alloc });
    }

    for t in transforms {
        eprintln!("  [{}] eval {}", model_name, t.label());
        // intra-pruning edits weights -> dedicated runtime
        let scores = match &t {
            Transform::IntraPrune { frac } => {
                let mut params =
                    crate::runtime::weights::HostParams::load_npz(
                        manifest.model_dir(model_name).join(&entry.files.params),
                        &entry,
                    )?;
                pruning::intra_prune_params(&mut params, *frac)?;
                let pruned_model = model.reload_with_params(params)?;
                let rc = RunConfig::for_transform(&entry, &t, Some(&calib))?;
                eval_scores(&pruned_model, suite, &rc, sel)?
            }
            _ => {
                let rc = RunConfig::for_transform(&entry, &t, Some(&calib))?;
                eval_scores(&model, suite, &rc, sel)?
            }
        };
        let tput = pm
            .throughput(&t, cfg.paper_batch, cfg.paper_in_len, cfg.paper_out_len)
            .throughput_tok_s;
        results.push(ConfigResult {
            model: model_name.to_string(),
            label: t.label(),
            transform: t,
            throughput_tok_s: tput,
            scores,
        });
    }
    Ok(results)
}

fn eval_scores(
    model: &ModelRuntime,
    suite: &EvalSuite,
    rc: &RunConfig,
    sel: ScoreSel,
) -> Result<EvalScores> {
    let mut s = EvalScores::default();
    if sel.lmeval {
        s.lmeval = mc::task_suite(model, suite, &mc::lmeval_tasks(suite), rc)?;
        s.lmeval_avg = mc::mean_accuracy(&s.lmeval);
    }
    if sel.longqa {
        s.longqa_f1 = generate::longqa_f1(model, suite, rc)?;
    }
    if sel.passkey {
        s.passkey_acc = generate::passkey(model, suite, rc)?.0;
    }
    if sel.ppl {
        s.perplexity = perplexity::all_corpora(model, suite, rc)?;
    }
    if sel.vlm {
        s.vlm = mc::task_suite(model, suite, &mc::vlm_tasks(suite), rc)?;
        s.vlm_avg = mc::mean_accuracy(&s.vlm);
    }
    Ok(s)
}

/// Emit Figs. 4-7 from LLM results and Fig. 8 from the VLM result.
pub fn emit_figures(
    out_dir: &Path,
    llm_results: &[ConfigResult],
    vlm_results: &[ConfigResult],
) -> Result<Vec<FigureOutput>> {
    let mut figs = Vec::new();

    // Fig. 4: avg accuracy vs throughput (9 probe tasks).
    let mut fig4 = FigureOutput::new(
        "fig4_lmeval_accuracy_vs_throughput",
        &["model", "transform", "tok_s", "avg_accuracy"],
    );
    for r in llm_results {
        fig4.row(vec![
            r.model.clone(),
            r.label.clone(),
            f(r.throughput_tok_s),
            f(r.scores.lmeval_avg),
        ]);
    }
    fig4.emit(out_dir)?;
    figs.push(fig4);

    // Fig. 5: Qasper-analogue F1 vs throughput (3 models in the paper).
    let fig5_models = ["qwen1.5-moe-a2.7b", "deepseek-v2-lite", "olmoe-1b-7b"];
    let mut fig5 = FigureOutput::new(
        "fig5_longqa_f1_vs_throughput",
        &["model", "transform", "tok_s", "f1"],
    );
    for r in llm_results.iter().filter(|r| fig5_models.contains(&r.model.as_str())) {
        fig5.row(vec![
            r.model.clone(),
            r.label.clone(),
            f(r.throughput_tok_s),
            f(r.scores.longqa_f1),
        ]);
    }
    fig5.emit(out_dir)?;
    figs.push(fig5);

    // Fig. 6: passkey retrieval vs throughput (5 models).
    let mut fig6 = FigureOutput::new(
        "fig6_passkey_vs_throughput",
        &["model", "transform", "tok_s", "passkey_acc"],
    );
    for r in llm_results {
        fig6.row(vec![
            r.model.clone(),
            r.label.clone(),
            f(r.throughput_tok_s),
            f(r.scores.passkey_acc),
        ]);
    }
    fig6.emit(out_dir)?;
    figs.push(fig6);

    // Fig. 7: perplexity vs throughput per corpus.
    let mut fig7 = FigureOutput::new(
        "fig7_perplexity_vs_throughput",
        &["model", "transform", "corpus", "tok_s", "ppl"],
    );
    for r in llm_results {
        for (corpus, ppl) in &r.scores.perplexity {
            fig7.row(vec![
                r.model.clone(),
                r.label.clone(),
                corpus.clone(),
                f(r.throughput_tok_s),
                f(*ppl),
            ]);
        }
    }
    fig7.emit(out_dir)?;
    figs.push(fig7);

    // Fig. 8: VLM ablation.
    let mut fig8 = FigureOutput::new(
        "fig8_vlm_accuracy_vs_throughput",
        &["model", "transform", "task", "tok_s", "accuracy"],
    );
    for r in vlm_results {
        for (task, acc) in &r.scores.vlm {
            fig8.row(vec![
                r.model.clone(),
                r.label.clone(),
                task.clone(),
                f(r.throughput_tok_s),
                f(*acc),
            ]);
        }
        fig8.row(vec![
            r.model.clone(),
            r.label.clone(),
            "avg".into(),
            f(r.throughput_tok_s),
            f(r.scores.vlm_avg),
        ]);
    }
    fig8.emit(out_dir)?;
    figs.push(fig8);

    Ok(figs)
}

/// Full Figs. 4-8 pipeline over all models.
pub fn run_all(
    out_dir: &Path,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &ExperimentConfig,
    models: Option<&[&str]>,
) -> Result<()> {
    let suite = EvalSuite::load(manifest)?;
    let mut llm_results = Vec::new();
    let llms: Vec<&str> = models
        .map(|m| m.to_vec())
        .unwrap_or_else(|| LLM_NAMES.to_vec());
    for name in &llms {
        eprintln!("[figs4-7] {name}");
        llm_results.extend(evaluate_model(rt, manifest, &suite, name, cfg, ScoreSel::all())?);
    }
    let vlm_sel = ScoreSel {
        lmeval: false,
        longqa: false,
        passkey: false,
        ppl: false,
        vlm: true,
    };
    let vlm_results = if models.is_none() || models.unwrap().contains(&"deepseek-vl2-tiny") {
        eprintln!("[fig8] deepseek-vl2-tiny");
        evaluate_model(rt, manifest, &suite, "deepseek-vl2-tiny", cfg, vlm_sel)?
    } else {
        Vec::new()
    };
    emit_figures(out_dir, &llm_results, &vlm_results)?;
    let verdicts = super::pareto::summarize(out_dir, &llm_results, &vlm_results)?;
    eprintln!(
        "pareto: LExI dominates {:.0}% of pruning points across models/metrics",
        super::pareto::domination_rate(&verdicts) * 100.0
    );
    Ok(())
}
