//! Request-timeline Gantt figure (`lexi figures --exp timeline`): one
//! small traced sim run rendered as per-request queue → prefill →
//! decode segments on absolute virtual time.
//!
//! The segments come straight from the span trace's critical paths
//! (see [`crate::obs`]), so the figure shows the same decomposition the
//! `critical_path_*.csv` artifact reports: where each request's latency
//! actually went, request by request, replica by replica.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::model::spec;
use crate::config::server::{ScenarioKind, ServerConfig};
use crate::perfmodel::PerfModel;
use crate::server::{self, Contender, QualityLadder};

use super::series::{f, FigureOutput};

/// Run a small deterministic traced sim and emit the Gantt rows.
pub fn run(out_dir: &Path) -> Result<FigureOutput> {
    let m = spec("minicpm-moe-8x2b")?;
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 48,
        scenario: ScenarioKind::Poisson,
        service_in_len: 256,
        service_out_len: 32,
        trace: true,
        ..Default::default()
    };
    let table = server::sensitivity_table(&m, None, cfg.seed);
    let pm = PerfModel::new(m.clone(), cfg.seed);
    let contender = Contender {
        label: "lexi-ladder",
        ladder: QualityLadder::for_model(&m, &table, &cfg, &pm)?,
        adaptive: true,
    };
    let (scenario, trace) =
        server::scenario_and_trace(&contender.ladder.points()[0].service, &cfg)?;
    let runs = server::sim_runs(&m, std::slice::from_ref(&contender), &scenario, &trace, &cfg);
    let res = &runs[0].1;
    let log = res.trace.as_ref().context("traced run returned no span log")?;

    let mut fig = FigureOutput::new(
        &format!("fig_timeline_{}_{}", m.name, scenario.name),
        &["request", "class", "replica", "segment", "start_s", "end_s"],
    );
    for cp in log.critical_paths(&res.completed) {
        let segments = [
            ("queue", cp.arrival_s, cp.arrival_s + cp.queue_s),
            (
                "prefill",
                cp.arrival_s + cp.queue_s,
                cp.arrival_s + cp.ttft_s,
            ),
            ("decode", cp.arrival_s + cp.ttft_s, cp.arrival_s + cp.e2e_s),
        ];
        for (segment, start_s, end_s) in segments {
            fig.row(vec![
                cp.id.to_string(),
                cp.class.to_string(),
                cp.replica.to_string(),
                segment.to_string(),
                f(start_s),
                f(end_s),
            ]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_renders_contiguous_segments() {
        let dir = std::env::temp_dir().join("lexi_fig_timeline_test");
        let _ = std::fs::remove_dir_all(&dir);
        let fig = run(&dir).unwrap();
        assert!(!fig.rows.is_empty());
        assert_eq!(fig.rows.len() % 3, 0, "three segments per request");
        for req in fig.rows.chunks(3) {
            assert_eq!(req[0][3], "queue");
            assert_eq!(req[1][3], "prefill");
            assert_eq!(req[2][3], "decode");
            // identical f64 expressions format identically: the three
            // segments tile [arrival, finish] with no gaps
            assert_eq!(req[0][5], req[1][4], "queue..prefill contiguous");
            assert_eq!(req[1][5], req[2][4], "prefill..decode contiguous");
        }
        assert!(dir
            .join("fig_timeline_minicpm-moe-8x2b_poisson.csv")
            .exists());
    }
}
