//! SLO burn-rate timeline figure (`lexi figures --exp health`): one
//! small flash-crowd sim run under `--health --pressure burn`, rendered
//! as the worst-class fast-window burn rate over virtual time with the
//! raised health events overlaid as point markers.
//!
//! The series comes straight from [`crate::obs::HealthReport`]'s
//! `burn_series` (sampled each engine observation), so the figure shows
//! exactly what the ladder/shedder saw when `--pressure burn` degraded
//! quality ahead of the hard admission cap.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::model::spec;
use crate::config::server::{PressureMode, ScenarioKind, ServerConfig};
use crate::perfmodel::PerfModel;
use crate::server::{self, Contender, QualityLadder};

use super::series::{f, FigureOutput};

/// Run a small deterministic flash-crowd sim with the health engine on
/// and emit the burn-rate timeline rows.
pub fn run(out_dir: &Path) -> Result<FigureOutput> {
    let m = spec("minicpm-moe-8x2b")?;
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 48,
        scenario: ScenarioKind::FlashCrowd,
        service_in_len: 256,
        service_out_len: 32,
        pressure: PressureMode::Burn,
        health: true,
        ..Default::default()
    };
    let table = server::sensitivity_table(&m, None, cfg.seed);
    let pm = PerfModel::new(m.clone(), cfg.seed);
    let contender = Contender {
        label: "lexi-ladder",
        ladder: QualityLadder::for_model(&m, &table, &cfg, &pm)?,
        adaptive: true,
    };
    let (scenario, trace) =
        server::scenario_and_trace(&contender.ladder.points()[0].service, &cfg)?;
    let runs = server::sim_runs(&m, std::slice::from_ref(&contender), &scenario, &trace, &cfg);
    let res = &runs[0].1;
    let health = res
        .health
        .as_ref()
        .context("health-enabled run returned no health outcome")?;

    let mut fig = FigureOutput::new(
        &format!("fig_health_{}_{}", m.name, scenario.name),
        &["kind", "t_s", "burn", "label"],
    );
    for &(t_s, burn) in &health.report.burn_series {
        fig.row(vec![
            "burn".to_string(),
            f(t_s),
            f(burn),
            String::new(),
        ]);
    }
    for ev in &health.events {
        fig.row(vec![
            "event".to_string(),
            f(ev.t_s),
            f(health.report.peak_fast_burn),
            ev.event.label().to_string(),
        ]);
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_figure_renders_burn_series() {
        let dir = std::env::temp_dir().join("lexi_fig_health_test");
        let _ = std::fs::remove_dir_all(&dir);
        let fig = run(&dir).unwrap();
        let burns = fig.rows.iter().filter(|r| r[0] == "burn").count();
        assert!(burns > 0, "burn series must be non-empty");
        // burn samples are on non-decreasing virtual time
        let ts: Vec<f64> = fig
            .rows
            .iter()
            .filter(|r| r[0] == "burn")
            .map(|r| r[1].parse().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        assert!(dir
            .join("fig_health_minicpm-moe-8x2b_flash-crowd.csv")
            .exists());
    }
}
