//! Regeneration harness for every table and figure in the paper
//! (experiment index: DESIGN.md §6).

pub mod ablation;
pub mod accuracy_throughput;
pub mod cross_validation;
pub mod elasticity;
pub mod fig2;
pub mod fig3;
pub mod health;
pub mod memory;
pub mod pareto;
pub mod quality_surface;
pub mod series;
pub mod table1;
pub mod timeline;

pub use series::FigureOutput;
