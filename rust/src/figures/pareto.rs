//! Pareto analysis of the accuracy-vs-throughput results — the paper's
//! headline reading of Figs. 4-8 ("LExI Pareto-dominates pruning").
//!
//! Consumes [`super::accuracy_throughput::ConfigResult`]s and reports,
//! per model and metric: which configurations are on the Pareto front,
//! and whether every pruning point is dominated by some LExI point
//! (higher-or-equal accuracy AND higher-or-equal throughput, one strict).

use std::path::Path;

use anyhow::Result;

use super::accuracy_throughput::ConfigResult;
use super::series::FigureOutput;

/// One (label, throughput, accuracy-like score) point; higher is better
/// on both axes (perplexity callers should negate).
#[derive(Clone, Debug)]
pub struct Point {
    pub label: String,
    pub tput: f64,
    pub score: f64,
}

pub fn dominates(a: &Point, b: &Point) -> bool {
    (a.tput >= b.tput && a.score >= b.score) && (a.tput > b.tput || a.score > b.score)
}

/// Indices of the Pareto-optimal points.
pub fn pareto_front(points: &[Point]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| !points.iter().any(|p| dominates(p, &points[i])))
        .collect()
}

/// Verdict for one (model, metric): is every pruning point dominated by
/// some LExI point (or the baseline)?
#[derive(Clone, Debug)]
pub struct Verdict {
    pub model: String,
    pub metric: String,
    pub lexi_on_front: usize,
    pub pruning_on_front: usize,
    pub pruning_points_dominated_by_lexi: usize,
    pub pruning_points_total: usize,
}

pub fn analyze(model: &str, metric: &str, points: &[Point]) -> Verdict {
    let front = pareto_front(points);
    let is_lexi = |l: &str| l.starts_with("lexi");
    let is_prune = |l: &str| l.starts_with("inter") || l.starts_with("intra");
    let lexi_pts: Vec<&Point> = points.iter().filter(|p| is_lexi(&p.label)).collect();
    let prune_idx: Vec<usize> = (0..points.len())
        .filter(|&i| is_prune(&points[i].label))
        .collect();
    let dominated = prune_idx
        .iter()
        .filter(|&&i| lexi_pts.iter().any(|l| dominates(l, &points[i])))
        .count();
    Verdict {
        model: model.to_string(),
        metric: metric.to_string(),
        lexi_on_front: front.iter().filter(|&&i| is_lexi(&points[i].label)).count(),
        pruning_on_front: front
            .iter()
            .filter(|&&i| is_prune(&points[i].label))
            .count(),
        pruning_points_dominated_by_lexi: dominated,
        pruning_points_total: prune_idx.len(),
    }
}

/// Extract metric points from evaluated configs.
pub fn points_for_metric(results: &[ConfigResult], model: &str, metric: &str) -> Vec<Point> {
    results
        .iter()
        .filter(|r| r.model == model)
        .map(|r| Point {
            label: r.label.clone(),
            tput: r.throughput_tok_s,
            score: match metric {
                "lmeval" => r.scores.lmeval_avg,
                "longqa" => r.scores.longqa_f1,
                "passkey" => r.scores.passkey_acc,
                "vlm" => r.scores.vlm_avg,
                // mean negative ppl across corpora (higher = better)
                "ppl" => {
                    -r.scores.perplexity.iter().map(|(_, p)| p).sum::<f64>()
                        / r.scores.perplexity.len().max(1) as f64
                }
                _ => f64::NAN,
            },
        })
        .collect()
}

/// Emit the Pareto summary for a full Figs. 4-8 run.
pub fn summarize(
    out_dir: &Path,
    llm_results: &[ConfigResult],
    vlm_results: &[ConfigResult],
) -> Result<Vec<Verdict>> {
    let mut fig = FigureOutput::new(
        "pareto_summary",
        &[
            "model",
            "metric",
            "lexi_on_front",
            "pruning_on_front",
            "pruning_dominated_by_lexi",
            "pruning_total",
        ],
    );
    let mut verdicts = Vec::new();
    let mut models: Vec<String> = llm_results.iter().map(|r| r.model.clone()).collect();
    models.dedup();
    for model in &models {
        for metric in ["lmeval", "longqa", "passkey", "ppl"] {
            let pts = points_for_metric(llm_results, model, metric);
            if pts.iter().all(|p| p.score == 0.0) {
                continue; // metric not collected in this run
            }
            let v = analyze(model, metric, &pts);
            fig.row(vec![
                v.model.clone(),
                v.metric.clone(),
                v.lexi_on_front.to_string(),
                v.pruning_on_front.to_string(),
                v.pruning_points_dominated_by_lexi.to_string(),
                v.pruning_points_total.to_string(),
            ]);
            verdicts.push(v);
        }
    }
    let mut vlm_models: Vec<String> = vlm_results.iter().map(|r| r.model.clone()).collect();
    vlm_models.dedup();
    for model in &vlm_models {
        let pts = points_for_metric(vlm_results, model, "vlm");
        if !pts.is_empty() {
            let v = analyze(model, "vlm", &pts);
            fig.row(vec![
                v.model.clone(),
                v.metric.clone(),
                v.lexi_on_front.to_string(),
                v.pruning_on_front.to_string(),
                v.pruning_points_dominated_by_lexi.to_string(),
                v.pruning_points_total.to_string(),
            ]);
            verdicts.push(v);
        }
    }
    fig.emit(out_dir)?;
    Ok(verdicts)
}

/// Convenience used by EXPERIMENTS.md: fraction of pruning points that
/// some LExI point dominates, across all verdicts.
pub fn domination_rate(verdicts: &[Verdict]) -> f64 {
    let (dom, tot) = verdicts.iter().fold((0usize, 0usize), |(d, t), v| {
        (
            d + v.pruning_points_dominated_by_lexi,
            t + v.pruning_points_total,
        )
    });
    dom as f64 / tot.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, tput: f64, score: f64) -> Point {
        Point {
            label: label.into(),
            tput,
            score,
        }
    }

    #[test]
    fn front_excludes_dominated() {
        let pts = vec![
            pt("base", 100.0, 0.9),
            pt("inter50.0", 130.0, 0.5), // fast but inaccurate: on the front
            pt("lexi-B8", 120.0, 0.85),
            pt("intra25.0", 105.0, 0.6), // dominated by lexi
        ];
        let front = pareto_front(&pts);
        assert!(front.contains(&0) && front.contains(&1) && front.contains(&2));
        assert!(!front.contains(&3));
        let v = analyze("m", "x", &pts);
        assert_eq!(v.pruning_points_total, 2);
        assert_eq!(v.pruning_points_dominated_by_lexi, 1); // intra only
        assert_eq!(v.lexi_on_front, 1);
        assert_eq!(v.pruning_on_front, 1);
    }

    #[test]
    fn dominates_requires_strictness() {
        let a = pt("a", 1.0, 1.0);
        assert!(!dominates(&a, &a));
        assert!(dominates(&pt("b", 1.0, 1.1), &a));
        assert!(!dominates(&pt("c", 0.9, 1.1), &a));
    }
}
