//! Figures 3 & 9: per-layer top-k sensitivity heatmaps (Alg. 1 output on
//! the trained analogues, normalized per layer as in the paper's plots).

use std::path::Path;

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::lexi::pipeline::{stage1, table_path};
use crate::lexi::SensitivityTable;
use crate::runtime::{Manifest, ModelRuntime, Runtime};

use super::series::{f, FigureOutput};

/// Fig. 3's four models; Fig. 9 (appendix) adds the remaining two.
pub const FIG3_MODELS: [&str; 4] = [
    "mixtral-8x7b",
    "qwen1.5-moe-a2.7b",
    "olmoe-1b-7b",
    "deepseek-vl2-tiny",
];
pub const FIG9_MODELS: [&str; 2] = ["minicpm-moe-8x2b", "deepseek-v2-lite"];

pub fn heatmap_rows(table: &SensitivityTable) -> Vec<(usize, u32, f64, f64)> {
    let norm = table.normalized();
    let mut rows = Vec::new();
    for (layer, (raw_row, norm_row)) in table.loss.iter().zip(&norm).enumerate() {
        for k in 1..=table.k_base {
            rows.push((
                layer,
                k,
                raw_row[(k - 1) as usize],
                norm_row[(k - 1) as usize],
            ));
        }
    }
    rows
}

pub fn run(
    out_dir: &Path,
    rt: &Runtime,
    manifest: &Manifest,
    models: &[&str],
    cfg: &ExperimentConfig,
    name: &str,
) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(name, &["model", "layer", "k", "delta", "delta_norm"]);
    for model_name in models {
        eprintln!("[{name}] profiling {model_name}...");
        let model = ModelRuntime::load(rt, manifest, model_name)?;
        let cache = table_path(&manifest.root, model_name);
        let table = stage1(&model, cfg, Some(&cache), false)?;
        for (layer, k, raw, norm) in heatmap_rows(&table) {
            fig.row(vec![
                model_name.to_string(),
                layer.to_string(),
                k.to_string(),
                f(raw),
                f(norm),
            ]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}
