//! Quality-surface figure (`lexi figures --exp quality-surface`): the
//! 2-D lattice priced analytically — one row per (k, s) point with its
//! modeled decode step time, capacity, proxy quality loss, Pareto
//! frontier membership, and how many pure-k rungs it dominates.
//!
//! The rows come straight from [`crate::server::bench_quality_surface`],
//! so the figure shows exactly what the `quality_surface_*.csv`
//! artifact reports. Both axis kinds are rendered: intra on a top-8
//! model, skip on a top-2 model (dynamic skipping needs top-2 routing).

use std::path::Path;

use anyhow::Result;

use crate::config::model::spec;
use crate::config::server::{LadderAxes, ServerConfig};
use crate::server;

use super::series::{f, FigureOutput};

/// One small deterministic surface sweep per axis kind.
pub fn run(out_dir: &Path) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(
        "fig_quality_surface",
        &[
            "model",
            "axes",
            "point",
            "k",
            "s",
            "mean_active_experts",
            "step_time_ms",
            "capacity_rps",
            "quality_loss",
            "on_frontier",
            "pure_k_dominated",
        ],
    );
    for (model, axes) in [
        ("olmoe-1b-7b", LadderAxes::KIntra),
        ("mixtral-8x7b", LadderAxes::KSkip),
    ] {
        let m = spec(model)?;
        let cfg = ServerConfig {
            ladder_axes: axes,
            ..Default::default()
        };
        let rows = server::bench_quality_surface(&m, &cfg, None, out_dir)?;
        for r in &rows {
            fig.row(vec![
                r.model.clone(),
                r.axes.clone(),
                r.label.clone(),
                r.k.to_string(),
                r.s.to_string(),
                f(r.mean_active_experts),
                f(r.step_time_s * 1e3),
                f(r.capacity_rps),
                if r.quality_loss.is_finite() {
                    f(r.quality_loss)
                } else {
                    String::new()
                },
                (r.on_frontier as u8).to_string(),
                r.pure_k_dominated.to_string(),
            ]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quality_surface_figure_covers_both_axis_kinds() {
        let dir = std::env::temp_dir().join("lexi_fig_quality_surface_test");
        let _ = std::fs::remove_dir_all(&dir);
        let fig = run(&dir).unwrap();
        assert!(fig.rows.iter().any(|r| r[1] == "k-intra"));
        assert!(fig.rows.iter().any(|r| r[1] == "k-skip"));
        // every sweep has at least one frontier point, and the full
        // lattice is bigger than either 1-D ladder
        assert!(fig.rows.iter().any(|r| r[9] == "1"));
        assert!(fig.rows.iter().filter(|r| r[0] == "olmoe-1b-7b").count() > 4);
        assert!(dir.join("fig_quality_surface.csv").exists());
        assert!(dir
            .join("quality_surface_olmoe-1b-7b_k_intra.csv")
            .exists());
        assert!(dir
            .join("quality_surface_mixtral-8x7b_k_skip.json")
            .exists());
    }
}
