//! Bench-memory curves: HBM budget × eviction-policy hit-rate/goodput
//! figure regenerated from `bench_memory_*.json` sweep artifacts (the
//! ROADMAP's outstanding residency figure).
//!
//! Every `bench_memory_<model>_<scenario>.json` in the output directory
//! becomes one `fig_<stem>_curves.csv`: rows sorted (policy, budget) so
//! each policy's budget curve is contiguous — hit rate, stall tail,
//! goodput, throughput, and the perf-model cross-check side by side.
//! When no sweep artifact exists yet, a small deterministic default
//! sweep is run first so `lexi figures --exp memory` always renders.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::model::spec;
use crate::config::server::{EvictKind, ScenarioKind, ServerConfig};
use crate::server;

use super::series::{f, FigureOutput};

/// Regenerate the curves for every bench-memory sweep in `out_dir`,
/// running a small default sweep first when none exists.
pub fn run(out_dir: &Path) -> Result<Vec<FigureOutput>> {
    let mut files = sweep_files(out_dir)?;
    if files.is_empty() {
        let m = spec("minicpm-moe-8x2b")?;
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 32,
            scenario: ScenarioKind::Bursty,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        server::bench_memory(&m, &cfg, &[0.3, 0.5, 0.8], &EvictKind::all(), None, out_dir)?;
        files = sweep_files(out_dir)?;
        anyhow::ensure!(!files.is_empty(), "default bench-memory sweep wrote no JSON");
    }
    let mut figs = Vec::new();
    for path in files {
        figs.push(curves_from_json(&path, out_dir)?);
    }
    Ok(figs)
}

/// `bench_memory_*.json` artifacts in `dir`, sorted by name.
fn sweep_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    if dir.exists() {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with("bench_memory_") && name.ends_with(".json") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// One sweep artifact -> one emitted figure.
fn curves_from_json(path: &Path, out_dir: &Path) -> Result<FigureOutput> {
    let json = crate::util::json::parse_file(path)?;
    let rows = json
        .as_arr()
        .with_context(|| format!("{} is not a bench-memory array", path.display()))?;
    struct Row {
        policy: String,
        prefetch: f64,
        budget: f64,
        hit_rate: f64,
        stall_p95_s: f64,
        goodput: f64,
        tok_s: f64,
        pm_tok_s: f64,
    }
    let mut parsed = Vec::new();
    for r in rows {
        parsed.push(Row {
            policy: r.get("policy")?.as_str()?.to_string(),
            prefetch: r.get("prefetch")?.as_f64()?,
            budget: r.get("budget_frac")?.as_f64()?,
            hit_rate: r.get("hit_rate")?.as_f64()?,
            stall_p95_s: r.get("stall_p95_s")?.as_f64()?,
            goodput: r.get("goodput_rps")?.as_f64()?,
            tok_s: r.get("throughput_tok_s")?.as_f64()?,
            pm_tok_s: r.get("pm_tok_s")?.as_f64()?,
        });
    }
    // curve order: one contiguous budget sweep per policy
    parsed.sort_by(|a, b| {
        a.policy
            .cmp(&b.policy)
            .then(a.budget.partial_cmp(&b.budget).unwrap())
    });
    let stem = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("bench_memory");
    let mut fig = FigureOutput::new(
        &format!("fig_{stem}_curves"),
        &[
            "policy",
            "prefetch",
            "budget_frac",
            "hit_rate",
            "stall_p95_ms",
            "goodput_rps",
            "throughput_tok_s",
            "pm_tok_s",
        ],
    );
    for r in &parsed {
        fig.row(vec![
            r.policy.clone(),
            (if r.prefetch > 0.0 { "on" } else { "off" }).to_string(),
            f(r.budget),
            f(r.hit_rate),
            f(r.stall_p95_s * 1e3),
            f(r.goodput),
            f(r.tok_s),
            f(r.pm_tok_s),
        ]);
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regenerates_default_sweep_and_emits_curves() {
        let dir = std::env::temp_dir().join("lexi_fig_memory_test");
        let _ = std::fs::remove_dir_all(&dir);
        let figs = run(&dir).unwrap();
        assert_eq!(figs.len(), 1);
        // 3 budgets x 3 policies, policy-major curve order
        assert_eq!(figs[0].rows.len(), 9);
        let policies: Vec<&str> = figs[0].rows.iter().map(|r| r[0].as_str()).collect();
        let mut sorted = policies.clone();
        sorted.sort();
        assert_eq!(policies, sorted, "rows must be policy-major for curves");
        assert!(dir
            .join("fig_bench_memory_minicpm-moe-8x2b_bursty_curves.csv")
            .exists());

        // second invocation reuses the existing sweep artifact
        let again = run(&dir).unwrap();
        assert_eq!(again[0].rows.len(), 9);
    }
}
