//! Elastic-control-plane figure (`lexi figures --exp elasticity`): one
//! small deterministic `bench_elasticity` sweep rendered as grouped
//! bars — goodput and provisioned replica-seconds per provisioning cell
//! (fixed-min / fixed-max / autoscale / autoscale+shed), plus the
//! heterogeneous tier mix's interactive p95 TTFT per routing policy.
//!
//! The rows come straight from [`crate::server::bench_elasticity`], so
//! the figure shows exactly what the `bench_elasticity_*.csv` artifact
//! reports.

use std::path::Path;

use anyhow::Result;

use crate::config::model::spec;
use crate::config::server::{ScenarioKind, ServerConfig};
use crate::server;

use super::series::{f, FigureOutput};

/// Run a small deterministic elasticity sweep and emit one row per cell.
pub fn run(out_dir: &Path) -> Result<FigureOutput> {
    let m = spec("minicpm-moe-8x2b")?;
    let cfg = ServerConfig {
        replicas: 2,
        slots_per_replica: 4,
        n_requests: 48,
        scenario: ScenarioKind::Diurnal,
        service_in_len: 256,
        service_out_len: 32,
        ..Default::default()
    };
    let rows = server::bench_elasticity(&m, &cfg, None, out_dir)?;
    let scenario = rows
        .first()
        .map(|r| r.scenario.clone())
        .unwrap_or_else(|| "diurnal".to_string());
    let mut fig = FigureOutput::new(
        &format!("fig_elasticity_{}_{scenario}", m.name),
        &[
            "family",
            "cell",
            "policy",
            "replicas",
            "goodput_rps",
            "interactive_ttft_p95_ms",
            "replica_seconds",
            "shed",
            "scale_ups",
            "drains",
        ],
    );
    for r in &rows {
        fig.row(vec![
            r.family.to_string(),
            r.cell.clone(),
            r.policy.clone(),
            r.replicas.to_string(),
            f(r.goodput_rps),
            f(r.interactive_ttft_p95_s * 1e3),
            f(r.replica_seconds),
            r.shed.to_string(),
            r.scale_ups.to_string(),
            r.drains.to_string(),
        ]);
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elasticity_figure_covers_both_families() {
        let dir = std::env::temp_dir().join("lexi_fig_elasticity_test");
        let _ = std::fs::remove_dir_all(&dir);
        let fig = run(&dir).unwrap();
        // 4 provisioning cells + uniform reference + 3 tier-mix policies
        assert_eq!(fig.rows.len(), 8);
        assert_eq!(fig.rows.iter().filter(|r| r[0] == "elastic").count(), 4);
        assert_eq!(fig.rows.iter().filter(|r| r[0] == "hetero").count(), 4);
        assert!(fig.rows.iter().any(|r| r[2] == "classaware"));
        assert!(fig.rows.iter().any(|r| r[1].contains("autoscale")));
        assert!(dir
            .join("fig_elasticity_minicpm-moe-8x2b_diurnal.csv")
            .exists());
        // the sweep artifact lands next to the figure
        assert!(dir
            .join("bench_elasticity_minicpm-moe-8x2b_diurnal.csv")
            .exists());
    }
}
