//! Figure 2: Throughput vs. active experts under inter and intra expert
//! pruning — the motivating experiment showing pruning does not buy
//! throughput while reducing top-k does.
//!
//! Series: for each of the six models, for each pruning configuration
//! {baseline, inter/intra at 12.5/25/50 %}, sweep top-k in 1..=k_base and
//! report modeled H100 throughput (paper setup: batch 16, tensor
//! parallelism, in/out lengths per §3).

use std::path::Path;

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::config::model::{registry, ModelSpec};
use crate::moe::allocation::Allocation;
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;

use super::series::{f, FigureOutput};

/// One model's sweep: (transform label, k, tok/s).
pub fn sweep_model(
    spec: &ModelSpec,
    cfg: &ExperimentConfig,
) -> Result<Vec<(String, u32, f64)>> {
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let mut out = Vec::new();
    let mut transforms: Vec<Transform> = vec![Transform::Baseline];
    for &frac in &cfg.prune_fracs {
        transforms.push(Transform::InterPrune { frac });
        transforms.push(Transform::IntraPrune { frac });
    }
    for t in &transforms {
        for k in 1..=spec.top_k as u32 {
            // pruning transforms keep their own expert/ffn geometry; the
            // k sweep is applied on top via a uniform allocation
            let combined = match t {
                Transform::Baseline => Transform::Lexi {
                    allocation: Allocation::uniform(spec.n_layers, k),
                },
                other => other.clone(),
            };
            let b = match t {
                Transform::Baseline => {
                    pm.throughput(&combined, cfg.paper_batch, cfg.paper_in_len, cfg.paper_out_len)
                }
                // sweep k for pruned variants through a k-clamped view
                _ => {
                    let mut pb = pm.throughput(
                        &combined,
                        cfg.paper_batch,
                        cfg.paper_in_len,
                        cfg.paper_out_len,
                    );
                    if (k as usize) < spec.top_k {
                        // re-evaluate with reduced k under the same pruning
                        let alloc = Allocation::uniform(spec.n_layers, k);
                        pb = pm.throughput_with_k(
                            t,
                            &alloc,
                            cfg.paper_batch,
                            cfg.paper_in_len,
                            cfg.paper_out_len,
                        );
                    }
                    pb
                }
            };
            out.push((t.label(), k, b.throughput_tok_s));
        }
    }
    Ok(out)
}

pub fn run(out_dir: &Path, cfg: &ExperimentConfig) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new("fig2_pruning_throughput", &["model", "transform", "k", "tok_s"]);
    for spec in registry() {
        for (label, k, tput) in sweep_model(&spec, cfg)? {
            fig.row(vec![spec.name.to_string(), label, k.to_string(), f(tput)]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

/// Shape assertions mirroring the paper's reading of Fig. 2 (used by the
/// integration tests):
///  * reducing top-k raises throughput for every model;
///  * pruning's gain is far below proportional (50% of the weights gone
///    buys < 1.6x) — load imbalance and unchanged per-token top-k;
///  * for the high-expert-count models, the top-k lever dominates the
///    pruning lever (the paper's low-k models, Mixtral/MiniCPM, only
///    show "marginal gains", which the paper itself notes).
pub fn check_shape(rows: &[(String, u32, f64)], k_base: u32, n_experts: usize) -> Result<()> {
    let get = |label: &str, k: u32| -> Option<f64> {
        rows.iter()
            .find(|(l, kk, _)| l == label && *kk == k)
            .map(|&(_, _, t)| t)
    };
    let base = get("base", k_base).unwrap();
    let k1 = get("base", 1).unwrap();
    anyhow::ensure!(k1 > base, "k=1 must beat k_base ({k1} vs {base})");
    if let Some(inter50) = get("inter50.0", k_base) {
        let prune_gain = inter50 / base;
        anyhow::ensure!(
            prune_gain < 1.6,
            "50% inter-pruning bought {prune_gain:.2}x — far above the paper's regime"
        );
        if n_experts >= 32 {
            let k_gain = k1 / base;
            anyhow::ensure!(
                k_gain > prune_gain,
                "top-k lever ({k_gain:.2}x) must dominate pruning ({prune_gain:.2}x) \
                 for high-E models"
            );
        }
    }
    Ok(())
}
