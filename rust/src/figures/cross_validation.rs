//! Sim-vs-engine cross-validation divergence figure: one row per
//! (contender, metric, percentile) with the engine measurement, both sim
//! variants, and their relative divergence — the data behind the
//! "calibration closes the gap" plot the CI gate uploads.

use super::series::{f, FigureOutput};
use crate::calibrate::{CrossValidation, PERCENTILES};

/// Build the divergence figure (`fig_cross_validation_<model>_<scenario>`).
pub fn divergence_figure(cv: &CrossValidation) -> FigureOutput {
    let mut fig = FigureOutput::new(
        &format!("fig_cross_validation_{}_{}", cv.model, cv.scenario),
        &[
            "contender",
            "metric",
            "percentile",
            "engine_s",
            "sim_raw_s",
            "sim_cal_s",
            "raw_rel_div",
            "cal_rel_div",
        ],
    );
    for c in &cv.contenders {
        for (metric, eng, raw, cal, draw, dcal) in [
            (
                "ttft",
                &c.engine.ttft_s,
                &c.sim_raw.ttft_s,
                &c.sim_calibrated.ttft_s,
                &c.raw.ttft,
                &c.calibrated.ttft,
            ),
            (
                "tpot",
                &c.engine.tpot_s,
                &c.sim_raw.tpot_s,
                &c.sim_calibrated.tpot_s,
                &c.raw.tpot,
                &c.calibrated.tpot,
            ),
        ] {
            for (i, p) in PERCENTILES.iter().enumerate() {
                fig.row(vec![
                    c.label.clone(),
                    metric.to_string(),
                    format!("p{}", *p as u32),
                    f(eng[i]),
                    f(raw[i]),
                    f(cal[i]),
                    f(draw[i]),
                    f(dcal[i]),
                ]);
            }
        }
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::{BackendSummary, ContenderValidation, Divergence};

    fn summary(scale: f64) -> BackendSummary {
        BackendSummary {
            n_completed: 4,
            served_tokens: 64,
            goodput_rps: 1.0,
            throughput_tok_s: 100.0,
            makespan_s: 10.0,
            ttft_s: [0.1 * scale, 0.2 * scale, 0.3 * scale],
            tpot_s: [0.01 * scale, 0.02 * scale, 0.03 * scale],
        }
    }

    #[test]
    fn figure_has_one_row_per_contender_metric_percentile() {
        let eng = summary(1.0);
        let raw = summary(2.0);
        let cal = summary(1.1);
        let cv = CrossValidation {
            model: "m".into(),
            scenario: "poisson".into(),
            seed: 0,
            tolerance: 0.5,
            gate_p99: false,
            calibrated_rungs: vec![0],
            contenders: vec![ContenderValidation {
                label: "baseline".into(),
                raw: Divergence::between(&raw, &eng),
                calibrated: Divergence::between(&cal, &eng),
                engine: eng,
                sim_raw: raw,
                sim_calibrated: cal,
                token_parity: true,
            }],
            pass: true,
        };
        let fig = divergence_figure(&cv);
        assert_eq!(fig.rows.len(), 6); // 1 contender x 2 metrics x 3 percentiles
        assert_eq!(fig.header.len(), 8);
        assert!(fig.name.contains("cross_validation_m_poisson"));
        // raw divergence column reads ~100% for the 2x-off sim
        assert!(fig.rows[0][6].starts_with('1'));
    }
}
