//! Ablation studies beyond the paper's main figures:
//!
//! 1. **Allocation quality** — LExI's GA vs the exact DP optimum vs
//!    uniform vs random feasible allocations at the same budget
//!    (validates that Stage 2's search quality is not the bottleneck).
//! 2. **Limitations table** — expert-weight memory per transform: LExI
//!    keeps the full footprint (the paper's stated limitation), pruning
//!    shrinks it, and the combined transform gets both levers.
//! 3. **Dynamic-skip comparison** — NAEE's token-adaptive skipping vs
//!    LExI static allocations on the top-2 models.

use std::path::Path;

use anyhow::Result;

use crate::config::experiment::ExperimentConfig;
use crate::config::model::{registry, spec};
use crate::lexi::evolution::{evolve, exact_dp, EvolutionParams};
use crate::lexi::SensitivityTable;
use crate::moe::allocation::{Allocation, Bounds};
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;
use crate::util::Pcg32;

use super::series::{f, FigureOutput};

/// Allocation-quality ablation over a sensitivity table (measured or
/// synthetic). Emits fitness of GA / DP / uniform / random per budget.
pub fn allocation_quality(
    out_dir: &Path,
    table: &SensitivityTable,
    cfg: &ExperimentConfig,
) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(
        &format!("ablation_allocation_quality_{}", table.model),
        &["budget", "method", "fitness", "evals"],
    );
    let bounds = Bounds::paper(table.k_base);
    let l = table.n_layers() as u32;
    let full = l * table.k_base;
    let mut rng = Pcg32::seeded(cfg.seed ^ 0xab1a);
    for fracs in [0.5, 0.65, 0.8] {
        let budget = ((full as f64 * fracs) as u32).max(l);
        let params = EvolutionParams {
            population: cfg.ga_population,
            generations: cfg.ga_generations,
            mutation_rate: cfg.ga_mutation,
            tournament: 4,
            seed: cfg.seed,
        };
        if let Some(ga) = evolve(table, budget, bounds, &params) {
            fig.row(vec![
                budget.to_string(),
                "lexi-ga".into(),
                f(ga.best_fitness),
                ga.evaluations.to_string(),
            ]);
        }
        if let Some(dp) = exact_dp(table, budget, bounds) {
            fig.row(vec![
                budget.to_string(),
                "exact-dp".into(),
                f(table.fitness(&dp.k)),
                "-".into(),
            ]);
        }
        // uniform at the nearest feasible per-layer k
        let uni_k = (budget as f64 / l as f64).floor() as u32;
        if uni_k >= 1 {
            let mut uni = Allocation::uniform(l as usize, uni_k);
            uni.project(bounds, budget, &mut rng);
            fig.row(vec![
                budget.to_string(),
                "uniform".into(),
                f(table.fitness(&uni.k)),
                "1".into(),
            ]);
        }
        // mean of random feasible allocations
        let mut sum = 0.0;
        let n_rand = 32;
        for _ in 0..n_rand {
            let r = Allocation::random_feasible(l as usize, bounds, budget, &mut rng).unwrap();
            sum += table.fitness(&r.k);
        }
        fig.row(vec![
            budget.to_string(),
            "random-mean".into(),
            f(sum / n_rand as f64),
            n_rand.to_string(),
        ]);
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

/// Limitations table: memory footprint + throughput per transform
/// (paper §6: LExI optimizes compute, not memory; combination fixes it).
pub fn limitations_memory(out_dir: &Path, cfg: &ExperimentConfig) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(
        "ablation_memory_limitations",
        &["model", "transform", "expert_mem_gib", "tok_s", "reduces_memory"],
    );
    for m in registry() {
        let pm = PerfModel::new(m.clone(), cfg.seed);
        let half_k = Allocation::uniform(m.n_layers, ((m.top_k + 1) / 2) as u32);
        let transforms = vec![
            Transform::Baseline,
            Transform::InterPrune { frac: 0.5 },
            Transform::IntraPrune { frac: 0.5 },
            Transform::Lexi {
                allocation: half_k.clone(),
            },
            Transform::LexiPlusInter {
                allocation: half_k,
                frac: 0.5,
            },
        ];
        for t in transforms {
            let b = pm.throughput(&t, cfg.paper_batch, cfg.paper_in_len, cfg.paper_out_len);
            fig.row(vec![
                m.name.to_string(),
                t.label(),
                f(t.expert_memory_gib(&m)),
                f(b.throughput_tok_s),
                t.reduces_memory().to_string(),
            ]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

/// Hot-set coverage: per-layer cumulative routing mass of the top-k
/// experts ([`crate::moe::RoutingSim::top_p_mass`] — the same ranking
/// the residency prefetcher and the k_vec-aware pinning use) plus the
/// HBM bytes that hot set costs. Shows why a small expert cache covers
/// most traffic on skewed layers and why uniform layers defeat it.
pub fn hot_set_coverage(out_dir: &Path, cfg: &ExperimentConfig) -> Result<FigureOutput> {
    use crate::moe::arch::ModelGeom;
    use crate::perfmodel::loadbalance::LayerRouting;
    use crate::perfmodel::Hardware;

    let mut fig = FigureOutput::new(
        "ablation_hot_set_coverage",
        &["model", "layer", "k", "top_p_mass", "hot_set_gib"],
    );
    let hw = Hardware::h100();
    for name in ["qwen1.5-moe-a2.7b", "olmoe-1b-7b"] {
        let m = spec(name)?;
        let geom = ModelGeom::paper_scale(&m);
        let shard_gib = geom.layer.expert_weight_bytes(hw.dtype_bytes)
            / m.paper.n_gpus as f64
            / (1u64 << 30) as f64;
        let lr = LayerRouting::synthetic(m.n_layers, m.n_experts, cfg.seed);
        for (j, sim) in lr.sims.iter().enumerate() {
            let mut k = 1usize;
            while k <= m.n_experts {
                fig.row(vec![
                    name.to_string(),
                    j.to_string(),
                    k.to_string(),
                    f(sim.top_p_mass(k)),
                    f(k as f64 * shard_gib),
                ]);
                k *= 2;
            }
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

/// NAEE dynamic skipping vs LExI static allocation on the top-2 models
/// (the paper restricts skipping to k_base = 2).
pub fn dynamic_skip_comparison(out_dir: &Path, cfg: &ExperimentConfig) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(
        "ablation_dynamic_skip",
        &["model", "transform", "expected_k", "tok_s"],
    );
    for name in ["mixtral-8x7b", "minicpm-moe-8x2b"] {
        let m = spec(name)?;
        let pm = PerfModel::new(m.clone(), cfg.seed);
        for thr in [0.2, 0.4, 0.6] {
            let t = Transform::DynamicSkip { threshold: thr };
            let b = pm.throughput(&t, cfg.paper_batch, cfg.paper_in_len, cfg.paper_out_len);
            fig.row(vec![
                name.to_string(),
                t.label(),
                f(t.expected_k(&m, thr * 0.8)),
                f(b.throughput_tok_s),
            ]);
        }
        for k in 1..=2u32 {
            let t = Transform::Lexi {
                allocation: Allocation::uniform(m.n_layers, k),
            };
            let b = pm.throughput(&t, cfg.paper_batch, cfg.paper_in_len, cfg.paper_out_len);
            fig.row(vec![
                name.to_string(),
                t.label(),
                k.to_string(),
                f(b.throughput_tok_s),
            ]);
        }
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_quality_orders_methods() {
        let table = SensitivityTable::synthetic("test", 16, 8, |x| 1.0 + 2.0 * x, 3);
        let out = std::env::temp_dir().join("lexi_ablation_test");
        let cfg = ExperimentConfig::fast();
        let fig = allocation_quality(&out, &table, &cfg).unwrap();
        // for each budget: dp <= ga <= random-mean
        for budget in ["64", "83", "102"] {
            let get = |m: &str| {
                fig.rows
                    .iter()
                    .find(|r| r[0] == budget && r[1] == m)
                    .map(|r| r[2].parse::<f64>().unwrap())
            };
            if let (Some(dp), Some(ga), Some(rnd)) =
                (get("exact-dp"), get("lexi-ga"), get("random-mean"))
            {
                assert!(dp <= ga + 1e-9, "budget {budget}");
                assert!(ga <= rnd + 1e-9, "budget {budget}: ga {ga} rnd {rnd}");
            }
        }
    }

    #[test]
    fn hot_set_coverage_is_monotone_per_layer() {
        let out = std::env::temp_dir().join("lexi_ablation_hotset");
        let cfg = ExperimentConfig::fast();
        let fig = hot_set_coverage(&out, &cfg).unwrap();
        assert!(!fig.rows.is_empty());
        // within one (model, layer), mass grows with k and ends near 1
        let mut prev: Option<(String, String, f64)> = None;
        for r in &fig.rows {
            let mass: f64 = r[3].parse().unwrap();
            assert!((0.0..=1.0 + 1e-9).contains(&mass));
            if let Some((m, l, pm)) = &prev {
                if *m == r[0] && *l == r[1] {
                    assert!(mass >= *pm - 1e-12, "{}/{} not monotone", r[0], r[1]);
                }
            }
            prev = Some((r[0].clone(), r[1].clone(), mass));
        }
    }

    #[test]
    fn limitations_lexi_keeps_memory() {
        let out = std::env::temp_dir().join("lexi_ablation_mem");
        let cfg = ExperimentConfig::fast();
        let fig = limitations_memory(&out, &cfg).unwrap();
        let mixtral_base = fig
            .rows
            .iter()
            .find(|r| r[0] == "mixtral-8x7b" && r[1] == "base")
            .unwrap();
        let mixtral_lexi = fig
            .rows
            .iter()
            .find(|r| r[0] == "mixtral-8x7b" && r[1].starts_with("lexi-B") && !r[1].contains('+'))
            .unwrap();
        assert_eq!(mixtral_base[2], mixtral_lexi[2], "LExI must not change memory");
        let combined = fig
            .rows
            .iter()
            .find(|r| r[0] == "mixtral-8x7b" && r[1].contains('+'))
            .unwrap();
        assert!(combined[2].parse::<f64>().unwrap() < mixtral_base[2].parse::<f64>().unwrap());
    }
}
