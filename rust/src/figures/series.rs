//! Figure output plumbing: every experiment emits a CSV into `results/`
//! plus a human-readable table on stdout (same rows the paper plots).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::csv::CsvWriter;

pub struct FigureOutput {
    pub name: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl FigureOutput {
    pub fn new(name: &str, header: &[&str]) -> Self {
        FigureOutput {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        debug_assert_eq!(fields.len(), self.header.len());
        self.rows.push(fields);
    }

    pub fn csv_path(&self, out_dir: &Path) -> PathBuf {
        out_dir.join(format!("{}.csv", self.name))
    }

    /// Write the CSV and print the table.
    pub fn emit(&self, out_dir: &Path) -> Result<()> {
        let header_refs: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        let mut w = CsvWriter::create(self.csv_path(out_dir), &header_refs)?;
        for r in &self.rows {
            w.row(r)?;
        }
        self.print();
        println!("  -> {}", self.csv_path(out_dir).display());
        Ok(())
    }

    pub fn print(&self) {
        println!("\n### {} ###", self.name);
        let widths: Vec<usize> = self
            .header
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([h.len()])
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let line = |fields: &[String]| {
            fields
                .iter()
                .enumerate()
                .map(|(i, f)| format!("{:>w$}", f, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Shared float formatting for figure rows.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_csv() {
        let mut fig = FigureOutput::new("test_fig", &["a", "b"]);
        fig.row(vec!["x".into(), f(1.23456)]);
        let dir = std::env::temp_dir().join("lexi_fig_test");
        fig.emit(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("test_fig.csv")).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("1.235"));
    }
}
