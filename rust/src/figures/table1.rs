//! Table 1: the MoE model registry (structure + paper-scale dims).

use std::path::Path;

use anyhow::Result;

use crate::config::model::registry;

use super::series::FigureOutput;

pub fn run(out_dir: &Path) -> Result<FigureOutput> {
    let mut fig = FigureOutput::new(
        "table1_models",
        &[
            "model", "params_b", "layers", "experts", "topk", "ffn_dim", "hidden", "gpus",
        ],
    );
    for m in registry() {
        fig.row(vec![
            m.paper_name.to_string(),
            format!("{}", m.paper.params_b),
            m.n_layers.to_string(),
            m.n_experts.to_string(),
            m.top_k.to_string(),
            m.paper.ffn.to_string(),
            m.paper.hidden.to_string(),
            m.paper.n_gpus.to_string(),
        ]);
    }
    fig.emit(out_dir)?;
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_six_models() {
        let dir = std::env::temp_dir().join("lexi_t1_test");
        let fig = run(&dir).unwrap();
        assert_eq!(fig.rows.len(), 6);
        // paper Table 1 row: Mixtral 46.7B, 32 layers, 8 experts, top-2
        let mix = fig
            .rows
            .iter()
            .find(|r| r[0].contains("Mixtral"))
            .unwrap();
        assert_eq!(&mix[1], "46.7");
        assert_eq!(&mix[2], "32");
        assert_eq!(&mix[3], "8");
        assert_eq!(&mix[4], "2");
    }
}
