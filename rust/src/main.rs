//! `lexi` CLI — the Layer-3 coordinator entry point.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   lexi table1                         print + CSV Table 1
//!   lexi profile  --model M             Stage-1 sensitivity profiling
//!   lexi search   --model M --budget B  Stage-2 allocation search
//!   lexi optimize --model M             full LExI pipeline (budget sweep)
//!   lexi eval     --model M [--lexi B|--inter F|--intra F]
//!   lexi serve    --model M [--requests N]
//!   lexi bench-serve [--scenario S] [--replicas N] [--route P]
//!                    [--backend sim|engine] [--table auto|synthetic|measured]
//!                    [--ladder replica|cluster] [--pressure queue|slack|slack-ewma|burn]
//!                    [--steal N] [--steal-cooldown S] [--trace-file F]
//!                    [--hbm-budget F] [--evict lru|lfu|kvec] [--prefetch on|off]
//!                    [--model M] [--requests N]
//!                    multi-replica front-end (sim or real engine replicas)
//!   lexi bench-memory [--budgets F1,F2] [--evict all|lru,lfu,kvec] [--scenario S]
//!                    expert-residency sweep: HBM budgets x eviction policies
//!   lexi bench-elasticity [--scenario S] [--autoscale MIN:MAX]
//!                    [--replica-tiers h100:N,a100:M]
//!                    elastic control plane sweep: fixed vs autoscaled
//!                    provisioning (± shedding), hetero tiers x routing
//!   lexi bench-quality-surface [--ladder-axes k|k-intra|k-skip]
//!                    [--ladder-fracs F1,F2] [--intra-fracs F1,F2]
//!                    [--skip-thresholds T1,T2]
//!                    price every 2-D lattice point: (modeled latency,
//!                    quality loss) frontier + pure-k dominance
//!   lexi calibrate  [--scenario S] [--requests N] [--seed S]
//!                    run the engine backend and fit a sim ServiceModel
//!                    calibration artifact from its step-time telemetry
//!   lexi cross-validate [--calibration F] [--tolerance T] [--gate-p99]
//!                    [--append F]
//!                    replay one seeded trace on engine + raw/calibrated sim,
//!                    gate on TTFT/TPOT percentile divergence (nonzero exit
//!                    beyond tolerance)
//!   lexi trace    --check F [--prom F]   validate observability artifacts
//!   lexi bundle   --check F              validate a flight-recorder debug bundle
//!   lexi figures  --exp fig2|fig3|fig9|figs4-8|table1|memory|timeline|elasticity|
//!                       health|quality-surface|all
//!
//! Global flags: --artifacts DIR (default ./artifacts), --out DIR
//! (default ./results), --iters N, --fast.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lexi_moe::config::experiment::ExperimentConfig;
use lexi_moe::config::model::spec;
use lexi_moe::config::serving::ServingConfig;
use lexi_moe::engine::{Engine, SamplingParams};
use lexi_moe::eval::{EvalSuite, RunConfig};
use lexi_moe::figures;
use lexi_moe::lexi::pipeline::{stage1, stage2, table_path};
use lexi_moe::moe::transform::Transform;
use lexi_moe::runtime::{Manifest, ModelRuntime, Runtime};
use lexi_moe::util::Pcg32;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = match name {
                "fast" | "force" | "verify" | "trace" | "selfprof" | "gate-p99" | "shed"
                | "compare" | "health" => "1".to_string(),
                _ => it.next().with_context(|| format!("--{name} needs a value"))?,
            };
            flags.insert(name.to_string(), val);
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(Args { cmd, flags })
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn model(&self) -> Result<&str> {
        self.get("model").context("--model <name> required")
    }

    fn out_dir(&self) -> PathBuf {
        PathBuf::from(self.get("out").unwrap_or("results"))
    }

    fn artifacts(&self) -> PathBuf {
        self.get("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(Manifest::default_dir)
    }

    fn experiment_cfg(&self) -> ExperimentConfig {
        let mut cfg = if self.get("fast").is_some() {
            ExperimentConfig::fast()
        } else {
            ExperimentConfig::default()
        };
        if let Some(i) = self.get("iters") {
            cfg.sensitivity_iters = i.parse().unwrap_or(cfg.sensitivity_iters);
        }
        if let Some(s) = self.get("seed") {
            cfg.seed = s.parse().unwrap_or(0);
        }
        cfg
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "table1" => {
            figures::table1::run(&args.out_dir())?;
        }
        "profile" => cmd_profile(&args)?,
        "search" => cmd_search(&args)?,
        "optimize" => cmd_optimize(&args)?,
        "eval" => cmd_eval(&args)?,
        "serve" => cmd_serve(&args)?,
        "bench-serve" => cmd_bench_serve(&args)?,
        "bench-scale" => cmd_bench_scale(&args)?,
        "bench-memory" => cmd_bench_memory(&args)?,
        "bench-elasticity" => cmd_bench_elasticity(&args)?,
        "bench-quality-surface" => cmd_bench_quality_surface(&args)?,
        "calibrate" => cmd_calibrate(&args)?,
        "cross-validate" => cmd_cross_validate(&args)?,
        "trace" => cmd_trace(&args)?,
        "bundle" => cmd_bundle(&args)?,
        "figures" => cmd_figures(&args)?,
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "lexi — LExI MoE inference coordinator\n\
         commands: table1 | profile | search | optimize | eval | serve | bench-serve |\n\
                   bench-scale | bench-memory | bench-elasticity |\n\
                   bench-quality-surface | calibrate |\n\
                   cross-validate | trace | bundle | figures\n\
         flags: --model M --budget B --artifacts DIR --out DIR --iters N --fast\n\
         figures: --exp table1|fig2|fig3|fig9|figs4-8|ablations|memory|timeline|\n\
                      elasticity|health|quality-surface|all [--models a,b]\n\
         bench-serve: --scenario poisson|bursty|diurnal|closed-loop|flash-crowd|trace-replay|all\n\
                      --replicas N --slots N --route rr|jsq|p2c|classaware --backend sim|engine\n\
                      --table auto|synthetic|measured --ladder replica|cluster\n\
                      --pressure queue|slack|slack-ewma|burn --steal N (steals/instant, 0=off)\n\
                      --steal-cooldown S (min seconds between steals per replica)\n\
                      --hbm-budget F (expert HBM budget, fraction of footprint)\n\
                      --evict lru|lfu|kvec --prefetch on|off\n\
                      --trace-file F (JSONL log for trace-replay)\n\
                      --calibration F (sim service models refit from the artifact)\n\
                      --shed (class-aware admission shedding; batch drops first)\n\
                      --autoscale MIN:MAX (replica autoscaler bounds, sim backend)\n\
                      --replica-tiers h100:N,a100:M (hardware tiers + speed-weighted\n\
                      routing, sim backend; counts must sum to --replicas)\n\
                      --trace (record spans; emit Perfetto/critical-path/Prometheus\n\
                      artifacts) --trace-ring-cap N --metrics-interval S\n\
                      --health (SLO health engine: windowed burn rates, anomaly\n\
                      detection, debug bundles on critical events)\n\
                      --pressure burn (ladder/shedder degrade on error-budget\n\
                      burn rate; implies the health engine)\n\
                      --selfprof (wall-clock profile of the sim's own hot sections;\n\
                      appends to BENCH_selfprof.json, --selfprof-out F overrides)\n\
                      --ladder-axes k|k-intra|k-skip (2-D quality lattice: active\n\
                      experts x intra-expert sparsity / dynamic-skip aggressiveness;\n\
                      default k keeps the historical 1-D ladder bit-identical)\n\
                      --ladder-fracs F1,F2 (k-axis budget fractions, default .8,.65,.5)\n\
                      --intra-fracs F1,F2 (FFN prune fractions per s level, (0,1))\n\
                      --skip-thresholds T1,T2 (gate-ratio thresholds, (0,1]; top-2 only)\n\
                      --requests N --model M --seed S\n\
         bench-quality-surface: bench-serve lattice flags; prices every lattice\n\
                      point analytically, writes quality_surface_<model>_<axes>.{{csv,json}}\n\
                      with Pareto frontier + pure-k dominance columns\n\
         bench-scale: event-loop scale benchmark on synthetic sim replicas\n\
                      --replicas N (default 1000) --requests N (default 1000000)\n\
                      --scenario S (default diurnal) --slots N --shards N --seed S\n\
                      --compare (also run the rebuild-per-arrival snapshot baseline\n\
                      and report the cluster.snapshot speedup)\n\
                      --selfprof-out F (default BENCH_selfprof.json)\n\
         bench-memory: --budgets F1,F2,.. (fractions) --evict all|lru,lfu,kvec\n\
                      --scenario S --replicas N --slots N --requests N --prefetch on|off\n\
                      --model M --seed S\n\
         bench-elasticity: --scenario S (default diurnal) --autoscale MIN:MAX --shed\n\
                      --replica-tiers h100:N,a100:M --replicas N --slots N\n\
                      --requests N --model M --seed S\n\
         calibrate: --scenario S --replicas N --slots N --requests N --model M --seed S\n\
                      (writes calibration_<model>_<scenario>.json to --out)\n\
         cross-validate: calibrate flags plus --calibration F (reuse a saved artifact)\n\
                      --tolerance T (gated TTFT/TPOT divergence, default 0.5)\n\
                      --gate-p99 (extend the gate to p99) --append F (append one\n\
                      trajectory entry to F, e.g. the repo-root BENCH_serve.json)\n\
         trace: --check F (validate Perfetto trace_event JSON; warns when the\n\
                      ring dropped events) --prom F (validate Prometheus text\n\
                      exposition)\n\
         bundle: --check F (validate a flight-recorder debug_bundle_*.json)"
    );
}

fn load_model(args: &Args) -> Result<(Runtime, Manifest, ModelRuntime)> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(args.artifacts())?;
    let model = ModelRuntime::load(&rt, &manifest, args.model()?)?;
    Ok((rt, manifest, model))
}

fn cmd_profile(args: &Args) -> Result<()> {
    let (_rt, manifest, model) = load_model(args)?;
    let cfg = args.experiment_cfg();
    let cache = table_path(&manifest.root, args.model()?);
    let force = args.get("force").is_some();
    let t0 = std::time::Instant::now();
    let table = if force {
        let t = lexi_moe::lexi::sensitivity::profile_model(
            &model,
            &cfg,
            Some(&|l, n| eprint!("\rlayer {}/{n}", l + 1)),
        )?;
        eprintln!();
        t.save_json(&cache)?;
        t
    } else {
        stage1(&model, &cfg, Some(&cache), false)?
    };
    println!(
        "sensitivity table for {} ({} layers x k<={}, {} iters) in {:.1}s",
        table.model,
        table.n_layers(),
        table.k_base,
        table.iters,
        t0.elapsed().as_secs_f64()
    );
    for (j, row) in table.loss.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:8.3}")).collect();
        println!("layer {j:>2}: {}", cells.join(" "));
    }
    println!("cached at {}", cache.display());
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let (_rt, manifest, model) = load_model(args)?;
    let cfg = args.experiment_cfg();
    let budget: u32 = args
        .get("budget")
        .context("--budget <B> required")?
        .parse()?;
    let cache = table_path(&manifest.root, args.model()?);
    let table = stage1(&model, &cfg, Some(&cache), false)?;
    let res = stage2(&table, budget, &cfg)?;
    println!(
        "best allocation for budget {budget}: {}\nfitness {:.4} after {} evaluations",
        res.best, res.best_fitness, res.evaluations
    );
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let (_rt, manifest, model) = load_model(args)?;
    let cfg = args.experiment_cfg();
    let mspec = spec(args.model()?)?;
    let cache = table_path(&manifest.root, args.model()?);
    let budgets: Vec<u32> = mspec.budget_sweep().iter().map(|&b| b as u32).collect();
    let allocs = lexi_moe::lexi::pipeline::optimize(&model, &budgets, &cfg, Some(&cache))?;
    println!(
        "LExI allocations for {} (baseline B={}):",
        mspec.name,
        mspec.baseline_budget()
    );
    for (b, a) in allocs {
        println!("  B={b:>4}: {a}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (_rt, manifest, model) = load_model(args)?;
    let cfg = args.experiment_cfg();
    let suite = EvalSuite::load(&manifest)?;
    let entry = model.entry.clone();
    let calib = lexi_moe::runtime::weights::CalibStats::load_npz(
        manifest.model_dir(args.model()?).join(&entry.files.calib),
        entry.n_layers,
        entry.n_experts,
    )?;

    let transform = if let Some(b) = args.get("lexi") {
        let budget: u32 = b.parse()?;
        let cache = table_path(&manifest.root, args.model()?);
        let table = stage1(&model, &cfg, Some(&cache), false)?;
        Transform::Lexi {
            allocation: stage2(&table, budget, &cfg)?.best,
        }
    } else if let Some(f) = args.get("inter") {
        Transform::InterPrune { frac: f.parse()? }
    } else if let Some(f) = args.get("intra") {
        Transform::IntraPrune { frac: f.parse()? }
    } else {
        Transform::Baseline
    };

    let rc = RunConfig::for_transform(&entry, &transform, Some(&calib))?;
    println!("evaluating {} under {} ...", entry.name, transform.label());
    let t0 = std::time::Instant::now();
    if entry.is_vlm {
        let vlm = lexi_moe::eval::multiple_choice::task_suite(
            &model,
            &suite,
            &lexi_moe::eval::multiple_choice::vlm_tasks(&suite),
            &rc,
        )?;
        for (t, a) in &vlm {
            println!("vlm {t:<12} {a:.3}");
        }
    } else {
        let lmeval = lexi_moe::eval::multiple_choice::task_suite(
            &model,
            &suite,
            &lexi_moe::eval::multiple_choice::lmeval_tasks(&suite),
            &rc,
        )?;
        println!(
            "lmeval avg: {:.3}",
            lexi_moe::eval::multiple_choice::mean_accuracy(&lmeval)
        );
        for (t, a) in &lmeval {
            println!("  {t:<12} {a:.3}");
        }
        println!(
            "longqa F1: {:.3}",
            lexi_moe::eval::generate::longqa_f1(&model, &suite, &rc)?
        );
        let (acc, per_depth) = lexi_moe::eval::generate::passkey(&model, &suite, &rc)?;
        println!("passkey: {acc:.3} per-depth {per_depth:?}");
        for (c, p) in lexi_moe::eval::perplexity::all_corpora(&model, &suite, &rc)? {
            println!("ppl[{c}]: {p:.3}");
        }
    }
    println!("eval wall: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (_rt, manifest, model) = load_model(args)?;
    let n_requests: usize = args.get("requests").unwrap_or("16").parse()?;
    let entry = model.entry.clone();
    let scfg = ServingConfig {
        batch: entry.batch,
        max_seq: entry.max_seq,
        prefill_len: entry.prefill_len,
        ..Default::default()
    };
    let rc = RunConfig::baseline(&entry);
    let mut engine = Engine::new(&model, scfg, rc.k_vec, rc.gate_bias)?;

    // synthetic prompt trace from the eval corpus
    let suite = EvalSuite::load(&manifest)?;
    let seqs = suite.ppl_seqs("c4")?;
    let mut rng = Pcg32::seeded(7);
    for i in 0..n_requests {
        let row = seqs.row(i % seqs.n_rows());
        let plen = 16 + rng.gen_usize(48);
        engine.submit(
            row[..plen.min(row.len())].to_vec(),
            SamplingParams {
                max_new_tokens: 8 + rng.gen_usize(8),
                stop_on_eos: false,
                ..Default::default()
            },
        )?;
    }
    let outs = engine.run_until_complete()?;
    println!("{}", engine.metrics.summary());
    println!("sample output: {:?}", outs.first().map(|o| &o.tokens));
    Ok(())
}

/// Parse a comma-separated f64 list flag with the flag name in errors.
fn parse_f64_list(list: &str, flag: &str) -> Result<Vec<f64>> {
    list.split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .with_context(|| format!("{flag} entry '{s}' is not a number"))
        })
        .collect()
}

/// Shared `ServerConfig` flag parsing for `bench-serve`/`bench-memory`
/// (`--evict` is intentionally absent: bench-serve takes one policy,
/// bench-memory sweeps a list).
fn server_cfg_from_args(args: &Args) -> Result<lexi_moe::config::server::ServerConfig> {
    use lexi_moe::config::server::{
        parse_autoscale, validate_axis_levels, validate_ladder_fracs, BackendKind, LadderAxes,
        LadderScope, PolicyKind, PressureMode, ServerConfig, TableMode, TierKind,
    };
    let mut cfg = ServerConfig::default();
    if let Some(n) = args.get("replicas") {
        cfg.replicas = n.parse().context("--replicas must be an integer")?;
        anyhow::ensure!(cfg.replicas >= 1, "--replicas must be >= 1");
    }
    if let Some(n) = args.get("slots") {
        cfg.slots_per_replica = n.parse().context("--slots must be an integer")?;
        anyhow::ensure!(cfg.slots_per_replica >= 1, "--slots must be >= 1");
    }
    // --route is the canonical routing flag; --policy stays as an alias
    if let Some(p) = args.get("route").or_else(|| args.get("policy")) {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(t) = args.get("table") {
        cfg.table_mode = TableMode::parse(t)?;
    }
    if let Some(l) = args.get("ladder") {
        cfg.ladder_scope = LadderScope::parse(l)?;
    }
    if let Some(p) = args.get("pressure") {
        cfg.pressure = PressureMode::parse(p)?;
    }
    if let Some(a) = args.get("ladder-axes") {
        cfg.ladder_axes = LadderAxes::parse(a)?;
    }
    // axis levels are validated HERE, with the flag name in the error,
    // not deep inside lattice construction
    if let Some(list) = args.get("ladder-fracs") {
        cfg.ladder_fracs = parse_f64_list(list, "--ladder-fracs")?;
        validate_ladder_fracs(&cfg.ladder_fracs)?;
    }
    if let Some(list) = args.get("intra-fracs") {
        cfg.intra_fracs = parse_f64_list(list, "--intra-fracs")?;
        validate_axis_levels(&cfg.intra_fracs, LadderAxes::KIntra)?;
    }
    if let Some(list) = args.get("skip-thresholds") {
        cfg.skip_thresholds = parse_f64_list(list, "--skip-thresholds")?;
        validate_axis_levels(&cfg.skip_thresholds, LadderAxes::KSkip)?;
    }
    if let Some(n) = args.get("steal") {
        cfg.steal_bound = n.parse().context("--steal must be an integer (steals per instant)")?;
    }
    if let Some(s) = args.get("steal-cooldown") {
        cfg.steal_cooldown_s = s.parse().context("--steal-cooldown must be seconds (f64)")?;
        anyhow::ensure!(cfg.steal_cooldown_s >= 0.0, "--steal-cooldown must be >= 0");
    }
    if let Some(f) = args.get("hbm-budget") {
        let frac: f64 = f.parse().context("--hbm-budget must be a fraction in (0, 1]")?;
        anyhow::ensure!(
            frac > 0.0 && frac <= 1.0,
            "--hbm-budget is a fraction of the expert footprint in (0, 1]"
        );
        cfg.hbm_budget_frac = Some(frac);
    }
    if let Some(p) = args.get("prefetch") {
        cfg.prefetch = match p {
            "on" | "1" | "true" => true,
            "off" | "0" | "false" => false,
            other => anyhow::bail!("--prefetch must be on|off (got '{other}')"),
        };
    }
    if let Some(f) = args.get("trace-file") {
        cfg.trace_file = Some(PathBuf::from(f));
    }
    if let Some(f) = args.get("calibration") {
        cfg.calibration_file = Some(PathBuf::from(f));
    }
    if args.get("trace").is_some() {
        cfg.trace = true;
    }
    if let Some(n) = args.get("trace-ring-cap") {
        cfg.trace_ring_cap = n.parse().context("--trace-ring-cap must be an integer")?;
        anyhow::ensure!(cfg.trace_ring_cap > 0, "--trace-ring-cap must be >= 1");
    }
    if let Some(s) = args.get("metrics-interval") {
        cfg.metrics_interval_s = s.parse().context("--metrics-interval must be seconds (f64)")?;
        anyhow::ensure!(cfg.metrics_interval_s > 0.0, "--metrics-interval must be > 0");
    }
    if args.get("selfprof").is_some() {
        cfg.selfprof = true;
    }
    if args.get("shed").is_some() {
        cfg.shed = true;
    }
    if args.get("health").is_some() {
        cfg.health = true;
    }
    if let Some(a) = args.get("autoscale") {
        cfg.autoscale = Some(parse_autoscale(a)?);
    }
    if let Some(t) = args.get("replica-tiers") {
        cfg.replica_tiers = Some(TierKind::parse_spec(t)?);
    }
    if let Some(n) = args.get("shards") {
        cfg.shards = n.parse().context("--shards must be an integer")?;
        anyhow::ensure!(cfg.shards >= 1, "--shards must be >= 1");
    }
    if let Some(n) = args.get("requests") {
        cfg.n_requests = n.parse().context("--requests must be an integer")?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().context("--seed must be an integer")?;
    }
    Ok(cfg)
}

/// Multi-replica serving benchmark over the `server::` subsystem.
/// `--backend sim` (default) replays perf-model-calibrated virtual-time
/// replicas; `--backend engine` drives real `engine::Engine` replicas
/// through the same front door. The ladder's Stage-1 table source is
/// controlled by `--table` and logged per run; `--route classaware`,
/// `--pressure slack|slack-ewma`, `--steal N`, and `--steal-cooldown S`
/// switch on the telemetry-driven control-plane features;
/// `--hbm-budget F` puts expert weights under the residency model.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    use lexi_moe::config::server::{EvictKind, ScenarioKind};

    let model_name = args.get("model").unwrap_or("qwen1.5-moe-a2.7b");
    let mspec = spec(model_name)?;
    let mut cfg = server_cfg_from_args(args)?;
    // bench-serve takes ONE eviction policy; bench-memory sweeps a list
    if let Some(e) = args.get("evict") {
        cfg.evict = EvictKind::parse(e)?;
    }
    // residency knobs without a budget are a contradiction, not a no-op
    anyhow::ensure!(
        cfg.hbm_budget_frac.is_some()
            || (args.get("evict").is_none() && args.get("prefetch").is_none()),
        "--evict/--prefetch configure the expert residency store; \
         pass --hbm-budget <frac> to enable it"
    );
    // a trace file implies replay when no scenario was named; naming a
    // different one is a contradiction, not something to ignore
    let scenario_flag = match args.get("scenario") {
        Some(s) => s,
        None if cfg.trace_file.is_some() => "trace-replay",
        None => "bursty",
    };
    let scenarios: Vec<ScenarioKind> = if scenario_flag == "all" {
        ScenarioKind::all().to_vec()
    } else {
        vec![ScenarioKind::parse(scenario_flag)?]
    };
    anyhow::ensure!(
        cfg.trace_file.is_none() || scenarios.contains(&ScenarioKind::TraceReplay),
        "--trace-file only makes sense with --scenario trace-replay (got '{scenario_flag}')"
    );

    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== bench-serve: {model_name}, {} replicas x {} slots, route {}, backend {}, \
         ladder scope {}, pressure {}, steal {}, {} requests/scenario ===\n",
        cfg.replicas,
        cfg.slots_per_replica,
        cfg.policy.label(),
        cfg.backend.label(),
        cfg.ladder_scope.label(),
        cfg.pressure.label(),
        cfg.steal_bound,
        cfg.n_requests
    );
    if let Some(frac) = cfg.hbm_budget_frac {
        println!(
            "expert residency: HBM budget {:.0}% of footprint, evict {}, prefetch {}\n",
            frac * 100.0,
            cfg.evict.label(),
            if cfg.prefetch { "on" } else { "off" }
        );
    }
    if cfg.selfprof {
        lexi_moe::obs::selfprof::enable();
    }
    lexi_moe::server::report::print_header();
    for kind in &scenarios {
        cfg.scenario = *kind;
        let reports = lexi_moe::server::bench_serve(&mspec, &cfg, artifacts_opt, &out)?;
        lexi_moe::server::report::print_comparison(&reports);
    }
    if cfg.selfprof {
        let prof = lexi_moe::obs::selfprof::disable_and_collect();
        prof.print();
        let path = PathBuf::from(args.get("selfprof-out").unwrap_or("BENCH_selfprof.json"));
        let label = format!(
            "bench-serve {} {} x{}",
            model_name,
            scenarios
                .iter()
                .map(|s| s.label())
                .collect::<Vec<_>>()
                .join("+"),
            cfg.n_requests
        );
        lexi_moe::obs::append_trajectory(&path, "sim-selfprof", prof.to_json(&label))?;
        println!("self-profile appended to {}", path.display());
    }
    println!("reports written to {}", out.display());
    Ok(())
}

/// Event-loop scale benchmark (`lexi bench-scale`): a synthetic-service
/// sim cluster at cluster scale (default 1000 replicas x 1M requests),
/// self-profiled, appending one trajectory entry per run to
/// `BENCH_selfprof.json`. With `--compare` the rebuild-per-instant
/// snapshot baseline runs first on the identical trace and the
/// `cluster.snapshot` speedup of the incremental cache is reported.
fn cmd_bench_scale(args: &Args) -> Result<()> {
    use lexi_moe::config::server::ScenarioKind;

    let replicas: usize = args.get("replicas").unwrap_or("1000").parse()?;
    let slots: usize = args.get("slots").unwrap_or("8").parse()?;
    let requests: usize = args.get("requests").unwrap_or("1000000").parse()?;
    let shards: usize = args.get("shards").unwrap_or("1").parse()?;
    let seed: u64 = args.get("seed").unwrap_or("0").parse()?;
    anyhow::ensure!(replicas >= 1 && slots >= 1 && shards >= 1 && requests >= 1);
    let kind = ScenarioKind::parse(args.get("scenario").unwrap_or("diurnal"))?;
    anyhow::ensure!(
        kind != ScenarioKind::TraceReplay,
        "bench-scale generates its own trace; pick a generative scenario"
    );
    let path = PathBuf::from(args.get("selfprof-out").unwrap_or("BENCH_selfprof.json"));
    let tag = format!("{}x{}", replicas, requests);
    println!(
        "=== bench-scale: {replicas} replicas x {slots} slots, {} scenario, \
         {requests} requests, {shards} shard(s), seed {seed} ===\n",
        kind.label()
    );

    let baseline = if args.get("compare").is_some() {
        println!("rebuild-per-instant baseline ...");
        let run = lexi_moe::server::bench_scale(replicas, slots, requests, kind, seed, 1, true);
        run.prof.print();
        println!(
            "baseline: {:.2}s wall, {} completed, {} rejected\n",
            run.wall_s, run.completed, run.rejected
        );
        let mut entry = run.prof.to_json(&format!("bench-scale rebuild {tag}"));
        annotate_scale_entry(&mut entry, &run, replicas, requests);
        lexi_moe::obs::append_trajectory(&path, "sim-selfprof", entry)?;
        Some(run)
    } else {
        None
    };

    println!("incremental snapshots ...");
    let run = lexi_moe::server::bench_scale(replicas, slots, requests, kind, seed, shards, false);
    run.prof.print();
    println!(
        "incremental: {:.2}s wall, {} completed, {} rejected",
        run.wall_s, run.completed, run.rejected
    );
    let mut entry = run.prof.to_json(&format!("bench-scale incremental {tag}"));
    annotate_scale_entry(&mut entry, &run, replicas, requests);
    lexi_moe::obs::append_trajectory(&path, "sim-selfprof", entry)?;
    println!("self-profile appended to {}", path.display());

    if let Some(base) = baseline {
        anyhow::ensure!(
            base.completed == run.completed && base.rejected == run.rejected,
            "snapshot modes diverged: rebuild {}/{} vs incremental {}/{}",
            base.completed,
            base.rejected,
            run.completed,
            run.rejected
        );
        let (b, i) = (base.section_ms("cluster.snapshot"), run.section_ms("cluster.snapshot"));
        anyhow::ensure!(i > 0.0, "incremental run recorded no cluster.snapshot time");
        println!(
            "\ncluster.snapshot: rebuild {:.1} ms -> incremental {:.1} ms ({:.1}x); \
             wall {:.2}s -> {:.2}s ({:.2}x)",
            b,
            i,
            b / i,
            base.wall_s,
            run.wall_s,
            base.wall_s / run.wall_s
        );
    }
    Ok(())
}

/// Attach run-shape metadata to a bench-scale trajectory entry so the
/// regression gate can match entries without parsing labels.
fn annotate_scale_entry(
    entry: &mut lexi_moe::util::json::Json,
    run: &lexi_moe::server::ScaleRun,
    replicas: usize,
    requests: usize,
) {
    use lexi_moe::util::json::Json;
    if let Json::Obj(fields) = entry {
        fields.insert("replicas".to_string(), Json::Num(replicas as f64));
        fields.insert("requests".to_string(), Json::Num(requests as f64));
        fields.insert("wall_s".to_string(), Json::Num(run.wall_s));
        fields.insert("completed".to_string(), Json::Num(run.completed as f64));
    }
}

/// Elastic-control-plane sweep (`lexi bench-elasticity`): fixed
/// provisioning vs autoscaling (± class-aware shedding), plus a
/// heterogeneous H100/A100 tier mix across routing policies, all on one
/// shared workload contract. `--autoscale min:max` and
/// `--replica-tiers h100:N,a100:M` override the default cell bounds.
fn cmd_bench_elasticity(args: &Args) -> Result<()> {
    use lexi_moe::config::server::ScenarioKind;

    let model_name = args.get("model").unwrap_or("qwen1.5-moe-a2.7b");
    let mspec = spec(model_name)?;
    let mut cfg = server_cfg_from_args(args)?;
    anyhow::ensure!(
        cfg.calibration_file.is_none(),
        "--calibration applies to bench-serve / cross-validate, not bench-elasticity"
    );
    // diurnal by default: the load swing is what provisioning elasticity
    // is for
    cfg.scenario = match args.get("scenario") {
        Some(s) => ScenarioKind::parse(s)?,
        None => ScenarioKind::Diurnal,
    };
    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== bench-elasticity: {model_name}, reference {} replicas x {} slots, scenario {}, \
         {} requests/cell ===\n",
        cfg.replicas,
        cfg.slots_per_replica,
        cfg.scenario.label(),
        cfg.n_requests
    );
    let rows = lexi_moe::server::bench_elasticity(&mspec, &cfg, artifacts_opt, &out)?;
    lexi_moe::server::report::print_elasticity_header();
    lexi_moe::server::report::print_elasticity_rows(&rows);
    println!("\nreports written to {}", out.display());
    Ok(())
}

/// Expert-residency sweep: HBM budgets x eviction policies through the
/// serving cluster (`lexi bench-memory`). Budgets are fractions of the
/// model's full per-GPU expert footprint.
fn cmd_bench_memory(args: &Args) -> Result<()> {
    use lexi_moe::config::server::{EvictKind, ScenarioKind};

    let model_name = args.get("model").unwrap_or("qwen1.5-moe-a2.7b");
    let mspec = spec(model_name)?;
    let mut cfg = server_cfg_from_args(args)?;
    anyhow::ensure!(
        cfg.calibration_file.is_none(),
        "--calibration applies to bench-serve / cross-validate, not bench-memory"
    );
    cfg.scenario = match args.get("scenario") {
        Some(s) => ScenarioKind::parse(s)?,
        None => ScenarioKind::Bursty,
    };
    let budgets: Vec<f64> = args
        .get("budgets")
        .unwrap_or("0.35,0.6")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .with_context(|| format!("--budgets entry '{s}' is not a number"))
        })
        .collect::<Result<_>>()?;
    let policies: Vec<EvictKind> = match args.get("evict") {
        None | Some("all") => EvictKind::all().to_vec(),
        Some(list) => list
            .split(',')
            .map(|s| EvictKind::parse(s.trim()))
            .collect::<Result<_>>()?,
    };

    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== bench-memory: {model_name}, {} replicas x {} slots, scenario {}, \
         budgets {:?}, policies {:?}, prefetch {}, {} requests/cell ===\n",
        cfg.replicas,
        cfg.slots_per_replica,
        cfg.scenario.label(),
        budgets,
        policies.iter().map(|p| p.label()).collect::<Vec<_>>(),
        if cfg.prefetch { "on" } else { "off" },
        cfg.n_requests
    );
    let rows = lexi_moe::server::bench_memory(
        &mspec,
        &cfg,
        &budgets,
        &policies,
        artifacts_opt,
        &out,
    )?;
    lexi_moe::server::report::print_memory_header();
    lexi_moe::server::report::print_memory_rows(&rows);
    println!("\nreports written to {}", out.display());
    Ok(())
}

/// Price every point of the 2-D quality lattice analytically and emit
/// the (modeled latency, proxy quality loss) surface with Pareto
/// frontier + pure-k dominance annotations
/// (`lexi bench-quality-surface`).
fn cmd_bench_quality_surface(args: &Args) -> Result<()> {
    use lexi_moe::config::server::LadderAxes;

    let model_name = args.get("model").unwrap_or("qwen1.5-moe-a2.7b");
    let mspec = spec(model_name)?;
    let mut cfg = server_cfg_from_args(args)?;
    anyhow::ensure!(
        cfg.calibration_file.is_none(),
        "--calibration applies to bench-serve / cross-validate, not bench-quality-surface"
    );
    // the sweep is about the second axis; default it on (intra works on
    // every model, skip needs a top-2 router) unless the user chose
    if args.get("ladder-axes").is_none() {
        cfg.ladder_axes = LadderAxes::KIntra;
    }
    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== bench-quality-surface: {model_name}, axes {}, ladder fracs {:?}, \
         intra fracs {:?}, skip thresholds {:?} ===\n",
        cfg.ladder_axes.label(),
        cfg.ladder_fracs,
        cfg.intra_fracs,
        cfg.skip_thresholds
    );
    lexi_moe::server::bench_quality_surface(&mspec, &cfg, artifacts_opt, &out)?;
    println!("\nreports written to {}", out.display());
    Ok(())
}

/// Shared setup of the calibration commands: model spec + `ServerConfig`
/// with a calibration-sized request default (the engine backend pays
/// real compute per request, so the default trace is smaller than
/// bench-serve's).
fn calibration_setup(
    args: &Args,
) -> Result<(lexi_moe::ModelSpec, lexi_moe::config::server::ServerConfig)> {
    use lexi_moe::config::server::ScenarioKind;
    let model_name = args.get("model").unwrap_or("qwen1.5-moe-a2.7b");
    let mspec = spec(model_name)?;
    let mut cfg = server_cfg_from_args(args)?;
    if args.get("requests").is_none() {
        cfg.n_requests = 64;
    }
    if let Some(s) = args.get("scenario") {
        cfg.scenario = ScenarioKind::parse(s)?;
    } else if cfg.trace_file.is_some() {
        cfg.scenario = ScenarioKind::TraceReplay;
    }
    Ok((mspec, cfg))
}

/// Run the engine backend over one seeded scenario and fit the sim
/// `ServiceModel` calibration artifact from its step-time telemetry.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let (mspec, cfg) = calibration_setup(args)?;
    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== calibrate: {}, {} replicas x {} slots, scenario {}, {} requests, seed {} ===\n",
        mspec.name,
        cfg.replicas,
        cfg.slots_per_replica,
        cfg.scenario.label(),
        cfg.n_requests,
        cfg.seed
    );
    lexi_moe::calibrate::calibrate(&mspec, &cfg, artifacts_opt, &out)?;
    Ok(())
}

/// Replay the same seeded trace on the engine backend and on the raw +
/// calibrated sim, and gate on percentile divergence: exits nonzero when
/// the calibrated sim diverges from the engine beyond --tolerance.
fn cmd_cross_validate(args: &Args) -> Result<()> {
    let (mspec, cfg) = calibration_setup(args)?;
    let tolerance = match args.get("tolerance") {
        Some(t) => t.parse().context("--tolerance must be a fraction (f64)")?,
        None => lexi_moe::calibrate::DEFAULT_TOLERANCE,
    };
    let out = args.out_dir();
    let artifacts = args.artifacts();
    let artifacts_opt = artifacts.exists().then_some(artifacts.as_path());
    println!(
        "=== cross-validate: {}, {} replicas x {} slots, scenario {}, {} requests, \
         seed {}, tolerance {:.0}% ===\n",
        mspec.name,
        cfg.replicas,
        cfg.slots_per_replica,
        cfg.scenario.label(),
        cfg.n_requests,
        cfg.seed,
        tolerance * 100.0
    );
    let gate_p99 = args.get("gate-p99").is_some();
    let append = args.get("append").map(PathBuf::from);
    let cv = lexi_moe::calibrate::cross_validate(
        &mspec,
        &cfg,
        artifacts_opt,
        cfg.calibration_file.as_deref(),
        tolerance,
        gate_p99,
        append.as_deref(),
        &out,
    )?;
    anyhow::ensure!(
        cv.pass,
        "cross-validation FAILED: calibrated-sim divergence {:.1}% exceeds tolerance {:.1}% \
         (or served-token parity broke); see {}",
        cv.contenders[0].calibrated.max_gated_with(gate_p99) * 100.0,
        tolerance * 100.0,
        out.join(format!("cross_validate_{}_{}.json", cv.model, cv.scenario))
            .display()
    );
    Ok(())
}

/// Validate observability artifacts (`lexi trace`): `--check F` checks
/// a Perfetto `trace_event` JSON document's shape, `--prom F`
/// additionally validates a Prometheus text exposition. Exits nonzero on
/// the first malformed artifact — the CI smoke gate for `--trace`.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .get("check")
        .context("--check <perfetto.json> required")?;
    let doc = lexi_moe::util::json::parse_file(Path::new(path))
        .with_context(|| format!("reading trace {path}"))?;
    let sum = lexi_moe::obs::check_perfetto(&doc)
        .with_context(|| format!("validating trace {path}"))?;
    println!("{path}: ok ({} spans, {} instants)", sum.spans, sum.instants);
    if sum.dropped > 0 {
        eprintln!(
            "warning: {path}: trace ring overflowed, {} event(s) dropped — \
             the timeline is truncated; rerun with a larger --trace-ring-cap",
            sum.dropped
        );
    }
    if let Some(p) = args.get("prom") {
        let text =
            std::fs::read_to_string(p).with_context(|| format!("reading exposition {p}"))?;
        let ps = lexi_moe::obs::check_prometheus(&text)
            .with_context(|| format!("validating exposition {p}"))?;
        println!("{p}: ok ({} families, {} samples)", ps.families, ps.samples);
    }
    Ok(())
}

/// Validate a flight-recorder debug bundle (`lexi bundle --check F`):
/// checks the self-contained `debug_bundle_*.json` shape (run config,
/// cluster snapshot, window state, recorder tail) and prints a summary.
/// Exits nonzero on a malformed bundle — the CI gate for `--health`.
fn cmd_bundle(args: &Args) -> Result<()> {
    let path = args
        .get("check")
        .context("--check <debug_bundle.json> required")?;
    let doc = lexi_moe::util::json::parse_file(Path::new(path))
        .with_context(|| format!("reading bundle {path}"))?;
    let sum = lexi_moe::obs::check_bundle(&doc)
        .with_context(|| format!("validating bundle {path}"))?;
    println!(
        "{path}: ok (t={:.2}s, trigger '{}', {} recorder entries, {} replicas, {} events)",
        sum.t_s, sum.trigger, sum.n_entries, sum.n_replicas, sum.n_events
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let exp = args.get("exp").unwrap_or("all");
    let out = args.out_dir();
    let cfg = args.experiment_cfg();
    let models_owned: Option<Vec<String>> = args
        .get("models")
        .map(|s| s.split(',').map(|x| x.to_string()).collect());
    let models: Option<Vec<&str>> = models_owned
        .as_ref()
        .map(|v| v.iter().map(|s| s.as_str()).collect());

    let needs_runtime = matches!(exp, "fig3" | "fig9" | "figs4-8" | "ablations" | "all");
    let (rt, manifest) = if needs_runtime {
        (
            Some(Runtime::cpu()?),
            Some(Manifest::load(args.artifacts())?),
        )
    } else {
        (None, None)
    };

    if matches!(exp, "table1" | "all") {
        figures::table1::run(&out)?;
    }
    if matches!(exp, "fig2" | "all") {
        figures::fig2::run(&out, &cfg)?;
    }
    if matches!(exp, "fig3" | "all") {
        figures::fig3::run(
            &out,
            rt.as_ref().unwrap(),
            manifest.as_ref().unwrap(),
            &figures::fig3::FIG3_MODELS,
            &cfg,
            "fig3_sensitivity_heatmaps",
        )?;
    }
    if matches!(exp, "fig9" | "all") {
        figures::fig3::run(
            &out,
            rt.as_ref().unwrap(),
            manifest.as_ref().unwrap(),
            &figures::fig3::FIG9_MODELS,
            &cfg,
            "fig9_sensitivity_heatmaps",
        )?;
    }
    // NOT part of "ablations": rendering may run a (small) bench-memory
    // sweep when no sweep artifact exists, and ablations stays cheap
    if matches!(exp, "memory" | "all") {
        figures::memory::run(&out)?;
    }
    if matches!(exp, "timeline" | "all") {
        figures::timeline::run(&out)?;
    }
    if matches!(exp, "elasticity" | "all") {
        figures::elasticity::run(&out)?;
    }
    if matches!(exp, "health" | "all") {
        figures::health::run(&out)?;
    }
    if matches!(exp, "quality-surface" | "all") {
        figures::quality_surface::run(&out)?;
    }
    if matches!(exp, "ablations" | "all") {
        figures::ablation::limitations_memory(&out, &cfg)?;
        figures::ablation::dynamic_skip_comparison(&out, &cfg)?;
        figures::ablation::hot_set_coverage(&out, &cfg)?;
        // allocation-quality ablation over measured tables when present
        if let (Some(rt_ref), Some(man)) = (rt.as_ref(), manifest.as_ref()) {
            for name in ["qwen1.5-moe-a2.7b", "olmoe-1b-7b"] {
                if man.models.contains_key(name) {
                    let model = ModelRuntime::load(rt_ref, man, name)?;
                    let table = stage1(
                        &model,
                        &cfg,
                        Some(&table_path(&man.root, name)),
                        false,
                    )?;
                    figures::ablation::allocation_quality(&out, &table, &cfg)?;
                }
            }
        }
    }
    if matches!(exp, "figs4-8" | "all") {
        figures::accuracy_throughput::run_all(
            &out,
            rt.as_ref().unwrap(),
            manifest.as_ref().unwrap(),
            &cfg,
            models.as_deref(),
        )?;
    }
    Ok(())
}
