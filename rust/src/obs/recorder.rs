//! Flight recorder + debug-bundle format (`lexi bundle --check`).
//!
//! The [`FlightRecorder`] is a small always-on ring the
//! [`HealthEngine`](super::health::HealthEngine) feeds with salient
//! control-plane happenings (sheds, rejects, steals, rung switches,
//! anomalies, burn transitions). It is independent of the span
//! [`Tracer`](super::trace::Tracer): tracing is an opt-in artifact
//! pipeline, the recorder is the black box that is *always* running
//! when the health engine is on, bounded by both an entry cap and a
//! time horizon so it costs O(cap) memory whatever the run length.
//!
//! On a critical health event the engine freezes the recorder tail into
//! a self-contained *debug bundle*: one JSON document holding the last
//! seconds of recorder entries, the current [`ClusterSnapshot`]
//! (per-replica telemetry), the health digest, and the active run
//! config — everything needed to reconstruct "what did the cluster look
//! like just before it went critical" without the full trace.
//! [`check_bundle`] validates the format (the `lexi bundle --check`
//! implementation), mirroring `check_perfetto` / `check_prometheus` in
//! [`super::export`].

use std::collections::VecDeque;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Bundle format marker (`"format"` key of every bundle document).
pub const BUNDLE_FORMAT: &str = "lexi-debug-bundle";
/// Current bundle schema version.
pub const BUNDLE_VERSION: f64 = 1.0;

/// One recorded happening: a timestamped kind tag plus a small JSON
/// detail payload.
#[derive(Clone, Debug)]
pub struct FlightEntry {
    /// Virtual-time seconds of the happening.
    pub t_s: f64,
    /// Static kind tag (`"shed"`, `"steal"`, `"burn"`, `"anomaly"`, ...).
    pub kind: &'static str,
    /// Kind-specific payload.
    pub detail: Json,
}

/// Bounded ring of [`FlightEntry`]s: oldest entries are dropped (and
/// counted) at the cap, and [`tail_json`](Self::tail_json) additionally
/// clips to a time horizon.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    horizon_s: f64,
    dropped: u64,
    entries: VecDeque<FlightEntry>,
}

impl FlightRecorder {
    pub fn new(cap: usize, horizon_s: f64) -> Self {
        FlightRecorder {
            cap: cap.max(1),
            horizon_s: horizon_s.max(0.0),
            dropped: 0,
            entries: VecDeque::new(),
        }
    }

    pub fn record(&mut self, t_s: f64, kind: &'static str, detail: Json) {
        if self.entries.len() == self.cap {
            self.entries.pop_front();
            self.dropped += 1;
        }
        self.entries.push_back(FlightEntry { t_s, kind, detail });
    }

    /// Entries currently held (post-drop).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries lost to the ring cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The recorder tail as a JSON array: entries within
    /// `horizon_s` of `now` (all retained entries when the horizon is
    /// 0), oldest first.
    pub fn tail_json(&self, now_s: f64) -> Json {
        let cutoff = if self.horizon_s > 0.0 {
            now_s - self.horizon_s
        } else {
            f64::NEG_INFINITY
        };
        Json::Arr(
            self.entries
                .iter()
                .filter(|e| e.t_s >= cutoff)
                .map(|e| {
                    Json::obj(vec![
                        ("t_s", Json::Num(e.t_s)),
                        ("kind", Json::Str(e.kind.to_string())),
                        ("detail", e.detail.clone()),
                    ])
                })
                .collect(),
        )
    }
}

/// What [`check_bundle`] found in a valid bundle document.
#[derive(Clone, Debug, PartialEq)]
pub struct BundleSummary {
    /// Virtual time the bundle was frozen at.
    pub t_s: f64,
    /// Human-readable trigger description (e.g. `burn_critical class 0`).
    pub trigger: String,
    /// Recorder entries carried in the bundle tail.
    pub n_entries: usize,
    /// Replicas in the embedded cluster snapshot.
    pub n_replicas: usize,
    /// Health events the engine had raised by freeze time.
    pub n_events: usize,
}

/// Validate a debug-bundle document: format marker, schema version,
/// and every section a self-contained bundle must carry. Returns a
/// summary of what the bundle holds (the `lexi bundle --check` output).
pub fn check_bundle(doc: &Json) -> Result<BundleSummary> {
    let format = doc
        .get("format")
        .context("bundle has no 'format' marker")?
        .as_str()?;
    ensure!(
        format == BUNDLE_FORMAT,
        "not a debug bundle: format '{format}' (expected '{BUNDLE_FORMAT}')"
    );
    let version = doc.get("version")?.as_f64()?;
    ensure!(
        version == BUNDLE_VERSION,
        "unsupported bundle version {version} (expected {BUNDLE_VERSION})"
    );
    let t_s = doc.get("t_s")?.as_f64()?;
    ensure!(t_s.is_finite() && t_s >= 0.0, "bad bundle timestamp {t_s}");

    let trigger = doc.get("trigger").context("bundle has no 'trigger'")?;
    let kind = trigger.get("kind")?.as_str()?.to_string();
    let trigger_label = match trigger.opt("class") {
        Some(c) => format!("{kind} class {}", c.as_usize()?),
        None => kind,
    };

    doc.get("config")?
        .as_obj()
        .context("bundle 'config' must be an object")?;

    let cluster = doc.get("cluster").context("bundle has no 'cluster' snapshot")?;
    let replicas = cluster.get("replicas")?.as_arr()?;
    for (i, r) in replicas.iter().enumerate() {
        r.get("replica")
            .and_then(|v| v.as_usize())
            .with_context(|| format!("cluster replica[{i}] malformed"))?;
        r.get("queue_len")
            .and_then(|v| v.as_usize())
            .with_context(|| format!("cluster replica[{i}] malformed"))?;
    }

    let health = doc.get("health").context("bundle has no 'health' digest")?;
    health.get("peak_fast_burn")?.as_f64()?;
    let n_events = health.get("events")?.as_arr()?.len();

    let entries = doc.get("events")?.as_arr()?;
    for (i, e) in entries.iter().enumerate() {
        let et = e
            .get("t_s")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("recorder entry[{i}] malformed"))?;
        ensure!(
            et <= t_s + 1e-9,
            "recorder entry[{i}] is from the future ({et} > {t_s})"
        );
        e.get("kind")
            .and_then(|v| v.as_str())
            .with_context(|| format!("recorder entry[{i}] has no kind"))?;
    }

    Ok(BundleSummary {
        t_s,
        trigger: trigger_label,
        n_entries: entries.len(),
        n_replicas: replicas.len(),
        n_events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_caps_and_clips_to_horizon() {
        let mut r = FlightRecorder::new(3, 10.0);
        for t in 0..5 {
            r.record(t as f64, "tick", Json::Num(t as f64));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        // horizon clip: at now=13, only t>=3 survives
        let tail = r.tail_json(13.0);
        let arr = tail.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("t_s").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(arr[1].get("kind").unwrap().as_str().unwrap(), "tick");
    }

    fn minimal_bundle() -> Json {
        Json::obj(vec![
            ("format", Json::Str(BUNDLE_FORMAT.to_string())),
            ("version", Json::Num(BUNDLE_VERSION)),
            ("t_s", Json::Num(4.5)),
            (
                "trigger",
                Json::obj(vec![
                    ("kind", Json::Str("burn_critical".to_string())),
                    ("class", Json::Num(0.0)),
                ]),
            ),
            ("config", Json::obj(vec![("seed", Json::Num(0.0))])),
            (
                "cluster",
                Json::obj(vec![
                    ("now_s", Json::Num(4.5)),
                    (
                        "replicas",
                        Json::Arr(vec![Json::obj(vec![
                            ("replica", Json::Num(0.0)),
                            ("queue_len", Json::Num(7.0)),
                        ])]),
                    ),
                ]),
            ),
            (
                "health",
                Json::obj(vec![
                    ("peak_fast_burn", Json::Num(6.0)),
                    ("events", Json::Arr(vec![])),
                ]),
            ),
            (
                "events",
                Json::Arr(vec![Json::obj(vec![
                    ("t_s", Json::Num(4.0)),
                    ("kind", Json::Str("shed".to_string())),
                    ("detail", Json::Null),
                ])]),
            ),
        ])
    }

    #[test]
    fn check_bundle_accepts_and_summarizes() {
        let s = check_bundle(&minimal_bundle()).unwrap();
        assert_eq!(s.t_s, 4.5);
        assert_eq!(s.trigger, "burn_critical class 0");
        assert_eq!(s.n_entries, 1);
        assert_eq!(s.n_replicas, 1);
        assert_eq!(s.n_events, 0);
    }

    #[test]
    fn check_bundle_rejects_malformed_documents() {
        // wrong format marker
        let mut b = minimal_bundle();
        if let Json::Obj(m) = &mut b {
            m.insert("format".to_string(), Json::Str("perfetto".to_string()));
        }
        assert!(check_bundle(&b).is_err());

        // missing cluster section
        let mut b = minimal_bundle();
        if let Json::Obj(m) = &mut b {
            m.remove("cluster");
        }
        assert!(check_bundle(&b).is_err());

        // recorder entry from after the freeze instant
        let mut b = minimal_bundle();
        if let Json::Obj(m) = &mut b {
            m.insert(
                "events".to_string(),
                Json::Arr(vec![Json::obj(vec![
                    ("t_s", Json::Num(99.0)),
                    ("kind", Json::Str("shed".to_string())),
                    ("detail", Json::Null),
                ])]),
            );
        }
        let err = check_bundle(&b).unwrap_err().to_string();
        assert!(err.contains("future"), "{err}");
    }
}
