//! Trace exporters: Chrome/Perfetto `trace_event` JSON, the
//! per-request critical-path CSV, and the shape checkers behind
//! `lexi trace --check`.
//!
//! The Perfetto file renders two track groups: process 0 holds one
//! thread per request (queue → prefill → decode complete spans), and
//! process `replica + 1` holds that replica's phase spans plus instant
//! markers for rung switches and steals. Timestamps are microseconds,
//! as the `trace_event` format requires. `rung` fields are linear
//! quality-lattice indices (row-major `s * k_dim + k`; identical to
//! the historical rung index on 1-D ladders), so traces from 2-D
//! lattice runs stay shape-compatible with every earlier consumer.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::csv_row;
use crate::server::backend::CompletedRequest;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

use super::trace::{EventKind, TraceLog};

fn span(name: &str, cat: &str, ts_s: f64, dur_s: f64, pid: usize, tid: u64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(ts_s * 1e6)),
        ("dur", Json::Num((dur_s * 1e6).max(0.0))),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", args),
    ])
}

fn instant(name: &str, cat: &str, ts_s: f64, pid: usize, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("p".to_string())),
        ("ts", Json::Num(ts_s * 1e6)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("args", args),
    ])
}

/// Render one finished run as Chrome/Perfetto `trace_event` JSON.
pub fn perfetto_json(log: &TraceLog, completed: &[CompletedRequest]) -> Json {
    let mut events = Vec::new();
    // request tracks: queue / prefill / decode spans per completion
    for cp in log.critical_paths(completed) {
        let req_args = |extra: Vec<(&str, Json)>| {
            let mut a = vec![
                ("class", Json::Num(cp.class as f64)),
                ("replica", Json::Num(cp.replica as f64)),
            ];
            a.extend(extra);
            Json::obj(a)
        };
        events.push(span(
            "queue",
            "request",
            cp.arrival_s,
            cp.queue_s,
            0,
            cp.id,
            req_args(vec![("steal_migrations", Json::Num(cp.steal_migrations as f64))]),
        ));
        events.push(span(
            "prefill",
            "request",
            cp.arrival_s + cp.queue_s,
            cp.prefill_s,
            0,
            cp.id,
            req_args(vec![("stall_s", Json::Num(cp.stall_s))]),
        ));
        events.push(span(
            "decode",
            "request",
            cp.arrival_s + cp.ttft_s,
            cp.decode_s,
            0,
            cp.id,
            req_args(vec![("e2e_s", Json::Num(cp.e2e_s))]),
        ));
    }
    // replica tracks: phase spans + control-plane instants
    for e in &log.events {
        match &e.kind {
            EventKind::PhaseStart {
                replica,
                phase,
                rung,
                dur_s,
                stall_s,
                active,
                ..
            } => {
                events.push(span(
                    phase.label(),
                    "phase",
                    e.t_s,
                    *dur_s,
                    replica + 1,
                    0,
                    Json::obj(vec![
                        ("rung", Json::Num(*rung as f64)),
                        ("active", Json::Num(*active as f64)),
                        ("stall_s", Json::Num(*stall_s)),
                    ]),
                ));
            }
            EventKind::RungSwitch { replica, rung } => {
                events.push(instant(
                    "rung_switch",
                    "ladder",
                    e.t_s,
                    replica + 1,
                    Json::obj(vec![("rung", Json::Num(*rung as f64))]),
                ));
            }
            EventKind::Steal { id, victim, thief } => {
                events.push(instant(
                    "steal",
                    "steal",
                    e.t_s,
                    victim + 1,
                    Json::obj(vec![
                        ("id", Json::Num(*id as f64)),
                        ("thief", Json::Num(*thief as f64)),
                    ]),
                ));
            }
            EventKind::Reject { id, class } => {
                events.push(instant(
                    "reject",
                    "admission",
                    e.t_s,
                    0,
                    Json::obj(vec![
                        ("id", Json::Num(*id as f64)),
                        ("class", Json::Num(*class as f64)),
                    ]),
                ));
            }
            EventKind::Shed { id, class, reason } => {
                events.push(instant(
                    "shed",
                    "admission",
                    e.t_s,
                    0,
                    Json::obj(vec![
                        ("id", Json::Num(*id as f64)),
                        ("class", Json::Num(*class as f64)),
                        ("reason", Json::Str(reason.to_string())),
                    ]),
                ));
            }
            EventKind::ScaleUp { replica } => {
                events.push(instant("scale_up", "autoscale", e.t_s, replica + 1, Json::obj(vec![])));
            }
            EventKind::Drain { replica } => {
                events.push(instant("drain", "autoscale", e.t_s, replica + 1, Json::obj(vec![])));
            }
            _ => {}
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            Json::obj(vec![("dropped_events", Json::Num(log.dropped as f64))]),
        ),
    ])
}

/// Column order of the critical-path CSV.
pub const CRITICAL_PATH_HEADER: [&str; 10] = [
    "request",
    "class",
    "replica",
    "queue_s",
    "prefill_s",
    "decode_s",
    "expert_stall_s",
    "steal_migrations",
    "ttft_s",
    "e2e_s",
];

/// Write the per-request critical-path breakdown CSV. f64 fields use
/// Rust's shortest round-trip formatting, so parsing a value back
/// yields the bit-exact sim number.
pub fn write_critical_path_csv(
    path: &Path,
    log: &TraceLog,
    completed: &[CompletedRequest],
) -> Result<()> {
    let mut w = CsvWriter::create(path, &CRITICAL_PATH_HEADER)?;
    for cp in log.critical_paths(completed) {
        csv_row!(
            w,
            cp.id,
            cp.class,
            cp.replica,
            cp.queue_s,
            cp.prefill_s,
            cp.decode_s,
            cp.stall_s,
            cp.steal_migrations,
            cp.ttft_s,
            cp.e2e_s
        )?;
    }
    Ok(())
}

/// Summary of a validated Perfetto file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PerfettoSummary {
    pub spans: usize,
    pub instants: usize,
    /// Events lost to the trace ring cap (`otherData.dropped_events`);
    /// nonzero means the trace is truncated and `lexi trace --check`
    /// warns about it.
    pub dropped: u64,
}

/// Validate the shape of a Chrome/Perfetto `trace_event` JSON document:
/// a `traceEvents` array whose entries carry `name`/`ph`/`ts`/`pid`,
/// with `dur >= 0` on complete (`"X"`) spans.
pub fn check_perfetto(doc: &Json) -> Result<PerfettoSummary> {
    let events = doc
        .get("traceEvents")
        .context("missing top-level 'traceEvents'")?
        .as_arr()
        .context("'traceEvents' is not an array")?;
    let mut sum = PerfettoSummary::default();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|p| p.as_str())
            .with_context(|| format!("event {i}: missing string 'ph'"))?;
        e.get("name")
            .and_then(|n| n.as_str())
            .with_context(|| format!("event {i}: missing string 'name'"))?;
        let ts = e
            .get("ts")
            .and_then(|t| t.as_f64())
            .with_context(|| format!("event {i}: missing numeric 'ts'"))?;
        anyhow::ensure!(ts.is_finite(), "event {i}: non-finite ts {ts}");
        e.get("pid")
            .and_then(|p| p.as_f64())
            .with_context(|| format!("event {i}: missing numeric 'pid'"))?;
        match ph {
            "X" => {
                let dur = e
                    .get("dur")
                    .and_then(|d| d.as_f64())
                    .with_context(|| format!("event {i}: 'X' span without 'dur'"))?;
                anyhow::ensure!(dur >= 0.0, "event {i}: negative dur {dur}");
                sum.spans += 1;
            }
            "i" => sum.instants += 1,
            other => bail!("event {i}: unsupported phase type '{other}'"),
        }
    }
    anyhow::ensure!(sum.spans > 0, "no complete spans in trace");
    // tolerate files from writers that omit otherData; ours always
    // embeds the drop count
    sum.dropped = doc
        .opt("otherData")
        .and_then(|o| o.opt("dropped_events"))
        .and_then(|d| d.as_f64().ok())
        .map(|d| d.max(0.0) as u64)
        .unwrap_or(0);
    Ok(sum)
}

/// Summary of a validated Prometheus exposition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PromSummary {
    pub families: usize,
    pub samples: usize,
}

/// Validate Prometheus text exposition: every sample is preceded by a
/// `# TYPE` for its family, values parse as floats, and histogram
/// bucket counts are cumulative with a `le="+Inf"` terminator.
pub fn check_prometheus(text: &str) -> Result<PromSummary> {
    let mut sum = PromSummary::default();
    let mut current_family: Option<String> = None;
    let mut bucket_last: Option<(String, u64)> = None;
    let mut saw_inf = true;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().context("# TYPE without a name")?;
            let ty = it.next().context("# TYPE without a type")?;
            anyhow::ensure!(
                matches!(ty, "counter" | "gauge" | "histogram" | "summary"),
                "line {ln}: unknown metric type '{ty}'"
            );
            anyhow::ensure!(saw_inf, "histogram before line {ln} lacks a +Inf bucket");
            current_family = Some(name.to_string());
            if ty == "histogram" {
                saw_inf = false;
            }
            bucket_last = None;
            sum.families += 1;
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (name_labels, value) = line
            .rsplit_once(' ')
            .with_context(|| format!("line {ln}: no value on '{line}'"))?;
        value
            .parse::<f64>()
            .with_context(|| format!("line {ln}: value '{value}' is not a float"))?;
        let name = name_labels.split('{').next().unwrap_or(name_labels);
        let family = current_family
            .as_deref()
            .with_context(|| format!("line {ln}: sample before any # TYPE"))?;
        anyhow::ensure!(
            name.starts_with(family),
            "line {ln}: sample '{name}' outside family '{family}'"
        );
        if name.ends_with("_bucket") {
            let count: u64 = value
                .parse()
                .with_context(|| format!("line {ln}: bucket count '{value}'"))?;
            let series = name_labels
                .split("le=")
                .next()
                .unwrap_or(name_labels)
                .to_string();
            if let Some((prev_series, prev)) = &bucket_last {
                if *prev_series == series {
                    anyhow::ensure!(
                        count >= *prev,
                        "line {ln}: bucket counts not cumulative ({count} < {prev})"
                    );
                }
            }
            if name_labels.contains("le=\"+Inf\"") {
                saw_inf = true;
            }
            bucket_last = Some((series, count));
        }
        sum.samples += 1;
    }
    anyhow::ensure!(saw_inf, "final histogram lacks a +Inf bucket");
    anyhow::ensure!(sum.samples > 0, "no samples in exposition");
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{PhaseKind, Tracer};

    fn sample_run() -> (TraceLog, Vec<CompletedRequest>) {
        let mut t = Tracer::new(1024);
        t.record(0.0, EventKind::Arrival { id: 1, class: 0 });
        t.record(
            0.1,
            EventKind::PhaseStart {
                replica: 0,
                phase: PhaseKind::Prefill,
                rung: 0,
                dur_s: 0.2,
                stall_s: 0.0,
                active: 1,
                ids: vec![1],
            },
        );
        t.record(0.3, EventKind::FirstToken { id: 1, replica: 0 });
        t.record(0.4, EventKind::RungSwitch { replica: 0, rung: 1 });
        t.record(
            0.9,
            EventKind::Finish {
                id: 1,
                replica: 0,
                class: 0,
                ttft_s: 0.3,
                e2e_s: 0.9,
                tokens: 4,
            },
        );
        let completed = vec![CompletedRequest {
            id: 1,
            class: 0,
            arrival_s: 0.0,
            prompt_len: 32,
            tokens: 4,
            ttft_s: 0.3,
            e2e_s: 0.9,
            finish_s: 0.9,
            replica: 0,
        }];
        (t.finish(), completed)
    }

    #[test]
    fn perfetto_round_trips_and_checks() {
        let (log, completed) = sample_run();
        let doc = perfetto_json(&log, &completed);
        let re = crate::util::json::parse(&doc.to_string_pretty()).unwrap();
        let sum = check_perfetto(&re).unwrap();
        // 3 request spans + 1 phase span; 1 rung-switch instant
        assert_eq!(sum.spans, 4);
        assert_eq!(sum.instants, 1);
        assert_eq!(sum.dropped, 0);
    }

    #[test]
    fn checker_surfaces_dropped_events() {
        // a 3-cap ring fed 5 events reports its truncation in otherData
        let mut t = Tracer::new(3);
        for i in 0..4u64 {
            t.record(i as f64, EventKind::Arrival { id: i, class: 0 });
        }
        t.record(
            4.0,
            EventKind::PhaseStart {
                replica: 0,
                phase: PhaseKind::Prefill,
                rung: 0,
                dur_s: 0.2,
                stall_s: 0.0,
                active: 1,
                ids: vec![3],
            },
        );
        let doc = perfetto_json(&t.finish(), &[]);
        let sum = check_perfetto(&doc).unwrap();
        assert_eq!(sum.dropped, 2);
        // a writer omitting otherData still validates, with dropped = 0
        let mut bare = doc.clone();
        if let Json::Obj(m) = &mut bare {
            m.remove("otherData");
        }
        assert_eq!(check_perfetto(&bare).unwrap().dropped, 0);
    }

    #[test]
    fn elastic_instants_render_and_check() {
        let mut t = Tracer::new(64);
        t.record(0.0, EventKind::ScaleUp { replica: 1 });
        t.record(
            0.1,
            EventKind::PhaseStart {
                replica: 0,
                phase: PhaseKind::Prefill,
                rung: 0,
                dur_s: 0.2,
                stall_s: 0.0,
                active: 1,
                ids: vec![1],
            },
        );
        t.record(0.5, EventKind::Shed { id: 9, class: 3, reason: "slack" });
        t.record(0.9, EventKind::Drain { replica: 1 });
        let doc = perfetto_json(&t.finish(), &[]);
        let sum = check_perfetto(&doc).unwrap();
        assert_eq!(sum.spans, 1);
        assert_eq!(sum.instants, 3);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let shed = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok()
                == Some("shed".to_string()))
            .unwrap();
        let args = shed.get("args").unwrap();
        assert_eq!(args.get("reason").unwrap().as_str().unwrap(), "slack");
        assert_eq!(args.get("class").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn check_rejects_malformed_traces() {
        assert!(check_perfetto(&Json::obj(vec![])).is_err());
        let bad = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![Json::obj(vec![("ph", Json::Str("X".into()))])]),
        )]);
        assert!(check_perfetto(&bad).is_err());
    }

    #[test]
    fn critical_path_csv_round_trips_bit_exactly() {
        let (log, completed) = sample_run();
        let dir = std::env::temp_dir().join("lexi_obs_export_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("cp.csv");
        write_critical_path_csv(&path, &log, &completed).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), CRITICAL_PATH_HEADER.join(","));
        let row: Vec<&str> = lines.next().unwrap().split(',').collect();
        let queue: f64 = row[3].parse().unwrap();
        let prefill: f64 = row[4].parse().unwrap();
        let decode: f64 = row[5].parse().unwrap();
        let ttft: f64 = row[8].parse().unwrap();
        let e2e: f64 = row[9].parse().unwrap();
        // shortest round-trip formatting: the identities survive the file
        assert_eq!(prefill, ttft - queue);
        assert_eq!(decode, e2e - ttft);
        assert_eq!(ttft, completed[0].ttft_s);
    }

    #[test]
    fn prometheus_checker_accepts_registry_output() {
        let (log, completed) = sample_run();
        let m = crate::obs::MetricsRegistry::from_run(&log, &completed);
        let text = m.prometheus_text();
        let sum = check_prometheus(&text).unwrap();
        assert!(sum.families >= 4, "{sum:?}");
        assert!(sum.samples > 10, "{sum:?}");
        // tampering with a bucket count breaks cumulativity
        let bad = text.replace("le=\"+Inf\"} 1", "le=\"+Inf\"} 0");
        assert!(check_prometheus(&bad).is_err());
    }
}
