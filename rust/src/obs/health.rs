//! Streaming SLO health engine (`bench-serve --health`, `--pressure
//! burn`).
//!
//! Consumes the same per-instant [`ClusterSnapshot`] stream the control
//! plane already runs on, plus the event loop's request-outcome hooks,
//! and maintains:
//!
//! - **sliding virtual-time windows** of per-class TTFT/TPOT
//!   attainment, shed/reject counts, and steal rates. The base unit is
//!   a 10 s bucket; a ring of closed buckets covers the 300 s horizon,
//!   so the 10 s / 60 s / 300 s views are mergeable bucket sums, never
//!   re-scans. Each bucket also pools TTFT/TPOT into fixed-bucket
//!   [`Histogram`]s — the cheap `Histogram::quantile` path, not exact
//!   samples — keeping window state O(buckets × classes).
//! - **error-budget burn rate** per SLO class in the Prometheus/SRE
//!   multi-window style: `burn = violation_frac / budget_frac`, where a
//!   rejected request counts as a violation (it definitionally missed
//!   its SLO). Transitions are raised as typed
//!   [`HealthEvent::BurnWarn`] / [`HealthEvent::BurnCritical`] /
//!   [`HealthEvent::Recovered`] only when BOTH the fast (10 s) and slow
//!   (60 s) windows cross the threshold, so a single bad instant cannot
//!   page and a long slow bleed cannot hide.
//! - an **anomaly detector**: per-replica EWMA mean/variance with
//!   z-score flags on the step-time, queue-depth, and `hbm_pressure`
//!   series, plus rung-flap (switch count per fast window) and
//!   starved-replica (idle while peers drown) signatures.
//! - an always-on bounded [`FlightRecorder`]; entering BurnCritical
//!   freezes a self-contained debug bundle (recorder tail + cluster
//!   snapshot + health digest + active config), rate-limited by a
//!   cooldown and a per-run cap, validated by `lexi bundle --check`.
//!
//! The engine is an *observer*: with `--health` alone it reads
//! telemetry and completions but feeds nothing back, so schedules are
//! byte-identical to an engine-less run (regression-tested). Only
//! `--pressure burn` routes [`HealthEngine::burn_frac`] into the
//! ladder controller and shedder.

use std::collections::VecDeque;

use crate::server::backend::CompletedRequest;
use crate::server::telemetry::ClusterSnapshot;
use crate::server::workload::SloTarget;
use crate::util::json::Json;

use super::metrics::{Histogram, LATENCY_BUCKETS_S};
use super::recorder::{FlightRecorder, BUNDLE_FORMAT, BUNDLE_VERSION};

/// Integer-ns key of a virtual-time instant (the event loop's own
/// `time_key`); used to observe each distinct instant exactly once.
fn time_key(t_s: f64) -> u64 {
    (t_s * 1e9) as u64
}

/// Tunables of the health engine. The defaults implement the classic
/// SRE multi-window recipe (10 s fast / 60 s slow over a 10% error
/// budget, warn at 2x burn, critical at 5x) scaled to sim virtual time.
#[derive(Clone, Debug)]
pub struct HealthConfig {
    /// Base aggregation bucket (s); every window is a whole number of
    /// buckets merged.
    pub bucket_s: f64,
    /// Closed buckets retained (ring length); with `bucket_s` = 10 s
    /// and 30 buckets the longest answerable window is 300 s.
    pub n_buckets: usize,
    /// Fast burn window (s).
    pub fast_window_s: f64,
    /// Slow burn window (s).
    pub slow_window_s: f64,
    /// Allowed SLO-violation fraction (the error budget): burn =
    /// violation_frac / budget_frac.
    pub budget_frac: f64,
    /// Burn rate at which a class enters Warn.
    pub warn_burn: f64,
    /// Burn rate at which a class enters Critical (bundle trigger).
    pub critical_burn: f64,
    /// Minimum outcomes in a window before its burn is trusted.
    pub min_samples: u64,
    /// |z| threshold of the EWMA anomaly detector.
    pub z_threshold: f64,
    /// EWMA observations before z-scores are trusted.
    pub anomaly_warmup: u64,
    /// EWMA smoothing factor.
    pub ewma_alpha: f64,
    /// Rung switches per replica within one fast window that count as
    /// flapping.
    pub flap_threshold: usize,
    /// Flight-recorder entry cap.
    pub recorder_cap: usize,
    /// Flight-recorder bundle horizon (s of tail kept in a bundle).
    pub recorder_horizon_s: f64,
    /// Minimum spacing between two bundle dumps (s).
    pub bundle_cooldown_s: f64,
    /// Bundle dumps per run at most.
    pub max_bundles: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            bucket_s: 10.0,
            n_buckets: 30,
            fast_window_s: 10.0,
            slow_window_s: 60.0,
            budget_frac: 0.1,
            warn_burn: 2.0,
            critical_burn: 5.0,
            min_samples: 8,
            z_threshold: 3.0,
            anomaly_warmup: 16,
            ewma_alpha: 0.2,
            flap_threshold: 4,
            recorder_cap: 4096,
            recorder_horizon_s: 30.0,
            bundle_cooldown_s: 30.0,
            max_bundles: 3,
        }
    }
}

/// A typed health transition or anomaly flag.
#[derive(Clone, Debug, PartialEq)]
pub enum HealthEvent {
    /// A class's fast AND slow burn crossed the warn threshold.
    BurnWarn {
        class: usize,
        fast_burn: f64,
        slow_burn: f64,
    },
    /// A class's fast AND slow burn crossed the critical threshold
    /// (freezes a debug bundle, subject to cooldown/cap).
    BurnCritical {
        class: usize,
        fast_burn: f64,
        slow_burn: f64,
    },
    /// A previously warning/critical class dropped back below warn on
    /// both windows.
    Recovered { class: usize },
    /// The anomaly detector flagged a per-replica signature.
    Anomaly {
        replica: usize,
        signature: AnomalySignature,
        /// z-score that tripped the flag (0 for count-based
        /// signatures like rung-flap).
        z: f64,
    },
}

impl HealthEvent {
    /// Stable kind tag (metrics label, recorder entries, JSON).
    pub fn label(&self) -> &'static str {
        match self {
            HealthEvent::BurnWarn { .. } => "burn_warn",
            HealthEvent::BurnCritical { .. } => "burn_critical",
            HealthEvent::Recovered { .. } => "recovered",
            HealthEvent::Anomaly { .. } => "anomaly",
        }
    }

    fn to_json(&self) -> Json {
        match self {
            HealthEvent::BurnWarn {
                class,
                fast_burn,
                slow_burn,
            }
            | HealthEvent::BurnCritical {
                class,
                fast_burn,
                slow_burn,
            } => Json::obj(vec![
                ("kind", Json::Str(self.label().to_string())),
                ("class", Json::Num(*class as f64)),
                ("fast_burn", Json::Num(*fast_burn)),
                ("slow_burn", Json::Num(*slow_burn)),
            ]),
            HealthEvent::Recovered { class } => Json::obj(vec![
                ("kind", Json::Str(self.label().to_string())),
                ("class", Json::Num(*class as f64)),
            ]),
            HealthEvent::Anomaly {
                replica,
                signature,
                z,
            } => Json::obj(vec![
                ("kind", Json::Str(self.label().to_string())),
                ("replica", Json::Num(*replica as f64)),
                ("signature", Json::Str(signature.label().to_string())),
                ("z", Json::Num(*z)),
            ]),
        }
    }
}

/// Which per-replica pathology an [`HealthEvent::Anomaly`] names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnomalySignature {
    /// Rung switches faster than `flap_threshold` per fast window: the
    /// ladder controller is oscillating.
    RungFlap,
    /// `hbm_pressure` z-spike: the expert store is thrashing.
    ResidencyThrash,
    /// A replica sits idle while a peer's queue is deep: routing or
    /// stealing has starved it.
    StarvedReplica,
    /// Step-time EWMA z-spike.
    StepTimeSpike,
    /// Queue-depth z-spike.
    QueueSpike,
}

impl AnomalySignature {
    pub fn label(&self) -> &'static str {
        match self {
            AnomalySignature::RungFlap => "rung_flap",
            AnomalySignature::ResidencyThrash => "residency_thrash",
            AnomalySignature::StarvedReplica => "starved_replica",
            AnomalySignature::StepTimeSpike => "step_time_spike",
            AnomalySignature::QueueSpike => "queue_spike",
        }
    }
}

/// A health event with the virtual-time instant it was raised at.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedHealthEvent {
    pub t_s: f64,
    pub event: HealthEvent,
}

impl TimedHealthEvent {
    pub fn to_json(&self) -> Json {
        let mut j = self.event.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("t_s".to_string(), Json::Num(self.t_s));
        }
        j
    }
}

/// Per-class outcome counts of one window bucket (and of the run
/// lifetime): mergeable by field-wise addition.
#[derive(Clone, Debug, Default)]
struct ClassCounts {
    /// Outcomes: completions + rejections (the burn denominator).
    n: u64,
    /// SLO violations: violated completions + rejections.
    violations: u64,
    completed: u64,
    ttft_violations: u64,
    tpot_violations: u64,
    shed: u64,
    rejected: u64,
}

/// One closed-or-open aggregation bucket.
#[derive(Debug)]
struct Bucket {
    start_s: f64,
    per_class: Vec<ClassCounts>,
    steals: u64,
    ttft: Histogram,
    tpot: Histogram,
}

impl Bucket {
    fn new(start_s: f64, n_classes: usize) -> Self {
        Bucket {
            start_s,
            per_class: vec![ClassCounts::default(); n_classes],
            steals: 0,
            ttft: Histogram::new(&LATENCY_BUCKETS_S),
            tpot: Histogram::new(&LATENCY_BUCKETS_S),
        }
    }
}

/// EWMA mean/variance tracker with a z-score probe. The standard
/// deviation is floored at 1% of |mean| so a spike out of a perfectly
/// flat series still registers instead of dividing by ~0.
#[derive(Clone, Debug)]
struct Ewma {
    alpha: f64,
    warmup: u64,
    mean: f64,
    var: f64,
    n: u64,
}

impl Ewma {
    fn new(alpha: f64, warmup: u64) -> Self {
        Ewma {
            alpha,
            warmup,
            mean: 0.0,
            var: 0.0,
            n: 0,
        }
    }

    /// z-score of `x` against the pre-update statistics (`None` until
    /// warmed up), then fold `x` in.
    fn observe(&mut self, x: f64) -> Option<f64> {
        let z = if self.n >= self.warmup {
            let sd = self.var.sqrt().max(1e-9 + 0.01 * self.mean.abs());
            Some((x - self.mean) / sd)
        } else {
            None
        };
        if self.n == 0 {
            self.mean = x;
        } else {
            let d = x - self.mean;
            self.mean += self.alpha * d;
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        }
        self.n += 1;
        z
    }
}

/// Per-replica anomaly state.
#[derive(Debug)]
struct ReplicaDetector {
    step: Ewma,
    queue: Ewma,
    hbm: Ewma,
    /// Rung-switch instants within the last fast window.
    switches: VecDeque<f64>,
    /// Last flag instant per signature (cooldown bookkeeping),
    /// indexed by the order of [`AnomalySignature`] variants.
    last_flag_s: [f64; 5],
}

impl ReplicaDetector {
    fn new(cfg: &HealthConfig) -> Self {
        ReplicaDetector {
            step: Ewma::new(cfg.ewma_alpha, cfg.anomaly_warmup),
            queue: Ewma::new(cfg.ewma_alpha, cfg.anomaly_warmup),
            hbm: Ewma::new(cfg.ewma_alpha, cfg.anomaly_warmup),
            switches: VecDeque::new(),
            last_flag_s: [f64::NEG_INFINITY; 5],
        }
    }

    fn cooldown_ok(&mut self, sig: AnomalySignature, now: f64, window_s: f64) -> bool {
        let i = sig as usize;
        if now - self.last_flag_s[i] >= window_s {
            self.last_flag_s[i] = now;
            true
        } else {
            false
        }
    }
}

/// Per-class burn state machine level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BurnLevel {
    Healthy,
    Warn,
    Critical,
}

/// Run-lifetime per-class totals for the final report.
#[derive(Clone, Debug, Default)]
struct ClassTotals {
    counts: ClassCounts,
    peak_fast_burn: f64,
}

/// Final per-class health summary.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassHealth {
    pub class: usize,
    /// Outcomes (completions + rejections).
    pub n: u64,
    /// SLO violations among them.
    pub violations: u64,
    pub shed: u64,
    pub rejected: u64,
    /// `1 − violations/n` (1.0 with no outcomes).
    pub attainment: f64,
    /// Highest fast-window burn the class ever reached.
    pub peak_fast_burn: f64,
}

/// The digest section of [`HealthOutcome`]: what `TransformReport`
/// embeds and `figures --exp health` plots.
#[derive(Clone, Debug, PartialEq)]
pub struct HealthReport {
    pub makespan_s: f64,
    pub classes: Vec<ClassHealth>,
    /// Highest fast-window burn any class reached.
    pub peak_fast_burn: f64,
    pub warn_events: usize,
    pub critical_events: usize,
    pub recovered_events: usize,
    pub anomaly_events: usize,
    /// Cross-replica steals observed.
    pub steals: u64,
    /// p95 TTFT estimated from the pooled window histograms (the cheap
    /// `Histogram::quantile` path, NOT the exact report percentile).
    pub ttft_p95_est_s: f64,
    /// `(t_s, worst fast burn)` timeline, throttled to ~bucket_s/10
    /// resolution (the burn-rate figure input).
    pub burn_series: Vec<(f64, f64)>,
}

impl HealthReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("makespan_s", Json::Num(self.makespan_s)),
            ("peak_fast_burn", Json::Num(self.peak_fast_burn)),
            ("warn_events", Json::Num(self.warn_events as f64)),
            ("critical_events", Json::Num(self.critical_events as f64)),
            ("recovered_events", Json::Num(self.recovered_events as f64)),
            ("anomaly_events", Json::Num(self.anomaly_events as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("ttft_p95_est_s", Json::Num(self.ttft_p95_est_s)),
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("class", Json::Num(c.class as f64)),
                                ("n", Json::Num(c.n as f64)),
                                ("violations", Json::Num(c.violations as f64)),
                                ("shed", Json::Num(c.shed as f64)),
                                ("rejected", Json::Num(c.rejected as f64)),
                                ("attainment", Json::Num(c.attainment)),
                                ("peak_fast_burn", Json::Num(c.peak_fast_burn)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "burn_series",
                Json::Arr(
                    self.burn_series
                        .iter()
                        .map(|&(t, b)| Json::Arr(vec![Json::Num(t), Json::Num(b)]))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Everything the engine hands back when a run finishes: the digest,
/// the raised events, and any frozen debug bundles (the cluster stays
/// I/O-free; the bench layer writes them to disk).
#[derive(Clone, Debug)]
pub struct HealthOutcome {
    pub report: HealthReport,
    pub events: Vec<TimedHealthEvent>,
    pub bundles: Vec<Json>,
}

/// The streaming health engine. Owned by the cluster when `--health`
/// (or `--pressure burn`) is on; all hooks are O(1) amortized, burn
/// evaluation is O(buckets × classes) per distinct instant.
#[derive(Debug)]
pub struct HealthEngine {
    cfg: HealthConfig,
    n_classes: usize,
    run_config: Json,
    open: Bucket,
    closed: VecDeque<Bucket>,
    levels: Vec<BurnLevel>,
    totals: Vec<ClassTotals>,
    steals_total: u64,
    /// Worst per-class fast burn at the last evaluation (`None` until
    /// any class clears `min_samples`).
    worst_fast_burn: Option<f64>,
    burn_series: Vec<(f64, f64)>,
    events: Vec<TimedHealthEvent>,
    recorder: FlightRecorder,
    bundles: Vec<Json>,
    last_bundle_s: f64,
    last_observed_key: Option<u64>,
    last_snapshot: Option<ClusterSnapshot>,
    detectors: Vec<ReplicaDetector>,
    run_ttft: Histogram,
}

impl HealthEngine {
    /// `run_config` is embedded verbatim in every debug bundle (the
    /// "active config" a bundle reader needs to reproduce the run).
    pub fn new(cfg: HealthConfig, n_classes: usize, run_config: Json) -> Self {
        let n_classes = n_classes.max(1);
        HealthEngine {
            open: Bucket::new(0.0, n_classes),
            closed: VecDeque::new(),
            levels: vec![BurnLevel::Healthy; n_classes],
            totals: vec![ClassTotals::default(); n_classes],
            steals_total: 0,
            worst_fast_burn: None,
            burn_series: Vec::new(),
            events: Vec::new(),
            recorder: FlightRecorder::new(cfg.recorder_cap, cfg.recorder_horizon_s),
            bundles: Vec::new(),
            last_bundle_s: f64::NEG_INFINITY,
            last_observed_key: None,
            last_snapshot: None,
            detectors: Vec::new(),
            run_ttft: Histogram::new(&LATENCY_BUCKETS_S),
            n_classes,
            run_config,
            cfg,
        }
    }

    /// The ladder/shedder pressure reading: a slack-like health
    /// fraction, 1.0 when burn is zero, 0.0 at the critical threshold,
    /// negative beyond it. `None` (treated as +∞ slack by consumers)
    /// until any class has enough window samples to trust.
    pub fn burn_frac(&self) -> Option<f64> {
        self.worst_fast_burn
            .map(|b| 1.0 - b / self.cfg.critical_burn)
    }

    /// Events raised so far (exposed for `bench-serve --health`
    /// progress reporting and tests).
    pub fn events(&self) -> &[TimedHealthEvent] {
        &self.events
    }

    /// Bundles frozen so far.
    pub fn n_bundles(&self) -> usize {
        self.bundles.len()
    }

    // ---------------- event-loop hooks ----------------

    /// Observe the cluster at an event-loop instant. Deduplicated per
    /// distinct integer-ns instant, so revisits within one dispatch
    /// round are free; runs the anomaly detector and the burn state
    /// machine.
    pub fn observe(&mut self, snap: &ClusterSnapshot) {
        let key = time_key(snap.now_s);
        if self.last_observed_key == Some(key) {
            return;
        }
        self.last_observed_key = Some(key);
        let now = snap.now_s;
        self.roll(now);
        self.detect_anomalies(snap);
        self.last_snapshot = Some(snap.clone());
        self.evaluate(now);
    }

    /// An admitted request completed; `slo` is its class's target.
    pub fn on_completion(&mut self, c: &CompletedRequest, slo: SloTarget, now: f64) {
        self.roll(now);
        let tpot = c.tpot_s();
        let ttft_viol = c.ttft_s > slo.ttft_s;
        let tpot_viol = tpot > slo.tpot_s;
        let class = c.class.min(self.n_classes - 1);
        for counts in [
            &mut self.open.per_class[class],
            &mut self.totals[class].counts,
        ] {
            counts.n += 1;
            counts.completed += 1;
            if ttft_viol || tpot_viol {
                counts.violations += 1;
            }
            if ttft_viol {
                counts.ttft_violations += 1;
            }
            if tpot_viol {
                counts.tpot_violations += 1;
            }
        }
        self.open.ttft.observe(c.ttft_s);
        self.open.tpot.observe(tpot);
        self.run_ttft.observe(c.ttft_s);
    }

    /// Admission control rejected a request (hard cap, or the shedder —
    /// the event loop pairs every shed with a reject, so this is the
    /// one denominator hook).
    pub fn on_reject(&mut self, class: usize, now: f64) {
        self.roll(now);
        let class = class.min(self.n_classes - 1);
        for counts in [
            &mut self.open.per_class[class],
            &mut self.totals[class].counts,
        ] {
            counts.n += 1;
            counts.violations += 1;
            counts.rejected += 1;
        }
        self.recorder.record(
            now,
            "reject",
            Json::obj(vec![("class", Json::Num(class as f64))]),
        );
    }

    /// The shedder dropped a request ahead of the hard cap (attribution
    /// only; the paired [`Self::on_reject`] carries the burn counts).
    pub fn on_shed(&mut self, class: usize, reason: &'static str, now: f64) {
        self.roll(now);
        let class = class.min(self.n_classes - 1);
        self.open.per_class[class].shed += 1;
        self.totals[class].counts.shed += 1;
        self.recorder.record(
            now,
            "shed",
            Json::obj(vec![
                ("class", Json::Num(class as f64)),
                ("reason", Json::Str(reason.to_string())),
            ]),
        );
    }

    /// Work stealing migrated a queued request.
    pub fn on_steal(&mut self, victim: usize, thief: usize, now: f64) {
        self.roll(now);
        self.open.steals += 1;
        self.steals_total += 1;
        self.recorder.record(
            now,
            "steal",
            Json::obj(vec![
                ("victim", Json::Num(victim as f64)),
                ("thief", Json::Num(thief as f64)),
            ]),
        );
    }

    /// The ladder controller switched a replica's rung.
    pub fn on_rung_switch(&mut self, replica: usize, rung: usize, now: f64) {
        self.roll(now);
        self.recorder.record(
            now,
            "rung_switch",
            Json::obj(vec![
                ("replica", Json::Num(replica as f64)),
                ("rung", Json::Num(rung as f64)),
            ]),
        );
        self.ensure_detectors(replica + 1);
        let d = &mut self.detectors[replica];
        d.switches.push_back(now);
        let cutoff = now - self.cfg.fast_window_s;
        while d.switches.front().is_some_and(|&t| t < cutoff) {
            d.switches.pop_front();
        }
        if d.switches.len() >= self.cfg.flap_threshold
            && d.cooldown_ok(AnomalySignature::RungFlap, now, self.cfg.fast_window_s)
        {
            self.raise(
                now,
                HealthEvent::Anomaly {
                    replica,
                    signature: AnomalySignature::RungFlap,
                    z: 0.0,
                },
            );
        }
    }

    /// Drain the engine into its outcome at run end.
    pub fn finish(mut self, makespan_s: f64) -> HealthOutcome {
        // close the books at the final instant so the series ends there
        self.roll(makespan_s);
        self.evaluate(makespan_s);
        let classes = self
            .totals
            .iter()
            .enumerate()
            .map(|(class, t)| ClassHealth {
                class,
                n: t.counts.n,
                violations: t.counts.violations,
                shed: t.counts.shed,
                rejected: t.counts.rejected,
                attainment: if t.counts.n > 0 {
                    1.0 - t.counts.violations as f64 / t.counts.n as f64
                } else {
                    1.0
                },
                peak_fast_burn: t.peak_fast_burn,
            })
            .collect::<Vec<_>>();
        let count = |l: &str| self.events.iter().filter(|e| e.event.label() == l).count();
        let report = HealthReport {
            makespan_s,
            peak_fast_burn: classes.iter().fold(0.0f64, |a, c| a.max(c.peak_fast_burn)),
            warn_events: count("burn_warn"),
            critical_events: count("burn_critical"),
            recovered_events: count("recovered"),
            anomaly_events: count("anomaly"),
            steals: self.steals_total,
            ttft_p95_est_s: self.run_ttft.quantile(95.0),
            burn_series: self.burn_series,
            classes,
        };
        HealthOutcome {
            report,
            events: self.events,
            bundles: self.bundles,
        }
    }

    // ---------------- window machinery ----------------

    /// Advance the open bucket so `now` falls inside it, closing full
    /// buckets into the ring.
    fn roll(&mut self, now: f64) {
        while now >= self.open.start_s + self.cfg.bucket_s {
            let next = self.open.start_s + self.cfg.bucket_s;
            let closed = std::mem::replace(&mut self.open, Bucket::new(next, self.n_classes));
            self.closed.push_back(closed);
            if self.closed.len() > self.cfg.n_buckets {
                self.closed.pop_front();
            }
        }
    }

    /// Merge per-class `(n, violations)` over every bucket overlapping
    /// the last `window_s` seconds.
    fn window_counts(&self, now: f64, window_s: f64) -> Vec<(u64, u64)> {
        let cutoff = now - window_s;
        let mut per = vec![(0u64, 0u64); self.n_classes];
        let buckets = self
            .closed
            .iter()
            .filter(|b| b.start_s + self.cfg.bucket_s > cutoff)
            .chain(std::iter::once(&self.open));
        for b in buckets {
            for (class, c) in b.per_class.iter().enumerate() {
                per[class].0 += c.n;
                per[class].1 += c.violations;
            }
        }
        per
    }

    /// Burn rate from a `(n, violations)` window sum; `None` below the
    /// sample floor.
    fn burn_of(&self, n: u64, violations: u64) -> Option<f64> {
        (n >= self.cfg.min_samples)
            .then(|| (violations as f64 / n as f64) / self.cfg.budget_frac)
    }

    /// Run the per-class multi-window state machine and update the
    /// pressure reading + burn timeline.
    fn evaluate(&mut self, now: f64) {
        let fast = self.window_counts(now, self.cfg.fast_window_s);
        let slow = self.window_counts(now, self.cfg.slow_window_s);
        let mut worst: Option<f64> = None;
        let mut transitions: Vec<(usize, BurnLevel, f64, f64)> = Vec::new();
        for class in 0..self.n_classes {
            let fb = self.burn_of(fast[class].0, fast[class].1);
            let sb = self.burn_of(slow[class].0, slow[class].1);
            if let Some(f) = fb {
                worst = Some(worst.map_or(f, |w: f64| w.max(f)));
                if f > self.totals[class].peak_fast_burn {
                    self.totals[class].peak_fast_burn = f;
                }
            }
            let (Some(f), Some(s)) = (fb, sb) else {
                // not enough evidence in one of the windows: hold state
                continue;
            };
            let level = if f >= self.cfg.critical_burn && s >= self.cfg.critical_burn {
                BurnLevel::Critical
            } else if f >= self.cfg.warn_burn && s >= self.cfg.warn_burn {
                BurnLevel::Warn
            } else {
                BurnLevel::Healthy
            };
            if level != self.levels[class] {
                transitions.push((class, level, f, s));
            }
        }
        self.worst_fast_burn = worst;
        for (class, level, f, s) in transitions {
            let prev = self.levels[class];
            self.levels[class] = level;
            match level {
                BurnLevel::Critical => {
                    self.raise(
                        now,
                        HealthEvent::BurnCritical {
                            class,
                            fast_burn: f,
                            slow_burn: s,
                        },
                    );
                    self.dump_bundle(now, class, f, s);
                }
                BurnLevel::Warn => {
                    // only rising edges announce; critical → warn stays
                    // silent until full recovery
                    if prev == BurnLevel::Healthy {
                        self.raise(
                            now,
                            HealthEvent::BurnWarn {
                                class,
                                fast_burn: f,
                                slow_burn: s,
                            },
                        );
                    }
                }
                BurnLevel::Healthy => self.raise(now, HealthEvent::Recovered { class }),
            }
        }
        // throttled burn timeline for `figures --exp health`
        if let Some(w) = worst {
            let due = self
                .burn_series
                .last()
                .is_none_or(|&(t, b)| now - t >= self.cfg.bucket_s / 10.0 || b != w);
            if due {
                self.burn_series.push((now, w));
            }
        }
    }

    fn raise(&mut self, now: f64, event: HealthEvent) {
        self.recorder.record(now, "health", event.to_json());
        self.events.push(TimedHealthEvent { t_s: now, event });
    }

    // ---------------- anomaly detection ----------------

    fn ensure_detectors(&mut self, n: usize) {
        while self.detectors.len() < n {
            self.detectors.push(ReplicaDetector::new(&self.cfg));
        }
    }

    fn detect_anomalies(&mut self, snap: &ClusterSnapshot) {
        let now = snap.now_s;
        self.ensure_detectors(snap.replicas.len());
        let deepest = snap.replicas.iter().map(|t| t.queue_len).max().unwrap_or(0);
        let mut flagged: Vec<(usize, AnomalySignature, f64)> = Vec::new();
        for t in &snap.replicas {
            let d = &mut self.detectors[t.replica.min(self.detectors.len() - 1)];
            let zq = d.queue.observe(t.queue_len as f64);
            let zs = if t.step_ewma_s > 0.0 {
                d.step.observe(t.step_ewma_s)
            } else {
                None
            };
            let zh = t.hbm_pressure.and_then(|p| d.hbm.observe(p));
            let window = self.cfg.fast_window_s;
            if let Some(z) = zq {
                if z.abs() > self.cfg.z_threshold
                    && d.cooldown_ok(AnomalySignature::QueueSpike, now, window)
                {
                    flagged.push((t.replica, AnomalySignature::QueueSpike, z));
                }
            }
            if let Some(z) = zs {
                if z.abs() > self.cfg.z_threshold
                    && d.cooldown_ok(AnomalySignature::StepTimeSpike, now, window)
                {
                    flagged.push((t.replica, AnomalySignature::StepTimeSpike, z));
                }
            }
            if let Some(z) = zh {
                if z.abs() > self.cfg.z_threshold
                    && d.cooldown_ok(AnomalySignature::ResidencyThrash, now, window)
                {
                    flagged.push((t.replica, AnomalySignature::ResidencyThrash, z));
                }
            }
            // starved: accepting and empty while a peer's queue is deep
            if t.accepting
                && t.queue_len == 0
                && t.active == 0
                && deepest >= 4
                && d.cooldown_ok(AnomalySignature::StarvedReplica, now, window)
            {
                flagged.push((t.replica, AnomalySignature::StarvedReplica, 0.0));
            }
        }
        for (replica, signature, z) in flagged {
            self.raise(
                now,
                HealthEvent::Anomaly {
                    replica,
                    signature,
                    z,
                },
            );
        }
    }

    // ---------------- debug bundles ----------------

    /// Health digest embedded in bundles (a lighter sibling of the
    /// final [`HealthReport`], available mid-run).
    fn digest_json(&self) -> Json {
        let peak = self
            .totals
            .iter()
            .fold(0.0f64, |a, t| a.max(t.peak_fast_burn));
        Json::obj(vec![
            ("peak_fast_burn", Json::Num(peak)),
            (
                "worst_fast_burn",
                self.worst_fast_burn.map_or(Json::Null, Json::Num),
            ),
            (
                "events",
                Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "classes",
                Json::Arr(
                    self.totals
                        .iter()
                        .enumerate()
                        .map(|(class, t)| {
                            Json::obj(vec![
                                ("class", Json::Num(class as f64)),
                                ("n", Json::Num(t.counts.n as f64)),
                                ("violations", Json::Num(t.counts.violations as f64)),
                                ("shed", Json::Num(t.counts.shed as f64)),
                                ("rejected", Json::Num(t.counts.rejected as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("steals", Json::Num(self.steals_total as f64)),
        ])
    }

    fn dump_bundle(&mut self, now: f64, class: usize, fast_burn: f64, slow_burn: f64) {
        if self.bundles.len() >= self.cfg.max_bundles
            || now - self.last_bundle_s < self.cfg.bundle_cooldown_s
        {
            return;
        }
        self.last_bundle_s = now;
        let cluster = match &self.last_snapshot {
            Some(s) => s.to_json(),
            None => Json::obj(vec![
                ("now_s", Json::Num(now)),
                ("replicas", Json::Arr(vec![])),
            ]),
        };
        let bundle = Json::obj(vec![
            ("format", Json::Str(BUNDLE_FORMAT.to_string())),
            ("version", Json::Num(BUNDLE_VERSION)),
            ("t_s", Json::Num(now)),
            (
                "trigger",
                Json::obj(vec![
                    ("kind", Json::Str("burn_critical".to_string())),
                    ("class", Json::Num(class as f64)),
                    ("fast_burn", Json::Num(fast_burn)),
                    ("slow_burn", Json::Num(slow_burn)),
                ]),
            ),
            ("config", self.run_config.clone()),
            ("cluster", cluster),
            ("health", self.digest_json()),
            ("events", self.recorder.tail_json(now)),
        ]);
        self.bundles.push(bundle);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::telemetry::ReplicaTelemetry;

    fn slo(ttft_s: f64, tpot_s: f64) -> SloTarget {
        SloTarget { ttft_s, tpot_s }
    }

    fn completion(id: u64, class: usize, ttft_s: f64, finish_s: f64) -> CompletedRequest {
        CompletedRequest {
            id,
            class,
            arrival_s: (finish_s - ttft_s - 0.1).max(0.0),
            prompt_len: 64,
            tokens: 8,
            ttft_s,
            e2e_s: ttft_s + 0.07,
            finish_s,
            replica: 0,
        }
    }

    fn snap_at(now_s: f64) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s,
            replicas: vec![ReplicaTelemetry::idle(0)],
        }
    }

    fn engine() -> HealthEngine {
        HealthEngine::new(
            HealthConfig::default(),
            2,
            Json::obj(vec![("seed", Json::Num(0.0))]),
        )
    }

    #[test]
    fn burn_crosses_critical_and_freezes_a_valid_bundle() {
        let mut h = engine();
        // every completion violates a microscopic TTFT SLO → violation
        // fraction 1.0 → burn = 1.0 / 0.1 = 10 ≥ critical on both
        // windows once min_samples outcomes landed
        let bad = slo(1e-6, 1e-6);
        for i in 0..10u64 {
            let t = 0.2 + i as f64 * 0.1;
            h.on_completion(&completion(i, 0, 0.5, t), bad, t);
            h.observe(&snap_at(t + 1e-3));
        }
        assert!(h.burn_frac().unwrap() < 0.0, "burn beyond critical");
        let critical: Vec<_> = h
            .events()
            .iter()
            .filter(|e| e.event.label() == "burn_critical")
            .collect();
        assert_eq!(critical.len(), 1, "one critical transition");
        assert_eq!(h.n_bundles(), 1, "critical freezes exactly one bundle");

        let out = h.finish(2.0);
        assert_eq!(out.report.critical_events, 1);
        assert!(out.report.peak_fast_burn >= 10.0 - 1e-9);
        assert_eq!(out.report.classes[0].violations, 10);
        assert!((out.report.classes[0].attainment - 0.0).abs() < 1e-12);
        // the frozen bundle passes the validator
        let s = crate::obs::check_bundle(&out.bundles[0]).unwrap();
        assert_eq!(s.trigger, "burn_critical class 0");
        assert_eq!(s.n_replicas, 1);
        // round-trip through text, like `lexi bundle --check` does
        let doc = crate::util::json::parse(&out.bundles[0].to_string_pretty()).unwrap();
        crate::obs::check_bundle(&doc).unwrap();
    }

    #[test]
    fn healthy_runs_raise_nothing_and_recover_after_a_burst() {
        let mut h = engine();
        let easy = slo(10.0, 10.0);
        for i in 0..20u64 {
            let t = 0.1 + i as f64 * 0.05;
            h.on_completion(&completion(i, 0, 0.2, t), easy, t);
            h.observe(&snap_at(t + 1e-3));
        }
        assert!(h.events().is_empty());
        assert!((h.burn_frac().unwrap() - 1.0).abs() < 1e-9, "zero burn → frac 1");

        // now a violating burst drives it critical...
        let bad = slo(1e-6, 1e-6);
        for i in 100..130u64 {
            let t = 2.0 + (i - 100) as f64 * 0.05;
            h.on_completion(&completion(i, 0, 0.5, t), bad, t);
            h.observe(&snap_at(t + 1e-3));
        }
        assert!(h.events().iter().any(|e| e.event.label() == "burn_critical"));
        // ...and a long healthy stretch past the slow window recovers it
        for i in 200..400u64 {
            let t = 70.0 + (i - 200) as f64 * 0.5;
            h.on_completion(&completion(i, 0, 0.2, t), easy, t);
            h.observe(&snap_at(t + 1e-3));
        }
        assert!(h.events().iter().any(|e| e.event.label() == "recovered"));
        let out = h.finish(170.0);
        assert!(out.report.recovered_events >= 1);
        assert!(!out.report.burn_series.is_empty());
    }

    #[test]
    fn rejects_count_as_violations() {
        let mut h = engine();
        for i in 0..10 {
            h.on_reject(1, 0.1 + i as f64 * 0.01);
        }
        h.observe(&snap_at(0.25));
        // class 1 burned its whole budget through rejections alone
        assert!(h.events().iter().any(|e| matches!(
            e.event,
            HealthEvent::BurnCritical { class: 1, .. }
        )));
        let out = h.finish(1.0);
        assert_eq!(out.report.classes[1].rejected, 10);
        assert_eq!(out.report.classes[1].n, 10);
    }

    #[test]
    fn rung_flap_anomaly_fires_on_rapid_switching() {
        let mut h = engine();
        for i in 0..5 {
            h.on_rung_switch(0, i % 2, 0.5 + i as f64 * 0.2);
        }
        let flaps: Vec<_> = h
            .events()
            .iter()
            .filter(|e| {
                matches!(
                    e.event,
                    HealthEvent::Anomaly {
                        signature: AnomalySignature::RungFlap,
                        ..
                    }
                )
            })
            .collect();
        assert_eq!(flaps.len(), 1, "one flap flag per fast window");
        // switches outside the fast window don't accumulate
        let mut slow = engine();
        for i in 0..6 {
            slow.on_rung_switch(0, i % 2, i as f64 * 20.0);
        }
        assert!(slow.events().is_empty());
    }

    #[test]
    fn residency_thrash_and_queue_spike_flag_on_z_scores() {
        let mut h = engine();
        // warm up with flat series, then spike both
        for i in 0..20 {
            let mut t = ReplicaTelemetry::idle(0);
            t.queue_len = 2;
            t.hbm_pressure = Some(0.05);
            h.observe(&ClusterSnapshot {
                now_s: 0.1 + i as f64 * 0.1,
                replicas: vec![t],
            });
        }
        assert!(h.events().is_empty());
        let mut t = ReplicaTelemetry::idle(0);
        t.queue_len = 40;
        t.hbm_pressure = Some(0.9);
        h.observe(&ClusterSnapshot {
            now_s: 2.5,
            replicas: vec![t],
        });
        let sigs: Vec<&'static str> = h
            .events()
            .iter()
            .filter_map(|e| match &e.event {
                HealthEvent::Anomaly { signature, .. } => Some(signature.label()),
                _ => None,
            })
            .collect();
        assert!(sigs.contains(&"queue_spike"), "{sigs:?}");
        assert!(sigs.contains(&"residency_thrash"), "{sigs:?}");
    }

    #[test]
    fn starved_replica_flags_idle_next_to_deep_queue() {
        let mut h = engine();
        let mut busy = ReplicaTelemetry::idle(0);
        busy.queue_len = 9;
        busy.active = 4;
        let idle = ReplicaTelemetry::idle(1);
        h.observe(&ClusterSnapshot {
            now_s: 1.0,
            replicas: vec![busy, idle],
        });
        assert!(h.events().iter().any(|e| matches!(
            e.event,
            HealthEvent::Anomaly {
                replica: 1,
                signature: AnomalySignature::StarvedReplica,
                ..
            }
        )));
    }

    #[test]
    fn observe_dedupes_one_instant() {
        let mut h = engine();
        let mut busy = ReplicaTelemetry::idle(0);
        busy.queue_len = 9;
        let idle = ReplicaTelemetry::idle(1);
        let snap = ClusterSnapshot {
            now_s: 1.0,
            replicas: vec![busy, idle],
        };
        h.observe(&snap);
        let n = h.events().len();
        h.observe(&snap); // same instant: no double anomaly / evaluate
        assert_eq!(h.events().len(), n);
    }

    #[test]
    fn bundle_dumps_are_rate_limited() {
        let mut cfg = HealthConfig::default();
        cfg.bundle_cooldown_s = 1000.0;
        let mut h = HealthEngine::new(cfg, 1, Json::obj(vec![]));
        let bad = slo(1e-6, 1e-6);
        // drive critical, recover, drive critical again inside cooldown
        for i in 0..10u64 {
            let t = 0.1 + i as f64 * 0.01;
            h.on_completion(&completion(i, 0, 0.5, t), bad, t);
        }
        h.observe(&snap_at(0.3));
        assert_eq!(h.n_bundles(), 1);
        let easy = slo(10.0, 10.0);
        for i in 20..220u64 {
            let t = 70.0 + (i - 20) as f64 * 0.5;
            h.on_completion(&completion(i, 0, 0.2, t), easy, t);
            h.observe(&snap_at(t + 1e-3));
        }
        for i in 300..320u64 {
            let t = 200.0 + (i - 300) as f64 * 0.01;
            h.on_completion(&completion(i, 0, 0.5, t), bad, t);
        }
        h.observe(&snap_at(201.0));
        // second critical fired but the cooldown suppressed its bundle
        assert!(
            h.events()
                .iter()
                .filter(|e| e.event.label() == "burn_critical")
                .count()
                >= 2
        );
        assert_eq!(h.n_bundles(), 1);
    }

    #[test]
    fn report_json_carries_series_and_classes() {
        let mut h = engine();
        let easy = slo(10.0, 10.0);
        for i in 0..10u64 {
            let t = 0.1 + i as f64 * 0.1;
            h.on_completion(&completion(i, 0, 0.2, t), easy, t);
            h.observe(&snap_at(t + 1e-3));
        }
        let out = h.finish(1.5);
        let j = out.report.to_json();
        assert_eq!(j.get("classes").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("burn_series").unwrap().as_arr().unwrap().len() >= 1);
        assert_eq!(j.get("critical_events").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("ttft_p95_est_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
