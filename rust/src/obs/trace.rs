//! Request-lifecycle event bus: a bounded ring of [`TraceEvent`]s with
//! deterministic ordering.
//!
//! The cluster event loop and both replica backends share one
//! [`Tracer`] (`Rc<RefCell<_>>`; the sim is single-threaded), so every
//! event gets a monotonically increasing sequence number at record time
//! — a total order that is a pure function of the seeded run, never of
//! wall clock. Tracing is off by default: a `None` tracer records
//! nothing and allocates nothing, keeping default runs byte-identical.
//!
//! Timestamps are the virtual-time `now` values the sim itself computes
//! with, so trace-derived latencies are **bit-equal** to reported ones:
//! `t(FirstToken) - arrival_s` is the exact same f64 operation the
//! replica uses for `ttft_s`.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;

use crate::server::backend::CompletedRequest;

/// A shared tracer handle (the sim is single-threaded; `Rc` suffices).
pub type SharedTracer = Rc<RefCell<Tracer>>;

/// Prefill vs. decode phase of a replica step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    Prefill,
    Decode,
}

impl PhaseKind {
    pub fn label(&self) -> &'static str {
        match self {
            PhaseKind::Prefill => "prefill",
            PhaseKind::Decode => "decode",
        }
    }
}

/// One request-lifecycle or control-plane event.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A request reached the cluster front door.
    Arrival { id: u64, class: usize },
    /// Admission control shed the request (a terminal event; closed
    /// loops may re-arrive it later under the same id).
    Reject { id: u64, class: usize },
    /// The routing decision, with the per-replica candidate scores
    /// (load cost; lower wins) the policy saw.
    Route {
        id: u64,
        chosen: usize,
        scores: Vec<f64>,
    },
    /// The request entered a replica's EDF queue.
    QueuePush {
        id: u64,
        replica: usize,
        deadline_ns: u64,
    },
    /// A replica started a prefill or decode phase. `ids` names the
    /// requests newly entering service (prefill cohort); decode phases
    /// leave it empty. `stall_s` is the expert-residency fetch stall
    /// folded into `dur_s`.
    PhaseStart {
        replica: usize,
        phase: PhaseKind,
        rung: usize,
        dur_s: f64,
        stall_s: f64,
        active: usize,
        ids: Vec<u64>,
    },
    /// First output token of a request (TTFT reference point).
    FirstToken { id: u64, replica: usize },
    /// Terminal completion of an admitted request.
    Finish {
        id: u64,
        replica: usize,
        class: usize,
        ttft_s: f64,
        e2e_s: f64,
        tokens: usize,
    },
    /// The ladder controller moved a replica to `rung`.
    RungSwitch { replica: usize, rung: usize },
    /// Work stealing migrated a queued request between replicas.
    Steal { id: u64, victim: usize, thief: usize },
    /// The admission shedder dropped the request before the cap would
    /// have. Always paired with a [`EventKind::Reject`] for the same id
    /// at the same instant — `Reject` keeps span conservation exact,
    /// `Shed` carries the control-plane attribution (`reason`).
    Shed {
        id: u64,
        class: usize,
        reason: &'static str,
    },
    /// The autoscaler activated a replica (after its priced warmup).
    ScaleUp { replica: usize },
    /// The autoscaler began draining a replica toward retirement; the
    /// replica stops accepting new work but finishes what it holds.
    Drain { replica: usize },
}

/// One timestamped event with its deterministic sequence number.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Virtual-time seconds (sim) / event-loop seconds (engine).
    pub t_s: f64,
    /// Record-order sequence number: the deterministic total order.
    pub seq: u64,
    pub kind: EventKind,
}

/// Bounded event recorder. When the ring fills, the oldest events are
/// dropped (and counted) so long runs degrade gracefully instead of
/// growing without bound.
#[derive(Debug)]
pub struct Tracer {
    cap: usize,
    seq: u64,
    dropped: u64,
    events: VecDeque<TraceEvent>,
}

impl Tracer {
    pub fn new(cap: usize) -> Self {
        Tracer {
            cap: cap.max(1),
            seq: 0,
            dropped: 0,
            events: VecDeque::new(),
        }
    }

    pub fn shared(cap: usize) -> SharedTracer {
        Rc::new(RefCell::new(Tracer::new(cap)))
    }

    pub fn record(&mut self, t_s: f64, kind: EventKind) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(TraceEvent {
            t_s,
            seq: self.seq,
            kind,
        });
        self.seq += 1;
    }

    /// Drain the ring into an immutable [`TraceLog`].
    pub fn finish(&mut self) -> TraceLog {
        TraceLog {
            events: self.events.drain(..).collect(),
            dropped: self.dropped,
        }
    }
}

/// Record into an optional shared tracer — the one-line call sites on
/// the hot paths compile to a branch on `None` when tracing is off.
#[inline]
pub fn record_opt(tracer: &Option<SharedTracer>, t_s: f64, kind: impl FnOnce() -> EventKind) {
    if let Some(tr) = tracer {
        let kind = kind();
        tr.borrow_mut().record(t_s, kind);
    }
}

/// The finished, ordered event log of one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceLog {
    pub events: Vec<TraceEvent>,
    /// Events lost to the ring cap (0 on healthy runs).
    pub dropped: u64,
}

impl TraceLog {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn count(&self, pred: impl Fn(&EventKind) -> bool) -> usize {
        self.events.iter().filter(|e| pred(&e.kind)).count()
    }

    /// Virtual time of the first prefill phase that took `id` into
    /// service (the end of its queue wait).
    pub fn prefill_start(&self, id: u64) -> Option<f64> {
        self.events.iter().find_map(|e| match &e.kind {
            EventKind::PhaseStart {
                phase: PhaseKind::Prefill,
                ids,
                ..
            } if ids.contains(&id) => Some(e.t_s),
            _ => None,
        })
    }

    /// Virtual time of the request's first output token.
    pub fn first_token(&self, id: u64) -> Option<f64> {
        self.events.iter().find_map(|e| match &e.kind {
            EventKind::FirstToken { id: i, .. } if *i == id => Some(e.t_s),
            _ => None,
        })
    }

    /// Virtual time of the request's terminal completion.
    pub fn finish_time(&self, id: u64) -> Option<f64> {
        self.events.iter().find_map(|e| match &e.kind {
            EventKind::Finish { id: i, .. } if *i == id => Some(e.t_s),
            _ => None,
        })
    }

    /// Span conservation: every arrival terminates (finish or reject),
    /// and every admitted request finishes exactly once. Returns an
    /// error string naming the first violated invariant.
    pub fn check_conservation(&self) -> Result<(), String> {
        let mut arrivals = 0usize;
        let mut rejects = 0usize;
        let mut finished: BTreeMap<u64, usize> = BTreeMap::new();
        let mut admitted: BTreeSet<u64> = BTreeSet::new();
        for e in &self.events {
            match &e.kind {
                EventKind::Arrival { .. } => arrivals += 1,
                EventKind::Reject { .. } => rejects += 1,
                EventKind::QueuePush { id, .. } => {
                    admitted.insert(*id);
                }
                EventKind::Finish { id, .. } => *finished.entry(*id).or_insert(0) += 1,
                _ => {}
            }
        }
        if self.dropped > 0 {
            return Err(format!("{} events dropped; conservation unknowable", self.dropped));
        }
        let finishes: usize = finished.values().sum();
        if arrivals != finishes + rejects {
            return Err(format!(
                "{arrivals} arrivals but {finishes} finishes + {rejects} rejects"
            ));
        }
        if let Some((id, n)) = finished.iter().find(|(_, &n)| n != 1) {
            return Err(format!("request {id} finished {n} times"));
        }
        if let Some(id) = admitted.iter().find(|id| !finished.contains_key(id)) {
            return Err(format!("request {id} was admitted but never finished"));
        }
        Ok(())
    }

    /// Per-request critical-path breakdowns for every completion.
    ///
    /// `queue_s` is trace-derived (prefill start − arrival); `prefill_s`
    /// and `decode_s` are remainders (`ttft − queue`, `e2e − ttft`) so
    /// the three always reconstruct the reported totals. `stall_s` is
    /// the expert-fetch stall of the request's prefill phase
    /// (overlapped with, not additive to, the phase components).
    pub fn critical_paths(&self, completed: &[CompletedRequest]) -> Vec<CriticalPath> {
        // one pass over events: prefill start + stall per id, steal count
        let mut start: BTreeMap<u64, (f64, f64)> = BTreeMap::new();
        let mut steals: BTreeMap<u64, u32> = BTreeMap::new();
        for e in &self.events {
            match &e.kind {
                EventKind::PhaseStart {
                    phase: PhaseKind::Prefill,
                    stall_s,
                    ids,
                    ..
                } => {
                    for id in ids {
                        start.entry(*id).or_insert((e.t_s, *stall_s));
                    }
                }
                EventKind::Steal { id, .. } => *steals.entry(*id).or_insert(0) += 1,
                _ => {}
            }
        }
        completed
            .iter()
            .map(|c| {
                let (t_prefill, stall_s) =
                    start.get(&c.id).copied().unwrap_or((c.arrival_s, 0.0));
                let queue_s = t_prefill - c.arrival_s;
                CriticalPath {
                    id: c.id,
                    class: c.class,
                    replica: c.replica,
                    arrival_s: c.arrival_s,
                    queue_s,
                    prefill_s: c.ttft_s - queue_s,
                    decode_s: c.e2e_s - c.ttft_s,
                    stall_s,
                    steal_migrations: steals.get(&c.id).copied().unwrap_or(0),
                    ttft_s: c.ttft_s,
                    e2e_s: c.e2e_s,
                }
            })
            .collect()
    }
}

/// Where one request's latency went: queue wait vs prefill vs decode,
/// with expert-stall and steal-migration attribution alongside.
#[derive(Clone, Debug, PartialEq)]
pub struct CriticalPath {
    pub id: u64,
    pub class: usize,
    pub replica: usize,
    pub arrival_s: f64,
    /// Trace-derived EDF queue wait (prefill start − arrival).
    pub queue_s: f64,
    /// `ttft_s − queue_s`: with `queue_s`, reconstructs TTFT exactly.
    pub prefill_s: f64,
    /// `e2e_s − ttft_s`: the decode tail (TPOT × generated tokens).
    pub decode_s: f64,
    /// Expert-residency fetch stall of the request's prefill phase.
    pub stall_s: f64,
    /// Times the request migrated between replicas via work stealing.
    pub steal_migrations: u32,
    pub ttft_s: f64,
    pub e2e_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finish(id: u64) -> EventKind {
        EventKind::Finish {
            id,
            replica: 0,
            class: 0,
            ttft_s: 0.2,
            e2e_s: 0.5,
            tokens: 4,
        }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut t = Tracer::new(2);
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        t.record(1.0, EventKind::Arrival { id: 1, class: 0 });
        t.record(2.0, EventKind::Arrival { id: 2, class: 0 });
        let log = t.finish();
        assert_eq!(log.len(), 2);
        assert_eq!(log.dropped, 1);
        // sequence numbers survive the drop: deterministic total order
        assert_eq!(log.events[0].seq, 1);
        assert_eq!(log.events[1].seq, 2);
    }

    #[test]
    fn conservation_checks() {
        let mut t = Tracer::new(64);
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        t.record(0.0, EventKind::QueuePush { id: 0, replica: 0, deadline_ns: 10 });
        t.record(0.1, EventKind::Arrival { id: 1, class: 1 });
        t.record(0.1, EventKind::Reject { id: 1, class: 1 });
        t.record(0.5, finish(0));
        assert!(t.finish().check_conservation().is_ok());

        // a missing terminal event is caught
        let mut t = Tracer::new(64);
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        let err = t.finish().check_conservation().unwrap_err();
        assert!(err.contains("arrivals"), "{err}");

        // a double finish is caught
        let mut t = Tracer::new(64);
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        t.record(0.5, finish(0));
        t.record(0.6, finish(0));
        let err = t.finish().check_conservation().unwrap_err();
        assert!(err.contains("finished 2 times"), "{err}");
    }

    #[test]
    fn critical_path_reconstructs_totals() {
        let mut t = Tracer::new(64);
        t.record(
            0.25,
            EventKind::PhaseStart {
                replica: 0,
                phase: PhaseKind::Prefill,
                rung: 0,
                dur_s: 0.1,
                stall_s: 0.02,
                active: 1,
                ids: vec![7],
            },
        );
        let log = t.finish();
        let c = CompletedRequest {
            id: 7,
            class: 0,
            arrival_s: 0.1,
            prompt_len: 64,
            tokens: 8,
            ttft_s: 0.25,
            e2e_s: 0.9,
            finish_s: 1.0,
            replica: 0,
        };
        let cp = &log.critical_paths(std::slice::from_ref(&c))[0];
        assert_eq!(cp.queue_s, 0.25 - 0.1);
        // remainder construction: components reconstruct totals exactly
        assert_eq!(cp.prefill_s, c.ttft_s - cp.queue_s);
        assert_eq!(cp.decode_s, c.e2e_s - c.ttft_s);
        assert_eq!(cp.stall_s, 0.02);
        assert_eq!(cp.steal_migrations, 0);
    }
}
