//! Metrics registry: the one percentile implementation every report
//! uses, plus counters / gauges / fixed-bucket histograms with
//! Prometheus-text and JSONL exporters.
//!
//! [`Quantiles`] keeps exact samples (sort once, interpolate like
//! [`percentile_sorted`]) — it is the shared implementation behind
//! `server/report.rs`, `engine/metrics.rs`, and the cross-validation
//! summaries, so swapping them onto it changes no reported number.
//! [`Histogram`] is the fixed-bucket counterpart for the Prometheus
//! exposition, where exact samples would not fit the format.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::server::backend::CompletedRequest;
use crate::util::json::Json;
use crate::util::stats::percentile_sorted;

use super::trace::{EventKind, PhaseKind, TraceLog};

/// Exact-sample quantile estimator: sort once, interpolate many.
/// The numbers are identical to `util::stats::percentile` by
/// construction (same comparator, same interpolation).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Quantiles {
    sorted: Vec<f64>,
}

impl Quantiles {
    pub fn from_samples(xs: impl IntoIterator<Item = f64>) -> Self {
        let mut sorted: Vec<f64> = xs.into_iter().collect();
        // total_cmp: a stray NaN sorts last instead of panicking the
        // whole report out of a comparator unwrap
        sorted.sort_by(f64::total_cmp);
        Quantiles { sorted }
    }

    /// Wrap samples the caller already sorted (ascending, `total_cmp`
    /// order). Lets report builders sort one pooled vector once and
    /// slice it into many estimators instead of re-sorting per metric.
    pub fn from_sorted(sorted: Vec<f64>) -> Self {
        debug_assert!(
            sorted.windows(2).all(|w| w[0].total_cmp(&w[1]).is_le()),
            "from_sorted input must be ascending"
        );
        Quantiles { sorted }
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn q(&self, p: f64) -> f64 {
        percentile_sorted(&self.sorted, p)
    }

    pub fn n(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    pub fn sum(&self) -> f64 {
        self.sorted.iter().sum()
    }
}

/// Fixed-bucket cumulative histogram (Prometheus `le` semantics).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    /// Upper bounds (ascending); an implicit `+Inf` bucket follows.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

/// Default latency buckets (seconds): 1ms .. 10s, roughly log-spaced.
pub const LATENCY_BUCKETS_S: [f64; 12] = [
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0,
];

impl Histogram {
    pub fn new(bounds: &[f64]) -> Self {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// `(le, cumulative_count)` rows, ending with the `+Inf` bucket.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            let le = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, acc));
        }
        out
    }

    /// Estimated percentile (`p` in [0, 100]) by linear interpolation
    /// over the cumulative buckets — the Prometheus
    /// `histogram_quantile` rule. Exactness is bounded by the bucket
    /// grid: the answer lands inside the right bucket, interpolated by
    /// rank within it. Observations in the `+Inf` overflow bucket clamp
    /// to the last finite bound; an empty histogram reports 0.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0) * self.count as f64;
        let mut prev_le = 0.0;
        let mut prev_acc = 0u64;
        for (le, acc) in self.cumulative() {
            if (acc as f64) >= target {
                if le.is_infinite() {
                    return prev_le;
                }
                let in_bucket = (acc - prev_acc) as f64;
                if in_bucket == 0.0 {
                    return le;
                }
                let frac = (target - prev_acc as f64) / in_bucket;
                return prev_le + (le - prev_le) * frac.clamp(0.0, 1.0);
            }
            prev_le = le;
            prev_acc = acc;
        }
        prev_le
    }
}

/// A metric identity: name plus ordered label pairs.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct MetricKey {
    name: String,
    labels: Vec<(String, String)>,
}

fn key(name: &str, labels: &[(&str, String)]) -> MetricKey {
    MetricKey {
        name: name.to_string(),
        labels: labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    }
}

fn label_str(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Counters, gauges, and fixed-bucket histograms keyed by
/// `{replica, class, rung}`-style label sets, with Prometheus text and
/// JSONL snapshot exporters.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<MetricKey, u64>,
    gauges: BTreeMap<MetricKey, f64>,
    hists: BTreeMap<MetricKey, Histogram>,
    help: BTreeMap<String, &'static str>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, labels: &[(&str, String)], by: u64) {
        *self.counters.entry(key(name, labels)).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, labels: &[(&str, String)], v: f64) {
        self.gauges.insert(key(name, labels), v);
    }

    pub fn observe(&mut self, name: &str, labels: &[(&str, String)], bounds: &[f64], v: f64) {
        self.hists
            .entry(key(name, labels))
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    pub fn help(&mut self, name: &'static str, text: &'static str) {
        self.help.insert(name.to_string(), text);
    }

    pub fn counter(&self, name: &str, labels: &[(&str, String)]) -> u64 {
        self.counters.get(&key(name, labels)).copied().unwrap_or(0)
    }

    /// Sum of one counter over every label set it was recorded with.
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, v)| v)
            .sum()
    }

    /// Prometheus text exposition (`# TYPE` lines, histogram
    /// `_bucket`/`_sum`/`_count` expansion, `le="+Inf"` terminator).
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        // BTreeMap order groups label sets under one # TYPE header
        let mut last = String::new();
        for (k, v) in &self.counters {
            if last != k.name {
                if let Some(h) = self.help.get(&k.name) {
                    let _ = writeln!(out, "# HELP {} {h}", k.name);
                }
                let _ = writeln!(out, "# TYPE {} counter", k.name);
                last = k.name.clone();
            }
            let _ = writeln!(out, "{}{} {v}", k.name, label_str(&k.labels));
        }
        last.clear();
        for (k, v) in &self.gauges {
            if last != k.name {
                if let Some(h) = self.help.get(&k.name) {
                    let _ = writeln!(out, "# HELP {} {h}", k.name);
                }
                let _ = writeln!(out, "# TYPE {} gauge", k.name);
                last = k.name.clone();
            }
            let _ = writeln!(out, "{}{} {v}", k.name, label_str(&k.labels));
        }
        last.clear();
        for (k, h) in &self.hists {
            if last != k.name {
                if let Some(help) = self.help.get(&k.name) {
                    let _ = writeln!(out, "# HELP {} {help}", k.name);
                }
                let _ = writeln!(out, "# TYPE {} histogram", k.name);
                last = k.name.clone();
            }
            for (le, c) in h.cumulative() {
                let mut labels = k.labels.clone();
                let le_s = if le.is_infinite() {
                    "+Inf".to_string()
                } else {
                    format!("{le}")
                };
                labels.push(("le".to_string(), le_s));
                let _ = writeln!(out, "{}_bucket{} {c}", k.name, label_str(&labels));
            }
            let _ = writeln!(out, "{}_sum{} {}", k.name, label_str(&k.labels), h.sum());
            let _ = writeln!(out, "{}_count{} {}", k.name, label_str(&k.labels), h.count());
        }
        out
    }

    /// Build the full registry from one finished run: every request
    /// outcome, phase, stall, steal, and rung switch keyed by
    /// `{replica, class, rung}`.
    pub fn from_run(log: &TraceLog, completed: &[CompletedRequest]) -> Self {
        let mut m = MetricsRegistry::new();
        m.help("lexi_requests_completed_total", "completions per replica x class");
        m.help("lexi_requests_rejected_total", "admission-control sheds per class");
        m.help("lexi_steals_total", "queued requests migrated by work stealing");
        m.help("lexi_rung_switches_total", "ladder rung switches per replica");
        m.help("lexi_trace_events_dropped", "events lost to the trace ring cap");
        m.help(
            "lexi_trace_events_dropped_total",
            "events lost to the trace ring cap (counter twin: alertable, so truncated traces can't masquerade as complete)",
        );
        m.help("lexi_ttft_seconds", "time to first token per class");
        m.help("lexi_tpot_seconds", "time per output token per class");
        m.help("lexi_queue_wait_seconds", "EDF queue wait per class");
        m.help("lexi_phase_seconds", "phase duration per replica x phase x rung");
        m.help("lexi_expert_stall_seconds", "expert fetch stall per replica");
        m.help("lexi_requests_shed_total", "policy sheds per class x reason");
        m.help("lexi_scale_events_total", "autoscaler actions per kind");
        m.help("lexi_replicas_live", "replicas accepting work at run end");
        m.set_gauge("lexi_trace_events_dropped", &[], log.dropped as f64);
        m.inc("lexi_trace_events_dropped_total", &[], log.dropped);
        let (mut scale_ups, mut drains) = (0u64, 0u64);
        for e in &log.events {
            match &e.kind {
                EventKind::Reject { class, .. } => {
                    m.inc("lexi_requests_rejected_total", &[("class", class.to_string())], 1);
                }
                EventKind::Shed { class, reason, .. } => {
                    m.inc(
                        "lexi_requests_shed_total",
                        &[("class", class.to_string()), ("reason", reason.to_string())],
                        1,
                    );
                }
                EventKind::ScaleUp { .. } => {
                    scale_ups += 1;
                    m.inc("lexi_scale_events_total", &[("kind", "up".to_string())], 1);
                }
                EventKind::Drain { .. } => {
                    drains += 1;
                    m.inc("lexi_scale_events_total", &[("kind", "drain".to_string())], 1);
                }
                EventKind::Steal { .. } => m.inc("lexi_steals_total", &[], 1),
                EventKind::RungSwitch { replica, .. } => {
                    m.inc("lexi_rung_switches_total", &[("replica", replica.to_string())], 1);
                }
                EventKind::PhaseStart {
                    replica,
                    phase,
                    rung,
                    dur_s,
                    stall_s,
                    ..
                } => {
                    m.observe(
                        "lexi_phase_seconds",
                        &[
                            ("replica", replica.to_string()),
                            ("phase", phase.label().to_string()),
                            ("rung", rung.to_string()),
                        ],
                        &LATENCY_BUCKETS_S,
                        *dur_s,
                    );
                    if *stall_s > 0.0 {
                        m.observe(
                            "lexi_expert_stall_seconds",
                            &[("replica", replica.to_string())],
                            &LATENCY_BUCKETS_S,
                            *stall_s,
                        );
                    }
                }
                _ => {}
            }
        }
        // autoscaled runs emit one ScaleUp per initially-live replica at
        // t=0, so activations minus drains IS the live count; absent any
        // scale events the gauge stays unset (fixed clusters say nothing)
        if scale_ups + drains > 0 {
            m.set_gauge("lexi_replicas_live", &[], scale_ups as f64 - drains as f64);
        }
        for cp in log.critical_paths(completed) {
            m.observe(
                "lexi_queue_wait_seconds",
                &[("class", cp.class.to_string())],
                &LATENCY_BUCKETS_S,
                cp.queue_s,
            );
        }
        for c in completed {
            let labels = [
                ("replica", c.replica.to_string()),
                ("class", c.class.to_string()),
            ];
            m.inc("lexi_requests_completed_total", &labels, 1);
            m.observe(
                "lexi_ttft_seconds",
                &[("class", c.class.to_string())],
                &LATENCY_BUCKETS_S,
                c.ttft_s,
            );
            m.observe(
                "lexi_tpot_seconds",
                &[("class", c.class.to_string())],
                &LATENCY_BUCKETS_S,
                c.tpot_s(),
            );
        }
        m
    }

    /// Fold a finished run's SLO health outcome into the registry:
    /// per-class peak fast-window burn as `lexi_slo_burn_rate` gauges
    /// and every raised event as a `lexi_health_events_total` counter
    /// keyed by kind.
    pub fn record_health(&mut self, h: &crate::obs::health::HealthOutcome) {
        self.help(
            "lexi_slo_burn_rate",
            "peak fast-window error-budget burn rate per SLO class",
        );
        self.help(
            "lexi_health_events_total",
            "health-engine events per kind (burn_warn | burn_critical | recovered | anomaly)",
        );
        for c in &h.report.classes {
            self.set_gauge(
                "lexi_slo_burn_rate",
                &[("class", c.class.to_string())],
                c.peak_fast_burn,
            );
        }
        for e in &h.events {
            self.inc(
                "lexi_health_events_total",
                &[("kind", e.event.label().to_string())],
                1,
            );
        }
    }
}

/// Cumulative run counters sampled at `interval_s` virtual-time
/// boundaries, one compact JSON object per line (the JSONL snapshot
/// export). The final line lands on the last event's timestamp.
pub fn snapshots_jsonl(log: &TraceLog, interval_s: f64) -> String {
    let interval = if interval_s > 0.0 { interval_s } else { 1.0 };
    let mut evs: Vec<(f64, &EventKind)> = log.events.iter().map(|e| (e.t_s, &e.kind)).collect();
    evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut out = String::new();
    let (mut arrivals, mut completed, mut rejected) = (0u64, 0u64, 0u64);
    let (mut steals, mut switches, mut phases) = (0u64, 0u64, 0u64);
    let mut next_t = interval;
    let mut line = |t: f64, a: u64, c: u64, r: u64, s: u64, w: u64, p: u64, out: &mut String| {
        let j = Json::obj(vec![
            ("t_s", Json::Num(t)),
            ("arrivals", Json::Num(a as f64)),
            ("completed", Json::Num(c as f64)),
            ("rejected", Json::Num(r as f64)),
            ("steals", Json::Num(s as f64)),
            ("rung_switches", Json::Num(w as f64)),
            ("phases", Json::Num(p as f64)),
        ]);
        let _ = writeln!(out, "{}", j.to_string_compact());
    };
    for (t, kind) in &evs {
        while *t >= next_t {
            line(next_t, arrivals, completed, rejected, steals, switches, phases, &mut out);
            next_t += interval;
        }
        match kind {
            EventKind::Arrival { .. } => arrivals += 1,
            EventKind::Finish { .. } => completed += 1,
            EventKind::Reject { .. } => rejected += 1,
            EventKind::Steal { .. } => steals += 1,
            EventKind::RungSwitch { .. } => switches += 1,
            EventKind::PhaseStart { .. } => phases += 1,
            _ => {}
        }
    }
    let t_end = evs.last().map(|(t, _)| *t).unwrap_or(0.0);
    line(t_end, arrivals, completed, rejected, steals, switches, phases, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile;

    #[test]
    fn quantiles_match_stats_percentile() {
        let xs = [0.4, 0.1, 0.9, 0.3, 0.2, 0.7];
        let q = Quantiles::from_samples(xs.iter().copied());
        for p in [0.0, 25.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(q.q(p), percentile(&xs, p), "p{p}");
        }
        assert_eq!(q.n(), 6);
        assert_eq!(q.max(), 0.9);
        assert!(Quantiles::from_samples([]).is_empty());
        assert_eq!(Quantiles::from_samples([]).q(50.0), 0.0);
    }

    #[test]
    fn nan_samples_sort_last_instead_of_panicking() {
        // partial_cmp().unwrap() would panic here; total_cmp parks the
        // NaN after every finite sample so the low percentiles stay
        // meaningful
        let q = Quantiles::from_samples([0.3, f64::NAN, 0.1, 0.2]);
        assert_eq!(q.n(), 4);
        assert_eq!(q.q(0.0), 0.1);
        assert!(q.max().is_nan());
        assert!(Quantiles::from_samples([f64::NAN]).q(50.0).is_nan());
    }

    #[test]
    fn from_sorted_matches_from_samples() {
        let xs = [0.4, 0.1, 0.9, 0.3];
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        let a = Quantiles::from_samples(xs.iter().copied());
        let b = Quantiles::from_sorted(sorted);
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(a.q(p), b.q(p));
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut h = Histogram::new(&[0.1, 1.0]);
        for v in [0.05, 0.5, 0.5, 5.0] {
            h.observe(v);
        }
        assert_eq!(h.cumulative(), vec![(0.1, 1), (1.0, 3), (f64::INFINITY, 4)]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 6.05).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantile_interpolates_and_tracks_exact_quantiles() {
        // empty and degenerate cases
        assert_eq!(Histogram::new(&LATENCY_BUCKETS_S).quantile(50.0), 0.0);
        let mut h = Histogram::new(&[1.0, 2.0]);
        h.observe(10.0); // overflow bucket only
        assert_eq!(h.quantile(50.0), 2.0, "overflow clamps to last bound");

        // uniform fill of one bucket: the median interpolates mid-bucket
        let mut h = Histogram::new(&[1.0, 2.0]);
        for _ in 0..4 {
            h.observe(1.5);
        }
        assert!((h.quantile(50.0) - 1.5).abs() < 0.51);

        // against the exact estimator: the bucket-grid estimate must
        // land within one bucket of the true percentile
        let samples: Vec<f64> = (0..200).map(|i| 0.002 + 0.004 * (i % 50) as f64).collect();
        let mut h = Histogram::new(&LATENCY_BUCKETS_S);
        for &s in &samples {
            h.observe(s);
        }
        let exact = Quantiles::from_samples(samples.iter().copied());
        for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
            let e = exact.q(p);
            let est = h.quantile(p);
            // the true value's bucket bounds the estimate
            let hi = LATENCY_BUCKETS_S
                .iter()
                .copied()
                .find(|&b| e <= b)
                .unwrap_or(f64::INFINITY);
            let lo = LATENCY_BUCKETS_S
                .iter()
                .copied()
                .rev()
                .find(|&b| b < e)
                .unwrap_or(0.0);
            assert!(
                est >= lo - 1e-12 && est <= hi + 1e-12,
                "p{p}: estimate {est} outside bucket [{lo}, {hi}] of exact {e}"
            );
        }
        // quantiles are monotone in p
        assert!(h.quantile(10.0) <= h.quantile(50.0));
        assert!(h.quantile(50.0) <= h.quantile(99.0));
    }

    #[test]
    fn dropped_events_export_a_counter_twin() {
        // a 2-cap ring fed 4 events drops 2
        let mut t = crate::obs::Tracer::new(2);
        for i in 0..4 {
            t.record(i as f64, EventKind::Arrival { id: i, class: 0 });
        }
        let log = t.finish();
        assert_eq!(log.dropped, 2);
        let m = MetricsRegistry::from_run(&log, &[]);
        assert_eq!(m.counter_total("lexi_trace_events_dropped_total"), 2);
        let text = m.prometheus_text();
        assert!(text.contains("# TYPE lexi_trace_events_dropped_total counter"));
        // a clean run exports the counter at zero
        let mut t = crate::obs::Tracer::new(8);
        t.record(0.0, EventKind::Arrival { id: 0, class: 0 });
        let m = MetricsRegistry::from_run(&t.finish(), &[]);
        assert_eq!(m.counter_total("lexi_trace_events_dropped_total"), 0);
    }

    #[test]
    fn record_health_registers_burn_gauges_and_event_counters() {
        use crate::obs::health::{
            ClassHealth, HealthEvent, HealthOutcome, HealthReport, TimedHealthEvent,
        };
        let outcome = HealthOutcome {
            report: HealthReport {
                makespan_s: 10.0,
                classes: vec![ClassHealth {
                    class: 0,
                    n: 20,
                    violations: 5,
                    shed: 1,
                    rejected: 2,
                    attainment: 0.75,
                    peak_fast_burn: 3.5,
                }],
                peak_fast_burn: 3.5,
                warn_events: 1,
                critical_events: 0,
                recovered_events: 0,
                anomaly_events: 1,
                steals: 0,
                ttft_p95_est_s: 0.4,
                burn_series: vec![(1.0, 3.5)],
            },
            events: vec![
                TimedHealthEvent {
                    t_s: 1.0,
                    event: HealthEvent::BurnWarn {
                        class: 0,
                        fast_burn: 3.5,
                        slow_burn: 2.2,
                    },
                },
                TimedHealthEvent {
                    t_s: 2.0,
                    event: HealthEvent::Anomaly {
                        replica: 1,
                        signature: crate::obs::health::AnomalySignature::QueueSpike,
                        z: 4.2,
                    },
                },
            ],
            bundles: vec![],
        };
        let mut m = MetricsRegistry::new();
        m.record_health(&outcome);
        assert_eq!(
            m.counter("lexi_health_events_total", &[("kind", "burn_warn".to_string())]),
            1
        );
        assert_eq!(
            m.counter("lexi_health_events_total", &[("kind", "anomaly".to_string())]),
            1
        );
        let text = m.prometheus_text();
        assert!(text.contains("lexi_slo_burn_rate{class=\"0\"} 3.5"));
    }

    #[test]
    fn prometheus_text_has_types_and_inf() {
        let mut m = MetricsRegistry::new();
        m.inc("lexi_x_total", &[("class", "0".to_string())], 2);
        m.set_gauge("lexi_g", &[], 1.5);
        m.observe("lexi_h_seconds", &[], &[0.1], 0.05);
        let text = m.prometheus_text();
        assert!(text.contains("# TYPE lexi_x_total counter"));
        assert!(text.contains("lexi_x_total{class=\"0\"} 2"));
        assert!(text.contains("# TYPE lexi_g gauge"));
        assert!(text.contains("# TYPE lexi_h_seconds histogram"));
        assert!(text.contains("lexi_h_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("lexi_h_seconds_count 1"));
        assert_eq!(m.counter_total("lexi_x_total"), 2);
    }

    #[test]
    fn elastic_events_feed_counters_and_the_live_gauge() {
        let mut t = crate::obs::Tracer::new(64);
        t.record(0.0, EventKind::ScaleUp { replica: 0 });
        t.record(0.0, EventKind::ScaleUp { replica: 1 });
        t.record(1.0, EventKind::Shed { id: 7, class: 2, reason: "queue" });
        t.record(1.5, EventKind::Shed { id: 8, class: 2, reason: "slack" });
        t.record(2.0, EventKind::ScaleUp { replica: 2 });
        t.record(9.0, EventKind::Drain { replica: 2 });
        let log = t.finish();
        let m = MetricsRegistry::from_run(&log, &[]);
        assert_eq!(
            m.counter(
                "lexi_requests_shed_total",
                &[("class", "2".to_string()), ("reason", "queue".to_string())],
            ),
            1
        );
        assert_eq!(m.counter_total("lexi_requests_shed_total"), 2);
        assert_eq!(m.counter("lexi_scale_events_total", &[("kind", "up".to_string())]), 3);
        assert_eq!(m.counter("lexi_scale_events_total", &[("kind", "drain".to_string())]), 1);
        // 3 activations - 1 drain = 2 live at run end
        let text = m.prometheus_text();
        assert!(text.contains("lexi_replicas_live 2"));
        // a run without scale events keeps the gauge unset
        let empty = MetricsRegistry::from_run(&crate::obs::Tracer::new(8).finish(), &[]);
        assert!(!empty.prometheus_text().contains("lexi_replicas_live"));
    }

    #[test]
    fn snapshots_cover_the_run() {
        let mut t = crate::obs::Tracer::new(64);
        t.record(0.2, EventKind::Arrival { id: 0, class: 0 });
        t.record(
            2.5,
            EventKind::Finish {
                id: 0,
                replica: 0,
                class: 0,
                ttft_s: 0.5,
                e2e_s: 2.3,
                tokens: 4,
            },
        );
        let log = t.finish();
        let jsonl = snapshots_jsonl(&log, 1.0);
        let lines: Vec<&str> = jsonl.lines().collect();
        // boundaries at t=1, t=2, plus the final line at t=2.5
        assert_eq!(lines.len(), 3);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("arrivals").unwrap().as_usize().unwrap(), 1);
        assert_eq!(first.get("completed").unwrap().as_usize().unwrap(), 0);
        let last = crate::util::json::parse(lines[2]).unwrap();
        assert_eq!(last.get("completed").unwrap().as_usize().unwrap(), 1);
    }
}
