//! Sim self-profiler: scoped wall-clock timers around the event loop's
//! own hot sections (EDF queue ops, snapshot construction, routing,
//! telemetry scans), answering the ROADMAP's "how fast is the simulator
//! itself" question.
//!
//! Disabled by default: [`scope`] checks one thread-local flag and
//! returns `None` without touching the clock, so instrumented hot paths
//! cost a predictable branch. Timings are wall clock and feed only the
//! `BENCH_selfprof.json` trajectory — they never enter the virtual-time
//! sim, so profiling cannot perturb sim outputs.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static SECTIONS: RefCell<BTreeMap<&'static str, SectionStat>> =
        const { RefCell::new(BTreeMap::new()) };
}

/// Aggregate timing of one instrumented section.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SectionStat {
    pub calls: u64,
    pub total_ns: u128,
    pub max_ns: u128,
}

/// Start collecting (clears any previous sections).
pub fn enable() {
    SECTIONS.with(|s| s.borrow_mut().clear());
    ENABLED.with(|e| e.set(true));
}

pub fn is_enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Stop collecting and return the profile gathered since [`enable`].
pub fn disable_and_collect() -> SelfProfile {
    ENABLED.with(|e| e.set(false));
    let sections = SECTIONS.with(|s| std::mem::take(&mut *s.borrow_mut()));
    SelfProfile {
        sections: sections.into_iter().collect(),
    }
}

/// RAII timer: records elapsed wall time into its section on drop.
pub struct ProfGuard {
    key: &'static str,
    start: Instant,
}

impl Drop for ProfGuard {
    fn drop(&mut self) {
        let dt = self.start.elapsed().as_nanos();
        SECTIONS.with(|s| {
            let mut map = s.borrow_mut();
            let stat = map.entry(self.key).or_default();
            stat.calls += 1;
            stat.total_ns += dt;
            stat.max_ns = stat.max_ns.max(dt);
        });
    }
}

/// Scoped timer for `key`; `None` (and no clock read) when disabled.
#[inline]
pub fn scope(key: &'static str) -> Option<ProfGuard> {
    if !is_enabled() {
        return None;
    }
    Some(ProfGuard {
        key,
        start: Instant::now(),
    })
}

/// Time the rest of the enclosing scope under `key` when the
/// self-profiler is enabled; a single thread-local branch otherwise.
#[macro_export]
macro_rules! prof_scope {
    ($key:expr) => {
        let _prof_guard = $crate::obs::selfprof::scope($key);
    };
}

/// A finished self-profile, exportable as a `BENCH_selfprof.json`
/// trajectory entry.
#[derive(Clone, Debug, Default)]
pub struct SelfProfile {
    /// `(section, stat)` pairs, sorted by section name.
    pub sections: Vec<(&'static str, SectionStat)>,
}

impl SelfProfile {
    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }

    /// Trajectory entry: per-section call counts and wall-time totals.
    pub fn to_json(&self, label: &str) -> Json {
        Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            (
                "sections",
                Json::Arr(
                    self.sections
                        .iter()
                        .map(|(name, s)| {
                            Json::obj(vec![
                                ("name", Json::Str(name.to_string())),
                                ("calls", Json::Num(s.calls as f64)),
                                ("total_ms", Json::Num(s.total_ns as f64 / 1e6)),
                                (
                                    "mean_us",
                                    Json::Num(if s.calls > 0 {
                                        s.total_ns as f64 / 1e3 / s.calls as f64
                                    } else {
                                        0.0
                                    }),
                                ),
                                ("max_us", Json::Num(s.max_ns as f64 / 1e3)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn print(&self) {
        println!("--- sim self-profile ({} sections) ---", self.sections.len());
        for (name, s) in &self.sections {
            println!(
                "{name:<24} {:>10} calls  {:>10.3} ms total  {:>8.3} us/call",
                s.calls,
                s.total_ns as f64 / 1e6,
                if s.calls > 0 {
                    s.total_ns as f64 / 1e3 / s.calls as f64
                } else {
                    0.0
                }
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_is_inert() {
        assert!(!is_enabled());
        assert!(scope("x").is_none());
        assert!(disable_and_collect().is_empty());
    }

    #[test]
    fn enabled_scope_records_sections() {
        enable();
        for _ in 0..3 {
            prof_scope!("test.section");
            std::hint::black_box(1 + 1);
        }
        {
            prof_scope!("test.other");
        }
        let prof = disable_and_collect();
        assert!(!is_enabled());
        let sec = prof
            .sections
            .iter()
            .find(|(n, _)| *n == "test.section")
            .expect("section recorded");
        assert_eq!(sec.1.calls, 3);
        assert!(sec.1.max_ns <= sec.1.total_ns);
        assert_eq!(prof.sections.len(), 2);
        // json export round-trips
        let j = prof.to_json("unit");
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "unit");
        assert_eq!(j.get("sections").unwrap().as_arr().unwrap().len(), 2);
        // collection cleared the buffer
        assert!(disable_and_collect().is_empty());
    }
}
