//! Unified observability: request-span tracing, a metrics registry,
//! exporters, and a sim self-profiler.
//!
//! The serving control plane makes every decision (routing, ladder
//! moves, stealing, eviction) from telemetry, but run-level percentile
//! reports cannot answer "why did THIS request miss its TTFT SLO" or
//! "where does the event loop itself spend time". This module is the
//! one observability layer both replica backends share:
//!
//! - [`trace`]    — [`TraceEvent`] ring buffer recording request
//!   lifecycle spans (admission, EDF queue wait, route decision with
//!   candidate scores, prefill/decode phases, rung switches, expert
//!   stalls, steals, terminal events), deterministically ordered and
//!   **off by default**: a disabled tracer records nothing, allocates
//!   nothing on the hot path, and leaves every sim output byte-identical
//!   to the untraced build.
//! - [`metrics`]  — [`Quantiles`] (the one exact-sample percentile
//!   implementation every report uses) plus a [`MetricsRegistry`] of
//!   counters / gauges / fixed-bucket histograms keyed by
//!   `{replica, class, rung}`, exported as Prometheus text and JSONL
//!   snapshots at configurable virtual-time intervals.
//! - [`export`]   — Chrome/Perfetto `trace_event` JSON, the
//!   per-request critical-path breakdown CSV (queue vs prefill vs
//!   decode vs expert stall vs steal migration), and the shape
//!   checkers behind `lexi trace --check`.
//! - [`selfprof`] — scoped wall-clock timers ([`prof_scope!`]) around
//!   the sim's own hot sections (EDF queue ops, snapshot construction,
//!   routing, telemetry scans), aggregated into the repo-root
//!   `BENCH_selfprof.json` trajectory.
//! - [`health`]   — the streaming SLO health engine
//!   (`bench-serve --health`): sliding virtual-time windows of
//!   per-class attainment, multi-window error-budget burn rates raised
//!   as typed [`HealthEvent`]s, an EWMA z-score anomaly detector
//!   (rung-flap, residency-thrash, starved-replica signatures), and the
//!   `--pressure burn` feedback signal for the ladder and shedder.
//! - [`recorder`] — the always-on bounded [`FlightRecorder`] behind the
//!   health engine; critical events freeze its tail into self-contained
//!   `debug_bundle_<t>.json` documents validated by `lexi bundle
//!   --check` ([`check_bundle`]).

pub mod export;
pub mod health;
pub mod metrics;
pub mod recorder;
pub mod selfprof;
pub mod trace;

pub use export::{check_perfetto, check_prometheus, perfetto_json, write_critical_path_csv};
pub use health::{
    AnomalySignature, HealthConfig, HealthEngine, HealthEvent, HealthOutcome, HealthReport,
    TimedHealthEvent,
};
pub use metrics::{Histogram, MetricsRegistry, Quantiles};
pub use recorder::{check_bundle, BundleSummary, FlightRecorder};
pub use selfprof::SelfProfile;
pub use trace::{CriticalPath, EventKind, PhaseKind, SharedTracer, TraceEvent, TraceLog, Tracer};

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append `entry` to a `{"entries": [...]}` trajectory file (the
/// repo-root `BENCH_serve.json` / `BENCH_selfprof.json` format),
/// creating the file with `bench` metadata when it does not exist yet.
/// A file that exists but fails to parse is backed up to `<path>.bad`
/// (with a warning) before the fresh document replaces it, so a corrupt
/// trajectory never silently loses its history.
pub fn append_trajectory(path: &Path, bench: &str, entry: Json) -> Result<()> {
    let mut doc = match crate::util::json::parse_file(path) {
        Ok(j) => j,
        Err(err) => {
            if path.exists() {
                let bad = path.with_extension(
                    path.extension()
                        .map(|e| format!("{}.bad", e.to_string_lossy()))
                        .unwrap_or_else(|| "bad".to_string()),
                );
                std::fs::rename(path, &bad).with_context(|| {
                    format!("backing up corrupt trajectory to {}", bad.display())
                })?;
                eprintln!(
                    "warning: trajectory {} is corrupt ({err:#}); backed up to {} and starting fresh",
                    path.display(),
                    bad.display()
                );
            }
            Json::obj(vec![
                ("bench", Json::Str(bench.to_string())),
                ("entries", Json::Arr(vec![])),
            ])
        }
    };
    match &mut doc {
        Json::Obj(map) => {
            let entries = map
                .entry("entries".to_string())
                .or_insert_with(|| Json::Arr(vec![]));
            match entries {
                Json::Arr(v) => v.push(entry),
                other => anyhow::bail!("'entries' in {} is {other:?}, not an array", path.display()),
            }
        }
        other => anyhow::bail!("{} holds {other:?}, not an object", path.display()),
    }
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing trajectory {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectory_appends_and_creates() {
        let dir = std::env::temp_dir().join("lexi_obs_trajectory_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_t.json");
        append_trajectory(&path, "t", Json::obj(vec![("x", Json::Num(1.0))])).unwrap();
        append_trajectory(&path, "t", Json::obj(vec![("x", Json::Num(2.0))])).unwrap();
        let j = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "t");
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].get("x").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn corrupt_trajectory_is_backed_up_not_destroyed() {
        let dir = std::env::temp_dir().join("lexi_obs_trajectory_bad_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_t.json");
        std::fs::write(&path, "{ not json").unwrap();
        append_trajectory(&path, "t", Json::obj(vec![("x", Json::Num(1.0))])).unwrap();
        // the fresh file holds the new entry...
        let j = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(j.get("entries").unwrap().as_arr().unwrap().len(), 1);
        // ...and the corrupt original survives as .json.bad
        let bad = dir.join("BENCH_t.json.bad");
        assert_eq!(std::fs::read_to_string(&bad).unwrap(), "{ not json");
    }
}
