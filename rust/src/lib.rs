//! # lexi-moe
//!
//! Full-system reproduction of **LExI: Layer-Adaptive Active Experts for
//! Efficient MoE Model Inference** (Chitty-Venkata et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is the Layer-3 coordinator: a vLLM-like serving engine, the
//! LExI optimizer (Stage-1 Monte-Carlo sensitivity profiling + Stage-2
//! evolutionary allocation search), the pruning baselines the paper
//! compares against, an analytical H100 performance model, the evaluation
//! harness, and the per-figure experiment drivers. Model compute runs in
//! AOT-compiled XLA executables loaded via PJRT (`runtime`); Python is
//! never on the request path.
//!
//! Module map (see DESIGN.md §5):
//! - [`config`]  — model / serving / experiment configuration
//! - [`moe`]     — MoE architecture substrate (geometry, allocations, routing)
//! - [`lexi`]    — the paper's contribution (Alg. 1 + Alg. 2)
//! - [`pruning`] — inter / intra / dynamic-skip baselines
//! - [`perfmodel`] — H100 roofline + load-balance + comm simulator
//!   (optionally under an HBM expert budget)
//! - [`experts`] — expert residency subsystem: tiered HBM/host weight
//!   store, pluggable eviction (LRU / LFU / k_vec-aware pinning), and
//!   predictive prefetch from routing popularity (`lexi bench-memory`)
//! - [`runtime`] — model backends: the PJRT bridge (HLO text ->
//!   compiled executables) and the synthetic host model, both behind
//!   [`runtime::ModelBackend`]
//! - [`engine`]  — continuous-batching serving stack (generic over the
//!   model backend)
//! - [`server`]  — multi-replica front-end: scenarios + trace replay,
//!   SLO scheduling, the [`server::ReplicaBackend`] trait over
//!   simulated/real replicas, and a telemetry-driven control plane
//!   ([`server::ClusterSnapshot`] → routing incl. SLO-class-aware,
//!   queue/EDF-slack adaptive quality lattice — active-experts budgets
//!   x optional intra-expert sparsity / dynamic-skip axis
//!   ([`server::QualityLattice`]) — cross-replica work stealing)
//! - [`ctrl`]    — elastic control plane over the same snapshots:
//!   class-aware admission shedding ([`ctrl::Shedder`]), a replica
//!   autoscaler pricing spin-up as expert prewarm + Stage-1 table load
//!   ([`ctrl::Autoscaler`]), and heterogeneous replica tiers with
//!   speed-weighted routing (`lexi bench-elasticity`)
//! - [`calibrate`] — calibration subsystem: occupancy-bucketed engine
//!   step-time artifacts, least-squares refit of the sim
//!   [`server::ServiceModel`] per ladder rung
//!   (`ServiceModel::from_calibration`), and the `lexi calibrate` /
//!   `lexi cross-validate` backend cross-validation gate
//! - [`obs`]     — unified observability: per-request span tracing
//!   ([`obs::Tracer`], off by default and byte-identical when
//!   disabled), the shared metrics registry / [`obs::Quantiles`]
//!   percentile implementation, Perfetto + Prometheus + critical-path
//!   exporters (`lexi trace`), the sim self-profiler
//!   (`BENCH_selfprof.json`), and the SLO health engine
//!   ([`obs::HealthEngine`]: windowed burn-rate monitoring + EWMA
//!   anomaly detection) with its always-on flight recorder
//!   ([`obs::FlightRecorder`]) dumping debug bundles validated by
//!   `lexi bundle --check`
//! - [`eval`]    — task harness (ppl, passkey, longqa, probes, VLM)
//! - [`figures`] — regeneration of every paper table/figure
//! - [`util`]    — rng, stats, csv

pub mod calibrate;
pub mod config;
pub mod ctrl;
pub mod engine;
pub mod eval;
pub mod experts;
pub mod figures;
pub mod lexi;
pub mod moe;
pub mod obs;
pub mod perfmodel;
pub mod pruning;
pub mod runtime;
pub mod server;
pub mod util;

pub use config::model::{ModelSpec, PaperScale, MODEL_NAMES};
pub use moe::allocation::Allocation;
