//! Scalar statistics helpers shared by metrics and the perf model.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile (p in [0, 100]); input need not be sorted.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, p)
}

/// [`percentile`] over an already-sorted slice — use when taking several
/// percentiles of the same data (avoids re-cloning and re-sorting).
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Frobenius norm of the difference of two equal-length vectors.
pub fn frobenius_diff(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Token-level F1 between predicted and gold token multisets (Qasper metric).
pub fn token_f1(pred: &[i32], gold: &[i32]) -> f64 {
    if pred.is_empty() || gold.is_empty() {
        return if pred.is_empty() && gold.is_empty() { 1.0 } else { 0.0 };
    }
    let mut gold_counts = std::collections::HashMap::new();
    for t in gold {
        *gold_counts.entry(*t).or_insert(0i32) += 1;
    }
    let mut overlap = 0;
    for t in pred {
        if let Some(c) = gold_counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn f1_exact_match() {
        assert!((token_f1(&[1, 2], &[1, 2]) - 1.0).abs() < 1e-9);
        assert_eq!(token_f1(&[3, 4], &[1, 2]), 0.0);
        let half = token_f1(&[1, 9], &[1, 2]);
        assert!((half - 0.5).abs() < 1e-9);
    }

    #[test]
    fn frobenius_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(frobenius_diff(&a, &a), 0.0);
        let b = [1.0f32, -2.0, 4.0];
        assert!((frobenius_diff(&a, &b) - 1.0).abs() < 1e-9);
    }
}
