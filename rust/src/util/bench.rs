//! Tiny benchmark harness (no criterion offline — see Cargo.toml).
//! Auto-calibrates iteration counts, reports mean / p50 / p95 wall time.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget` (default 2 s), after a warmup.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with_budget(name, Duration::from_secs(2), &mut f)
}

pub fn bench_with_budget<F: FnMut()>(
    name: &str,
    budget: Duration,
    f: &mut F,
) -> BenchResult {
    // Warmup + iteration estimation.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let target = (budget.as_nanos() / once.as_nanos()).clamp(3, 10_000) as usize;

    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean,
        p50,
        p95,
    };
    println!("{r}");
    r
}

/// Header line for bench tables.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench_with_budget(
            "noop",
            Duration::from_millis(20),
            &mut || {
                x = x.wrapping_add(1);
            },
        );
        assert!(r.iters >= 3);
        assert!(r.p95 >= r.p50);
    }
}
