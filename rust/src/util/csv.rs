//! Minimal CSV writer for figure series (results/*.csv).

use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: std::fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter {
            file,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.cols,
            "row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }
}

/// Convenience macro: csv_row!(w, model, 1.5, "x") stringifies each field.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($f:expr),+ $(,)?) => {
        $w.row(&[$(format!("{}", $f)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("lexi_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            assert!(w.row(&["1".into()]).is_err());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }
}
