//! PCG32: small, fast, reproducible RNG (O'Neill 2014). Dependency-free so
//! every experiment in the repo is bit-reproducible from a seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    #[inline]
    pub fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u32) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Standard normal via Box-Muller (used for Alg. 1's X ~ N(0,1)).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = (self.gen_f64()).max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a buffer with N(0,1) f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gen_normal() as f32;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential variate with the given rate (Poisson inter-arrivals).
    pub fn gen_exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.gen_f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..1000 {
            assert!(r.gen_range(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Pcg32::seeded(3);
        let w = [0.0, 0.9, 0.1];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > counts[2] * 4);
    }
}
