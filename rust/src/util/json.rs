//! Minimal JSON parser/emitter (the build environment has no serde_json;
//! see Cargo.toml). Supports the full JSON grammar we exchange with the
//! Python build step: objects, arrays, strings with escapes, numbers,
//! bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "not a usize: {n}");
        Ok(n as usize)
    }

    pub fn as_i32(&self) -> Result<i32> {
        let n = self.as_f64()?;
        anyhow::ensure!(n.fract() == 0.0, "not an integer: {n}");
        Ok(n as i32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }

    // ---------------- constructors ----------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_u32s(xs: &[u32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------- emission ----------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parsing ----------------

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    anyhow::ensure!(pos == bytes.len(), "trailing characters at byte {pos}");
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    parse(&text).with_context(|| format!("parsing {path:?}"))
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_number(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<()> {
    anyhow::ensure!(
        b[*pos..].starts_with(word.as_bytes()),
        "expected '{word}' at byte {pos}"
    );
    *pos += word.len();
    Ok(())
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len() && b[*pos] == b':', "expected ':' at {pos}");
        *pos += 1;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            c => bail!("expected ',' or '}}' at byte {pos}, got '{}'", c as char),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut a = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(a));
    }
    loop {
        a.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            c => bail!("expected ',' or ']' at byte {pos}, got '{}'", c as char),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    anyhow::ensure!(*pos < b.len() && b[*pos] == b'"', "expected string at {pos}");
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "bad \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 sequence
                let start = *pos;
                let len = utf8_len(b[*pos]);
                *pos += len;
                s.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
    bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(text.parse::<f64>().with_context(|| {
        format!("bad number '{text}' at byte {start}")
    })?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        let re = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""café — ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ok");
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(1.5).to_string_compact(), "1.5");
    }
}
