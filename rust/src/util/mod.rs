//! Small self-contained utilities: deterministic RNG, statistics, CSV.

pub mod bench;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Pcg32;
pub use stats::{mean, percentile, std_dev};
