//! Expert-importance scoring from calibration statistics.
//!
//! NAEE ranks experts by their contribution on a calibration set; our
//! build step exports per-(layer, expert) router statistics from real
//! forward passes over the training mixture (python/compile/train.py
//! `calibration_stats`). Importance = selection frequency x mean gate
//! mass — experts that are rarely routed to, or receive little weight
//! when they are, score low and are pruned first.

use crate::runtime::weights::CalibStats;

/// Importance score per (layer, expert); higher = keep.
pub fn expert_importance(calib: &CalibStats) -> Vec<Vec<f64>> {
    calib
        .sel_freq
        .iter()
        .zip(&calib.gate_mass)
        .map(|(freq, mass)| {
            freq.iter()
                .zip(mass)
                .map(|(&f, &m)| f as f64 * (1e-9 + m as f64))
                .collect()
        })
        .collect()
}

/// Per-layer keep-masks removing the `frac` least-important experts
/// (never pruning below one survivor).
pub fn keep_masks(importance: &[Vec<f64>], frac: f64) -> Vec<Vec<bool>> {
    importance
        .iter()
        .map(|scores| {
            let e = scores.len();
            let remove = ((e as f64 * frac).round() as usize).min(e - 1);
            let mut order: Vec<usize> = (0..e).collect();
            order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
            let mut keep = vec![true; e];
            for &i in order.iter().take(remove) {
                keep[i] = false;
            }
            keep
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib(freq: Vec<Vec<f32>>, mass: Vec<Vec<f32>>) -> CalibStats {
        CalibStats {
            mean_prob: freq.clone(),
            sel_freq: freq,
            gate_mass: mass,
        }
    }

    #[test]
    fn importance_orders_by_usage() {
        let c = calib(
            vec![vec![0.9, 0.1, 0.5, 0.0]],
            vec![vec![1.0, 1.0, 1.0, 1.0]],
        );
        let imp = expert_importance(&c);
        assert!(imp[0][0] > imp[0][2] && imp[0][2] > imp[0][1] && imp[0][1] > imp[0][3]);
    }

    #[test]
    fn keep_masks_remove_least_important() {
        let imp = vec![vec![0.9, 0.1, 0.5, 0.3]];
        let keep = keep_masks(&imp, 0.5);
        assert_eq!(keep[0], vec![true, false, true, false]);
    }

    #[test]
    fn keep_masks_never_remove_all() {
        let imp = vec![vec![0.1, 0.2]];
        let keep = keep_masks(&imp, 1.0);
        assert_eq!(keep[0].iter().filter(|&&k| k).count(), 1);
    }
}
