//! Baseline post-training optimizations the paper compares against:
//! NAEE inter-expert pruning, MoE-I² intra-expert pruning, and NAEE
//! dynamic expert skipping. All of them (unlike LExI) depend on
//! calibration data, consumed here as the build-time router statistics
//! in `calib.npz`.

pub mod calibration;
pub mod dynamic_skip;
pub mod inter;
pub mod intra;

pub use inter::inter_prune_bias;
pub use intra::intra_prune_params;
