//! NAEE-style inter-expert pruning (Lu et al. 2024).
//!
//! Removes whole experts per layer. At runtime this is expressed as a
//! -1e9 gate-bias on the pruned experts: the router can never select
//! them, and the surviving experts absorb their tokens — exactly the
//! mechanism behind the paper's load-imbalance observation. Memory
//! savings are modeled in `perfmodel` (the executable keeps the weights;
//! the *accuracy* consequence is exact).

use anyhow::Result;

use crate::moe::transform::PRUNE_BIAS;
use crate::runtime::weights::CalibStats;

use super::calibration::{expert_importance, keep_masks};

/// Build the [L*E] gate-bias vector implementing `frac` inter-pruning.
pub fn inter_prune_bias(calib: &CalibStats, frac: f64) -> Vec<f32> {
    let importance = expert_importance(calib);
    let masks = keep_masks(&importance, frac);
    masks
        .iter()
        .flat_map(|layer| {
            layer
                .iter()
                .map(|&keep| if keep { 0.0 } else { PRUNE_BIAS })
        })
        .collect()
}

/// Validate a bias vector: correct count pruned per layer, never all.
pub fn validate_bias(bias: &[f32], n_layers: usize, n_experts: usize, frac: f64) -> Result<()> {
    anyhow::ensure!(bias.len() == n_layers * n_experts);
    let expect = ((n_experts as f64 * frac).round() as usize).min(n_experts - 1);
    for l in 0..n_layers {
        let row = &bias[l * n_experts..(l + 1) * n_experts];
        let pruned = row.iter().filter(|&&b| b != 0.0).count();
        anyhow::ensure!(
            pruned == expect,
            "layer {l}: pruned {pruned}, expected {expect}"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib(l: usize, e: usize) -> CalibStats {
        let mut freq = vec![vec![0.0f32; e]; l];
        for (li, row) in freq.iter_mut().enumerate() {
            for (ei, v) in row.iter_mut().enumerate() {
                *v = ((li * 7 + ei * 13) % e) as f32 / e as f32 + 0.01;
            }
        }
        CalibStats {
            mean_prob: freq.clone(),
            sel_freq: freq.clone(),
            gate_mass: freq,
        }
    }

    #[test]
    fn bias_has_correct_prune_counts() {
        let c = calib(4, 8);
        for frac in [0.125, 0.25, 0.5] {
            let bias = inter_prune_bias(&c, frac);
            validate_bias(&bias, 4, 8, frac).unwrap();
        }
    }

    #[test]
    fn zero_frac_prunes_nothing() {
        let c = calib(2, 8);
        let bias = inter_prune_bias(&c, 0.0);
        assert!(bias.iter().all(|&b| b == 0.0));
    }
}
