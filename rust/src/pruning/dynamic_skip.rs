//! NAEE dynamic expert skipping (Lu et al. 2024, §inference-time policy).
//!
//! Token-adaptive: for a top-2 model, skip the 2nd expert when its gate
//! weight is below `threshold` x the top-1 weight. The paper notes this
//! "cannot work beyond top-k = 2"; we enforce that. Because the decision
//! is per token it cannot be expressed through the static `k_vec` input;
//! its *performance* effect is the expected-k model in `perfmodel`, and
//! its *accuracy* effect is approximated by the k distribution it induces
//! (evaluated in the ablation bench, not the main figures — matching the
//! paper, which excludes it from Figs. 4-8).

use anyhow::Result;

/// Skip decision for one token given its sorted top-2 gate weights.
pub fn should_skip(g1: f32, g2: f32, threshold: f64) -> bool {
    (g2 as f64) < threshold * g1 as f64
}

/// Expected skip rate over a set of (g1, g2) samples.
pub fn skip_rate(gates: &[(f32, f32)], threshold: f64) -> f64 {
    if gates.is_empty() {
        return 0.0;
    }
    gates
        .iter()
        .filter(|&&(g1, g2)| should_skip(g1, g2, threshold))
        .count() as f64
        / gates.len() as f64
}

/// Validate applicability: the paper restricts the policy to k_base = 2.
pub fn check_applicable(k_base: usize) -> Result<()> {
    anyhow::ensure!(
        k_base == 2,
        "dynamic skipping is only defined for top-2 models (got k_base={k_base})"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_threshold_semantics() {
        assert!(should_skip(0.8, 0.1, 0.5)); // 0.1 < 0.4
        assert!(!should_skip(0.6, 0.4, 0.5)); // 0.4 >= 0.3
    }

    #[test]
    fn rate_monotone_in_threshold() {
        let gates: Vec<(f32, f32)> = (0..100)
            .map(|i| {
                let g2 = 0.5 * (i as f32) / 100.0;
                (1.0 - g2, g2)
            })
            .collect();
        let lo = skip_rate(&gates, 0.2);
        let hi = skip_rate(&gates, 0.8);
        assert!(hi > lo);
    }

    #[test]
    fn only_top2_models() {
        assert!(check_applicable(2).is_ok());
        assert!(check_applicable(4).is_err());
    }
}
