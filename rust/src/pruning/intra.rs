//! MoE-I²-style intra-expert pruning (Yang et al. 2024).
//!
//! Shrinks every expert's FFN intermediate dimension. The original uses
//! low-rank decomposition; we implement the structured-magnitude variant:
//! for each (layer, expert), rank FFN columns by the combined magnitude
//! of their W1/W3 input columns and W2 output row, and zero the weakest
//! `frac`. Zeroed columns are mathematically equivalent to removing them
//! (SwiGLU of a zero column is zero), so the *accuracy* effect is exact
//! while the compiled graph keeps its static shape; the FLOP effect is
//! modeled in `perfmodel` with the reduced dim.

use anyhow::Result;

use crate::runtime::weights::HostParams;

/// Zero the weakest `frac` FFN columns of every expert in-place.
/// Expects stacked tensors: w1/w3 [L,E,H,F] and w2 [L,E,F,H].
pub fn intra_prune_params(params: &mut HostParams, frac: f64) -> Result<usize> {
    let shape = params.get("layers/w1")?.shape.clone();
    let (l, e, h, f) = (shape[0], shape[1], shape[2], shape[3]);
    let n_zero = ((f as f64 * frac).round() as usize).min(f - 1);
    if n_zero == 0 {
        return Ok(0);
    }

    // Column scores from the current weights.
    let mut zeroed = 0usize;
    let mut cols: Vec<(f64, usize)> = Vec::with_capacity(f);
    for li in 0..l {
        for ei in 0..e {
            cols.clear();
            {
                let w1 = &params.get("layers/w1")?.data;
                let w3 = &params.get("layers/w3")?.data;
                let w2 = &params.get("layers/w2")?.data;
                let base1 = (li * e + ei) * h * f;
                let base2 = (li * e + ei) * f * h;
                for fi in 0..f {
                    let mut s = 0.0f64;
                    for hi in 0..h {
                        let c1 = w1[base1 + hi * f + fi] as f64;
                        let c3 = w3[base1 + hi * f + fi] as f64;
                        s += c1 * c1 + c3 * c3;
                    }
                    for hi in 0..h {
                        let c2 = w2[base2 + fi * h + hi] as f64;
                        s += c2 * c2;
                    }
                    cols.push((s, fi));
                }
            }
            cols.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let kill: Vec<usize> = cols.iter().take(n_zero).map(|&(_, fi)| fi).collect();
            {
                let base1 = (li * e + ei) * h * f;
                let w1 = &mut params.get_mut("layers/w1")?.data;
                for &fi in &kill {
                    for hi in 0..h {
                        w1[base1 + hi * f + fi] = 0.0;
                    }
                }
                let w3 = &mut params.get_mut("layers/w3")?.data;
                for &fi in &kill {
                    for hi in 0..h {
                        w3[base1 + hi * f + fi] = 0.0;
                    }
                }
                let base2 = (li * e + ei) * f * h;
                let w2 = &mut params.get_mut("layers/w2")?.data;
                for &fi in &kill {
                    for hi in 0..h {
                        w2[base2 + fi * h + hi] = 0.0;
                    }
                }
            }
            zeroed += kill.len();
        }
    }
    Ok(zeroed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::HostTensor;

    fn toy_params(l: usize, e: usize, h: usize, f: usize) -> HostParams {
        let mut p = HostParams::default();
        let n1 = l * e * h * f;
        let mk = |n: usize, seed: u64| -> Vec<f32> {
            let mut rng = crate::util::Pcg32::seeded(seed);
            (0..n).map(|_| rng.gen_normal() as f32).collect()
        };
        p.tensors.insert(
            "layers/w1".into(),
            HostTensor::new(vec![l, e, h, f], mk(n1, 1)),
        );
        p.tensors.insert(
            "layers/w3".into(),
            HostTensor::new(vec![l, e, h, f], mk(n1, 2)),
        );
        p.tensors.insert(
            "layers/w2".into(),
            HostTensor::new(vec![l, e, f, h], mk(n1, 3)),
        );
        p
    }

    #[test]
    fn zeroes_expected_column_count() {
        let mut p = toy_params(2, 3, 4, 8);
        let zeroed = intra_prune_params(&mut p, 0.25).unwrap();
        assert_eq!(zeroed, 2 * 3 * 2); // 25% of 8 = 2 per (layer, expert)
        // verify a zeroed column is fully zero in w1, w3, w2
        let w1 = p.get("layers/w1").unwrap();
        let f = 8;
        let h = 4;
        let mut zero_cols = 0;
        for fi in 0..f {
            let col_zero = (0..h).all(|hi| w1.data[hi * f + fi] == 0.0);
            if col_zero {
                zero_cols += 1;
            }
        }
        assert_eq!(zero_cols, 2);
    }

    #[test]
    fn zero_frac_is_noop() {
        let mut p = toy_params(1, 2, 4, 8);
        let before = p.get("layers/w1").unwrap().data.clone();
        assert_eq!(intra_prune_params(&mut p, 0.0).unwrap(), 0);
        assert_eq!(p.get("layers/w1").unwrap().data, before);
    }

    #[test]
    fn prunes_weakest_columns_first() {
        let mut p = toy_params(1, 1, 2, 4);
        // make column 2 tiny everywhere
        for t in ["layers/w1", "layers/w3"] {
            let w = &mut p.get_mut(t).unwrap().data;
            for hi in 0..2 {
                w[hi * 4 + 2] = 1e-6;
            }
        }
        let w2 = &mut p.get_mut("layers/w2").unwrap().data;
        for hi in 0..2 {
            w2[2 * 2 + hi] = 1e-6;
        }
        intra_prune_params(&mut p, 0.25).unwrap();
        let w1 = &p.get("layers/w1").unwrap().data;
        assert!((0..2).all(|hi| w1[hi * 4 + 2] == 0.0));
        assert!((0..2).any(|hi| w1[hi * 4] != 0.0));
    }
}
