//! Multi-replica serving front-end (the `lexi bench-serve` subsystem).
//!
//! The paper's claim is about *serving* efficiency, so this module puts
//! LExI where it earns its keep: a cluster of N continuous-batching
//! replicas behind admission control, SLO-aware EDF scheduling, and
//! pluggable routing, driven by seeded workload scenarios. The cluster
//! is generic over [`ReplicaBackend`]: virtual-time replicas calibrated
//! from the analytical perf model (deterministic, artifact-free,
//! bit-reproducible from a seed), or real `engine::Engine` replicas
//! behind the same front door (`--backend engine`), wall-clock mapped
//! onto the event loop.
//!
//! Every cluster-level decision flows through one control plane: each
//! replica reports a structured [`ReplicaTelemetry`], the event loop
//! assembles a [`ClusterSnapshot`] per dispatch instant, and routing
//! (including SLO-class-aware joint rung+routing), the quality-ladder
//! controller (queue-depth or EDF-slack pressure), and bounded
//! cross-replica work stealing are all pure functions of that snapshot.
//!
//! Module map:
//! - [`workload`]  — arrival processes x request-shape profiles,
//!   trace replay from recorded JSONL logs
//! - [`scheduler`] — admission control + multi-class EDF queues
//!   (integer-ns deadlines)
//! - [`telemetry`] — `ReplicaTelemetry` / `ClusterSnapshot`, the one
//!   signal surface every cluster policy consumes
//! - [`backend`]   — the `ReplicaBackend` trait the cluster drives
//! - [`replica`]   — virtual-time continuous-batching replica
//! - [`engine_backend`] — real-engine replica (wall-clock phases,
//!   measured step-time histograms)
//! - [`router`]    — cluster, `RoutingPolicy` impls, work stealing,
//!   the event loop
//! - [`ladder`]    — LExI quality ladder + cluster-global controller
//! - [`report`]    — TTFT/TPOT percentiles, goodput-under-SLO, CSV/JSON
//!
//! With `--trace` every run additionally records request-lifecycle
//! spans through the shared [`crate::obs`] layer and emits Perfetto /
//! critical-path / Prometheus / JSONL artifacts per transform; the
//! default stays untraced and byte-identical.
//!
//! With `--hbm-budget` every replica additionally carries an
//! [`ExpertResidency`](crate::experts::ExpertResidency) model: expert
//! weights live in a tiered HBM/host store, demand misses stall phases,
//! rung switches prewarm the pinned hot set, and `lexi bench-memory`
//! sweeps budgets x eviction policies ([`bench_memory`]).
//!
//! With `--shed`, `--autoscale min:max`, and `--replica-tiers` the
//! cluster additionally runs the elastic control plane
//! ([`crate::ctrl`]): class-aware admission shedding, telemetry-driven
//! replica autoscaling (spin-up priced as expert prewarm + table load),
//! and heterogeneous hardware tiers with speed-weighted routing — all
//! pure consumers of the same `ClusterSnapshot`, swept side by side by
//! `lexi bench-elasticity` ([`bench_elasticity`]).

pub mod backend;
pub mod engine_backend;
pub mod ladder;
pub mod replica;
pub mod report;
pub mod router;
pub mod scheduler;
pub mod telemetry;
pub mod workload;

use std::fmt;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::model::ModelSpec;
use crate::config::server::{
    BackendKind, EvictKind, PressureMode, ScenarioKind, ServerConfig, TableMode, TierKind,
};
use crate::config::serving::ServingConfig;
use crate::ctrl::{hardware_for, AutoscalePolicy, Autoscaler, ShedPolicy, Shedder};
use crate::engine::Engine;
use crate::experts::{ExpertResidency, ResidencyConfig};
use crate::lexi::SensitivityTable;
use crate::moe::allocation::Allocation;
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;
use crate::runtime::{Manifest, ModelBackend, ModelRuntime, Runtime, SyntheticModel};

pub use backend::{BackendStats, CompletedRequest, ReplicaBackend};
pub use engine_backend::EngineReplica;
pub use ladder::{
    LadderController, LadderPolicy, PointId, QualityLadder, QualityLattice, QualityPoint, Rung,
};
pub use replica::{Replica, ServiceModel};
pub use report::{
    ElasticityReport, LatencySamples, MemoryReport, QualitySurfaceReport, TransformReport,
};
pub use router::{Cluster, RoutingPolicy, RunResult};
pub use scheduler::{AdmissionControl, EdfQueue, QueuedRequest};
pub use telemetry::{
    ClusterSnapshot, ReplicaTelemetry, StepSample, StepTimeSummary, TelemetryDetail,
};
pub use workload::{load_trace_jsonl, Scenario, SloTarget, Trace, TraceRequest};

/// Where the Stage-1 table used for ladder construction came from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TableSource {
    /// Measured table cached by `lexi profile` in the artifacts dir.
    Measured(PathBuf),
    /// Deterministic synthetic depth profile.
    Synthetic,
}

impl fmt::Display for TableSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TableSource::Measured(p) => write!(f, "measured ({})", p.display()),
            TableSource::Synthetic => write!(f, "synthetic depth profile"),
        }
    }
}

/// Stage-1 table for ladder construction: measured table when cached in
/// the artifacts dir, synthetic depth profile otherwise (deterministic
/// either way).
pub fn sensitivity_table(spec: &ModelSpec, artifacts: Option<&Path>, seed: u64) -> SensitivityTable {
    sensitivity_table_sourced(spec, artifacts, seed, TableMode::Auto)
        .expect("auto table mode is infallible")
        .0
}

/// [`sensitivity_table`] with an explicit source policy, reporting which
/// source was actually used (`lexi bench-serve --table ...`).
pub fn sensitivity_table_sourced(
    spec: &ModelSpec,
    artifacts: Option<&Path>,
    seed: u64,
    mode: TableMode,
) -> Result<(SensitivityTable, TableSource)> {
    if mode != TableMode::Synthetic {
        if let Some(root) = artifacts {
            let cache = crate::lexi::pipeline::table_path(root, spec.name);
            if let Ok(t) = SensitivityTable::load_json(&cache) {
                // both dims must match the spec: ladder construction
                // searches Bounds::paper(spec.top_k), which indexes
                // loss[j][k-1]
                if t.n_layers() == spec.n_layers && t.k_base == spec.top_k as u32 {
                    return Ok((t, TableSource::Measured(cache)));
                }
                if mode == TableMode::Measured {
                    bail!(
                        "cached table at {} does not match {} ({} layers x k<={} expected); \
                         re-run `lexi profile --model {} --force`",
                        cache.display(),
                        spec.name,
                        spec.n_layers,
                        spec.top_k,
                        spec.name
                    );
                }
            } else if mode == TableMode::Measured {
                bail!(
                    "no measured sensitivity table at {}; run `lexi profile --model {}` first",
                    cache.display(),
                    spec.name
                );
            }
        } else if mode == TableMode::Measured {
            bail!("--table measured needs an artifacts dir with a cached Stage-1 table");
        }
    }
    let t = SensitivityTable::synthetic(spec.name, spec.n_layers, spec.top_k as u32, |x| {
        0.8 + 2.4 * x
    }, seed);
    Ok((t, TableSource::Synthetic))
}

/// The transform line-up every serving comparison runs.
#[derive(Clone)]
pub(crate) struct Contender {
    pub(crate) label: &'static str,
    pub(crate) ladder: QualityLadder,
    pub(crate) adaptive: bool,
}

fn contenders(
    spec: &ModelSpec,
    table: &SensitivityTable,
    cfg: &ServerConfig,
    pm: &PerfModel,
    calibration: Option<&crate::calibrate::CalibrationArtifact>,
) -> Result<Vec<Contender>> {
    let mut full = QualityLadder::for_model(spec, table, cfg, pm)?;
    // Refit the ladder's service models from measured engine step times
    // when an artifact was supplied. baseline / lexi-fixed derive from
    // the (now calibrated) full-ladder rungs below; inter-prune is not a
    // ladder rung and keeps its analytical model.
    if let Some(art) = calibration {
        let applied = crate::calibrate::apply_to_ladder(&mut full, art, false);
        println!(
            "service models recalibrated from engine telemetry: rungs {:?} of {} \
             ({} samples, source {})",
            applied,
            full.n_rungs(),
            art.n_samples(),
            art.source
        );
    }
    // fixed mid-ladder rung: the paper's static ~65% deployment (the
    // middle of the k axis — s-axis points never seed fixed contenders)
    let fixed_rung = full
        .points()
        .get(full.k_dim() / 2)
        .unwrap_or(&full.points()[0]);
    let fixed = QualityLadder::fixed_with_loss(
        &fixed_rung.label,
        fixed_rung.allocation.clone(),
        fixed_rung.service.clone(),
        fixed_rung.quality_loss,
    );
    let baseline = QualityLadder::fixed(
        "base",
        full.points()[0].allocation.clone(),
        full.points()[0].service.clone(),
    );
    // Expert removal's accuracy cost is not on the Stage-1 top-k scale:
    // NaN -> the report shows quality loss as unknown, not as zero.
    let inter = QualityLadder::fixed_with_loss(
        "inter50",
        Allocation::uniform(spec.n_layers, spec.top_k as u32),
        ServiceModel::from_perf(
            pm,
            &Transform::InterPrune { frac: 0.5 },
            cfg.slots_per_replica,
            cfg.service_in_len,
            cfg.service_out_len,
            "inter50",
        ),
        f64::NAN,
    );
    Ok(vec![
        Contender {
            label: "baseline",
            ladder: baseline,
            adaptive: false,
        },
        Contender {
            label: "lexi-fixed",
            ladder: fixed,
            adaptive: false,
        },
        Contender {
            label: "lexi-ladder",
            ladder: full,
            adaptive: true,
        },
        Contender {
            label: "inter-prune",
            ladder: inter,
            adaptive: false,
        },
    ])
}

/// Run the full serving comparison for one scenario and write the
/// CSV/JSON reports. Returns the per-transform reports in line-up order
/// (baseline, lexi-fixed, lexi-ladder, inter-prune).
pub fn bench_serve(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<Vec<TransformReport>> {
    validate_elastic(cfg)?;
    let (table, source) = sensitivity_table_sourced(spec, artifacts, cfg.seed, cfg.table_mode)?;
    println!("ladder Stage-1 table source: {source}");
    let calibration = load_calibration(spec, cfg)?;
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let line_up = contenders(spec, &table, cfg, &pm, calibration.as_ref())?;
    let tiered = tier_line_ups(spec, &table, cfg)?;
    let base_svc = &line_up[0].ladder.points()[0].service;
    let (scenario, trace) = scenario_and_trace(base_svc, cfg)?;

    let runs = match cfg.backend {
        BackendKind::Sim => {
            sim_runs_elastic(spec, &line_up, tiered.as_deref(), &scenario, &trace, cfg)
                .into_iter()
                .map(|(report, res, _)| (report, res))
                .collect()
        }
        BackendKind::Engine => match try_real_runtime(spec, artifacts) {
            Some(model) => {
                println!("engine backend: compiled PJRT runtime ({})", spec.name);
                engine_runs(spec, &model, &line_up, &scenario, &trace, cfg)?
            }
            None => {
                let model = synthetic_engine_model(spec, cfg, &scenario);
                engine_runs(spec, &model, &line_up, &scenario, &trace, cfg)?
            }
        },
    };
    if cfg.trace {
        for (report, res) in &runs {
            write_obs_artifacts(spec, &scenario, &report.transform, res, cfg, out_dir)?;
        }
    }
    if health_enabled(cfg) {
        for (report, res) in &runs {
            write_health_artifacts(&report.transform, res, out_dir)?;
        }
    }
    let reports: Vec<TransformReport> = runs.into_iter().map(|(report, _)| report).collect();

    // sim keeps the PR 1 file names (bit-identical artifacts from the
    // same seed); engine-backed runs get their own stem so the two
    // backends' results can sit side by side for cross-validation
    let stem = match cfg.backend {
        BackendKind::Sim => format!("bench_serve_{}_{}", spec.name, scenario.name),
        BackendKind::Engine => format!("bench_serve_{}_{}_engine", spec.name, scenario.name),
    };
    report::write_csv(&out_dir.join(format!("{stem}.csv")), &reports)?;
    report::write_json(&out_dir.join(format!("{stem}.json")), &reports)?;
    Ok(reports)
}

/// `lexi bench-memory`: sweep HBM budgets x eviction policies over the
/// adaptive LExI ladder on one scenario, reporting residency hit rates,
/// stall percentiles, and the resulting serving quality per cell — the
/// memory-constrained regime where layer-adaptive active experts beat
/// uniform top-k on weight traffic, not just FLOPs. Budgets are
/// fractions of the model's full per-GPU expert footprint.
pub fn bench_memory(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    budgets: &[f64],
    policies: &[EvictKind],
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<Vec<MemoryReport>> {
    anyhow::ensure!(!budgets.is_empty(), "bench-memory needs at least one --budgets entry");
    anyhow::ensure!(!policies.is_empty(), "bench-memory needs at least one eviction policy");
    anyhow::ensure!(
        budgets.iter().all(|&f| f > 0.0 && f <= 1.0),
        "--budgets entries must be fractions in (0, 1]"
    );
    anyhow::ensure!(
        cfg.scenario != ScenarioKind::TraceReplay,
        "bench-memory sweeps generative scenarios (got trace-replay)"
    );
    let (table, source) = sensitivity_table_sourced(spec, artifacts, cfg.seed, cfg.table_mode)?;
    println!("ladder Stage-1 table source: {source}");
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let ladder = QualityLadder::for_model(spec, &table, cfg, &pm)?;
    let base_svc = &ladder.points()[0].service;

    // the identical workload contract across every sweep cell
    let (scenario, trace) = scenario_and_trace(base_svc, cfg)?;

    // per-GPU expert footprint: the unit --budgets fractions refer to
    let geom = crate::moe::arch::ModelGeom::paper_scale(spec);
    let hw = crate::perfmodel::Hardware::h100();
    let per_gpu_bytes = geom.expert_param_count() * hw.dtype_bytes as f64
        / spec.paper.n_gpus as f64;

    let mut rows = Vec::new();
    for &frac in budgets {
        // analytical cross-check: the perf model's expert-traffic term
        // under the same budget (baseline transform, service shape)
        let pm_tok_s = PerfModel::new(spec.clone(), cfg.seed)
            .with_hbm_budget_bytes(frac * per_gpu_bytes)
            .throughput(
                &Transform::Baseline,
                cfg.slots_per_replica,
                cfg.service_in_len,
                cfg.service_out_len,
            )
            .throughput_tok_s;
        for &policy in policies {
            let mut cell = cfg.clone();
            cell.hbm_budget_frac = Some(frac);
            cell.evict = policy;
            let contender = Contender {
                label: "lexi-ladder",
                ladder: ladder.clone(),
                adaptive: true,
            };
            let reports = sim_reports(
                spec,
                std::slice::from_ref(&contender),
                &scenario,
                &trace,
                &cell,
            );
            let r = &reports[0];
            let agg = r
                .residency_aggregate()
                .expect("budgeted run must report residency");
            rows.push(MemoryReport {
                scenario: scenario.name.to_string(),
                transform: r.transform.clone(),
                budget_frac: frac,
                policy: policy.label(),
                prefetch: cell.prefetch,
                hit_rate: agg.hit_rate(),
                prefetch_hits: agg.prefetch_hits,
                evictions: agg.evictions,
                stall_total_s: agg.stall_s,
                stall_p50_s: agg.stall_p50_s,
                stall_p95_s: agg.stall_p95_s,
                goodput_rps: r.goodput_rps,
                throughput_tok_s: r.throughput_tok_s,
                ttft_p95_s: r.ttft_p95_s,
                pm_tok_s,
            });
        }
    }
    let stem = format!("bench_memory_{}_{}", spec.name, scenario.name);
    report::write_memory_csv(&out_dir.join(format!("{stem}.csv")), &rows)?;
    report::write_memory_json(&out_dir.join(format!("{stem}.json")), &rows)?;
    Ok(rows)
}

/// `lexi bench-elasticity`: sweep the elastic control plane over one
/// scenario and the adaptive LExI ladder, two families side by side on
/// the identical workload contract:
///
/// - **elastic** — provisioning cells: fixed at the autoscaler's `min`,
///   fixed at its `max`, autoscaling between the two, and autoscaling
///   plus class-aware shedding. The headline comparison is goodput vs
///   provisioned replica-seconds against `fixed-max`.
/// - **hetero** — a uniform H100 cluster (JSQ reference) against a
///   mixed H100/A100 tier split under rr / jsq / classaware routing,
///   showing what speed-weighted, class-aware placement buys on
///   interactive p95 TTFT.
///
/// `--autoscale` and `--replica-tiers` override the default cell
/// bounds; `cfg.replicas` is the workload-calibration reference, so
/// every cell faces the same trace.
pub fn bench_elasticity(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<Vec<ElasticityReport>> {
    anyhow::ensure!(
        cfg.backend == BackendKind::Sim,
        "bench-elasticity sweeps the analytical sim backend only"
    );
    anyhow::ensure!(
        cfg.calibration_file.is_none(),
        "bench-elasticity re-prices hardware tiers analytically; drop --calibration"
    );
    let (table, source) = sensitivity_table_sourced(spec, artifacts, cfg.seed, cfg.table_mode)?;
    println!("ladder Stage-1 table source: {source}");
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let ladder = QualityLadder::for_model(spec, &table, cfg, &pm)?;
    let contender = Contender {
        label: "lexi-ladder",
        ladder,
        adaptive: true,
    };
    let base_svc = &contender.ladder.points()[0].service;

    // the identical workload contract across every sweep cell,
    // calibrated against the reference (uniform, fixed) cluster
    let (scenario, trace) = scenario_and_trace(base_svc, cfg)?;

    let (min, max) = cfg
        .autoscale
        .unwrap_or(((cfg.replicas / 2).max(1), cfg.replicas * 2));
    anyhow::ensure!(min <= max, "--autoscale min must not exceed max");
    let tiers = cfg.replica_tiers.clone().unwrap_or_else(|| {
        vec![
            (TierKind::H100, cfg.replicas - cfg.replicas / 2),
            (TierKind::A100, cfg.replicas / 2),
        ]
    });
    crate::ctrl::validate_tiers(&tiers, cfg.replicas)?;
    let tier_label = tiers
        .iter()
        .filter(|&&(_, n)| n > 0)
        .map(|&(t, n)| format!("{}:{n}", t.label()))
        .collect::<Vec<_>>()
        .join(",");

    let run_cell = |cell: &ServerConfig| -> Result<(TransformReport, RunResult, LatencySamples)> {
        validate_elastic(cell)?;
        let tiered = tier_line_ups(spec, &table, cell)?;
        let mut runs = sim_runs_elastic(
            spec,
            std::slice::from_ref(&contender),
            tiered.as_deref(),
            &scenario,
            &trace,
            cell,
        );
        Ok(runs.remove(0))
    };
    let to_row = |family: &'static str,
                  cell_label: String,
                  cell: &ServerConfig,
                  report: &TransformReport,
                  res: &RunResult,
                  samples: &LatencySamples| {
        // merge the already-sorted interactive-class TTFT lanes instead
        // of re-filtering and re-sorting the completion list
        let interactive = crate::obs::Quantiles::from_sorted(
            samples.merged_ttft(|class| scenario.profiles[class].priority == 0),
        );
        ElasticityReport {
            scenario: scenario.name.to_string(),
            family,
            cell: cell_label,
            policy: cell.policy.label().to_string(),
            replicas: report.replicas,
            goodput_rps: report.goodput_rps,
            throughput_tok_s: report.throughput_tok_s,
            interactive_ttft_p95_s: interactive.q(95.0),
            completed: report.n_completed,
            rejected: report.n_rejected,
            shed: res.shed_by_class.as_ref().map_or(0, |v| v.iter().sum()),
            replica_seconds: res
                .replica_seconds
                .unwrap_or(report.replicas as f64 * report.makespan_s),
            scale_ups: report.scale_ups.unwrap_or(0),
            drains: report.drains.unwrap_or(0),
        }
    };

    let mut rows = Vec::new();
    // elastic family: fixed floors/ceilings vs the autoscaler
    let elastic_cells: [(String, Box<dyn Fn(&mut ServerConfig)>); 4] = [
        (
            format!("fixed-min({min})"),
            Box::new(move |c| c.replicas = min),
        ),
        (
            format!("fixed-max({max})"),
            Box::new(move |c| c.replicas = max),
        ),
        (
            format!("autoscale({min}:{max})"),
            Box::new(move |c| {
                c.replicas = min;
                c.autoscale = Some((min, max));
            }),
        ),
        (
            format!("autoscale({min}:{max})+shed"),
            Box::new(move |c| {
                c.replicas = min;
                c.autoscale = Some((min, max));
                c.shed = true;
            }),
        ),
    ];
    for (label, mutate) in &elastic_cells {
        let mut cell = cfg.clone();
        cell.replica_tiers = None;
        cell.autoscale = None;
        cell.shed = false;
        mutate(&mut cell);
        let (report, res, samples) = run_cell(&cell)?;
        rows.push(to_row("elastic", label.clone(), &cell, &report, &res, &samples));
    }
    // hetero family: uniform reference, then the tier mix per policy
    use crate::config::server::PolicyKind;
    {
        let mut cell = cfg.clone();
        cell.replica_tiers = None;
        cell.autoscale = None;
        cell.shed = false;
        cell.policy = PolicyKind::Jsq;
        let (report, res, samples) = run_cell(&cell)?;
        rows.push(to_row(
            "hetero",
            format!("h100:{}", cfg.replicas),
            &cell,
            &report,
            &res,
            &samples,
        ));
    }
    for policy in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::ClassAware] {
        let mut cell = cfg.clone();
        cell.replica_tiers = Some(tiers.clone());
        cell.autoscale = None;
        cell.shed = false;
        cell.policy = policy;
        let (report, res, samples) = run_cell(&cell)?;
        rows.push(to_row("hetero", tier_label.clone(), &cell, &report, &res, &samples));
    }

    let stem = format!("bench_elasticity_{}_{}", spec.name, scenario.name);
    report::write_elasticity_csv(&out_dir.join(format!("{stem}.csv")), &rows)?;
    report::write_elasticity_json(&out_dir.join(format!("{stem}.json")), &rows)?;
    Ok(rows)
}

/// `lexi bench-quality-surface`: price every point of the quality
/// lattice analytically and emit the (modeled latency, proxy quality
/// loss) surface — modeled decode step time at full occupancy,
/// single-replica capacity at the `--service-len` request shape, and
/// the Stage-1-comparable loss per point — annotated with the Pareto
/// frontier over the whole lattice and, per point, how many pure-k
/// rungs (the legacy 1-D ladder) it strictly dominates. A 2-D point
/// with `pure_k_dominated > 0` is the lattice earning its keep: equal
/// or better modeled latency than a k-only rung at equal or lower
/// quality loss.
pub fn bench_quality_surface(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<Vec<report::QualitySurfaceReport>> {
    let (table, source) = sensitivity_table_sourced(spec, artifacts, cfg.seed, cfg.table_mode)?;
    println!("ladder Stage-1 table source: {source}");
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let lattice = QualityLattice::for_model(spec, &table, cfg, &pm)?;

    // order key for dominance: non-finite loss never dominates and is
    // dominated by any finite-loss point at equal speed
    let loss_key = |q: f64| if q.is_finite() { q } else { f64::INFINITY };
    let step = |p: &QualityPoint| p.service.step_time(cfg.slots_per_replica);
    let dominates = |a: &QualityPoint, b: &QualityPoint| {
        let (sa, sb) = (step(a), step(b));
        let (qa, qb) = (loss_key(a.quality_loss), loss_key(b.quality_loss));
        sa <= sb && qa <= qb && (sa < sb || qa < qb)
    };

    let points = lattice.points();
    let mut rows = Vec::with_capacity(points.len());
    for (idx, p) in points.iter().enumerate() {
        let id = lattice.point_id(idx).expect("enumerate stays on-lattice");
        let on_frontier = !points
            .iter()
            .enumerate()
            .any(|(j, q)| j != idx && dominates(q, p));
        let pure_k_dominated = (0..lattice.k_dim())
            .filter(|&k| {
                let j = lattice
                    .index_of(PointId { k, s: 0 })
                    .expect("s=0 row always exists");
                j != idx && dominates(p, &points[j])
            })
            .count();
        let mean_active_experts = if id.s == 0 {
            let a = &p.allocation;
            a.k.iter().map(|&k| k as f64).sum::<f64>() / a.k.len().max(1) as f64
        } else {
            let level = match cfg.ladder_axes {
                crate::config::server::LadderAxes::KIntra => p.intra_frac,
                crate::config::server::LadderAxes::KSkip => p.skip_threshold,
                crate::config::server::LadderAxes::K => 0.0,
            };
            let eff = ladder::effective_k(
                &p.allocation,
                cfg.ladder_axes,
                level,
                spec.top_k as u32,
                &pm,
            );
            eff.iter().sum::<f64>() / eff.len().max(1) as f64
        };
        rows.push(report::QualitySurfaceReport {
            model: spec.name.to_string(),
            axes: cfg.ladder_axes.label().to_string(),
            label: p.label.clone(),
            k: id.k,
            s: id.s,
            intra_frac: p.intra_frac,
            skip_threshold: p.skip_threshold,
            mean_active_experts,
            step_time_s: step(p),
            capacity_rps: p
                .service
                .capacity_rps(cfg.service_in_len as f64, cfg.service_out_len as f64),
            quality_loss: p.quality_loss,
            on_frontier,
            pure_k_dominated,
        });
    }

    report::print_quality_surface_header();
    report::print_quality_surface_rows(&rows);
    let frontier = rows.iter().filter(|r| r.on_frontier).count();
    let winners = rows
        .iter()
        .filter(|r| r.s > 0 && r.pure_k_dominated > 0)
        .count();
    println!(
        "  -> {} lattice points ({} x {}), {} on the Pareto frontier, \
         {} sparsity-axis points dominate at least one pure-k rung",
        rows.len(),
        lattice.k_dim(),
        lattice.s_dim(),
        frontier,
        winners
    );

    let stem = format!(
        "quality_surface_{}_{}",
        spec.name,
        cfg.ladder_axes.label().replace('-', "_")
    );
    report::write_quality_surface_csv(&out_dir.join(format!("{stem}.csv")), &rows)?;
    report::write_quality_surface_json(&out_dir.join(format!("{stem}.json")), &rows)?;
    Ok(rows)
}

/// One measured event-loop scale run (see [`bench_scale`]).
pub struct ScaleRun {
    /// Wall-clock time of `Cluster::run` alone (trace generation and
    /// cluster construction are excluded).
    pub wall_s: f64,
    pub completed: usize,
    pub rejected: u64,
    /// Self-profile of the run's hot sections (`cluster.snapshot`,
    /// `cluster.route`, `cluster.step_shards`, EDF ops, ...).
    pub prof: crate::obs::selfprof::SelfProfile,
}

impl ScaleRun {
    /// Total wall time (ms) spent in one profiled section, 0 when the
    /// section never ran.
    pub fn section_ms(&self, name: &str) -> f64 {
        self.prof
            .sections
            .iter()
            .find(|(n, _)| *n == name)
            .map_or(0.0, |(_, s)| s.total_ns as f64 / 1e6)
    }
}

/// Event-loop scale benchmark (`lexi bench-scale`): drive a cluster of
/// `replicas` virtual-time replicas with a *synthetic* service model
/// through a full seeded scenario of `n_requests` arrivals, under the
/// self-profiler. The synthetic service keeps the per-phase math
/// trivial, so the measurement isolates the event loop itself —
/// snapshot assembly, routing, EDF queue ops, replica stepping — rather
/// than the perf model. `rebuild` switches the cluster onto the
/// pre-incremental rebuild-per-instant snapshot path
/// ([`Cluster::with_snapshot_rebuild`]) so `--compare` can price the
/// incremental cache against its baseline on the identical trace; both
/// modes produce byte-identical schedules, only the wall clock moves.
pub fn bench_scale(
    replicas: usize,
    slots: usize,
    n_requests: usize,
    kind: ScenarioKind,
    seed: u64,
    shards: usize,
    rebuild: bool,
) -> ScaleRun {
    use crate::config::server::PolicyKind;
    let svc = ServiceModel::synthetic("scale", 1e-5, 1e-3, slots);
    // mixture means come from the profile catalog, so probe with a
    // unit-capacity scenario first (same recipe as estimate_capacity)
    let probe = Scenario::from_kind(kind, 1.0);
    let capacity = replicas as f64 * svc.capacity_rps(probe.mean_prompt_tokens(), probe.mean_gen_tokens());
    let mut scenario = Scenario::from_kind(kind, capacity);
    let slack = 2.0 * svc.step_time(slots);
    scenario.resolve_slos(
        |tokens| svc.prefill_time(tokens * slots) + slack,
        svc.step_time(slots),
    );
    let trace = scenario.generate(n_requests, seed);

    let ladder = QualityLadder::fixed("scale", Allocation::uniform(4, 2), svc);
    let mut cluster = Cluster::new(
        replicas,
        slots,
        PolicyKind::Jsq,
        ladder,
        None,
        // admission cap scales with the cluster so rejections stay a
        // workload property, not an artifact of the bench size
        64 * replicas,
        scenario.profiles.len(),
        0.0,
        seed,
    )
    .with_shards(shards);
    if rebuild {
        cluster = cluster.with_snapshot_rebuild();
    }

    crate::obs::selfprof::enable();
    let t0 = std::time::Instant::now();
    let res = cluster.run(&scenario, &trace);
    let wall_s = t0.elapsed().as_secs_f64();
    let prof = crate::obs::selfprof::disable_and_collect();
    ScaleRun {
        wall_s,
        completed: res.completed.len(),
        rejected: res.rejected_by_class.iter().sum(),
        prof,
    }
}

/// Emit one transform's observability artifacts (`--trace`): Perfetto
/// `trace_event` JSON, the per-request critical-path CSV, Prometheus
/// text, and JSONL metrics snapshots (see [`crate::obs`]). No-op when
/// the run carried no trace.
fn write_obs_artifacts(
    spec: &ModelSpec,
    scenario: &Scenario,
    label: &str,
    res: &RunResult,
    cfg: &ServerConfig,
    out_dir: &Path,
) -> Result<()> {
    let Some(log) = &res.trace else {
        return Ok(());
    };
    std::fs::create_dir_all(out_dir)?;
    let stem = format!("{}_{}_{}", spec.name, scenario.name, label);
    let doc = crate::obs::perfetto_json(log, &res.completed);
    let trace_path = out_dir.join(format!("trace_{stem}.json"));
    std::fs::write(&trace_path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", trace_path.display()))?;
    crate::obs::write_critical_path_csv(
        &out_dir.join(format!("critical_path_{stem}.csv")),
        log,
        &res.completed,
    )?;
    let mut registry = crate::obs::MetricsRegistry::from_run(log, &res.completed);
    if let Some(h) = &res.health {
        registry.record_health(h);
    }
    std::fs::write(
        out_dir.join(format!("metrics_{stem}.prom")),
        registry.prometheus_text(),
    )?;
    std::fs::write(
        out_dir.join(format!("metrics_{stem}.jsonl")),
        crate::obs::metrics::snapshots_jsonl(log, cfg.metrics_interval_s),
    )?;
    println!(
        "trace artifacts for {label}: {} ({} events, {} dropped)",
        trace_path.display(),
        log.events.len(),
        log.dropped
    );
    Ok(())
}

/// Whether this config runs the SLO health engine: `--health` asks for
/// pure observation, and `--pressure burn` implies it (the burn signal
/// has to come from somewhere).
pub(crate) fn health_enabled(cfg: &ServerConfig) -> bool {
    cfg.health || cfg.pressure == PressureMode::Burn
}

/// Fresh health engine for one contender's run, carrying enough run
/// config for its debug bundles to be self-contained.
fn health_engine_for(
    spec: &ModelSpec,
    label: &str,
    scenario: &Scenario,
    cfg: &ServerConfig,
) -> crate::obs::HealthEngine {
    use crate::util::json::Json;
    let run_config = Json::obj(vec![
        ("model", Json::Str(spec.name.to_string())),
        ("transform", Json::Str(label.to_string())),
        ("scenario", Json::Str(scenario.name.to_string())),
        ("replicas", Json::Num(cfg.replicas as f64)),
        ("slots", Json::Num(cfg.slots_per_replica as f64)),
        ("policy", Json::Str(cfg.policy.label().to_string())),
        ("pressure", Json::Str(cfg.pressure.label().to_string())),
        ("seed", Json::Num(cfg.seed as f64)),
    ]);
    crate::obs::HealthEngine::new(
        crate::obs::HealthConfig::default(),
        scenario.profiles.len(),
        run_config,
    )
}

/// Print one transform's health summary and write any frozen debug
/// bundles as `debug_bundle_<transform>_<ms>.json` (the files `lexi
/// bundle --check` validates). No-op when the run carried no health
/// outcome.
fn write_health_artifacts(label: &str, res: &RunResult, out_dir: &Path) -> Result<()> {
    let Some(h) = &res.health else {
        return Ok(());
    };
    println!(
        "health {label}: peak fast burn {:.2}, {} warn / {} critical / {} anomaly events, \
         {} bundle(s)",
        h.report.peak_fast_burn,
        h.report.warn_events,
        h.report.critical_events,
        h.report.anomaly_events,
        h.bundles.len()
    );
    if h.bundles.is_empty() {
        return Ok(());
    }
    std::fs::create_dir_all(out_dir)?;
    for bundle in &h.bundles {
        let t_ms = bundle
            .opt("t_s")
            .and_then(|t| t.as_f64().ok())
            .map_or(0, |t| (t * 1000.0) as u64);
        let path = out_dir.join(format!("debug_bundle_{label}_{t_ms}.json"));
        std::fs::write(&path, bundle.to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("  debug bundle: {}", path.display());
    }
    Ok(())
}

/// Scenario + seeded trace calibrated against `base_svc` — the one
/// workload contract shared by `bench_serve`, `bench_memory`, and the
/// calibration pipeline. Rates and SLOs are derived from the BASELINE
/// service model so every contender (and both backends) faces the
/// identical trace: TTFT reference = a full batched-cohort prefill of
/// the class's prompts plus two decode steps of scheduling slack (what
/// an unqueued arrival at a busy replica actually experiences).
pub(crate) fn scenario_and_trace(
    base_svc: &ServiceModel,
    cfg: &ServerConfig,
) -> Result<(Scenario, Trace)> {
    let slack = 2.0 * base_svc.step_time(cfg.slots_per_replica);
    let mut scenario = Scenario::from_kind(cfg.scenario, estimate_capacity(base_svc, cfg));
    if cfg.scenario == ScenarioKind::TraceReplay {
        let path = cfg
            .trace_file
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--scenario trace-replay needs --trace-file <jsonl>"))?;
        let n = scenario.load_replay(path)?;
        println!("trace replay: {n} requests from {}", path.display());
    }
    scenario.resolve_slos(
        |tokens| base_svc.prefill_time(tokens * cfg.slots_per_replica) + slack,
        base_svc.step_time(cfg.slots_per_replica),
    );
    let trace = scenario.generate(cfg.n_requests, cfg.seed);
    Ok((scenario, trace))
}

/// Load and validate the calibration artifact named by
/// `cfg.calibration_file` (`None` when the flag is absent — the default
/// analytical service models stay in place, byte for byte).
fn load_calibration(
    spec: &ModelSpec,
    cfg: &ServerConfig,
) -> Result<Option<crate::calibrate::CalibrationArtifact>> {
    let Some(path) = &cfg.calibration_file else {
        return Ok(None);
    };
    let art = crate::calibrate::CalibrationArtifact::load(path)?;
    art.ensure_matches(spec.name, cfg)
        .with_context(|| format!("applying calibration artifact {}", path.display()))?;
    Ok(Some(art))
}

/// Residency model for one replica under `--hbm-budget` (`None` keeps
/// the historical every-expert-resident behavior). `overlap_s` is the
/// per-step compute window transfers can hide behind.
fn replica_residency(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    k_vec: Vec<i32>,
    replica: usize,
    overlap_s: Option<f64>,
) -> Option<ExpertResidency> {
    let frac = cfg.hbm_budget_frac?;
    let mut rc = ResidencyConfig::for_model(spec, frac, cfg.evict, cfg.seed);
    rc.prefetch = cfg.prefetch;
    if let Some(o) = overlap_s {
        rc.overlap_s_per_step = o;
    }
    Some(ExpertResidency::new(&rc, k_vec, replica as u64))
}

/// The PR 1 path: virtual-time replicas, bit-identical from the seed.
/// With `--hbm-budget`, every replica additionally carries an expert
/// residency model whose miss stalls inflate its phase durations.
fn sim_reports(
    spec: &ModelSpec,
    line_up: &[Contender],
    scenario: &Scenario,
    trace: &Trace,
    cfg: &ServerConfig,
) -> Vec<TransformReport> {
    sim_runs(spec, line_up, scenario, trace, cfg)
        .into_iter()
        .map(|(report, _)| report)
        .collect()
}

/// [`sim_reports`] keeping the full [`RunResult`] per contender — the
/// calibration pipeline reads completions and step samples from it.
pub(crate) fn sim_runs(
    spec: &ModelSpec,
    line_up: &[Contender],
    scenario: &Scenario,
    trace: &Trace,
    cfg: &ServerConfig,
) -> Vec<(TransformReport, RunResult)> {
    sim_runs_elastic(spec, line_up, None, scenario, trace, cfg)
        .into_iter()
        .map(|(report, res, _)| (report, res))
        .collect()
}

/// [`sim_runs`] plus the elastic control plane: shedding, autoscaling,
/// and heterogeneous tiers, each wired only when its config flag asks
/// for it (the default path builds the identical cluster as before).
/// `tier_line_ups[t]` holds the contender ladders re-priced on tier
/// `t`'s hardware (see [`tier_line_ups`]), matched to `line_up` entries
/// by label; tier indices follow `cfg.replica_tiers` spec order.
pub(crate) fn sim_runs_elastic(
    spec: &ModelSpec,
    line_up: &[Contender],
    tier_line_ups: Option<&[Vec<Contender>]>,
    scenario: &Scenario,
    trace: &Trace,
    cfg: &ServerConfig,
) -> Vec<(TransformReport, RunResult, LatencySamples)> {
    // replica index -> tier index under --replica-tiers (empty otherwise)
    let tier_idx: Vec<usize> = cfg
        .replica_tiers
        .as_deref()
        .map(|tiers| {
            tiers
                .iter()
                .enumerate()
                .flat_map(|(ti, &(_, n))| std::iter::repeat(ti).take(n))
                .collect()
        })
        .unwrap_or_default();
    // under --autoscale the cluster is provisioned for `max` slots, with
    // only the initial live set accepting work
    let pool = cfg
        .autoscale
        .map_or(cfg.replicas, |(_, max)| cfg.replicas.max(max));
    let mut runs = Vec::new();
    for (ci, c) in line_up.iter().enumerate() {
        let quality: Vec<f64> = c.ladder.points().iter().map(|r| r.quality_loss).collect();
        let policy = c.adaptive.then(|| LadderPolicy::from_config(cfg));
        let ladder = Rc::new(c.ladder.clone());
        // match the tier's re-priced contender by label, not position:
        // callers may pass a sub-slice of the full line-up (e.g.
        // bench_elasticity runs the lexi-ladder contender alone)
        let tier_ladders: Vec<Rc<QualityLadder>> = tier_line_ups
            .map(|tl| {
                tl.iter()
                    .map(|l| {
                        let tc = l
                            .iter()
                            .find(|tc| tc.label == c.label)
                            .unwrap_or(&l[ci.min(l.len() - 1)]);
                        Rc::new(tc.ladder.clone())
                    })
                    .collect()
            })
            .unwrap_or_default();
        // residency transfers overlap with one full-batch decode step
        let overlap = ladder.points()[0].service.step_time(cfg.slots_per_replica);
        let backends: Vec<Box<dyn ReplicaBackend>> = (0..pool)
            .map(|i| {
                let rungs = tier_idx
                    .get(i)
                    .map(|&ti| Rc::clone(&tier_ladders[ti]))
                    .unwrap_or_else(|| Rc::clone(&ladder));
                let mut r = Replica::new(i, cfg.slots_per_replica, rungs);
                let res = replica_residency(spec, cfg, ladder.k_vec(0).unwrap(), i, Some(overlap));
                if let Some(res) = res {
                    r = r.with_residency(res);
                }
                Box::new(r) as Box<dyn ReplicaBackend>
            })
            .collect();
        let mut cluster = Cluster::from_backends(
            backends,
            cfg.policy,
            Rc::clone(&ladder),
            policy,
            cfg.queue_cap,
            scenario.profiles.len(),
            cfg.reconfig_penalty_s,
            cfg.seed,
        )
        .with_stealing(cfg.steal_bound)
        .with_steal_cooldown(cfg.steal_cooldown_s)
        .with_shards(cfg.shards);
        if cfg.shed {
            cluster = cluster
                .with_shedding(Shedder::new(ShedPolicy::from_config(cfg), scenario.profiles.len()));
        }
        if let Some((min, max)) = cfg.autoscale {
            // spin-up = prewarming the baseline rung's expert hot set +
            // loading the Stage-1 table over the host link
            let rc = ResidencyConfig::for_model(
                spec,
                cfg.hbm_budget_frac.unwrap_or(1.0),
                cfg.evict,
                cfg.seed,
            );
            let warmup_s = crate::ctrl::warmup_cost_s(&rc, &ladder.k_vec(0).unwrap());
            let scale_policy = AutoscalePolicy::for_cluster(
                min,
                max,
                cfg.slots_per_replica,
                overlap,
                warmup_s,
                cfg.slack_degrade_frac,
            );
            cluster = cluster.with_autoscale(Autoscaler::new(scale_policy, pool, cfg.replicas));
        }
        if cfg.replica_tiers.is_some() {
            cluster = cluster.with_speed_weighted_routing();
        }
        if cfg.trace {
            cluster = cluster.with_tracing(cfg.trace_ring_cap);
        }
        if health_enabled(cfg) {
            cluster = cluster.with_health(health_engine_for(spec, c.label, scenario, cfg));
        }
        let res = cluster.run(scenario, trace);
        // pool + sort the latency samples once; the report and every
        // extra percentile view (bench-elasticity's interactive TTFT
        // column) slice the same sorted vectors
        let samples = LatencySamples::collect(&res.completed);
        let report = TransformReport::from_run_with(
            scenario,
            c.label,
            cfg.policy.label(),
            &res,
            &quality,
            &samples,
        );
        runs.push((report, res, samples));
    }
    runs
}

/// Reject elastic-flag combinations the benches cannot honor: tiers
/// must cover the cluster exactly, and both autoscaling and tier
/// re-pricing are defined on the analytical sim backend only.
fn validate_elastic(cfg: &ServerConfig) -> Result<()> {
    if let Some(tiers) = &cfg.replica_tiers {
        crate::ctrl::validate_tiers(tiers, cfg.replicas)?;
        anyhow::ensure!(
            cfg.autoscale.is_none(),
            "--replica-tiers cannot be combined with --autoscale (tier specs cover a fixed \
             replica count)"
        );
        anyhow::ensure!(
            cfg.calibration_file.is_none(),
            "--replica-tiers cannot be combined with --calibration (measured step times \
             describe one hardware tier)"
        );
        anyhow::ensure!(
            cfg.backend == BackendKind::Sim,
            "--replica-tiers needs --backend sim (engine replicas run on real hardware)"
        );
    }
    if cfg.autoscale.is_some() {
        anyhow::ensure!(
            cfg.backend == BackendKind::Sim,
            "--autoscale needs --backend sim"
        );
    }
    Ok(())
}

/// Per-tier contender line-ups for `--replica-tiers`: the whole line-up
/// is rebuilt once per tier with that tier's
/// [`Hardware`](crate::perfmodel::Hardware) constants
/// behind the perf model, so every rung's service model (prefill
/// coefficients, per-occupancy decode costs) is priced on the hardware
/// the replica actually runs. Rung *allocations* are identical across
/// tiers — the Stage-1 table and the DP are hardware-independent — so
/// `tier_line_ups[t][c]` differs from `line_up[c]` only in service
/// models. `Ok(None)` without the flag.
fn tier_line_ups(
    spec: &ModelSpec,
    table: &SensitivityTable,
    cfg: &ServerConfig,
) -> Result<Option<Vec<Vec<Contender>>>> {
    let Some(tiers) = &cfg.replica_tiers else {
        return Ok(None);
    };
    let mut per_tier = Vec::with_capacity(tiers.len());
    for &(tier, _) in tiers {
        let mut pm = PerfModel::new(spec.clone(), cfg.seed);
        pm.hw = hardware_for(tier);
        per_tier.push(contenders(spec, table, cfg, &pm, None)?);
    }
    Ok(Some(per_tier))
}

/// Real engine replicas behind the same front door: every contender gets
/// a fresh cluster of `Engine`s over `model`, phases timed by wall
/// clock.
fn engine_reports<M: ModelBackend>(
    spec: &ModelSpec,
    model: &M,
    line_up: &[Contender],
    scenario: &Scenario,
    trace: &Trace,
    cfg: &ServerConfig,
) -> Result<Vec<TransformReport>> {
    Ok(engine_runs(spec, model, line_up, scenario, trace, cfg)?
        .into_iter()
        .map(|(report, _)| report)
        .collect())
}

/// [`engine_reports`] keeping the full [`RunResult`] per contender —
/// the measured step samples inside it are the calibration input.
pub(crate) fn engine_runs<M: ModelBackend>(
    spec: &ModelSpec,
    model: &M,
    line_up: &[Contender],
    scenario: &Scenario,
    trace: &Trace,
    cfg: &ServerConfig,
) -> Result<Vec<(TransformReport, RunResult)>> {
    let entry = model.entry().clone();
    if entry.batch != cfg.slots_per_replica {
        // the compiled graph's static batch wins over --slots; say so,
        // since capacity-relative arrival rates were calibrated for the
        // configured slot count
        println!(
            "engine backend: graph batch {} overrides --slots {}",
            entry.batch, cfg.slots_per_replica
        );
    }
    let scfg = ServingConfig {
        batch: entry.batch,
        max_seq: entry.max_seq,
        prefill_len: entry.prefill_len,
        kv_block: 16,
        kv_blocks_total: entry.batch * entry.max_seq.div_ceil(16),
        // the cluster-level admission cap bounds outstanding work; the
        // engine-internal queue only ever holds up to one batch
        queue_cap: cfg.queue_cap + cfg.n_requests + 1,
        max_new_tokens: 16,
        decode_burst: 8,
    };
    let mut runs = Vec::new();
    for c in line_up {
        let quality: Vec<f64> = c.ladder.points().iter().map(|r| r.quality_loss).collect();
        let ladder = Rc::new(c.ladder.clone());
        let policy = c.adaptive.then(|| LadderPolicy::from_config(cfg));
        let mut backends: Vec<Box<dyn ReplicaBackend + '_>> = Vec::new();
        for i in 0..cfg.replicas {
            let mut engine = Engine::new(
                model,
                scfg.clone(),
                ladder.k_vec(0).unwrap(),
                vec![0.0f32; entry.n_layers * entry.n_experts],
            )?;
            if let Some(res) = replica_residency(spec, cfg, ladder.k_vec(0).unwrap(), i, None) {
                engine.set_residency(res)?;
            }
            backends.push(Box::new(EngineReplica::new(i, engine, Rc::clone(&ladder))?));
        }
        let mut cluster = Cluster::from_backends(
            backends,
            cfg.policy,
            Rc::clone(&ladder),
            policy,
            cfg.queue_cap,
            scenario.profiles.len(),
            cfg.reconfig_penalty_s,
            cfg.seed,
        )
        .with_stealing(cfg.steal_bound)
        .with_steal_cooldown(cfg.steal_cooldown_s)
        .with_shards(cfg.shards);
        if cfg.shed {
            cluster = cluster
                .with_shedding(Shedder::new(ShedPolicy::from_config(cfg), scenario.profiles.len()));
        }
        if cfg.trace {
            cluster = cluster.with_tracing(cfg.trace_ring_cap);
        }
        if health_enabled(cfg) {
            cluster = cluster.with_health(health_engine_for(spec, c.label, scenario, cfg));
        }
        let res = cluster.run(scenario, trace);
        let report =
            TransformReport::from_run(scenario, c.label, cfg.policy.label(), &res, &quality);
        runs.push((report, res));
    }
    Ok(runs)
}

/// Compiled runtime for `--backend engine` when artifacts AND real XLA
/// bindings are available; `None` (with a notice) otherwise.
pub(crate) fn try_real_runtime(spec: &ModelSpec, artifacts: Option<&Path>) -> Option<ModelRuntime> {
    let root = artifacts?;
    let load = || -> Result<ModelRuntime> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(root)?;
        ModelRuntime::load(&rt, &manifest, spec.name)
    };
    match load() {
        Ok(m) => Some(m),
        Err(e) => {
            println!(
                "engine backend: compiled runtime unavailable ({e:#}); \
                 driving engine::Engine over the synthetic host model"
            );
            None
        }
    }
}

/// Host-synthetic model sized so the scenario's largest request shape
/// fits without truncation.
pub(crate) fn synthetic_engine_model(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    scenario: &Scenario,
) -> SyntheticModel {
    let mut max_prompt = scenario
        .profiles
        .iter()
        .map(|p| p.prompt_hi)
        .max()
        .unwrap_or(512);
    let mut max_gen = scenario.profiles.iter().map(|p| p.gen_hi).max().unwrap_or(64);
    // replayed logs may exceed the catalog's shape envelope
    if let workload::ArrivalProcess::Replay { requests } = &scenario.arrivals {
        for r in requests {
            max_prompt = max_prompt.max(r.prompt_len);
            max_gen = max_gen.max(r.new_tokens);
        }
    }
    SyntheticModel::new(
        spec.name,
        spec.n_layers,
        spec.n_experts,
        spec.top_k,
        cfg.slots_per_replica,
        max_prompt,
        max_prompt + max_gen + 2,
    )
}

/// Cluster capacity estimate (requests/s) for scenario calibration.
pub(crate) fn estimate_capacity(svc: &ServiceModel, cfg: &ServerConfig) -> f64 {
    // mixture means of the standard profile catalog
    let s = Scenario::from_kind(cfg.scenario, 1.0);
    cfg.replicas as f64 * svc.capacity_rps(s.mean_prompt_tokens(), s.mean_gen_tokens())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;
    use crate::config::server::ScenarioKind;

    #[test]
    fn bench_serve_emits_reports_and_files() {
        let m = spec("minicpm-moe-8x2b").unwrap();
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 48,
            scenario: ScenarioKind::Poisson,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let out = std::env::temp_dir().join("lexi_bench_serve_test");
        let _ = std::fs::remove_dir_all(&out);
        let reports = bench_serve(&m, &cfg, None, &out).unwrap();
        assert_eq!(reports.len(), 4);
        let labels: Vec<&str> = reports.iter().map(|r| r.transform.as_str()).collect();
        assert_eq!(labels, ["baseline", "lexi-fixed", "lexi-ladder", "inter-prune"]);
        for r in &reports {
            assert_eq!(r.n_completed as u64 + r.n_rejected, 48);
            assert!(r.throughput_tok_s > 0.0);
        }
        assert!(out.join("bench_serve_minicpm-moe-8x2b_poisson.csv").exists());
        assert!(out.join("bench_serve_minicpm-moe-8x2b_poisson.json").exists());
    }

    #[test]
    fn bench_serve_with_hbm_budget_reports_residency() {
        let m = spec("minicpm-moe-8x2b").unwrap();
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 32,
            scenario: ScenarioKind::Poisson,
            service_in_len: 256,
            service_out_len: 32,
            hbm_budget_frac: Some(0.4),
            ..Default::default()
        };
        let out = std::env::temp_dir().join("lexi_bench_serve_residency_test");
        let _ = std::fs::remove_dir_all(&out);
        let reports = bench_serve(&m, &cfg, None, &out).unwrap();
        for r in &reports {
            let agg = r.residency_aggregate().expect("budget set -> residency stats");
            assert!(agg.hits + agg.misses > 0, "{}: nothing demanded", r.transform);
            assert!(agg.hit_rate() >= 0.0 && agg.hit_rate() <= 1.0);
        }
        // the emitted JSON carries the residency block
        let json = crate::util::json::parse_file(
            &out.join("bench_serve_minicpm-moe-8x2b_poisson.json"),
        )
        .unwrap();
        assert!(json.as_arr().unwrap()[0].get("expert_hit_rate").is_ok());
    }

    #[test]
    fn bench_memory_sweeps_budgets_and_policies() {
        let m = spec("minicpm-moe-8x2b").unwrap();
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 24,
            scenario: ScenarioKind::Bursty,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let out = std::env::temp_dir().join("lexi_bench_memory_test");
        let _ = std::fs::remove_dir_all(&out);
        let budgets = [0.3, 0.8];
        let policies = EvictKind::all();
        let rows = bench_memory(&m, &cfg, &budgets, &policies, None, &out).unwrap();
        assert_eq!(rows.len(), budgets.len() * policies.len());
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.hit_rate), "{r:?}");
            assert!(r.stall_p95_s >= r.stall_p50_s - 1e-12);
            assert!(r.throughput_tok_s > 0.0 && r.pm_tok_s > 0.0);
        }
        // more HBM cannot hurt the hit rate for a fixed policy
        for policy in policies {
            let tight = rows
                .iter()
                .find(|r| r.budget_frac == 0.3 && r.policy == policy.label())
                .unwrap();
            let roomy = rows
                .iter()
                .find(|r| r.budget_frac == 0.8 && r.policy == policy.label())
                .unwrap();
            assert!(
                roomy.hit_rate >= tight.hit_rate - 1e-9,
                "{}: roomy {} < tight {}",
                policy.label(),
                roomy.hit_rate,
                tight.hit_rate
            );
        }
        assert!(out.join("bench_memory_minicpm-moe-8x2b_bursty.csv").exists());
        assert!(out.join("bench_memory_minicpm-moe-8x2b_bursty.json").exists());
        // replay is not a generative scenario
        let mut bad = cfg;
        bad.scenario = ScenarioKind::TraceReplay;
        assert!(bench_memory(&m, &bad, &budgets, &policies, None, &out).is_err());
    }

    #[test]
    fn bench_scale_modes_complete_the_same_trace() {
        // incremental + sharded vs rebuild-per-instant + serial: same
        // seeded trace, same outcome counts, both profiles populated
        let inc = bench_scale(6, 4, 1200, ScenarioKind::Diurnal, 3, 3, false);
        let reb = bench_scale(6, 4, 1200, ScenarioKind::Diurnal, 3, 1, true);
        assert_eq!(inc.completed as u64 + inc.rejected, 1200);
        assert_eq!(inc.completed, reb.completed);
        assert_eq!(inc.rejected, reb.rejected);
        assert!(inc.section_ms("cluster.snapshot") > 0.0);
        assert!(reb.section_ms("cluster.snapshot") > 0.0);
        assert!(inc.wall_s > 0.0 && reb.wall_s > 0.0);
    }

    #[test]
    fn table_source_policies_behave() {
        let m = spec("olmoe-1b-7b").unwrap();
        // no artifacts dir: auto + synthetic fall back, measured errors
        let (_, src) = sensitivity_table_sourced(&m, None, 0, TableMode::Auto).unwrap();
        assert_eq!(src, TableSource::Synthetic);
        let (_, src) = sensitivity_table_sourced(&m, None, 0, TableMode::Synthetic).unwrap();
        assert_eq!(src, TableSource::Synthetic);
        assert!(sensitivity_table_sourced(&m, None, 0, TableMode::Measured).is_err());

        // cache a measured-shaped table and watch auto pick it up
        let root = std::env::temp_dir().join("lexi_table_source_test");
        let _ = std::fs::remove_dir_all(&root);
        let cache = crate::lexi::pipeline::table_path(&root, m.name);
        let t = SensitivityTable::synthetic(m.name, m.n_layers, m.top_k as u32, |x| x, 3);
        t.save_json(&cache).unwrap();
        let (got, src) =
            sensitivity_table_sourced(&m, Some(root.as_path()), 0, TableMode::Measured).unwrap();
        assert_eq!(src, TableSource::Measured(cache));
        assert_eq!(got.n_layers(), m.n_layers);
    }
}
