//! Multi-replica serving front-end (the `lexi bench-serve` subsystem).
//!
//! The paper's claim is about *serving* efficiency, so this module puts
//! LExI where it earns its keep: a cluster of N continuous-batching
//! replicas behind admission control, SLO-aware EDF scheduling, and
//! pluggable routing, driven by seeded workload scenarios. Replicas run
//! in virtual time against perf-model-calibrated service models, so a
//! full comparison sweep (baseline / fixed LExI / adaptive LExI ladder /
//! inter-pruning, across four scenarios) needs no artifacts and is
//! bit-reproducible from a seed.
//!
//! Module map:
//! - [`workload`]  — arrival processes x request-shape profiles
//! - [`scheduler`] — admission control + multi-class EDF queues
//! - [`replica`]   — virtual-time continuous-batching replica
//! - [`router`]    — cluster, routing policies, discrete-event loop
//! - [`ladder`]    — adaptive LExI quality ladder (Stage-2 over time)
//! - [`report`]    — TTFT/TPOT percentiles, goodput-under-SLO, CSV/JSON

pub mod ladder;
pub mod replica;
pub mod report;
pub mod router;
pub mod scheduler;
pub mod workload;

use std::path::Path;

use anyhow::Result;

use crate::config::model::ModelSpec;
use crate::config::server::ServerConfig;
use crate::lexi::SensitivityTable;
use crate::moe::allocation::Allocation;
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;

pub use ladder::{LadderPolicy, QualityLadder, Rung};
pub use replica::{CompletedRequest, Replica, ServiceModel};
pub use report::TransformReport;
pub use router::{Cluster, RunResult};
pub use scheduler::{AdmissionControl, EdfQueue, QueuedRequest};
pub use workload::{Scenario, SloTarget, Trace, TraceRequest};

/// Stage-1 table for ladder construction: measured table when cached in
/// the artifacts dir, synthetic depth profile otherwise (deterministic
/// either way).
pub fn sensitivity_table(spec: &ModelSpec, artifacts: Option<&Path>, seed: u64) -> SensitivityTable {
    if let Some(root) = artifacts {
        let cache = crate::lexi::pipeline::table_path(root, spec.name);
        if let Ok(t) = SensitivityTable::load_json(&cache) {
            // both dims must match the spec: ladder construction searches
            // Bounds::paper(spec.top_k), which indexes loss[j][k-1]
            if t.n_layers() == spec.n_layers && t.k_base == spec.top_k as u32 {
                return t;
            }
        }
    }
    SensitivityTable::synthetic(spec.name, spec.n_layers, spec.top_k as u32, |x| 0.8 + 2.4 * x, seed)
}

/// The transform line-up every serving comparison runs.
struct Contender {
    label: &'static str,
    ladder: QualityLadder,
    adaptive: bool,
}

fn contenders(
    spec: &ModelSpec,
    table: &SensitivityTable,
    cfg: &ServerConfig,
    pm: &PerfModel,
) -> Result<Vec<Contender>> {
    let full = QualityLadder::for_model(spec, table, cfg, pm)?;
    // fixed mid-ladder rung: the paper's static ~65% deployment
    let fixed_rung = full.rungs.get(full.n_rungs() / 2).unwrap_or(&full.rungs[0]);
    let fixed = QualityLadder::fixed_with_loss(
        &fixed_rung.label,
        fixed_rung.allocation.clone(),
        fixed_rung.service.clone(),
        fixed_rung.quality_loss,
    );
    let baseline = QualityLadder::fixed(
        "base",
        full.rungs[0].allocation.clone(),
        full.rungs[0].service.clone(),
    );
    // Expert removal's accuracy cost is not on the Stage-1 top-k scale:
    // NaN -> the report shows quality loss as unknown, not as zero.
    let inter = QualityLadder::fixed_with_loss(
        "inter50",
        Allocation::uniform(spec.n_layers, spec.top_k as u32),
        ServiceModel::from_perf(
            pm,
            &Transform::InterPrune { frac: 0.5 },
            cfg.slots_per_replica,
            cfg.service_in_len,
            cfg.service_out_len,
            "inter50",
        ),
        f64::NAN,
    );
    Ok(vec![
        Contender {
            label: "baseline",
            ladder: baseline,
            adaptive: false,
        },
        Contender {
            label: "lexi-fixed",
            ladder: fixed,
            adaptive: false,
        },
        Contender {
            label: "lexi-ladder",
            ladder: full,
            adaptive: true,
        },
        Contender {
            label: "inter-prune",
            ladder: inter,
            adaptive: false,
        },
    ])
}

/// Run the full serving comparison for one scenario and write the
/// CSV/JSON reports. Returns the per-transform reports in line-up order
/// (baseline, lexi-fixed, lexi-ladder, inter-prune).
pub fn bench_serve(
    spec: &ModelSpec,
    cfg: &ServerConfig,
    artifacts: Option<&Path>,
    out_dir: &Path,
) -> Result<Vec<TransformReport>> {
    let table = sensitivity_table(spec, artifacts, cfg.seed);
    let pm = PerfModel::new(spec.clone(), cfg.seed);
    let line_up = contenders(spec, &table, cfg, &pm)?;
    let base_svc = &line_up[0].ladder.rungs[0].service;

    // Scenario rates + SLOs calibrated against the BASELINE service
    // model so every contender faces the identical workload contract.
    // TTFT reference = a full batched-cohort prefill of the class's
    // prompts plus two decode steps of scheduling slack (what an
    // unqueued arrival at a busy replica actually experiences).
    let slack = 2.0 * base_svc.step_time(cfg.slots_per_replica);
    let mut scenario = Scenario::from_kind(cfg.scenario, estimate_capacity(base_svc, cfg));
    scenario.resolve_slos(
        |tokens| base_svc.prefill_time(tokens * cfg.slots_per_replica) + slack,
        base_svc.step_time(cfg.slots_per_replica),
    );
    let trace = scenario.generate(cfg.n_requests, cfg.seed);

    let mut reports = Vec::new();
    for c in &line_up {
        let quality: Vec<f64> = c.ladder.rungs.iter().map(|r| r.quality_loss).collect();
        let policy = c.adaptive.then(|| LadderPolicy::from_config(cfg));
        let mut cluster = Cluster::new(
            cfg.replicas,
            cfg.slots_per_replica,
            cfg.policy,
            c.ladder.clone(),
            policy,
            cfg.queue_cap,
            scenario.profiles.len(),
            cfg.reconfig_penalty_s,
            cfg.seed,
        );
        let res = cluster.run(&scenario, &trace);
        reports.push(TransformReport::from_run(
            &scenario,
            c.label,
            cfg.policy.label(),
            &res,
            &quality,
        ));
    }

    let stem = format!("bench_serve_{}_{}", spec.name, scenario.name);
    report::write_csv(&out_dir.join(format!("{stem}.csv")), &reports)?;
    report::write_json(&out_dir.join(format!("{stem}.json")), &reports)?;
    Ok(reports)
}

/// Cluster capacity estimate (requests/s) for scenario calibration.
fn estimate_capacity(svc: &ServiceModel, cfg: &ServerConfig) -> f64 {
    // mixture means of the standard profile catalog
    let s = Scenario::from_kind(cfg.scenario, 1.0);
    cfg.replicas as f64 * svc.capacity_rps(s.mean_prompt_tokens(), s.mean_gen_tokens())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;
    use crate::config::server::ScenarioKind;

    #[test]
    fn bench_serve_emits_reports_and_files() {
        let m = spec("minicpm-moe-8x2b").unwrap();
        let cfg = ServerConfig {
            replicas: 2,
            slots_per_replica: 4,
            n_requests: 48,
            scenario: ScenarioKind::Poisson,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let out = std::env::temp_dir().join("lexi_bench_serve_test");
        let _ = std::fs::remove_dir_all(&out);
        let reports = bench_serve(&m, &cfg, None, &out).unwrap();
        assert_eq!(reports.len(), 4);
        let labels: Vec<&str> = reports.iter().map(|r| r.transform.as_str()).collect();
        assert_eq!(labels, ["baseline", "lexi-fixed", "lexi-ladder", "inter-prune"]);
        for r in &reports {
            assert_eq!(r.n_completed as u64 + r.n_rejected, 48);
            assert!(r.throughput_tok_s > 0.0);
        }
        assert!(out.join("bench_serve_minicpm-moe-8x2b_poisson.csv").exists());
        assert!(out.join("bench_serve_minicpm-moe-8x2b_poisson.json").exists());
    }
}
