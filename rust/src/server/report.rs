//! Serving reports: latency percentiles, goodput-under-SLO, utilization.
//!
//! One [`TransformReport`] summarizes one (scenario, transform, policy)
//! cluster run. Emission reuses the repo-wide writers: `util::csv` for
//! the per-row table, `util::json` for the full nested report (including
//! per-replica utilization and the ladder's rung occupancy).

use std::path::Path;

use anyhow::Result;

use crate::csv_row;
use crate::experts::ResidencyStats;
use crate::obs::Quantiles;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

use super::replica::CompletedRequest;
use super::router::RunResult;
use super::telemetry::StepTimeSummary;
use super::workload::{Scenario, SloTarget};

/// Aggregated serving metrics for one cluster run.
#[derive(Clone, Debug, PartialEq)]
pub struct TransformReport {
    pub scenario: String,
    pub transform: String,
    pub policy: String,
    pub replicas: usize,
    pub n_completed: usize,
    pub n_rejected: u64,
    /// Completions meeting BOTH their class TTFT and TPOT SLOs.
    pub n_slo_met: usize,
    pub makespan_s: f64,
    /// SLO-satisfying completions per second — the headline metric.
    pub goodput_rps: f64,
    /// (prompt + generated) tokens per second.
    pub throughput_tok_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p95_s: f64,
    pub tpot_p99_s: f64,
    pub mean_utilization: f64,
    pub per_replica_utilization: Vec<f64>,
    pub rung_switches: u64,
    /// Fraction of busy time spent at the zero-loss baseline rung.
    /// `None` when the ladder has no such rung (fixed degraded
    /// transforms run 100% of their time at THEIR rung, not at full
    /// quality — reporting 1.0 there would be a lie).
    pub full_quality_frac: Option<f64>,
    /// Busy-time-weighted mean Stage-1 proxy loss across rungs. `None`
    /// when the transform's loss is not on the Stage-1 scale (NaN rung).
    pub mean_quality_loss: Option<f64>,
    /// Cross-replica work steals. `None` under the default feature set
    /// (the default CSV/JSON artifacts stay byte-identical); populated
    /// whenever stealing, slack pressure, or class-aware routing ran.
    pub steals: Option<u64>,
    /// Worst queued EDF slack observed at any control-plane snapshot
    /// (same population rule as `steals`).
    pub min_slack_s: Option<f64>,
    /// Measured per-replica engine step-time histograms (p50/p95/max),
    /// the sim `ServiceModel` calibration input. `None` on the sim
    /// backend, whose step times are model outputs.
    pub step_time_per_replica: Option<Vec<StepTimeSummary>>,
    /// Per-replica expert-residency counters. `None` unless the run
    /// carried an HBM budget (`--hbm-budget`), so default artifacts
    /// keep their historical byte layout.
    pub residency_per_replica: Option<Vec<ResidencyStats>>,
    /// Requests policy-shed per SLO class (`None` without `--shed`).
    /// Sheds are a subset of `n_rejected`: the shedder charges the same
    /// per-class rejection counters the hard admission cap uses.
    pub shed_by_class: Option<Vec<u64>>,
    /// Provisioned replica-seconds integrated by the autoscaler (`None`
    /// on fixed clusters, where it is just `replicas * makespan_s`).
    pub replica_seconds: Option<f64>,
    /// Autoscaler activations over the run (`None` without
    /// `--autoscale`; counts exclude the initially-live set).
    pub scale_ups: Option<u64>,
    /// Autoscaler drain decisions over the run (same gating).
    pub drains: Option<u64>,
    /// SLO health-engine report: windowed burn rates, attainment per
    /// class, health-event counts, and the burn timeline (`None`
    /// without `--health` / `--pressure burn`, so default artifacts
    /// keep their historical byte layout).
    pub health: Option<crate::obs::HealthReport>,
}

/// Did a completion meet its class SLO?
pub fn meets_slo(c: &CompletedRequest, slo: &SloTarget) -> bool {
    c.ttft_s <= slo.ttft_s && c.tpot_s() <= slo.tpot_s
}

/// Latency samples for one run, pooled in a single pass over the
/// completions and sorted exactly once per (class, metric). Every
/// percentile a report needs afterwards — all-class, one class, or a
/// priority-filtered subset — is a slice or an O(n) ascending merge of
/// these vectors, never another full sort. `bench-elasticity` shares
/// one `LatencySamples` between [`TransformReport::from_run_with`] and
/// its interactive-TTFT column for exactly this reason.
#[derive(Clone, Debug, Default)]
pub struct LatencySamples {
    /// Ascending (`total_cmp` order) TTFT samples per SLO class.
    pub ttft_by_class: Vec<Vec<f64>>,
    /// Ascending TPOT samples pooled over all classes.
    pub tpot: Vec<f64>,
}

impl LatencySamples {
    pub fn collect(completed: &[CompletedRequest]) -> Self {
        let mut ttft_by_class: Vec<Vec<f64>> = Vec::new();
        let mut tpot = Vec::with_capacity(completed.len());
        for c in completed {
            if c.class >= ttft_by_class.len() {
                ttft_by_class.resize_with(c.class + 1, Vec::new);
            }
            ttft_by_class[c.class].push(c.ttft_s);
            tpot.push(c.tpot_s());
        }
        for v in &mut ttft_by_class {
            v.sort_by(f64::total_cmp);
        }
        tpot.sort_by(f64::total_cmp);
        LatencySamples { ttft_by_class, tpot }
    }

    /// Ascending merge of the per-class TTFT vectors whose class index
    /// `keep` selects. The merged multiset is identical to filtering
    /// the completions and sorting, so the percentiles are identical —
    /// without the O(n log n) re-sort.
    pub fn merged_ttft(&self, keep: impl Fn(usize) -> bool) -> Vec<f64> {
        let lanes: Vec<&[f64]> = self
            .ttft_by_class
            .iter()
            .enumerate()
            .filter(|(c, _)| keep(*c))
            .map(|(_, v)| v.as_slice())
            .collect();
        merge_ascending(&lanes)
    }

    /// All-class TTFT percentile view.
    pub fn ttft(&self) -> Quantiles {
        Quantiles::from_sorted(self.merged_ttft(|_| true))
    }

    /// All-class TPOT percentile view.
    pub fn tpot(&self) -> Quantiles {
        Quantiles::from_sorted(self.tpot.clone())
    }
}

/// K-way ascending merge of already-sorted lanes (`total_cmp` order).
/// Linear in the total sample count; the lane count is the class count,
/// a small constant.
fn merge_ascending(lanes: &[&[f64]]) -> Vec<f64> {
    let total: usize = lanes.iter().map(|l| l.len()).sum();
    let mut heads = vec![0usize; lanes.len()];
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        let mut best: Option<usize> = None;
        for (i, l) in lanes.iter().enumerate() {
            if heads[i] >= l.len() {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => l[heads[i]].total_cmp(&lanes[b][heads[b]]).is_lt(),
            };
            if better {
                best = Some(i);
            }
        }
        let b = best.expect("merge ran out of samples early");
        out.push(lanes[b][heads[b]]);
        heads[b] += 1;
    }
    out
}

impl TransformReport {
    pub fn from_run(
        scenario: &Scenario,
        transform: &str,
        policy: &str,
        res: &RunResult,
        rung_quality_loss: &[f64],
    ) -> Self {
        Self::from_run_with(
            scenario,
            transform,
            policy,
            res,
            rung_quality_loss,
            &LatencySamples::collect(&res.completed),
        )
    }

    /// [`from_run`](Self::from_run) over caller-pooled latency samples,
    /// so sweeps that need extra percentile views (bench-elasticity's
    /// interactive TTFT column) sort each sample vector exactly once.
    pub fn from_run_with(
        scenario: &Scenario,
        transform: &str,
        policy: &str,
        res: &RunResult,
        rung_quality_loss: &[f64],
        samples: &LatencySamples,
    ) -> Self {
        let makespan = res.makespan_s.max(1e-9);
        // the shared exact-percentile implementation (the pooled
        // vectors were sorted once; three percentiles read each)
        let ttft = samples.ttft();
        let tpot = samples.tpot();
        let n_slo_met = res
            .completed
            .iter()
            .filter(|c| meets_slo(c, &scenario.slos[c.class]))
            .count();
        let tokens: usize = res
            .completed
            .iter()
            .map(|c| c.prompt_len + c.tokens)
            .sum();
        let util: Vec<f64> = res
            .replica_busy_s
            .iter()
            .map(|b| (b / makespan).min(1.0))
            .collect();
        let busy_total: f64 = res.rung_time_s.iter().sum::<f64>().max(1e-12);
        let weighted = res
            .rung_time_s
            .iter()
            .zip(rung_quality_loss)
            .map(|(t, q)| t * q)
            .sum::<f64>()
            / busy_total;
        let mean_quality_loss = weighted.is_finite().then_some(weighted);
        let full_quality_frac = (rung_quality_loss.first().copied() == Some(0.0))
            .then(|| res.rung_time_s.first().copied().unwrap_or(0.0) / busy_total);
        TransformReport {
            scenario: scenario.name.to_string(),
            transform: transform.to_string(),
            policy: policy.to_string(),
            replicas: res.replica_busy_s.len(),
            n_completed: res.completed.len(),
            n_rejected: res.rejected_by_class.iter().sum(),
            n_slo_met,
            makespan_s: makespan,
            goodput_rps: n_slo_met as f64 / makespan,
            throughput_tok_s: tokens as f64 / makespan,
            ttft_p50_s: ttft.q(50.0),
            ttft_p95_s: ttft.q(95.0),
            ttft_p99_s: ttft.q(99.0),
            tpot_p50_s: tpot.q(50.0),
            tpot_p95_s: tpot.q(95.0),
            tpot_p99_s: tpot.q(99.0),
            mean_utilization: util.iter().sum::<f64>() / util.len().max(1) as f64,
            per_replica_utilization: util,
            rung_switches: res.rung_switches,
            full_quality_frac,
            mean_quality_loss,
            steals: res.steals,
            min_slack_s: res.min_slack_s,
            step_time_per_replica: res
                .step_time_per_replica
                .iter()
                .any(|s| s.is_some())
                .then(|| {
                    res.step_time_per_replica
                        .iter()
                        .map(|s| s.clone().unwrap_or_default())
                        .collect()
                }),
            residency_per_replica: res
                .residency_per_replica
                .iter()
                .any(|r| r.is_some())
                .then(|| {
                    res.residency_per_replica
                        .iter()
                        .map(|r| r.clone().unwrap_or_default())
                        .collect()
                }),
            shed_by_class: res.shed_by_class.clone(),
            replica_seconds: res.replica_seconds,
            scale_ups: res
                .scale_events
                .as_ref()
                .map(|ev| ev.iter().filter(|&&(_, _, up)| up).count() as u64),
            drains: res
                .scale_events
                .as_ref()
                .map(|ev| ev.iter().filter(|&&(_, _, up)| !up).count() as u64),
            health: res.health.as_ref().map(|h| h.report.clone()),
        }
    }

    /// Cluster-aggregate residency counters (`None` without a budget).
    pub fn residency_aggregate(&self) -> Option<ResidencyStats> {
        self.residency_per_replica
            .as_ref()
            .map(|per| ResidencyStats::aggregate(per.iter()))
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("transform", Json::Str(self.transform.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("replicas", Json::Num(self.replicas as f64)),
            ("n_completed", Json::Num(self.n_completed as f64)),
            ("n_rejected", Json::Num(self.n_rejected as f64)),
            ("n_slo_met", Json::Num(self.n_slo_met as f64)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s)),
            (
                "ttft_s",
                Json::obj(vec![
                    ("p50", Json::Num(self.ttft_p50_s)),
                    ("p95", Json::Num(self.ttft_p95_s)),
                    ("p99", Json::Num(self.ttft_p99_s)),
                ]),
            ),
            (
                "tpot_s",
                Json::obj(vec![
                    ("p50", Json::Num(self.tpot_p50_s)),
                    ("p95", Json::Num(self.tpot_p95_s)),
                    ("p99", Json::Num(self.tpot_p99_s)),
                ]),
            ),
            ("mean_utilization", Json::Num(self.mean_utilization)),
            (
                "per_replica_utilization",
                Json::from_f64s(&self.per_replica_utilization),
            ),
            ("rung_switches", Json::Num(self.rung_switches as f64)),
            (
                "full_quality_frac",
                self.full_quality_frac.map_or(Json::Null, Json::Num),
            ),
            (
                "mean_quality_loss",
                self.mean_quality_loss.map_or(Json::Null, Json::Num),
            ),
        ];
        // extended control-plane fields only appear when populated, so
        // default-flag artifacts keep their historical byte layout
        if let Some(n) = self.steals {
            pairs.push(("steals", Json::Num(n as f64)));
        }
        if let Some(s) = self.min_slack_s {
            pairs.push(("min_slack_s", Json::Num(s)));
        }
        if let Some(st) = &self.step_time_per_replica {
            pairs.push((
                "step_time_per_replica",
                Json::Arr(
                    st.iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("n", Json::Num(s.n as f64)),
                                ("p50_s", Json::Num(s.p50_s)),
                                ("p95_s", Json::Num(s.p95_s)),
                                ("max_s", Json::Num(s.max_s)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(per) = &self.residency_per_replica {
            let agg = ResidencyStats::aggregate(per.iter());
            pairs.push(("expert_hit_rate", Json::Num(agg.hit_rate())));
            pairs.push(("expert_stall_s", Json::Num(agg.stall_s)));
            pairs.push((
                "residency_per_replica",
                Json::Arr(per.iter().map(residency_json).collect()),
            ));
        }
        if let Some(shed) = &self.shed_by_class {
            pairs.push((
                "shed_by_class",
                Json::Arr(shed.iter().map(|&n| Json::Num(n as f64)).collect()),
            ));
            pairs.push(("shed_total", Json::Num(shed.iter().sum::<u64>() as f64)));
        }
        if let Some(rs) = self.replica_seconds {
            pairs.push(("replica_seconds", Json::Num(rs)));
        }
        if let Some(n) = self.scale_ups {
            pairs.push(("scale_ups", Json::Num(n as f64)));
        }
        if let Some(n) = self.drains {
            pairs.push(("drains", Json::Num(n as f64)));
        }
        if let Some(h) = &self.health {
            pairs.push(("health", h.to_json()));
        }
        Json::obj(pairs)
    }
}

/// JSON view of one replica's residency counters.
fn residency_json(s: &ResidencyStats) -> Json {
    Json::obj(vec![
        ("hits", Json::Num(s.hits as f64)),
        ("misses", Json::Num(s.misses as f64)),
        ("hit_rate", Json::Num(s.hit_rate())),
        ("prefetch_issued", Json::Num(s.prefetch_issued as f64)),
        ("prefetch_hits", Json::Num(s.prefetch_hits as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
        ("bypasses", Json::Num(s.bypasses as f64)),
        ("stall_s", Json::Num(s.stall_s)),
        ("stall_p50_s", Json::Num(s.stall_p50_s)),
        ("stall_p95_s", Json::Num(s.stall_p95_s)),
        ("steps", Json::Num(s.steps as f64)),
        ("hbm_budget_bytes", Json::Num(s.hbm_budget_bytes as f64)),
        ("hbm_used_bytes", Json::Num(s.hbm_used_bytes as f64)),
    ])
}

/// One `lexi bench-memory` sweep cell: a (HBM budget, eviction policy)
/// pair run through the full serving cluster, with the residency
/// counters and the resulting serving quality side by side.
#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub scenario: String,
    pub transform: String,
    /// HBM budget as a fraction of the full expert footprint.
    pub budget_frac: f64,
    pub policy: &'static str,
    pub prefetch: bool,
    pub hit_rate: f64,
    pub prefetch_hits: u64,
    pub evictions: u64,
    pub stall_total_s: f64,
    pub stall_p50_s: f64,
    pub stall_p95_s: f64,
    pub goodput_rps: f64,
    pub throughput_tok_s: f64,
    pub ttft_p95_s: f64,
    /// Analytical cross-check: perf-model baseline throughput under the
    /// same budget (the `PerfModel::with_hbm_budget_bytes` term).
    pub pm_tok_s: f64,
}

pub const MEMORY_CSV_HEADER: [&str; 15] = [
    "scenario",
    "transform",
    "budget_frac",
    "policy",
    "prefetch",
    "hit_rate",
    "prefetch_hits",
    "evictions",
    "stall_total_s",
    "stall_p50_ms",
    "stall_p95_ms",
    "goodput_rps",
    "throughput_tok_s",
    "ttft_p95_ms",
    "pm_tok_s",
];

/// Write one CSV row per bench-memory cell.
pub fn write_memory_csv(path: &Path, reports: &[MemoryReport]) -> Result<()> {
    let mut w = CsvWriter::create(path, &MEMORY_CSV_HEADER)?;
    for r in reports {
        csv_row!(
            w,
            r.scenario,
            r.transform,
            format!("{:.3}", r.budget_frac),
            r.policy,
            r.prefetch,
            format!("{:.4}", r.hit_rate),
            r.prefetch_hits,
            r.evictions,
            format!("{:.4}", r.stall_total_s),
            format!("{:.4}", r.stall_p50_s * 1e3),
            format!("{:.4}", r.stall_p95_s * 1e3),
            format!("{:.4}", r.goodput_rps),
            format!("{:.1}", r.throughput_tok_s),
            format!("{:.2}", r.ttft_p95_s * 1e3),
            format!("{:.1}", r.pm_tok_s),
        )?;
    }
    Ok(())
}

/// Write the bench-memory sweep as JSON.
pub fn write_memory_json(path: &Path, reports: &[MemoryReport]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let v = Json::Arr(
        reports
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("scenario", Json::Str(r.scenario.clone())),
                    ("transform", Json::Str(r.transform.clone())),
                    ("budget_frac", Json::Num(r.budget_frac)),
                    ("policy", Json::Str(r.policy.to_string())),
                    ("prefetch", Json::Num(r.prefetch as u8 as f64)),
                    ("hit_rate", Json::Num(r.hit_rate)),
                    ("prefetch_hits", Json::Num(r.prefetch_hits as f64)),
                    ("evictions", Json::Num(r.evictions as f64)),
                    ("stall_total_s", Json::Num(r.stall_total_s)),
                    ("stall_p50_s", Json::Num(r.stall_p50_s)),
                    ("stall_p95_s", Json::Num(r.stall_p95_s)),
                    ("goodput_rps", Json::Num(r.goodput_rps)),
                    ("throughput_tok_s", Json::Num(r.throughput_tok_s)),
                    ("ttft_p95_s", Json::Num(r.ttft_p95_s)),
                    ("pm_tok_s", Json::Num(r.pm_tok_s)),
                ])
            })
            .collect(),
    );
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

/// Print the bench-memory sweep as a table.
pub fn print_memory_header() {
    println!(
        "{:<12} {:>7} {:<6} {:>8} {:>8} {:>9} {:>11} {:>11} {:>8} {:>10}",
        "transform",
        "budget",
        "evict",
        "prefetch",
        "hitrate",
        "stall_s",
        "stall50ms",
        "stall95ms",
        "goodput",
        "tok/s"
    );
}

pub fn print_memory_rows(reports: &[MemoryReport]) {
    for r in reports {
        println!(
            "{:<12} {:>7.2} {:<6} {:>8} {:>7.1}% {:>9.3} {:>11.3} {:>11.3} {:>8.3} {:>10.1}",
            r.transform,
            r.budget_frac,
            r.policy,
            if r.prefetch { "on" } else { "off" },
            r.hit_rate * 100.0,
            r.stall_total_s,
            r.stall_p50_s * 1e3,
            r.stall_p95_s * 1e3,
            r.goodput_rps,
            r.throughput_tok_s,
        );
    }
}

/// One `lexi bench-elasticity` sweep cell: an elastic-control-plane
/// configuration (fixed provisioning vs autoscale vs autoscale+shed, or
/// a heterogeneous tier mix x routing policy) run over the shared
/// workload contract, with provisioning cost and interactive latency
/// side by side.
#[derive(Clone, Debug)]
pub struct ElasticityReport {
    pub scenario: String,
    /// Sweep family: `"elastic"` (provisioning cells) or `"hetero"`
    /// (tier-mix x routing cells).
    pub family: &'static str,
    /// Human-readable cell label, e.g. `fixed-max(8)`,
    /// `autoscale(2:8)+shed`, `h100:2,a100:2`.
    pub cell: String,
    pub policy: String,
    /// Provisioned pool size (autoscale cells: the `max` bound).
    pub replicas: usize,
    pub goodput_rps: f64,
    pub throughput_tok_s: f64,
    /// p95 TTFT over priority-0 (interactive) completions only.
    pub interactive_ttft_p95_s: f64,
    pub completed: usize,
    pub rejected: u64,
    /// Policy sheds (subset of `rejected`).
    pub shed: u64,
    /// Provisioned replica-seconds: autoscaler-integrated when elastic,
    /// `replicas * makespan` for fixed cells.
    pub replica_seconds: f64,
    pub scale_ups: u64,
    pub drains: u64,
}

pub const ELASTICITY_CSV_HEADER: [&str; 14] = [
    "scenario",
    "family",
    "cell",
    "policy",
    "replicas",
    "goodput_rps",
    "throughput_tok_s",
    "interactive_ttft_p95_ms",
    "completed",
    "rejected",
    "shed",
    "replica_seconds",
    "scale_ups",
    "drains",
];

/// Write one CSV row per bench-elasticity cell.
pub fn write_elasticity_csv(path: &Path, reports: &[ElasticityReport]) -> Result<()> {
    let mut w = CsvWriter::create(path, &ELASTICITY_CSV_HEADER)?;
    for r in reports {
        csv_row!(
            w,
            r.scenario,
            r.family,
            r.cell,
            r.policy,
            r.replicas,
            format!("{:.4}", r.goodput_rps),
            format!("{:.1}", r.throughput_tok_s),
            format!("{:.2}", r.interactive_ttft_p95_s * 1e3),
            r.completed,
            r.rejected,
            r.shed,
            format!("{:.2}", r.replica_seconds),
            r.scale_ups,
            r.drains,
        )?;
    }
    Ok(())
}

/// Write the bench-elasticity sweep as JSON.
pub fn write_elasticity_json(path: &Path, reports: &[ElasticityReport]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let v = Json::Arr(
        reports
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("scenario", Json::Str(r.scenario.clone())),
                    ("family", Json::Str(r.family.to_string())),
                    ("cell", Json::Str(r.cell.clone())),
                    ("policy", Json::Str(r.policy.clone())),
                    ("replicas", Json::Num(r.replicas as f64)),
                    ("goodput_rps", Json::Num(r.goodput_rps)),
                    ("throughput_tok_s", Json::Num(r.throughput_tok_s)),
                    (
                        "interactive_ttft_p95_s",
                        Json::Num(r.interactive_ttft_p95_s),
                    ),
                    ("completed", Json::Num(r.completed as f64)),
                    ("rejected", Json::Num(r.rejected as f64)),
                    ("shed", Json::Num(r.shed as f64)),
                    ("replica_seconds", Json::Num(r.replica_seconds)),
                    ("scale_ups", Json::Num(r.scale_ups as f64)),
                    ("drains", Json::Num(r.drains as f64)),
                ])
            })
            .collect(),
    );
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

/// Print the bench-elasticity sweep as a table.
pub fn print_elasticity_header() {
    println!(
        "{:<8} {:<22} {:<10} {:>4} {:>8} {:>10} {:>10} {:>5} {:>5} {:>5} {:>10} {:>4} {:>6}",
        "family",
        "cell",
        "policy",
        "rep",
        "goodput",
        "tok/s",
        "ittft95ms",
        "done",
        "rej",
        "shed",
        "rep-sec",
        "ups",
        "drains"
    );
}

pub fn print_elasticity_rows(reports: &[ElasticityReport]) {
    for r in reports {
        println!(
            "{:<8} {:<22} {:<10} {:>4} {:>8.3} {:>10.1} {:>10.2} {:>5} {:>5} {:>5} {:>10.1} {:>4} {:>6}",
            r.family,
            r.cell,
            r.policy,
            r.replicas,
            r.goodput_rps,
            r.throughput_tok_s,
            r.interactive_ttft_p95_s * 1e3,
            r.completed,
            r.rejected,
            r.shed,
            r.replica_seconds,
            r.scale_ups,
            r.drains,
        );
    }
}

pub const CSV_HEADER: [&str; 18] = [
    "scenario",
    "transform",
    "policy",
    "replicas",
    "n_completed",
    "n_rejected",
    "n_slo_met",
    "goodput_rps",
    "throughput_tok_s",
    "ttft_p50_ms",
    "ttft_p95_ms",
    "ttft_p99_ms",
    "tpot_p50_ms",
    "tpot_p95_ms",
    "tpot_p99_ms",
    "mean_utilization",
    "rung_switches",
    "makespan_s",
];

/// Write one CSV row per report.
pub fn write_csv(path: &Path, reports: &[TransformReport]) -> Result<()> {
    let mut w = CsvWriter::create(path, &CSV_HEADER)?;
    for r in reports {
        csv_row!(
            w,
            r.scenario,
            r.transform,
            r.policy,
            r.replicas,
            r.n_completed,
            r.n_rejected,
            r.n_slo_met,
            format!("{:.4}", r.goodput_rps),
            format!("{:.1}", r.throughput_tok_s),
            format!("{:.2}", r.ttft_p50_s * 1e3),
            format!("{:.2}", r.ttft_p95_s * 1e3),
            format!("{:.2}", r.ttft_p99_s * 1e3),
            format!("{:.3}", r.tpot_p50_s * 1e3),
            format!("{:.3}", r.tpot_p95_s * 1e3),
            format!("{:.3}", r.tpot_p99_s * 1e3),
            format!("{:.3}", r.mean_utilization),
            r.rung_switches,
            format!("{:.2}", r.makespan_s),
        )?;
    }
    Ok(())
}

/// Write the full nested report set as JSON.
pub fn write_json(path: &Path, reports: &[TransformReport]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let v = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

impl std::fmt::Display for TransformReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<14} {:<12} {:>5} {:>6} {:>8.3} {:>10.1} {:>9.1} {:>9.1} {:>8.2} {:>6.0}% {:>7}",
            self.transform,
            self.scenario,
            self.n_completed,
            self.n_rejected,
            self.goodput_rps,
            self.throughput_tok_s,
            self.ttft_p50_s * 1e3,
            self.ttft_p99_s * 1e3,
            self.tpot_p50_s * 1e3,
            self.mean_utilization * 100.0,
            self.rung_switches,
        )
    }
}

/// Print one scenario's report set: a row per transform, then the
/// ladder-vs-baseline goodput summary. Shared by `lexi bench-serve`
/// and the serve_benchmark example.
pub fn print_comparison(reports: &[TransformReport]) {
    for r in reports {
        println!("{r}");
    }
    let base = reports.iter().find(|r| r.transform == "baseline");
    let ladder = reports.iter().find(|r| r.transform == "lexi-ladder");
    if let (Some(base), Some(ladder)) = (base, ladder) {
        println!(
            "  -> ladder goodput {:.3} rps vs baseline {:.3} rps ({:+.0}%), \
             full-quality time {}, mean proxy quality loss {}\n",
            ladder.goodput_rps,
            base.goodput_rps,
            (ladder.goodput_rps / base.goodput_rps.max(1e-12) - 1.0) * 100.0,
            ladder
                .full_quality_frac
                .map_or_else(|| "n/a".to_string(), |f| format!("{:.0}%", f * 100.0)),
            ladder
                .mean_quality_loss
                .map_or_else(|| "n/a".to_string(), |q| format!("{q:.3}"))
        );
    }
}

/// Column header matching [`TransformReport`]'s `Display` row.
pub fn print_header() {
    println!(
        "{:<14} {:<12} {:>5} {:>6} {:>8} {:>10} {:>9} {:>9} {:>8} {:>7} {:>7}",
        "transform",
        "scenario",
        "done",
        "rej",
        "goodput",
        "tok/s",
        "ttft50ms",
        "ttft99ms",
        "tpot50ms",
        "util",
        "switch"
    );
}

/// One cell of the `lexi bench-quality-surface` sweep: a lattice point
/// priced by its analytical [`ServiceModel`](super::replica::ServiceModel)
/// and scored by its Stage-1-comparable proxy quality loss.
#[derive(Clone, Debug)]
pub struct QualitySurfaceReport {
    pub model: String,
    /// Ladder axes the lattice was built with ("k", "k-intra", "k-skip").
    pub axes: String,
    pub label: String,
    /// Lattice coordinate: k-axis index (0 = full base rung).
    pub k: usize,
    /// Lattice coordinate: sparsity-axis index (0 = axis off).
    pub s: usize,
    pub intra_frac: f64,
    pub skip_threshold: f64,
    /// Mean active experts per layer after both axes are applied.
    pub mean_active_experts: f64,
    /// Modeled decode step time at full batch occupancy.
    pub step_time_s: f64,
    /// Single-replica capacity from the service model (req/s).
    pub capacity_rps: f64,
    /// Proxy quality loss on the Stage-1 scale; NaN = not comparable
    /// (serialized as null in JSON, empty in CSV — never as zero).
    pub quality_loss: f64,
    /// Pareto-optimal over the whole lattice (no point is at least as
    /// fast AND at least as accurate with one strict improvement).
    pub on_frontier: bool,
    /// How many pure-k rungs (s = 0) this point dominates: no worse on
    /// both (step time, quality loss), strictly better on one.
    pub pure_k_dominated: usize,
}

pub const QUALITY_SURFACE_CSV_HEADER: [&str; 13] = [
    "model",
    "axes",
    "label",
    "k",
    "s",
    "intra_frac",
    "skip_threshold",
    "mean_active_experts",
    "step_time_ms",
    "capacity_rps",
    "quality_loss",
    "on_frontier",
    "pure_k_dominated",
];

/// Render a possibly-NaN quality loss for CSV: empty cell, not "NaN",
/// so downstream tooling never mistakes "unknown" for a number.
fn loss_csv(q: f64) -> String {
    if q.is_finite() {
        format!("{q:.4}")
    } else {
        String::new()
    }
}

/// Write one CSV row per lattice point.
pub fn write_quality_surface_csv(path: &Path, reports: &[QualitySurfaceReport]) -> Result<()> {
    let mut w = CsvWriter::create(path, &QUALITY_SURFACE_CSV_HEADER)?;
    for r in reports {
        csv_row!(
            w,
            r.model,
            r.axes,
            r.label,
            r.k,
            r.s,
            format!("{:.3}", r.intra_frac),
            format!("{:.3}", r.skip_threshold),
            format!("{:.3}", r.mean_active_experts),
            format!("{:.4}", r.step_time_s * 1e3),
            format!("{:.4}", r.capacity_rps),
            loss_csv(r.quality_loss),
            r.on_frontier,
            r.pure_k_dominated,
        )?;
    }
    Ok(())
}

/// Write the quality-surface sweep as JSON. Non-finite quality losses
/// serialize as `null`, never as a number.
pub fn write_quality_surface_json(path: &Path, reports: &[QualitySurfaceReport]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let v = Json::Arr(
        reports
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::Str(r.model.clone())),
                    ("axes", Json::Str(r.axes.clone())),
                    ("label", Json::Str(r.label.clone())),
                    ("k", Json::Num(r.k as f64)),
                    ("s", Json::Num(r.s as f64)),
                    ("intra_frac", Json::Num(r.intra_frac)),
                    ("skip_threshold", Json::Num(r.skip_threshold)),
                    ("mean_active_experts", Json::Num(r.mean_active_experts)),
                    ("step_time_s", Json::Num(r.step_time_s)),
                    ("capacity_rps", Json::Num(r.capacity_rps)),
                    (
                        "quality_loss",
                        if r.quality_loss.is_finite() {
                            Json::Num(r.quality_loss)
                        } else {
                            Json::Null
                        },
                    ),
                    ("on_frontier", Json::Num(r.on_frontier as u8 as f64)),
                    ("pure_k_dominated", Json::Num(r.pure_k_dominated as f64)),
                ])
            })
            .collect(),
    );
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

/// Print the quality-surface sweep as a table.
pub fn print_quality_surface_header() {
    println!(
        "{:<22} {:>3} {:>3} {:>7} {:>8} {:>9} {:>9} {:>8} {:>9} {:>9}",
        "point", "k", "s", "mean_k", "step_ms", "cap_rps", "loss", "frontier", "dom_k", "axes"
    );
}

pub fn print_quality_surface_rows(reports: &[QualitySurfaceReport]) {
    for r in reports {
        println!(
            "{:<22} {:>3} {:>3} {:>7.2} {:>8.3} {:>9.3} {:>9} {:>8} {:>9} {:>9}",
            r.label,
            r.k,
            r.s,
            r.mean_active_experts,
            r.step_time_s * 1e3,
            r.capacity_rps,
            if r.quality_loss.is_finite() {
                format!("{:.3}", r.quality_loss)
            } else {
                "n/a".to_string()
            },
            if r.on_frontier { "*" } else { "" },
            r.pure_k_dominated,
            r.axes,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::server::ScenarioKind;

    fn fake_run() -> RunResult {
        let completed = (0..10)
            .map(|i| CompletedRequest {
                id: i,
                class: 0,
                arrival_s: i as f64,
                prompt_len: 100,
                tokens: 20,
                ttft_s: 0.1 + 0.01 * i as f64,
                e2e_s: 0.5 + 0.01 * i as f64,
                finish_s: i as f64 + 0.5,
                replica: (i % 2) as usize,
            })
            .collect();
        RunResult {
            completed,
            rejected_by_class: vec![1, 0, 0, 0],
            makespan_s: 10.0,
            replica_busy_s: vec![8.0, 6.0],
            rung_switches: 3,
            rung_time_s: vec![10.0, 4.0],
            prefill_calls: 5,
            decode_steps: 100,
            rung_switch_events: vec![(1, 0), (2, 1), (3, 0)],
            steal_events: Vec::new(),
            steals: None,
            min_slack_s: None,
            step_time_per_replica: vec![None, None],
            step_samples_per_replica: vec![None, None],
            residency_per_replica: vec![None, None],
            shed_by_class: None,
            replica_seconds: None,
            scale_events: None,
            trace: None,
            health: None,
        }
    }

    fn scenario() -> Scenario {
        let mut s = Scenario::from_kind(ScenarioKind::Poisson, 10.0);
        // generous SLOs: everything passes
        s.resolve_slos(|_| 10.0, 10.0);
        s
    }

    #[test]
    fn report_aggregates_and_weights_quality() {
        let s = scenario();
        let r = TransformReport::from_run(&s, "ladder", "jsq", &fake_run(), &[0.0, 2.0]);
        assert_eq!(r.n_completed, 10);
        assert_eq!(r.n_rejected, 1);
        assert_eq!(r.n_slo_met, 10);
        assert!((r.goodput_rps - 1.0).abs() < 1e-12);
        assert!((r.mean_utilization - 0.7).abs() < 1e-12);
        // 14 busy-seconds total, 4 at quality loss 2.0
        assert!((r.mean_quality_loss.unwrap() - 8.0 / 14.0).abs() < 1e-12);
        assert!((r.full_quality_frac.unwrap() - 10.0 / 14.0).abs() < 1e-12);
        assert!(r.ttft_p99_s >= r.ttft_p50_s);
    }

    #[test]
    fn unknown_quality_scale_reports_none_not_zero() {
        let s = scenario();
        let r =
            TransformReport::from_run(&s, "inter50", "rr", &fake_run(), &[f64::NAN, f64::NAN]);
        assert!(r.mean_quality_loss.is_none());
        // a ladder with no zero-loss rung never ran at "full quality"
        assert!(r.full_quality_frac.is_none());
        let j = r.to_json();
        assert_eq!(*j.get("mean_quality_loss").unwrap(), Json::Null);
        assert_eq!(*j.get("full_quality_frac").unwrap(), Json::Null);
    }

    #[test]
    fn extended_fields_stay_dark_by_default_and_emit_when_populated() {
        let s = scenario();
        // default feature set: no extended keys in the JSON at all
        let dark = TransformReport::from_run(&s, "base", "jsq", &fake_run(), &[0.0, 2.0]);
        assert!(dark.steals.is_none() && dark.min_slack_s.is_none());
        assert!(dark.step_time_per_replica.is_none());
        assert!(dark.residency_per_replica.is_none());
        assert!(dark.residency_aggregate().is_none());
        assert!(dark.shed_by_class.is_none() && dark.replica_seconds.is_none());
        assert!(dark.scale_ups.is_none() && dark.drains.is_none());
        assert!(dark.health.is_none());
        let j = dark.to_json();
        assert!(j.opt("steals").is_none());
        assert!(j.opt("min_slack_s").is_none());
        assert!(j.opt("step_time_per_replica").is_none());
        assert!(j.opt("expert_hit_rate").is_none());
        assert!(j.opt("residency_per_replica").is_none());
        assert!(j.opt("shed_by_class").is_none());
        assert!(j.opt("replica_seconds").is_none());
        assert!(j.opt("scale_ups").is_none());
        assert!(j.opt("drains").is_none());
        assert!(j.opt("health").is_none());

        // extended run: steals + slack + measured step times all emit
        let mut run = fake_run();
        run.steals = Some(2);
        run.steal_events = vec![(5, 0, 1), (9, 0, 1)];
        run.min_slack_s = Some(0.125);
        run.step_time_per_replica = vec![
            Some(StepTimeSummary {
                n: 10,
                p50_s: 0.01,
                p95_s: 0.02,
                max_s: 0.05,
            }),
            None,
        ];
        let lit = TransformReport::from_run(&s, "base", "classaware", &run, &[0.0, 2.0]);
        assert_eq!(lit.steals, Some(2));
        assert_eq!(lit.min_slack_s, Some(0.125));
        let st = lit.step_time_per_replica.as_ref().unwrap();
        assert_eq!(st.len(), 2);
        assert_eq!(st[1], StepTimeSummary::default()); // missing -> zeroed
        let j = lit.to_json();
        assert_eq!(j.get("steals").unwrap().as_usize().unwrap(), 2);
        assert!((j.get("min_slack_s").unwrap().as_f64().unwrap() - 0.125).abs() < 1e-12);
        let arr = j.get("step_time_per_replica").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!((arr[0].get("p95_s").unwrap().as_f64().unwrap() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn elastic_fields_emit_when_the_control_plane_ran() {
        let s = scenario();
        let mut run = fake_run();
        run.shed_by_class = Some(vec![0, 3, 2, 0]);
        run.replica_seconds = Some(42.5);
        // two activations (beyond the initial set) and one drain
        run.scale_events = Some(vec![(10, 2, true), (20, 3, true), (90, 3, false)]);
        let r = TransformReport::from_run(&s, "lexi-ladder", "classaware", &run, &[0.0, 2.0]);
        assert_eq!(r.shed_by_class.as_deref(), Some(&[0, 3, 2, 0][..]));
        assert_eq!(r.replica_seconds, Some(42.5));
        assert_eq!(r.scale_ups, Some(2));
        assert_eq!(r.drains, Some(1));
        let j = r.to_json();
        let shed = j.get("shed_by_class").unwrap().as_arr().unwrap();
        assert_eq!(shed.len(), 4);
        assert_eq!(shed[1].as_usize().unwrap(), 3);
        assert_eq!(j.get("shed_total").unwrap().as_usize().unwrap(), 5);
        assert!((j.get("replica_seconds").unwrap().as_f64().unwrap() - 42.5).abs() < 1e-12);
        assert_eq!(j.get("scale_ups").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("drains").unwrap().as_usize().unwrap(), 1);

        // bench-elasticity writers roundtrip
        let row = ElasticityReport {
            scenario: "diurnal".into(),
            family: "elastic",
            cell: "autoscale(2:8)+shed".into(),
            policy: "classaware".into(),
            replicas: 8,
            goodput_rps: r.goodput_rps,
            throughput_tok_s: r.throughput_tok_s,
            interactive_ttft_p95_s: 0.25,
            completed: r.n_completed,
            rejected: r.n_rejected,
            shed: 5,
            replica_seconds: 42.5,
            scale_ups: 2,
            drains: 1,
        };
        let dir = std::env::temp_dir().join("lexi_elasticity_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_elasticity_csv(&dir.join("ela.csv"), std::slice::from_ref(&row)).unwrap();
        write_elasticity_json(&dir.join("ela.json"), std::slice::from_ref(&row)).unwrap();
        let csv = std::fs::read_to_string(dir.join("ela.csv")).unwrap();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("scenario,family,cell,policy,replicas"));
        assert!(csv.contains("autoscale(2:8)+shed"));
        let json = crate::util::json::parse_file(&dir.join("ela.json")).unwrap();
        let arr = json.as_arr().unwrap();
        assert_eq!(arr[0].get("family").unwrap().as_str().unwrap(), "elastic");
        assert_eq!(arr[0].get("shed").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn residency_fields_emit_when_a_budget_ran() {
        let s = scenario();
        let mut run = fake_run();
        run.residency_per_replica = vec![
            Some(ResidencyStats {
                hits: 90,
                misses: 10,
                prefetch_issued: 20,
                prefetch_hits: 15,
                evictions: 5,
                bypasses: 0,
                stall_s: 1.5,
                stall_p50_s: 0.001,
                stall_p95_s: 0.02,
                steps: 100,
                hbm_budget_bytes: 1 << 30,
                hbm_used_bytes: 1 << 29,
            }),
            None,
        ];
        let r = TransformReport::from_run(&s, "lexi-ladder", "jsq", &run, &[0.0, 2.0]);
        let agg = r.residency_aggregate().unwrap();
        assert!((agg.hit_rate() - 0.9).abs() < 1e-12);
        assert!((agg.stall_s - 1.5).abs() < 1e-12);
        let j = r.to_json();
        assert!((j.get("expert_hit_rate").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        let arr = j.get("residency_per_replica").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("hits").unwrap().as_usize().unwrap(), 90);
        // the missing replica zero-fills (same convention as step times)
        assert_eq!(arr[1].get("hits").unwrap().as_usize().unwrap(), 0);

        // bench-memory writers roundtrip
        let mem = MemoryReport {
            scenario: "bursty".into(),
            transform: "lexi-ladder".into(),
            budget_frac: 0.5,
            policy: "kvec",
            prefetch: true,
            hit_rate: agg.hit_rate(),
            prefetch_hits: agg.prefetch_hits,
            evictions: agg.evictions,
            stall_total_s: agg.stall_s,
            stall_p50_s: agg.stall_p50_s,
            stall_p95_s: agg.stall_p95_s,
            goodput_rps: r.goodput_rps,
            throughput_tok_s: r.throughput_tok_s,
            ttft_p95_s: r.ttft_p95_s,
            pm_tok_s: 1234.5,
        };
        let dir = std::env::temp_dir().join("lexi_memory_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_memory_csv(&dir.join("mem.csv"), std::slice::from_ref(&mem)).unwrap();
        write_memory_json(&dir.join("mem.json"), std::slice::from_ref(&mem)).unwrap();
        let csv = std::fs::read_to_string(dir.join("mem.csv")).unwrap();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("scenario,transform,budget_frac,policy,prefetch"));
        assert!(csv.contains("kvec"));
        let json = crate::util::json::parse_file(&dir.join("mem.json")).unwrap();
        let arr = json.as_arr().unwrap();
        assert_eq!(arr[0].get("policy").unwrap().as_str().unwrap(), "kvec");
    }

    #[test]
    fn pooled_samples_match_filter_then_sort() {
        // multiclass completions with interleaved latencies, so the
        // per-class merge actually has to interleave lanes
        let completed: Vec<CompletedRequest> = (0..30)
            .map(|i| CompletedRequest {
                id: i,
                class: (i % 3) as usize,
                arrival_s: 0.0,
                prompt_len: 10,
                tokens: 8,
                ttft_s: ((i * 37) % 30) as f64 * 0.01,
                e2e_s: 1.0 + i as f64 * 0.05,
                finish_s: 2.0,
                replica: 0,
            })
            .collect();
        let samples = LatencySamples::collect(&completed);
        let direct_ttft = Quantiles::from_samples(completed.iter().map(|c| c.ttft_s));
        let direct_tpot = Quantiles::from_samples(completed.iter().map(|c| c.tpot_s()));
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(samples.ttft().q(p), direct_ttft.q(p), "ttft p{p}");
            assert_eq!(samples.tpot().q(p), direct_tpot.q(p), "tpot p{p}");
        }
        // priority-style class filter: merged lanes == filter-then-sort
        let direct = Quantiles::from_samples(
            completed.iter().filter(|c| c.class != 2).map(|c| c.ttft_s),
        );
        let merged = Quantiles::from_sorted(samples.merged_ttft(|c| c != 2));
        assert_eq!(merged.n(), direct.n());
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(merged.q(p), direct.q(p), "filtered p{p}");
        }
    }

    #[test]
    fn tight_slo_fails_requests() {
        let mut s = scenario();
        s.resolve_slos(|_| 0.05, 10.0); // ttft target below every ttft
        let r = TransformReport::from_run(&s, "base", "rr", &fake_run(), &[0.0, 0.0]);
        assert_eq!(r.n_slo_met, 0);
        assert_eq!(r.goodput_rps, 0.0);
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let s = scenario();
        let r = TransformReport::from_run(&s, "ladder", "jsq", &fake_run(), &[0.0, 2.0]);
        let dir = std::env::temp_dir().join("lexi_server_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_csv(&dir.join("serve.csv"), std::slice::from_ref(&r)).unwrap();
        write_json(&dir.join("serve.json"), std::slice::from_ref(&r)).unwrap();
        let csv = std::fs::read_to_string(dir.join("serve.csv")).unwrap();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("scenario,transform,policy"));
        assert!(csv.contains("ladder"));
        let json = crate::util::json::parse_file(&dir.join("serve.json")).unwrap();
        let arr = json.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("transform").unwrap().as_str().unwrap(), "ladder");
        assert_eq!(arr[0].get("n_slo_met").unwrap().as_usize().unwrap(), 10);
    }

    #[test]
    fn nan_quality_loss_serializes_as_null_not_zero() {
        let point = |label: &str, s: usize, loss: f64| QualitySurfaceReport {
            model: "m".into(),
            axes: "k-intra".into(),
            label: label.to_string(),
            k: 0,
            s,
            intra_frac: 0.25 * s as f64,
            skip_threshold: 0.0,
            mean_active_experts: 2.0,
            step_time_s: 0.01,
            capacity_rps: 1.0,
            quality_loss: loss,
            on_frontier: true,
            pure_k_dominated: 0,
        };
        let reports = vec![point("base", 0, 0.0), point("odd", 1, f64::NAN)];
        let dir = std::env::temp_dir().join("lexi_quality_surface_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_quality_surface_csv(&dir.join("qs.csv"), &reports).unwrap();
        write_quality_surface_json(&dir.join("qs.json"), &reports).unwrap();

        let csv = std::fs::read_to_string(dir.join("qs.csv")).unwrap();
        assert!(!csv.contains("NaN"), "CSV leaked a NaN literal:\n{csv}");
        let json = crate::util::json::parse_file(&dir.join("qs.json")).unwrap();
        let arr = json.as_arr().unwrap();
        assert_eq!(arr[0].get("quality_loss").unwrap().as_f64().unwrap(), 0.0);
        assert!(
            matches!(arr[1].get("quality_loss"), Some(Json::Null)),
            "NaN loss must be null, got {:?}",
            arr[1].get("quality_loss")
        );
    }
}
