//! The cluster front door: N replica backends, pluggable routing, one
//! discrete-event loop.
//!
//! Arrivals pass admission control, get a TTFT deadline from their class
//! SLO, and are routed to a replica queue by a [`RoutingPolicy`]
//! (round-robin / join-shortest-queue / power-of-two-choices /
//! SLO-class-aware, pluggable impls instead of hardcoded branches).
//! Replicas are driven through the [`ReplicaBackend`] trait, so the same
//! loop serves the virtual-time [`Replica`](super::replica::Replica) and
//! the engine-backed
//! [`EngineReplica`](super::engine_backend::EngineReplica).
//!
//! All cluster-level decisions read ONE [`ClusterSnapshot`] telemetry
//! surface: the cluster-global [`LadderController`] retunes rung
//! assignments from it, routing policies pick replicas from it, and the
//! bounded work-stealing pass moves the worst-slack queued request from
//! the most pressured replica onto an idle one at dispatch instants. The
//! loop is fully deterministic for simulated backends: ties in virtual
//! time break by (arrival before completion, replica index, request id).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::rc::Rc;

use crate::config::server::{PolicyKind, PressureMode};
use crate::ctrl::{reweight_by_speed, Autoscaler, Shedder};
use crate::experts::ResidencyStats;
use crate::obs::health::{HealthEngine, HealthOutcome};
use crate::obs::trace::{record_opt, EventKind, TraceLog};
use crate::obs::{SharedTracer, Tracer};
use crate::prof_scope;
use crate::util::Pcg32;

use super::backend::{BackendStats, CompletedRequest, ReplicaBackend};
use super::ladder::{LadderController, LadderPolicy, QualityLadder};
use super::replica::Replica;
use super::scheduler::{AdmissionControl, QueuedRequest};
use super::telemetry::{
    ClusterSnapshot, SnapshotCache, StepSample, StepTimeSummary, TelemetryDetail,
};
use super::workload::{Scenario, Trace, TraceRequest};

/// Outcome of one cluster run over a trace.
#[derive(Debug)]
pub struct RunResult {
    pub completed: Vec<CompletedRequest>,
    pub rejected_by_class: Vec<u64>,
    /// Event-loop time at which the last request finished.
    pub makespan_s: f64,
    pub replica_busy_s: Vec<f64>,
    pub rung_switches: u64,
    /// Busy time per rung, summed over replicas.
    pub rung_time_s: Vec<f64>,
    pub prefill_calls: u64,
    pub decode_steps: u64,
    /// Every applied rung switch as `(time key ns, replica index)` —
    /// the flap-detection signal for the cluster-global controller.
    pub rung_switch_events: Vec<(u64, usize)>,
    /// Every cross-replica steal as `(time key ns, victim, thief)`.
    pub steal_events: Vec<(u64, usize, usize)>,
    /// Requests shed per SLO class by the class-aware shedder. `None`
    /// unless the cluster was built [`with_shedding`](Cluster::with_shedding).
    /// Shed requests are ALSO counted in `rejected_by_class`, so the
    /// arrivals = completions + rejections invariant is unchanged.
    pub shed_by_class: Option<Vec<u64>>,
    /// Provisioned replica-seconds (Active + Warming + Draining time)
    /// under the autoscaler — the cost side of the elasticity trade.
    /// `None` unless built [`with_autoscale`](Cluster::with_autoscale).
    pub replica_seconds: Option<f64>,
    /// Autoscaler actions as `(time key ns, replica, up)`; `up` is true
    /// for an activation, false for a drain. `None` unless built
    /// [`with_autoscale`](Cluster::with_autoscale).
    pub scale_events: Option<Vec<(u64, usize, bool)>>,
    /// Requests stolen across replicas. `None` unless an extended
    /// control-plane feature (stealing, slack pressure, class-aware
    /// routing) was active — default runs keep the PR 2 report shape.
    pub steals: Option<u64>,
    /// Worst (minimum) queued EDF slack seen at any control-plane
    /// snapshot. `None` under the default feature set, or when no
    /// queued request was ever observed.
    pub min_slack_s: Option<f64>,
    /// Measured step-time summaries, one per replica (`None` entries
    /// for virtual-time replicas, which have no measured steps).
    pub step_time_per_replica: Vec<Option<StepTimeSummary>>,
    /// Every measured step per replica, tagged for service-model
    /// calibration (`None` for virtual-time replicas) — the raw stream
    /// `calibrate::CalibrationArtifact` is accumulated from.
    pub step_samples_per_replica: Vec<Option<Vec<StepSample>>>,
    /// Expert-residency counters, one per replica (`None` entries for
    /// replicas running without a residency model — the default).
    pub residency_per_replica: Vec<Option<ResidencyStats>>,
    /// The run's span-event log (`None` unless the cluster was built
    /// [`with_tracing`](Cluster::with_tracing) — the default keeps the
    /// untraced report shape byte-for-byte).
    pub trace: Option<TraceLog>,
    /// SLO health-engine outcome: the windowed burn-rate report, the
    /// raised [`HealthEvent`](crate::obs::health::HealthEvent)s, and any
    /// frozen debug bundles. `None` unless the cluster was built
    /// [`with_health`](Cluster::with_health) — the default keeps every
    /// sim output byte-identical to the health-off build.
    pub health: Option<HealthOutcome>,
}

/// Pending arrival, ordered by (time ns, id) for a deterministic heap.
#[derive(Debug)]
struct PendingArrival(u64, TraceRequest);

impl PartialEq for PendingArrival {
    fn eq(&self, other: &Self) -> bool {
        (self.0, self.1.id) == (other.0, other.1.id)
    }
}
impl Eq for PendingArrival {}
impl PartialOrd for PendingArrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingArrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.0, self.1.id).cmp(&(other.0, other.1.id))
    }
}

fn time_key(t: f64) -> u64 {
    (t * 1e9) as u64
}

/// Replica-selection strategy of the front door: a pure function of the
/// request and the [`ClusterSnapshot`], so every policy sees the same
/// telemetry the ladder controller and the stealing pass see.
pub trait RoutingPolicy {
    fn label(&self) -> &'static str;

    /// Pick the replica for `req`. `rng` is the cluster's seeded stream
    /// (used only by randomized policies).
    fn route(&mut self, req: &QueuedRequest, snap: &ClusterSnapshot, rng: &mut Pcg32) -> usize;
}

/// Replicas currently accepting work (the routing candidate set),
/// yielded as a lazy iterator so the per-arrival routing path never
/// allocates. When none accepts, every replica is yielded so the
/// policies stay total — the requests are lost either way, and the
/// report shows the shortfall. With every replica healthy (the sim
/// backend always is) this is the identity set, so the policies behave
/// bit-identically to their pre-health-aware versions.
fn candidate_indices(snap: &ClusterSnapshot) -> impl Iterator<Item = usize> + Clone + '_ {
    let none_accepting = !snap.replicas.iter().any(|t| t.accepting);
    snap.replicas
        .iter()
        .filter(move |t| t.accepting || none_accepting)
        .map(|t| t.replica)
}

/// Cycle through replicas regardless of load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn label(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _req: &QueuedRequest, snap: &ClusterSnapshot, _rng: &mut Pcg32) -> usize {
        let c = candidate_indices(snap);
        let n = c.clone().count();
        let i = c.clone().nth(self.next % n).expect("no routing candidates");
        self.next += 1;
        i
    }
}

/// Join the shortest queue (token-weighted backlog).
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl RoutingPolicy for JoinShortestQueue {
    fn label(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, _req: &QueuedRequest, snap: &ClusterSnapshot, _rng: &mut Pcg32) -> usize {
        argmin_load(candidate_indices(snap), snap)
    }
}

/// Power-of-two-choices: sample two replicas, pick the lighter.
#[derive(Debug, Default)]
pub struct PowerOfTwoChoices;

impl RoutingPolicy for PowerOfTwoChoices {
    fn label(&self) -> &'static str {
        "p2c"
    }

    fn route(&mut self, _req: &QueuedRequest, snap: &ClusterSnapshot, rng: &mut Pcg32) -> usize {
        let c = candidate_indices(snap);
        let n = c.clone().count();
        if n == 1 {
            return c.clone().next().expect("no routing candidates");
        }
        let a = rng.gen_usize(n);
        let mut b = rng.gen_usize(n - 1);
        if b >= a {
            b += 1;
        }
        let ca = c.clone().nth(a).expect("no routing candidates");
        let cb = c.clone().nth(b).expect("no routing candidates");
        argmin_load([ca, cb].into_iter(), snap)
    }
}

/// SLO-class-aware joint rung+routing: batch-priority traffic is
/// steered toward degraded (deep-rung) replicas, so they absorb the
/// quality loss the ladder is selling, while interactive classes keep
/// the full-quality replicas. Load breaks ties within a rung band, so
/// with a uniform-rung cluster the policy collapses to JSQ exactly.
#[derive(Debug, Default)]
pub struct ClassAware;

impl RoutingPolicy for ClassAware {
    fn label(&self) -> &'static str {
        "classaware"
    }

    fn route(&mut self, req: &QueuedRequest, snap: &ClusterSnapshot, _rng: &mut Pcg32) -> usize {
        let c = candidate_indices(snap);
        // lattice depth (k + s) is the scalar "how degraded" measure; on
        // a 1-D lattice it equals the historical rung index exactly
        let max_depth = c
            .clone()
            .map(|i| snap.replicas[i].point.depth())
            .max()
            .unwrap_or(0);
        c.map(|i| &snap.replicas[i])
            .min_by_key(|t| {
                let depth_pref = if req.priority == 0 {
                    t.point.depth() // interactive: best quality first
                } else {
                    max_depth - t.point.depth() // batch: most degraded first
                };
                (depth_pref, t.load_cost, t.replica)
            })
            .expect("no routing candidates")
            .replica
    }
}

impl PolicyKind {
    /// Instantiate the routing-policy implementation for this kind.
    pub fn build(self) -> Box<dyn RoutingPolicy> {
        match self {
            PolicyKind::RoundRobin => Box::new(RoundRobin::default()),
            PolicyKind::Jsq => Box::new(JoinShortestQueue),
            PolicyKind::PowerOfTwo => Box::new(PowerOfTwoChoices),
            PolicyKind::ClassAware => Box::new(ClassAware),
        }
    }
}

/// Index of the lightest replica among `candidates` (ties -> lowest id).
fn argmin_load(candidates: impl Iterator<Item = usize>, snap: &ClusterSnapshot) -> usize {
    let mut best: Option<(u64, usize)> = None;
    for i in candidates {
        let cost = snap.replicas[i].load_cost;
        match best {
            None => best = Some((cost, i)),
            Some((bc, bi)) if (cost, i) < (bc, bi) => best = Some((cost, i)),
            _ => {}
        }
    }
    best.expect("no routing candidates").1
}

/// N replica backends behind one routing policy, one (optional)
/// cluster-global ladder controller, and an optional bounded
/// work-stealing pass — all consuming the same telemetry snapshot.
pub struct Cluster<'a> {
    pub backends: Vec<Box<dyn ReplicaBackend + 'a>>,
    pub router: Box<dyn RoutingPolicy>,
    /// The routing-policy kind the cluster was built with (report
    /// gating reads this, not the policy object's display label).
    pub policy_kind: PolicyKind,
    pub ladder: Rc<QualityLadder>,
    /// None = fixed rung 0 (static allocation); Some = adaptive ladder.
    pub controller: Option<LadderController>,
    pub admission: AdmissionControl,
    pub reconfig_penalty_s: f64,
    /// Cross-replica steals allowed per dispatch instant (0 = off).
    pub steal_bound: usize,
    /// Minimum event-loop time between steals touching one replica
    /// (thief or victim) — hysteresis so engine-backed replicas don't
    /// thrash work back and forth. 0 keeps the per-instant bound only.
    pub steal_cooldown_s: f64,
    /// Per-replica time of the last steal the replica participated in
    /// (−∞ before the first; indexed like `backends`).
    last_steal_s: Vec<f64>,
    /// Class-aware admission shedder (`None` = off, the default).
    shedder: Option<Shedder>,
    /// Replica autoscaler over the backend pool (`None` = the replica
    /// set is fixed, the default).
    scaler: Option<Autoscaler>,
    /// Reweight snapshot `load_cost` by each replica's measured step
    /// speed (heterogeneous hardware tiers; off by default).
    speed_weighted: bool,
    /// Persistent O(1)-field snapshot (per-arrival routing input),
    /// incrementally refreshed from dirty replicas only.
    load_cache: SnapshotCache,
    /// Persistent scan-field snapshot (control-plane input). Kept
    /// separate from `load_cache` so Load consumers never see stale
    /// scan fields a Full refresh left behind.
    full_cache: SnapshotCache,
    /// Reusable buffer for the masked/reweighted snapshot view, so the
    /// elastic control plane stays allocation-free per instant too.
    mask_scratch: ClusterSnapshot,
    /// Contiguous replica groups advanced independently between
    /// routing instants (`--shards`; 1 = the plain serial loop). Shard
    /// results merge in replica-index order, so every shard count
    /// reproduces the serial schedule byte-for-byte.
    shards: usize,
    /// Shared span tracer (`None` = tracing off, the default; see
    /// [`crate::obs`]). Never reads or perturbs the seeded rng.
    tracer: Option<SharedTracer>,
    /// Streaming SLO health engine (`None` = health monitoring off, the
    /// default). Pure observer of the same telemetry snapshots every
    /// control decision reads — it only feeds back into the schedule
    /// through `--pressure burn`, via the controller's and shedder's
    /// `set_burn_frac`.
    health: Option<HealthEngine>,
    rng: Pcg32,
}

/// Copy `src` into `scratch` (reusing the row allocation) and apply the
/// elastic-control-plane view transforms: the autoscaler masks
/// non-Active replicas out of the accepting set, and heterogeneous
/// clusters rescale `load_cost` by measured replica speed. Returns the
/// scratch buffer as the snapshot to consume. The cache's own buffer is
/// never masked in place — it must keep holding raw telemetry rows so
/// the next incremental refresh has valid clean rows to retain.
fn mask_into<'s>(
    scratch: &'s mut ClusterSnapshot,
    src: &ClusterSnapshot,
    scaler: Option<&Autoscaler>,
    speed_weighted: bool,
) -> &'s ClusterSnapshot {
    scratch.now_s = src.now_s;
    scratch.replicas.clone_from(&src.replicas);
    if let Some(sc) = scaler {
        sc.mask(scratch);
    }
    if speed_weighted {
        reweight_by_speed(scratch);
    }
    scratch
}

/// Refresh the named snapshot cache at `$now` and yield the
/// `&ClusterSnapshot` every control/routing decision consumes. With the
/// elastic control plane off (the default) the cache's persistent
/// buffer is served directly — the per-arrival routing path copies and
/// allocates nothing. With autoscaling or speed-weighted routing on,
/// the raw rows are masked into the reusable `mask_scratch` buffer via
/// [`mask_into`]. A macro rather than a `&mut self` method so the
/// returned borrow stays field-scoped: callers keep disjoint mutable
/// access to the router, controller, shedder, scaler, backends, and
/// rng while the snapshot is live.
macro_rules! cached_snapshot {
    ($cluster:expr, $cache:ident, $now:expr) => {{
        $cluster.$cache.refresh(&$cluster.backends, $now);
        if $cluster.scaler.is_some() || $cluster.speed_weighted {
            mask_into(
                &mut $cluster.mask_scratch,
                $cluster.$cache.snap(),
                $cluster.scaler.as_ref(),
                $cluster.speed_weighted,
            )
        } else {
            $cluster.$cache.snap()
        }
    }};
}

impl Cluster<'static> {
    /// Simulated cluster: N virtual-time replicas sharing one ladder.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_replicas: usize,
        slots_per_replica: usize,
        policy: PolicyKind,
        ladder: QualityLadder,
        ladder_policy: Option<LadderPolicy>,
        queue_cap: usize,
        n_classes: usize,
        reconfig_penalty_s: f64,
        seed: u64,
    ) -> Cluster<'static> {
        let ladder = Rc::new(ladder);
        let backends: Vec<Box<dyn ReplicaBackend>> = (0..n_replicas)
            .map(|i| {
                Box::new(Replica::new(i, slots_per_replica, Rc::clone(&ladder)))
                    as Box<dyn ReplicaBackend>
            })
            .collect();
        Cluster::from_backends(
            backends,
            policy,
            ladder,
            ladder_policy,
            queue_cap,
            n_classes,
            reconfig_penalty_s,
            seed,
        )
    }
}

impl<'a> Cluster<'a> {
    /// Cluster over caller-built backends (e.g. engine-backed replicas).
    #[allow(clippy::too_many_arguments)]
    pub fn from_backends(
        backends: Vec<Box<dyn ReplicaBackend + 'a>>,
        policy: PolicyKind,
        ladder: Rc<QualityLadder>,
        ladder_policy: Option<LadderPolicy>,
        queue_cap: usize,
        n_classes: usize,
        reconfig_penalty_s: f64,
        seed: u64,
    ) -> Cluster<'a> {
        assert!(queue_cap > 0, "queue_cap must be >= 1");
        assert!(!backends.is_empty(), "cluster needs at least one replica");
        let n = backends.len();
        Cluster {
            backends,
            router: policy.build(),
            policy_kind: policy,
            ladder,
            controller: ladder_policy.map(LadderController::new),
            admission: AdmissionControl::new(queue_cap, n_classes),
            reconfig_penalty_s,
            steal_bound: 0,
            steal_cooldown_s: 0.0,
            last_steal_s: vec![f64::NEG_INFINITY; n],
            shedder: None,
            scaler: None,
            speed_weighted: false,
            load_cache: SnapshotCache::new(n, TelemetryDetail::Load),
            full_cache: SnapshotCache::new(n, TelemetryDetail::Full),
            mask_scratch: ClusterSnapshot { now_s: 0.0, replicas: Vec::new() },
            shards: 1,
            tracer: None,
            health: None,
            rng: Pcg32::new(seed, 0x0707_2026),
        }
    }

    /// Enable span tracing: one shared ring of at most `cap` events,
    /// attached to the cluster loop and every backend. Tracing draws
    /// nothing from the seeded rng and adds no virtual-time work, so a
    /// traced run completes the exact same schedule as an untraced one.
    pub fn with_tracing(mut self, cap: usize) -> Self {
        let tracer = Tracer::shared(cap);
        for b in &mut self.backends {
            b.set_tracer(Rc::clone(&tracer));
        }
        self.tracer = Some(tracer);
        self
    }

    /// Enable the streaming SLO health engine (`--health`, and implied
    /// by `--pressure burn`): windowed burn-rate monitoring, anomaly
    /// detection, and flight-recorder debug bundles over the run.
    /// Observation never perturbs the schedule — the engine reads the
    /// same snapshots the control plane already builds.
    pub fn with_health(mut self, engine: HealthEngine) -> Self {
        self.health = Some(engine);
        self
    }

    /// Enable cross-replica work stealing: up to `bound` steals per
    /// dispatch instant (0 disables).
    pub fn with_stealing(mut self, bound: usize) -> Self {
        self.steal_bound = bound;
        self
    }

    /// Enforce a per-replica minimum interval between steals
    /// (`--steal-cooldown`): a replica that just stole or was stolen
    /// from sits the next `cooldown_s` of dispatch instants out.
    pub fn with_steal_cooldown(mut self, cooldown_s: f64) -> Self {
        self.steal_cooldown_s = cooldown_s;
        self
    }

    /// Enable class-aware admission shedding (`--shed`): batch-priority
    /// arrivals are dropped under queue or projected-slack pressure
    /// BEFORE the hard cap would turn interactive work away.
    pub fn with_shedding(mut self, shedder: Shedder) -> Self {
        self.shedder = Some(shedder);
        self
    }

    /// Enable replica autoscaling (`--autoscale min:max`): the scaler
    /// must cover exactly this cluster's backend pool. Non-Active
    /// replicas are masked out of every routing/stealing snapshot.
    pub fn with_autoscale(mut self, scaler: Autoscaler) -> Self {
        assert_eq!(
            scaler.states.len(),
            self.backends.len(),
            "autoscaler must cover the whole backend pool"
        );
        self.scaler = Some(scaler);
        self
    }

    /// Weigh replica speed in every load-based decision
    /// (`--replica-tiers`): snapshot `load_cost` becomes estimated
    /// drain time via each replica's step-time EWMA.
    pub fn with_speed_weighted_routing(mut self) -> Self {
        self.speed_weighted = true;
        self
    }

    /// Advance replicas in `n` contiguous shard groups between routing
    /// instants (`--shards`; clamped to at least 1). Shard outputs
    /// merge in replica-index order — exactly the serial visit order —
    /// so any shard count completes the same schedule byte-for-byte
    /// (regression-tested against the serial loop).
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    /// Disable the incremental snapshot caches: every refresh rebuilds
    /// every replica row, the pre-flattening cost model. Kept for
    /// `bench-scale --compare` and cache-equivalence tests.
    pub fn with_snapshot_rebuild(mut self) -> Self {
        self.load_cache.set_rebuild(true);
        self.full_cache.set_rebuild(true);
        self
    }

    /// One freshly built telemetry snapshot of every replica at
    /// `now_s`, for external callers that want an owned copy. The event
    /// loop itself never calls this: it serves every decision from the
    /// incremental [`SnapshotCache`]s (see `cached_snapshot!`), which
    /// re-read only replicas whose
    /// [`telemetry_version`](ReplicaBackend::telemetry_version) moved.
    pub fn snapshot(&self, now_s: f64, detail: TelemetryDetail) -> ClusterSnapshot {
        prof_scope!("cluster.snapshot");
        ClusterSnapshot {
            now_s,
            replicas: self
                .backends
                .iter()
                .map(|b| b.telemetry(now_s, detail))
                .collect(),
        }
    }

    /// Total queued + running requests (admission-control signal).
    fn outstanding(&self) -> usize {
        self.backends.iter().map(|b| b.outstanding()).sum()
    }

    /// Bounded work stealing at a dispatch instant: each fully idle
    /// replica pulls the worst-slack queued request from the most
    /// pressured busy replica (the one whose queued slack is most
    /// collapsed; token backlog breaks ties). Requests only move
    /// between queues, so completions are conserved exactly.
    fn steal_pass(
        &mut self,
        now: f64,
        events: &mut Vec<(u64, usize, usize)>,
        min_slack_obs: &mut f64,
    ) {
        prof_scope!("cluster.steal_pass");
        let mut budget = self.steal_bound;
        for thief in 0..self.backends.len() {
            if budget == 0 {
                break;
            }
            let t = &self.backends[thief];
            // the thief must be fully idle AND able to take work — a
            // failed backend would silently drop the stolen request
            if t.next_event_s().is_some() || t.outstanding() > 0 || !t.accepts_work() {
                continue;
            }
            // a non-Active (warming / draining / retired) replica never
            // steals: pulling work onto it would undo the autoscaler
            if self.scaler.as_ref().is_some_and(|sc| !sc.accepting(thief)) {
                continue;
            }
            // steal hysteresis: a replica that just participated in a
            // steal (either side) sits the cooldown out, so work cannot
            // ping-pong between replicas every instant
            if now - self.last_steal_s[thief] < self.steal_cooldown_s {
                continue;
            }
            // refresh per steal: the previous move changed the picture
            // (version-tracked, so only the replicas the last steal
            // touched are actually re-read)
            let snap = cached_snapshot!(self, full_cache, now);
            observe_min_slack(snap, min_slack_obs);
            let victim = snap
                .replicas
                .iter()
                .filter(|v| {
                    v.replica != thief
                        && v.queue_len > 0
                        && now - self.last_steal_s[v.replica] >= self.steal_cooldown_s
                        // only steal from a replica whose queue sits
                        // behind running or in-flight work; a fully idle
                        // victim is about to start that work itself
                        && (v.active > 0
                            || self.backends[v.replica].next_event_s().is_some())
                })
                .min_by(|a, b| {
                    let sa = a.min_slack_s.unwrap_or(f64::INFINITY);
                    let sb = b.min_slack_s.unwrap_or(f64::INFINITY);
                    sa.total_cmp(&sb)
                        .then(b.load_cost.cmp(&a.load_cost))
                        .then(a.replica.cmp(&b.replica))
                })
                .map(|v| v.replica);
            let Some(victim) = victim else { continue };
            if let Some(req) = self.backends[victim].steal_request() {
                events.push((time_key(now), victim, thief));
                record_opt(&self.tracer, now, || EventKind::Steal {
                    id: req.id,
                    victim,
                    thief,
                });
                if let Some(h) = &mut self.health {
                    h.on_steal(victim, thief, now);
                }
                self.backends[thief].admit(req);
                self.last_steal_s[thief] = now;
                self.last_steal_s[victim] = now;
                budget -= 1;
            }
        }
    }

    /// Start work on every idle replica and report the earliest next
    /// phase completion, one fused pass over `shards` contiguous
    /// backend chunks. Chunks share no state and their minima merge in
    /// shard order (= replica-index order), so the result is
    /// byte-identical to the serial visit for any shard count — and the
    /// chunk bodies are ready to fan out across worker threads once the
    /// backends (and their shared `Rc` ladder/tracer) become `Send`.
    /// Today the chunks execute serially, which already exercises the
    /// deterministic merge.
    fn step_shards(&mut self, now: f64) -> Option<u64> {
        prof_scope!("cluster.step_shards");
        let shard_len = self.backends.len().div_ceil(self.shards);
        let mut next: Option<u64> = None;
        for chunk in self.backends.chunks_mut(shard_len) {
            let mut shard_min: Option<u64> = None;
            for b in chunk.iter_mut() {
                b.try_start(now);
                if let Some(t) = b.next_event_s() {
                    let k = time_key(t);
                    if shard_min.map_or(true, |m| k < m) {
                        shard_min = Some(k);
                    }
                }
            }
            // merging minima is order-insensitive, so any shard
            // completion order yields the same next-event instant
            if let Some(k) = shard_min {
                if next.map_or(true, |m| k < m) {
                    next = Some(k);
                }
            }
        }
        next
    }

    /// Complete every phase due at `t_next`, sharded like
    /// [`step_shards`](Self::step_shards). Each chunk appends into its
    /// own reusable buffer in `shard_out`, and the buffers drain into
    /// `completed` in shard order (= replica-index order) — the exact
    /// sequence the serial completion sweep produces.
    fn complete_shards(
        &mut self,
        now: f64,
        t_next: u64,
        shard_out: &mut Vec<Vec<CompletedRequest>>,
        completed: &mut Vec<CompletedRequest>,
    ) {
        let shard_len = self.backends.len().div_ceil(self.shards);
        shard_out.resize_with(self.shards, Vec::new);
        for (chunk, out) in self.backends.chunks_mut(shard_len).zip(shard_out.iter_mut()) {
            for b in chunk.iter_mut() {
                if let Some(t) = b.next_event_s() {
                    if time_key(t) <= t_next {
                        b.complete_phase(now, out);
                    }
                }
            }
        }
        for out in shard_out.iter_mut() {
            completed.append(out);
        }
    }

    /// Replay a trace to completion. Closed-loop traces re-issue
    /// requests on completion until the spec's total is reached.
    pub fn run(&mut self, scenario: &Scenario, trace: &Trace) -> RunResult {
        assert_eq!(
            scenario.slos.len(),
            scenario.profiles.len(),
            "call Scenario::resolve_slos before Cluster::run"
        );
        let mut arrivals: BinaryHeap<Reverse<PendingArrival>> = trace
            .requests
            .iter()
            .map(|r| Reverse(PendingArrival(time_key(r.arrival_s), r.clone())))
            .collect();
        let mut spawn_rng = Pcg32::new(self.rng.next_u32() as u64, 0xc105_ed10);
        let mut spawned = trace.requests.len();
        let mut next_id = trace.requests.iter().map(|r| r.id + 1).max().unwrap_or(0);
        let mut completed: Vec<CompletedRequest> = Vec::new();
        // per-shard completion buffers, reused across instants
        let mut shard_out: Vec<Vec<CompletedRequest>> = Vec::new();
        let mut switch_events: Vec<(u64, usize)> = Vec::new();
        let mut steal_events: Vec<(u64, usize, usize)> = Vec::new();
        let mut scale_events: Vec<(u64, usize, bool)> = Vec::new();
        let mut min_slack_obs = f64::INFINITY;
        let mut now = 0.0f64;

        // seed the live-replica gauge: every initially Active slot
        // announces itself, so a trace reader can reconstruct the live
        // count from ScaleUp/Drain events alone
        if let Some(sc) = &self.scaler {
            for i in 0..self.backends.len() {
                if sc.accepting(i) {
                    record_opt(&self.tracer, 0.0, || EventKind::ScaleUp { replica: i });
                }
            }
        }

        let burn_pressure = self
            .controller
            .as_ref()
            .is_some_and(|c| c.policy.pressure == PressureMode::Burn);

        loop {
            // 0a. health observation: one Full-detail snapshot per
            // instant feeds the sliding windows and anomaly detectors.
            // The engine dedupes repeat instants, the snapshot read is
            // `&self`-pure, and min-slack folding is deliberately NOT
            // done here — a health-on run must keep every other output
            // byte-identical to the health-off run.
            if self.health.is_some() {
                let snap = cached_snapshot!(self, full_cache, now);
                self.health.as_mut().unwrap().observe(snap);
            }
            // 0b. elasticity: the autoscaler consumes the same snapshot
            // surface as every other control-plane decision and moves
            // replica slots through their lifecycle
            if self.scaler.is_some() {
                let snap = cached_snapshot!(self, full_cache, now);
                observe_min_slack(snap, &mut min_slack_obs);
                let acts = self.scaler.as_mut().unwrap().step(snap);
                for r in acts.activated {
                    scale_events.push((time_key(now), r, true));
                    record_opt(&self.tracer, now, || EventKind::ScaleUp { replica: r });
                }
                for r in acts.drained {
                    scale_events.push((time_key(now), r, false));
                    record_opt(&self.tracer, now, || EventKind::Drain { replica: r });
                }
            }
            // 1. control plane: one snapshot feeds the rung controller
            // and the stealing pass, then start work on every idle
            // replica
            if self.controller.is_some() {
                // queue pressure reads only O(1) fields; the EDF-slack
                // signal is the one that pays for the queue scans
                let detail = match self.controller.as_ref().unwrap().policy.pressure {
                    PressureMode::Queue => TelemetryDetail::Load,
                    PressureMode::Slack | PressureMode::SlackEwma | PressureMode::Burn => {
                        TelemetryDetail::Full
                    }
                };
                if burn_pressure {
                    let f = self.health.as_ref().and_then(|h| h.burn_frac());
                    self.controller.as_mut().unwrap().set_burn_frac(f);
                }
                let snap = match detail {
                    TelemetryDetail::Load => cached_snapshot!(self, load_cache, now),
                    TelemetryDetail::Full => cached_snapshot!(self, full_cache, now),
                };
                observe_min_slack(snap, &mut min_slack_obs);
                let ladder = Rc::clone(&self.ladder);
                let targets = self.controller.as_mut().unwrap().decide(snap, &ladder);
                for (i, b) in self.backends.iter_mut().enumerate() {
                    if targets[i] != snap.replicas[i].rung {
                        b.set_rung(targets[i], now, self.reconfig_penalty_s);
                        switch_events.push((time_key(now), i));
                        record_opt(&self.tracer, now, || EventKind::RungSwitch {
                            replica: i,
                            rung: targets[i],
                        });
                        if let Some(h) = &mut self.health {
                            h.on_rung_switch(i, targets[i], now);
                        }
                    }
                }
            }
            if self.steal_bound > 0 {
                self.steal_pass(now, &mut steal_events, &mut min_slack_obs);
            }
            // 2. next event: earliest arrival or phase completion. The
            // sharded pass fuses try_start with the per-shard
            // next-completion scan.
            let next_completion = self.step_shards(now);
            let next_arrival = arrivals.peek().map(|Reverse(PendingArrival(t, _))| *t);
            let t_next = match (next_arrival, next_completion) {
                (None, None) => break, // drained
                (Some(a), None) => a,
                (None, Some(c)) => c,
                (Some(a), Some(c)) => a.min(c),
            };
            now = t_next as f64 / 1e9;

            // 3a. deliver every arrival due now (arrivals before
            // completions at equal timestamps: a request can catch the
            // slot freed in the same instant on the NEXT iteration)
            let mut delivered = false;
            while let Some(Reverse(PendingArrival(t, _))) = arrivals.peek() {
                if *t > t_next {
                    break;
                }
                let Reverse(PendingArrival(_, req)) = arrivals.pop().unwrap();
                delivered = true;
                record_opt(&self.tracer, now, || EventKind::Arrival {
                    id: req.id,
                    class: req.class,
                });
                let outstanding = self.outstanding();
                let prio = scenario.profiles[req.class].priority;
                // class-aware shedding runs BEFORE the hard cap: batch
                // priorities are dropped under queue/slack pressure so
                // the cap's headroom stays available for interactive
                // work. A shed counts as a rejection (conservation) —
                // the paired Shed event carries the attribution.
                let shed_reason = if self.shedder.is_some() {
                    if burn_pressure {
                        let f = self.health.as_ref().and_then(|h| h.burn_frac());
                        self.shedder.as_mut().unwrap().set_burn_frac(f);
                    }
                    let snap = cached_snapshot!(self, full_cache, now);
                    observe_min_slack(snap, &mut min_slack_obs);
                    self.shedder
                        .as_mut()
                        .unwrap()
                        .decide(snap, outstanding, req.class, prio)
                } else {
                    None
                };
                if shed_reason.is_some() {
                    self.admission.rejected_by_class[req.class] += 1;
                }
                if let Some(reason) = shed_reason {
                    record_opt(&self.tracer, now, || EventKind::Shed {
                        id: req.id,
                        class: req.class,
                        reason,
                    });
                    // the paired Reject hook below charges the burn
                    // denominator; the shed hook only attributes it
                    if let Some(h) = &mut self.health {
                        h.on_shed(req.class, reason, now);
                    }
                }
                if shed_reason.is_some() || !self.admission.try_admit(outstanding, req.class) {
                    record_opt(&self.tracer, now, || EventKind::Reject {
                        id: req.id,
                        class: req.class,
                    });
                    if let Some(h) = &mut self.health {
                        h.on_reject(req.class, now);
                    }
                    // Closed loop: a rejected client is not destroyed —
                    // it backs off one think time and retries, keeping
                    // the scenario's concurrency contract. (Each retry
                    // that bounces is counted as a rejection.)
                    if let Some(spec) = &trace.closed_loop {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let mut retry = req;
                        retry.arrival_s = t;
                        arrivals.push(Reverse(PendingArrival(time_key(t), retry)));
                    }
                    continue;
                }
                let slo = scenario.slos[req.class];
                let qr = QueuedRequest::new(&req, prio, slo.ttft_s);
                // a fresh LOAD-level view per arrival: earlier
                // admissions in this round are part of the next
                // decision's input. Their rows are version-dirty, so
                // the incremental refresh re-reads exactly those and
                // the per-arrival path allocates nothing.
                let snap = cached_snapshot!(self, load_cache, now);
                let idx = {
                    prof_scope!("cluster.route");
                    self.router.route(&qr, snap, &mut self.rng)
                };
                record_opt(&self.tracer, now, || EventKind::Route {
                    id: qr.id,
                    chosen: idx,
                    scores: snap.replicas.iter().map(|t| t.load_cost as f64).collect(),
                });
                self.backends[idx].admit(qr);
            }
            if delivered {
                continue;
            }

            // 3b. complete every phase due now (sharded; per-shard
            // buffers merge in replica-index order)
            let before = completed.len();
            self.complete_shards(now, t_next, &mut shard_out, &mut completed);
            if let Some(h) = &mut self.health {
                for c in &completed[before..] {
                    h.on_completion(c, scenario.slos[c.class], now);
                }
            }
            // closed loop: each completion frees a client, which thinks
            // and re-issues
            if let Some(spec) = &trace.closed_loop {
                for _ in before..completed.len() {
                    if spawned < spec.total {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let req = scenario.make_request(next_id, t, &mut spawn_rng);
                        arrivals.push(Reverse(PendingArrival(time_key(t), req)));
                        next_id += 1;
                        spawned += 1;
                    }
                }
            }
        }

        let makespan_s = completed
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max)
            .max(now);
        if let Some(sc) = &mut self.scaler {
            // close the replica-seconds ledger at the run's end
            sc.account(makespan_s);
        }
        let stats: Vec<BackendStats> = self.backends.iter().map(|b| b.stats()).collect();
        let mut rung_time_s = vec![0.0; self.ladder.n_rungs()];
        for s in &stats {
            for (i, t) in s.rung_time_s.iter().enumerate() {
                rung_time_s[i.min(rung_time_s.len() - 1)] += *t;
            }
        }
        // extended control-plane features opt the report into the new
        // steal/slack fields; the default feature set keeps the PR 2
        // report shape byte-for-byte
        let extended = self.steal_bound > 0
            || self.policy_kind == PolicyKind::ClassAware
            || self.shedder.is_some()
            || self.scaler.is_some()
            || self.speed_weighted
            || self
                .controller
                .as_ref()
                .is_some_and(|c| c.policy.pressure != PressureMode::Queue);
        RunResult {
            rejected_by_class: self.admission.rejected_by_class.clone(),
            makespan_s,
            replica_busy_s: stats.iter().map(|s| s.busy_s).collect(),
            rung_switches: stats.iter().map(|s| s.rung_switches).sum(),
            rung_time_s,
            prefill_calls: stats.iter().map(|s| s.prefill_calls).sum(),
            decode_steps: stats.iter().map(|s| s.decode_steps).sum(),
            rung_switch_events: switch_events,
            steals: extended.then_some(steal_events.len() as u64),
            min_slack_s: (extended && min_slack_obs.is_finite()).then_some(min_slack_obs),
            steal_events,
            shed_by_class: self.shedder.as_ref().map(|s| s.shed_by_class.clone()),
            replica_seconds: self.scaler.as_ref().map(|s| s.replica_seconds),
            scale_events: self.scaler.is_some().then_some(scale_events),
            step_time_per_replica: stats.iter().map(|s| s.step_times.clone()).collect(),
            step_samples_per_replica: stats.iter().map(|s| s.step_samples.clone()).collect(),
            residency_per_replica: stats.iter().map(|s| s.residency.clone()).collect(),
            trace: self.tracer.as_ref().map(|t| t.borrow_mut().finish()),
            health: self.health.take().map(|h| h.finish(makespan_s)),
            completed,
        }
    }
}

/// Fold a snapshot's worst queued slack into the run-level minimum.
fn observe_min_slack(snap: &ClusterSnapshot, obs: &mut f64) {
    let s = snap.min_slack_s();
    if s < *obs {
        *obs = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::server::ScenarioKind;
    use crate::moe::allocation::Allocation;
    use crate::server::replica::ServiceModel;
    use crate::server::telemetry::ReplicaTelemetry;

    fn fixed_ladder(step_s: f64, slots: usize) -> QualityLadder {
        QualityLadder::fixed(
            "base",
            Allocation::uniform(4, 2),
            ServiceModel::synthetic("base", 1e-5, step_s, slots),
        )
    }

    fn scenario() -> Scenario {
        let mut s = Scenario::from_kind(ScenarioKind::Poisson, 10.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        s
    }

    fn cluster(policy: PolicyKind, n: usize) -> Cluster<'static> {
        Cluster::new(n, 4, policy, fixed_ladder(0.01, 4), None, 10_000, 4, 0.0, 0)
    }

    #[test]
    fn drains_a_trace_completely() {
        let s = scenario();
        let trace = s.generate(60, 1);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 60);
        assert_eq!(res.rejected_by_class.iter().sum::<u64>(), 0);
        assert!(res.makespan_s > 0.0);
        // every request's timeline is causally ordered
        for r in &res.completed {
            assert!(r.ttft_s > 0.0 && r.e2e_s >= r.ttft_s);
            assert!(r.finish_s >= r.arrival_s);
        }
        // default feature set: the extended report fields stay dark
        assert!(res.steals.is_none() && res.min_slack_s.is_none());
        assert!(res.shed_by_class.is_none() && res.replica_seconds.is_none());
        assert!(res.scale_events.is_none());
        assert!(res.trace.is_none());
        assert!(res.health.is_none());
        assert!(res.step_time_per_replica.iter().all(|s| s.is_none()));
        assert!(res.residency_per_replica.iter().all(|r| r.is_none()));
    }

    #[test]
    fn all_policies_complete_and_are_deterministic() {
        let s = scenario();
        let trace = s.generate(80, 3);
        for policy in [
            PolicyKind::RoundRobin,
            PolicyKind::Jsq,
            PolicyKind::PowerOfTwo,
            PolicyKind::ClassAware,
        ] {
            let a = cluster(policy, 3).run(&s, &trace);
            let b = cluster(policy, 3).run(&s, &trace);
            assert_eq!(a.completed.len(), 80, "{policy:?}");
            assert_eq!(a.completed, b.completed, "{policy:?} not deterministic");
            assert_eq!(a.makespan_s, b.makespan_s);
        }
    }

    #[test]
    fn sharded_stepping_is_byte_identical_to_serial() {
        // shard-order merge == replica-index order: any shard count must
        // reproduce the serial schedule exactly, across scenario shapes
        // and seeds, including the traced event order
        for kind in [
            ScenarioKind::Poisson,
            ScenarioKind::Bursty,
            ScenarioKind::Diurnal,
        ] {
            let mut s = Scenario::from_kind(kind, 10.0);
            s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
            for seed in [1u64, 7, 42] {
                let trace = s.generate(120, seed);
                let serial = cluster(PolicyKind::PowerOfTwo, 5)
                    .with_tracing(1 << 16)
                    .run(&s, &trace);
                for shards in [2usize, 3, 5, 9] {
                    let sharded = cluster(PolicyKind::PowerOfTwo, 5)
                        .with_shards(shards)
                        .with_tracing(1 << 16)
                        .run(&s, &trace);
                    let tag = format!("{kind:?} seed {seed} shards {shards}");
                    assert_eq!(serial.completed, sharded.completed, "{tag}");
                    assert_eq!(serial.rung_switch_events, sharded.rung_switch_events, "{tag}");
                    assert_eq!(serial.steal_events, sharded.steal_events, "{tag}");
                    assert_eq!(serial.makespan_s, sharded.makespan_s, "{tag}");
                    assert_eq!(serial.trace, sharded.trace, "{tag}: traced event order moved");
                }
            }
        }
    }

    #[test]
    fn incremental_snapshots_match_rebuild_under_full_control_plane() {
        // the incremental caches must be invisible even when every
        // snapshot consumer is live: slack-pressure controller, steals,
        // class-aware shedding, and the autoscaler's masked Full views
        use crate::config::server::{PressureMode, ServerConfig};
        use crate::ctrl::{AutoscalePolicy, Autoscaler, ShedPolicy, Shedder};
        let mut cfg = ServerConfig::default();
        cfg.queue_cap = 16;
        cfg.pressure = PressureMode::Slack;
        let mk = |rebuild: bool, shards: usize| {
            let mut c = Cluster::new(
                4,
                2,
                PolicyKind::PowerOfTwo,
                fixed_ladder(0.05, 2),
                Some(LadderPolicy::from_config(&cfg)),
                16,
                4,
                0.0,
                9,
            )
            .with_stealing(1)
            .with_steal_cooldown(0.01)
            .with_shards(shards)
            .with_shedding(Shedder::new(ShedPolicy::from_config(&cfg), 4))
            .with_autoscale(Autoscaler::new(
                AutoscalePolicy::for_cluster(2, 4, 2, 0.05, 0.1, 0.25),
                4,
                3,
            ));
            if rebuild {
                c = c.with_snapshot_rebuild();
            }
            c
        };
        let s = scenario();
        let trace = s.generate(150, 11);
        let base = mk(false, 1).run(&s, &trace);
        // the pressure must actually exercise the extended plane
        assert!(base.steals.is_some());
        assert!(base.shed_by_class.is_some());
        assert!(base.scale_events.is_some());
        for (rebuild, shards) in [(true, 1), (true, 3), (false, 4)] {
            let other = mk(rebuild, shards).run(&s, &trace);
            let tag = format!("rebuild={rebuild} shards={shards}");
            assert_eq!(base.completed, other.completed, "{tag}");
            assert_eq!(base.rejected_by_class, other.rejected_by_class, "{tag}");
            assert_eq!(base.steal_events, other.steal_events, "{tag}");
            assert_eq!(base.scale_events, other.scale_events, "{tag}");
            assert_eq!(base.shed_by_class, other.shed_by_class, "{tag}");
            assert_eq!(base.rung_switch_events, other.rung_switch_events, "{tag}");
            assert_eq!(base.min_slack_s, other.min_slack_s, "{tag}");
            assert_eq!(base.makespan_s, other.makespan_s, "{tag}");
        }
    }

    #[test]
    fn tracing_preserves_schedule_and_conserves_spans() {
        let s = scenario();
        let trace = s.generate(60, 1);
        let base = cluster(PolicyKind::Jsq, 2).run(&s, &trace);
        let traced = cluster(PolicyKind::Jsq, 2).with_tracing(1 << 16).run(&s, &trace);
        assert_eq!(base.completed, traced.completed, "tracing perturbed the run");
        assert_eq!(base.makespan_s, traced.makespan_s);
        let log = traced.trace.expect("traced run must carry its log");
        log.check_conservation().unwrap();
        // trace-derived latencies are bit-equal to the reported ones:
        // the events carry the same `now` values the replica computed
        // ttft/e2e from
        for c in &traced.completed {
            assert_eq!(log.first_token(c.id).unwrap() - c.arrival_s, c.ttft_s);
            assert_eq!(log.finish_time(c.id).unwrap() - c.arrival_s, c.e2e_s);
        }
        // every completion sits in some prefill cohort
        for c in &traced.completed {
            assert!(log.prefill_start(c.id).is_some());
        }
    }

    #[test]
    fn health_observation_never_perturbs_the_schedule() {
        use crate::obs::health::HealthConfig;
        use crate::util::json::Json;
        let s = scenario();
        let trace = s.generate(60, 1);
        let base = cluster(PolicyKind::Jsq, 2).run(&s, &trace);
        let engine = HealthEngine::new(HealthConfig::default(), s.profiles.len(), Json::obj(vec![]));
        let mut c = cluster(PolicyKind::Jsq, 2).with_health(engine);
        let res = c.run(&s, &trace);
        assert_eq!(base.completed, res.completed, "health observation perturbed the run");
        assert_eq!(base.makespan_s, res.makespan_s);
        let h = res.health.expect("health-on run must carry its outcome");
        assert_eq!(h.report.classes.iter().map(|c| c.n).sum::<u64>(), 60);
        assert!(h.report.makespan_s > 0.0);
    }

    #[test]
    fn admission_cap_rejects_overflow() {
        let s = scenario();
        let trace = s.generate(50, 2);
        let mut c = Cluster::new(
            1,
            2,
            PolicyKind::RoundRobin,
            fixed_ladder(10.0, 2), // glacial decode: queue must pile up
            None,
            4,
            4,
            0.0,
            0,
        );
        let res = c.run(&s, &trace);
        let rejected: u64 = res.rejected_by_class.iter().sum();
        assert!(rejected > 0, "cap never triggered");
        assert_eq!(res.completed.len() + rejected as usize, 50);
    }

    #[test]
    fn closed_loop_reissues_to_total() {
        let mut s = Scenario::from_kind(ScenarioKind::ClosedLoop, 5.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        let trace = s.generate(40, 4);
        assert!(trace.requests.len() < 40);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 40);
    }

    #[test]
    fn utilization_accounting_is_consistent() {
        let s = scenario();
        let trace = s.generate(30, 5);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        for &busy in &res.replica_busy_s {
            assert!(busy > 0.0 && busy <= res.makespan_s + 1e-9);
        }
        let rung_total: f64 = res.rung_time_s.iter().sum();
        let busy_total: f64 = res.replica_busy_s.iter().sum();
        assert!((rung_total - busy_total).abs() < 1e-9);
    }

    /// Snapshot fixture: replicas with given (rung, load_cost).
    fn snap_of(loads: &[(usize, u64)]) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s: 0.0,
            replicas: loads
                .iter()
                .enumerate()
                .map(|(i, &(rung, load))| {
                    let mut t = ReplicaTelemetry::idle(i);
                    t.rung = rung;
                    t.point = crate::server::ladder::PointId { k: rung, s: 0 };
                    t.load_cost = load;
                    t
                })
                .collect(),
        }
    }

    fn probe(priority: u8) -> QueuedRequest {
        QueuedRequest {
            id: 0,
            class: priority as usize,
            priority,
            arrival_s: 0.0,
            deadline_ns: 1_000_000_000,
            prompt_len: 64,
            new_tokens: 16,
        }
    }

    #[test]
    fn routing_policies_are_pluggable_objects() {
        let mut rng = Pcg32::seeded(0);
        let req = probe(0);
        let mut rr = PolicyKind::RoundRobin.build();
        assert_eq!(rr.label(), "rr");
        let flat = snap_of(&[(0, 0), (0, 0), (0, 0)]);
        assert_eq!(rr.route(&req, &flat, &mut rng), 0);
        assert_eq!(rr.route(&req, &flat, &mut rng), 1);
        assert_eq!(rr.route(&req, &flat, &mut rng), 2);
        assert_eq!(rr.route(&req, &flat, &mut rng), 0);

        let mut jsq = PolicyKind::Jsq.build();
        let skew = snap_of(&[(0, 5), (0, 1), (0, 9)]);
        assert_eq!(jsq.route(&req, &skew, &mut rng), 1);
        // ties break toward the lowest index
        let tied = snap_of(&[(0, 7), (0, 7), (0, 7)]);
        assert_eq!(jsq.route(&req, &tied, &mut rng), 0);

        let mut p2c = PolicyKind::PowerOfTwo.build();
        // single replica short-circuits without touching the rng
        assert_eq!(p2c.route(&req, &snap_of(&[(0, 0)]), &mut rng), 0);
        let four = snap_of(&[(0, 5), (0, 1), (0, 9), (0, 2)]);
        for _ in 0..32 {
            let i = p2c.route(&req, &four, &mut rng);
            assert!(i < 4);
        }
    }

    #[test]
    fn classaware_splits_traffic_by_rung_and_class() {
        let mut rng = Pcg32::seeded(0);
        let mut ca = PolicyKind::ClassAware.build();
        assert_eq!(ca.label(), "classaware");
        // replica 1 degraded to rung 2: batch goes there, interactive
        // keeps the full-quality replica
        let snap = snap_of(&[(0, 50), (2, 50), (0, 80)]);
        assert_eq!(ca.route(&probe(0), &snap, &mut rng), 0);
        assert_eq!(ca.route(&probe(2), &snap, &mut rng), 1);
        // within the same rung band, load breaks the tie (replica 0
        // lighter than replica 2)
        let snap = snap_of(&[(1, 50), (1, 20), (1, 80)]);
        assert_eq!(ca.route(&probe(0), &snap, &mut rng), 1);
        // uniform rungs: identical to JSQ
        let mut jsq = PolicyKind::Jsq.build();
        let flat = snap_of(&[(0, 5), (0, 1), (0, 9)]);
        assert_eq!(
            ca.route(&probe(0), &flat, &mut rng),
            jsq.route(&probe(0), &flat, &mut rng)
        );
    }

    #[test]
    fn routing_avoids_non_accepting_replicas() {
        let mut rng = Pcg32::seeded(0);
        let req = probe(2); // batch: classaware would prefer the deepest rung
        let mut snap = snap_of(&[(0, 50), (2, 5), (0, 9)]);
        snap.replicas[1].accepting = false; // the preferred one has failed
        let mut ca = PolicyKind::ClassAware.build();
        assert_ne!(ca.route(&req, &snap, &mut rng), 1);
        let mut jsq = PolicyKind::Jsq.build();
        assert_ne!(jsq.route(&req, &snap, &mut rng), 1);
        let mut rr = PolicyKind::RoundRobin.build();
        for _ in 0..8 {
            assert_ne!(rr.route(&req, &snap, &mut rng), 1);
        }
        let mut p2c = PolicyKind::PowerOfTwo.build();
        for _ in 0..32 {
            assert_ne!(p2c.route(&req, &snap, &mut rng), 1);
        }
        // nobody accepting: fall back to the full set, stay total
        for t in &mut snap.replicas {
            t.accepting = false;
        }
        assert!(jsq.route(&req, &snap, &mut rng) < 3);
    }

    #[test]
    fn work_stealing_rebalances_and_conserves() {
        // replica 0 is force-fed a pile of slow requests while replica
        // 1 idles: with stealing on, replica 1 must pick work up, and
        // nothing may be lost or duplicated.
        let mut s = scenario();
        // single class so routing is the only imbalance source
        s.profiles.truncate(1);
        s.slos.truncate(1);
        let trace = Trace {
            scenario: "steal",
            requests: (0..8u64)
                .map(|id| TraceRequest {
                    id,
                    class: 0,
                    arrival_s: 0.0,
                    prompt_len: 64,
                    new_tokens: 200,
                })
                .collect(),
            closed_loop: None,
        };
        let mk = |steal: usize| {
            let mut c = Cluster::new(
                2,
                1,
                PolicyKind::RoundRobin,
                fixed_ladder(0.01, 1),
                None,
                10_000,
                1,
                0.0,
                0,
            )
            .with_stealing(steal);
            // pre-load replica 0 with the whole pile (bypassing the
            // router, as if a burst had landed before rebalancing)
            for r in &trace.requests {
                c.backends[0].admit(QueuedRequest::new(r, 0, 1.0));
            }
            c
        };
        let empty = Trace {
            scenario: "steal",
            requests: vec![],
            closed_loop: None,
        };
        let base = mk(0).run(&s, &empty);
        let stolen = mk(1).run(&s, &empty);
        assert_eq!(base.completed.len(), 8);
        assert_eq!(stolen.completed.len(), 8, "stealing lost requests");
        let mut ids: Vec<u64> = stolen.completed.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8, "stealing duplicated a request");
        assert!(stolen.steals.unwrap() > 0, "no steal ever happened");
        assert_eq!(
            stolen.steals.unwrap() as usize,
            stolen.steal_events.len()
        );
        // without stealing replica 1 never works; with stealing it does
        assert_eq!(base.replica_busy_s[1], 0.0);
        assert!(stolen.replica_busy_s[1] > 0.0);
        assert!(stolen.makespan_s < base.makespan_s);
    }

    #[test]
    fn steal_cooldown_bounds_per_replica_steal_rate() {
        // same force-fed pile as work_stealing_rebalances_and_conserves,
        // but the thief must sit out `cooldown` between steals
        let mut s = scenario();
        s.profiles.truncate(1);
        s.slos.truncate(1);
        let requests: Vec<TraceRequest> = (0..8u64)
            .map(|id| TraceRequest {
                id,
                class: 0,
                arrival_s: 0.0,
                prompt_len: 64,
                new_tokens: 200,
            })
            .collect();
        let mk = |cooldown: f64| {
            let mut c = Cluster::new(
                2,
                1,
                PolicyKind::RoundRobin,
                fixed_ladder(0.01, 1),
                None,
                10_000,
                1,
                0.0,
                0,
            )
            .with_stealing(1)
            .with_steal_cooldown(cooldown);
            for r in &requests {
                c.backends[0].admit(QueuedRequest::new(r, 0, 1.0));
            }
            c
        };
        let empty = Trace {
            scenario: "steal",
            requests: vec![],
            closed_loop: None,
        };
        let eager = mk(0.0).run(&s, &empty);
        let cooled = mk(1e9).run(&s, &empty);
        // hysteresis: after the first steal the thief is in cooldown for
        // the rest of the run
        assert_eq!(cooled.steals, Some(1));
        assert!(eager.steals.unwrap() > 1);
        // nothing lost or duplicated either way
        for res in [&eager, &cooled] {
            let mut ids: Vec<u64> = res.completed.iter().map(|c| c.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 8);
        }
        // fewer steals -> the thief helps less -> no better makespan
        assert!(cooled.makespan_s >= eager.makespan_s);
    }
}
