//! The cluster front door: N replicas, pluggable routing, virtual-time
//! discrete-event loop.
//!
//! Arrivals pass admission control, get a TTFT deadline from their class
//! SLO, and are routed to a replica queue (round-robin /
//! join-shortest-queue / power-of-two-choices). Each replica then runs
//! the continuous-batching discipline of [`super::replica`]; the
//! adaptive quality ladder (when enabled) retunes each replica's
//! active-expert budget between phases. The loop is fully deterministic:
//! ties in virtual time break by (arrival before completion, replica
//! index, request id).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::server::PolicyKind;
use crate::util::Pcg32;

use super::ladder::{LadderPolicy, QualityLadder};
use super::replica::{CompletedRequest, Replica};
use super::scheduler::{AdmissionControl, QueuedRequest};
use super::workload::{Scenario, Trace, TraceRequest};

/// Outcome of one cluster run over a trace.
#[derive(Debug)]
pub struct RunResult {
    pub completed: Vec<CompletedRequest>,
    pub rejected_by_class: Vec<u64>,
    /// Virtual time at which the last request finished.
    pub makespan_s: f64,
    pub replica_busy_s: Vec<f64>,
    pub rung_switches: u64,
    /// Busy time per rung, summed over replicas.
    pub rung_time_s: Vec<f64>,
    pub prefill_calls: u64,
    pub decode_steps: u64,
}

/// Pending arrival, ordered by (time ns, id) for a deterministic heap.
#[derive(Debug)]
struct PendingArrival(u64, TraceRequest);

impl PartialEq for PendingArrival {
    fn eq(&self, other: &Self) -> bool {
        (self.0, self.1.id) == (other.0, other.1.id)
    }
}
impl Eq for PendingArrival {}
impl PartialOrd for PendingArrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingArrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.0, self.1.id).cmp(&(other.0, other.1.id))
    }
}

fn time_key(t: f64) -> u64 {
    (t * 1e9) as u64
}

/// N engine replicas behind one routing policy.
pub struct Cluster {
    pub replicas: Vec<Replica>,
    pub policy: PolicyKind,
    pub ladder: QualityLadder,
    /// None = fixed rung 0 (static allocation); Some = adaptive ladder.
    pub ladder_policy: Option<LadderPolicy>,
    pub admission: AdmissionControl,
    pub reconfig_penalty_s: f64,
    rr_next: usize,
    rng: Pcg32,
}

impl Cluster {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_replicas: usize,
        slots_per_replica: usize,
        policy: PolicyKind,
        ladder: QualityLadder,
        ladder_policy: Option<LadderPolicy>,
        queue_cap: usize,
        n_classes: usize,
        reconfig_penalty_s: f64,
        seed: u64,
    ) -> Self {
        assert!(queue_cap > 0, "queue_cap must be >= 1");
        let n_rungs = ladder.n_rungs();
        Cluster {
            replicas: (0..n_replicas)
                .map(|i| Replica::new(i, slots_per_replica, n_rungs))
                .collect(),
            policy,
            ladder,
            ladder_policy,
            admission: AdmissionControl::new(queue_cap, n_classes),
            reconfig_penalty_s,
            rr_next: 0,
            rng: Pcg32::new(seed, 0x0707_2026),
        }
    }

    /// Pick the replica for a new request under the configured policy.
    fn route(&mut self) -> usize {
        match self.policy {
            PolicyKind::RoundRobin => {
                let i = self.rr_next % self.replicas.len();
                self.rr_next += 1;
                i
            }
            PolicyKind::Jsq => argmin_load(&self.replicas, self.replicas.iter().map(|r| r.id)),
            PolicyKind::PowerOfTwo => {
                let n = self.replicas.len();
                if n == 1 {
                    return 0;
                }
                let a = self.rng.gen_usize(n);
                let mut b = self.rng.gen_usize(n - 1);
                if b >= a {
                    b += 1;
                }
                argmin_load(&self.replicas, [a, b].into_iter())
            }
        }
    }

    /// Total queued + running requests (admission-control signal).
    fn outstanding(&self) -> usize {
        self.replicas.iter().map(|r| r.outstanding()).sum()
    }

    /// Replay a trace to completion. Closed-loop traces re-issue
    /// requests on completion until the spec's total is reached.
    pub fn run(&mut self, scenario: &Scenario, trace: &Trace) -> RunResult {
        assert_eq!(
            scenario.slos.len(),
            scenario.profiles.len(),
            "call Scenario::resolve_slos before Cluster::run"
        );
        let mut arrivals: BinaryHeap<Reverse<PendingArrival>> = trace
            .requests
            .iter()
            .map(|r| Reverse(PendingArrival(time_key(r.arrival_s), r.clone())))
            .collect();
        let mut spawn_rng = Pcg32::new(self.rng.next_u32() as u64, 0xc105_ed10);
        let mut spawned = trace.requests.len();
        let mut next_id = trace.requests.iter().map(|r| r.id + 1).max().unwrap_or(0);
        let mut completed: Vec<CompletedRequest> = Vec::new();
        let mut now = 0.0f64;

        loop {
            // 1. start work on every idle replica (rung decision first)
            let ladder = &self.ladder;
            let policy = self.ladder_policy;
            for r in &mut self.replicas {
                if let Some(p) = &policy {
                    let rung = p.decide(
                        r.rung,
                        ladder.n_rungs(),
                        r.queue.len(),
                        now,
                        r.last_switch_s,
                    );
                    r.set_rung(rung, now, self.reconfig_penalty_s);
                }
                r.try_start(now, ladder.service(r.rung));
            }

            // 2. next event: earliest arrival or phase completion
            let next_arrival = arrivals.peek().map(|Reverse(PendingArrival(t, _))| *t);
            let next_completion = self
                .replicas
                .iter()
                .filter_map(|r| r.next_event_s())
                .map(time_key)
                .min();
            let t_next = match (next_arrival, next_completion) {
                (None, None) => break, // drained
                (Some(a), None) => a,
                (None, Some(c)) => c,
                (Some(a), Some(c)) => a.min(c),
            };
            now = t_next as f64 / 1e9;

            // 3a. deliver every arrival due now (arrivals before
            // completions at equal timestamps: a request can catch the
            // slot freed in the same instant on the NEXT iteration)
            let mut delivered = false;
            while let Some(Reverse(PendingArrival(t, _))) = arrivals.peek() {
                if *t > t_next {
                    break;
                }
                let Reverse(PendingArrival(_, req)) = arrivals.pop().unwrap();
                delivered = true;
                let outstanding = self.outstanding();
                if !self.admission.try_admit(outstanding, req.class) {
                    // Closed loop: a rejected client is not destroyed —
                    // it backs off one think time and retries, keeping
                    // the scenario's concurrency contract. (Each retry
                    // that bounces is counted as a rejection.)
                    if let Some(spec) = &trace.closed_loop {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let mut retry = req;
                        retry.arrival_s = t;
                        arrivals.push(Reverse(PendingArrival(time_key(t), retry)));
                    }
                    continue;
                }
                let slo = scenario.slos[req.class];
                let prio = scenario.profiles[req.class].priority;
                let qr = QueuedRequest::new(&req, prio, slo.ttft_s);
                let idx = self.route();
                self.replicas[idx].queue.push(qr);
            }
            if delivered {
                continue;
            }

            // 3b. complete every phase due now
            let before = completed.len();
            for r in &mut self.replicas {
                if let Some(t) = r.next_event_s() {
                    if time_key(t) <= t_next {
                        r.complete_phase(now, &mut completed);
                    }
                }
            }
            // closed loop: each completion frees a client, which thinks
            // and re-issues
            if let Some(spec) = &trace.closed_loop {
                for _ in before..completed.len() {
                    if spawned < spec.total {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let req = scenario.make_request(next_id, t, &mut spawn_rng);
                        arrivals.push(Reverse(PendingArrival(time_key(t), req)));
                        next_id += 1;
                        spawned += 1;
                    }
                }
            }
        }

        let makespan_s = completed
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max)
            .max(now);
        let mut rung_time_s = vec![0.0; self.ladder.n_rungs()];
        for r in &self.replicas {
            for (i, t) in r.rung_time_s.iter().enumerate() {
                rung_time_s[i.min(rung_time_s.len() - 1)] += t;
            }
        }
        RunResult {
            rejected_by_class: self.admission.rejected_by_class.clone(),
            makespan_s,
            replica_busy_s: self.replicas.iter().map(|r| r.busy_s).collect(),
            rung_switches: self.replicas.iter().map(|r| r.rung_switches).sum(),
            rung_time_s,
            prefill_calls: self.replicas.iter().map(|r| r.prefill_calls).sum(),
            decode_steps: self.replicas.iter().map(|r| r.decode_steps).sum(),
            completed,
        }
    }
}

/// Index of the lightest replica among `candidates` (ties -> lowest id).
fn argmin_load(replicas: &[Replica], candidates: impl Iterator<Item = usize>) -> usize {
    let mut best = None;
    for i in candidates {
        let cost = replicas[i].load_cost();
        match best {
            None => best = Some((cost, i)),
            Some((bc, bi)) if (cost, i) < (bc, bi) => best = Some((cost, i)),
            _ => {}
        }
    }
    best.expect("no routing candidates").1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::server::ScenarioKind;
    use crate::moe::allocation::Allocation;
    use crate::server::replica::ServiceModel;

    fn fixed_ladder(step_s: f64, slots: usize) -> QualityLadder {
        QualityLadder::fixed(
            "base",
            Allocation::uniform(4, 2),
            ServiceModel::synthetic("base", 1e-5, step_s, slots),
        )
    }

    fn scenario() -> Scenario {
        let mut s = Scenario::from_kind(ScenarioKind::Poisson, 10.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        s
    }

    fn cluster(policy: PolicyKind, n: usize) -> Cluster {
        Cluster::new(n, 4, policy, fixed_ladder(0.01, 4), None, 10_000, 4, 0.0, 0)
    }

    #[test]
    fn drains_a_trace_completely() {
        let s = scenario();
        let trace = s.generate(60, 1);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 60);
        assert_eq!(res.rejected_by_class.iter().sum::<u64>(), 0);
        assert!(res.makespan_s > 0.0);
        // every request's timeline is causally ordered
        for r in &res.completed {
            assert!(r.ttft_s > 0.0 && r.e2e_s >= r.ttft_s);
            assert!(r.finish_s >= r.arrival_s);
        }
    }

    #[test]
    fn all_policies_complete_and_are_deterministic() {
        let s = scenario();
        let trace = s.generate(80, 3);
        for policy in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
            let a = cluster(policy, 3).run(&s, &trace);
            let b = cluster(policy, 3).run(&s, &trace);
            assert_eq!(a.completed.len(), 80, "{policy:?}");
            assert_eq!(a.completed, b.completed, "{policy:?} not deterministic");
            assert_eq!(a.makespan_s, b.makespan_s);
        }
    }

    #[test]
    fn admission_cap_rejects_overflow() {
        let s = scenario();
        let trace = s.generate(50, 2);
        let mut c = Cluster::new(
            1,
            2,
            PolicyKind::RoundRobin,
            fixed_ladder(10.0, 2), // glacial decode: queue must pile up
            None,
            4,
            4,
            0.0,
            0,
        );
        let res = c.run(&s, &trace);
        let rejected: u64 = res.rejected_by_class.iter().sum();
        assert!(rejected > 0, "cap never triggered");
        assert_eq!(res.completed.len() + rejected as usize, 50);
    }

    #[test]
    fn closed_loop_reissues_to_total() {
        let mut s = Scenario::from_kind(ScenarioKind::ClosedLoop, 5.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        let trace = s.generate(40, 4);
        assert!(trace.requests.len() < 40);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 40);
    }

    #[test]
    fn utilization_accounting_is_consistent() {
        let s = scenario();
        let trace = s.generate(30, 5);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        for &busy in &res.replica_busy_s {
            assert!(busy > 0.0 && busy <= res.makespan_s + 1e-9);
        }
        let rung_total: f64 = res.rung_time_s.iter().sum();
        let busy_total: f64 = res.replica_busy_s.iter().sum();
        assert!((rung_total - busy_total).abs() < 1e-9);
    }
}
