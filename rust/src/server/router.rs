//! The cluster front door: N replica backends, pluggable routing, one
//! discrete-event loop.
//!
//! Arrivals pass admission control, get a TTFT deadline from their class
//! SLO, and are routed to a replica queue by a [`RoutingPolicy`]
//! (round-robin / join-shortest-queue / power-of-two-choices, pluggable
//! impls instead of hardcoded branches). Replicas are driven through the
//! [`ReplicaBackend`] trait, so the same loop serves the virtual-time
//! [`Replica`](super::replica::Replica) and the engine-backed
//! [`EngineReplica`](super::engine_backend::EngineReplica); the
//! cluster-global [`LadderController`] retunes rung assignments between
//! phases. The loop is fully deterministic for simulated backends: ties
//! in virtual time break by (arrival before completion, replica index,
//! request id).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::rc::Rc;

use crate::config::server::PolicyKind;
use crate::util::Pcg32;

use super::backend::{BackendStats, CompletedRequest, ReplicaBackend};
use super::ladder::{LadderController, LadderPolicy, QualityLadder, ReplicaView};
use super::replica::Replica;
use super::scheduler::{AdmissionControl, QueuedRequest};
use super::workload::{Scenario, Trace, TraceRequest};

/// Outcome of one cluster run over a trace.
#[derive(Debug)]
pub struct RunResult {
    pub completed: Vec<CompletedRequest>,
    pub rejected_by_class: Vec<u64>,
    /// Event-loop time at which the last request finished.
    pub makespan_s: f64,
    pub replica_busy_s: Vec<f64>,
    pub rung_switches: u64,
    /// Busy time per rung, summed over replicas.
    pub rung_time_s: Vec<f64>,
    pub prefill_calls: u64,
    pub decode_steps: u64,
    /// Every applied rung switch as `(time key ns, replica index)` —
    /// the flap-detection signal for the cluster-global controller.
    pub rung_switch_events: Vec<(u64, usize)>,
}

/// Pending arrival, ordered by (time ns, id) for a deterministic heap.
#[derive(Debug)]
struct PendingArrival(u64, TraceRequest);

impl PartialEq for PendingArrival {
    fn eq(&self, other: &Self) -> bool {
        (self.0, self.1.id) == (other.0, other.1.id)
    }
}
impl Eq for PendingArrival {}
impl PartialOrd for PendingArrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingArrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.0, self.1.id).cmp(&(other.0, other.1.id))
    }
}

fn time_key(t: f64) -> u64 {
    (t * 1e9) as u64
}

/// Replica-selection strategy of the front door. Implementations read
/// per-replica load through the `load_cost` callback so they stay
/// agnostic of the backend type.
pub trait RoutingPolicy {
    fn label(&self) -> &'static str;

    /// Pick the replica for a new request. `load_cost(i)` is replica
    /// `i`'s token-weighted backlog; `rng` is the cluster's seeded
    /// stream (used only by randomized policies).
    fn route(
        &mut self,
        n_replicas: usize,
        load_cost: &mut dyn FnMut(usize) -> u64,
        rng: &mut Pcg32,
    ) -> usize;
}

/// Cycle through replicas regardless of load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn label(&self) -> &'static str {
        "rr"
    }

    fn route(
        &mut self,
        n_replicas: usize,
        _load_cost: &mut dyn FnMut(usize) -> u64,
        _rng: &mut Pcg32,
    ) -> usize {
        let i = self.next % n_replicas;
        self.next += 1;
        i
    }
}

/// Join the shortest queue (token-weighted backlog).
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl RoutingPolicy for JoinShortestQueue {
    fn label(&self) -> &'static str {
        "jsq"
    }

    fn route(
        &mut self,
        n_replicas: usize,
        load_cost: &mut dyn FnMut(usize) -> u64,
        _rng: &mut Pcg32,
    ) -> usize {
        argmin_load(0..n_replicas, load_cost)
    }
}

/// Power-of-two-choices: sample two replicas, pick the lighter.
#[derive(Debug, Default)]
pub struct PowerOfTwoChoices;

impl RoutingPolicy for PowerOfTwoChoices {
    fn label(&self) -> &'static str {
        "p2c"
    }

    fn route(
        &mut self,
        n_replicas: usize,
        load_cost: &mut dyn FnMut(usize) -> u64,
        rng: &mut Pcg32,
    ) -> usize {
        if n_replicas == 1 {
            return 0;
        }
        let a = rng.gen_usize(n_replicas);
        let mut b = rng.gen_usize(n_replicas - 1);
        if b >= a {
            b += 1;
        }
        argmin_load([a, b].into_iter(), load_cost)
    }
}

impl PolicyKind {
    /// Instantiate the routing-policy implementation for this kind.
    pub fn build(self) -> Box<dyn RoutingPolicy> {
        match self {
            PolicyKind::RoundRobin => Box::new(RoundRobin::default()),
            PolicyKind::Jsq => Box::new(JoinShortestQueue),
            PolicyKind::PowerOfTwo => Box::new(PowerOfTwoChoices),
        }
    }
}

/// Index of the lightest replica among `candidates` (ties -> lowest id).
fn argmin_load(
    candidates: impl Iterator<Item = usize>,
    load_cost: &mut dyn FnMut(usize) -> u64,
) -> usize {
    let mut best: Option<(u64, usize)> = None;
    for i in candidates {
        let cost = load_cost(i);
        match best {
            None => best = Some((cost, i)),
            Some((bc, bi)) if (cost, i) < (bc, bi) => best = Some((cost, i)),
            _ => {}
        }
    }
    best.expect("no routing candidates").1
}

/// N replica backends behind one routing policy and one (optional)
/// cluster-global ladder controller.
pub struct Cluster<'a> {
    pub backends: Vec<Box<dyn ReplicaBackend + 'a>>,
    pub router: Box<dyn RoutingPolicy>,
    pub ladder: Rc<QualityLadder>,
    /// None = fixed rung 0 (static allocation); Some = adaptive ladder.
    pub controller: Option<LadderController>,
    pub admission: AdmissionControl,
    pub reconfig_penalty_s: f64,
    rng: Pcg32,
}

impl Cluster<'static> {
    /// Simulated cluster: N virtual-time replicas sharing one ladder.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_replicas: usize,
        slots_per_replica: usize,
        policy: PolicyKind,
        ladder: QualityLadder,
        ladder_policy: Option<LadderPolicy>,
        queue_cap: usize,
        n_classes: usize,
        reconfig_penalty_s: f64,
        seed: u64,
    ) -> Cluster<'static> {
        let ladder = Rc::new(ladder);
        let backends: Vec<Box<dyn ReplicaBackend>> = (0..n_replicas)
            .map(|i| {
                Box::new(Replica::new(i, slots_per_replica, Rc::clone(&ladder)))
                    as Box<dyn ReplicaBackend>
            })
            .collect();
        Cluster::from_backends(
            backends,
            policy,
            ladder,
            ladder_policy,
            queue_cap,
            n_classes,
            reconfig_penalty_s,
            seed,
        )
    }
}

impl<'a> Cluster<'a> {
    /// Cluster over caller-built backends (e.g. engine-backed replicas).
    #[allow(clippy::too_many_arguments)]
    pub fn from_backends(
        backends: Vec<Box<dyn ReplicaBackend + 'a>>,
        policy: PolicyKind,
        ladder: Rc<QualityLadder>,
        ladder_policy: Option<LadderPolicy>,
        queue_cap: usize,
        n_classes: usize,
        reconfig_penalty_s: f64,
        seed: u64,
    ) -> Cluster<'a> {
        assert!(queue_cap > 0, "queue_cap must be >= 1");
        assert!(!backends.is_empty(), "cluster needs at least one replica");
        Cluster {
            backends,
            router: policy.build(),
            ladder,
            controller: ladder_policy.map(LadderController::new),
            admission: AdmissionControl::new(queue_cap, n_classes),
            reconfig_penalty_s,
            rng: Pcg32::new(seed, 0x0707_2026),
        }
    }

    /// Pick the replica for a new request under the configured policy.
    fn route(&mut self) -> usize {
        let backends = &self.backends;
        self.router.route(
            backends.len(),
            &mut |i| backends[i].load_cost(),
            &mut self.rng,
        )
    }

    /// Total queued + running requests (admission-control signal).
    fn outstanding(&self) -> usize {
        self.backends.iter().map(|b| b.outstanding()).sum()
    }

    /// Replay a trace to completion. Closed-loop traces re-issue
    /// requests on completion until the spec's total is reached.
    pub fn run(&mut self, scenario: &Scenario, trace: &Trace) -> RunResult {
        assert_eq!(
            scenario.slos.len(),
            scenario.profiles.len(),
            "call Scenario::resolve_slos before Cluster::run"
        );
        let mut arrivals: BinaryHeap<Reverse<PendingArrival>> = trace
            .requests
            .iter()
            .map(|r| Reverse(PendingArrival(time_key(r.arrival_s), r.clone())))
            .collect();
        let mut spawn_rng = Pcg32::new(self.rng.next_u32() as u64, 0xc105_ed10);
        let mut spawned = trace.requests.len();
        let mut next_id = trace.requests.iter().map(|r| r.id + 1).max().unwrap_or(0);
        let mut completed: Vec<CompletedRequest> = Vec::new();
        let mut switch_events: Vec<(u64, usize)> = Vec::new();
        let mut now = 0.0f64;

        loop {
            // 1. rung decisions (one controller for the whole cluster),
            // then start work on every idle replica
            if let Some(ctl) = &mut self.controller {
                let views: Vec<ReplicaView> = self
                    .backends
                    .iter()
                    .map(|b| ReplicaView {
                        rung: b.rung(),
                        queue_len: b.queue_len(),
                        last_switch_s: b.last_switch_s(),
                    })
                    .collect();
                let targets = ctl.decide(&views, self.ladder.n_rungs(), now);
                for (i, b) in self.backends.iter_mut().enumerate() {
                    if targets[i] != b.rung() {
                        b.set_rung(targets[i], now, self.reconfig_penalty_s);
                        switch_events.push((time_key(now), i));
                    }
                }
            }
            for b in &mut self.backends {
                b.try_start(now);
            }

            // 2. next event: earliest arrival or phase completion
            let next_arrival = arrivals.peek().map(|Reverse(PendingArrival(t, _))| *t);
            let next_completion = self
                .backends
                .iter()
                .filter_map(|b| b.next_event_s())
                .map(time_key)
                .min();
            let t_next = match (next_arrival, next_completion) {
                (None, None) => break, // drained
                (Some(a), None) => a,
                (None, Some(c)) => c,
                (Some(a), Some(c)) => a.min(c),
            };
            now = t_next as f64 / 1e9;

            // 3a. deliver every arrival due now (arrivals before
            // completions at equal timestamps: a request can catch the
            // slot freed in the same instant on the NEXT iteration)
            let mut delivered = false;
            while let Some(Reverse(PendingArrival(t, _))) = arrivals.peek() {
                if *t > t_next {
                    break;
                }
                let Reverse(PendingArrival(_, req)) = arrivals.pop().unwrap();
                delivered = true;
                let outstanding = self.outstanding();
                if !self.admission.try_admit(outstanding, req.class) {
                    // Closed loop: a rejected client is not destroyed —
                    // it backs off one think time and retries, keeping
                    // the scenario's concurrency contract. (Each retry
                    // that bounces is counted as a rejection.)
                    if let Some(spec) = &trace.closed_loop {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let mut retry = req;
                        retry.arrival_s = t;
                        arrivals.push(Reverse(PendingArrival(time_key(t), retry)));
                    }
                    continue;
                }
                let slo = scenario.slos[req.class];
                let prio = scenario.profiles[req.class].priority;
                let qr = QueuedRequest::new(&req, prio, slo.ttft_s);
                let idx = self.route();
                self.backends[idx].admit(qr);
            }
            if delivered {
                continue;
            }

            // 3b. complete every phase due now
            let before = completed.len();
            for b in &mut self.backends {
                if let Some(t) = b.next_event_s() {
                    if time_key(t) <= t_next {
                        b.complete_phase(now, &mut completed);
                    }
                }
            }
            // closed loop: each completion frees a client, which thinks
            // and re-issues
            if let Some(spec) = &trace.closed_loop {
                for _ in before..completed.len() {
                    if spawned < spec.total {
                        let t = now + spawn_rng.gen_exp(1.0 / spec.think_s);
                        let req = scenario.make_request(next_id, t, &mut spawn_rng);
                        arrivals.push(Reverse(PendingArrival(time_key(t), req)));
                        next_id += 1;
                        spawned += 1;
                    }
                }
            }
        }

        let makespan_s = completed
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max)
            .max(now);
        let stats: Vec<BackendStats> = self.backends.iter().map(|b| b.stats()).collect();
        let mut rung_time_s = vec![0.0; self.ladder.n_rungs()];
        for s in &stats {
            for (i, t) in s.rung_time_s.iter().enumerate() {
                rung_time_s[i.min(rung_time_s.len() - 1)] += *t;
            }
        }
        RunResult {
            rejected_by_class: self.admission.rejected_by_class.clone(),
            makespan_s,
            replica_busy_s: stats.iter().map(|s| s.busy_s).collect(),
            rung_switches: stats.iter().map(|s| s.rung_switches).sum(),
            rung_time_s,
            prefill_calls: stats.iter().map(|s| s.prefill_calls).sum(),
            decode_steps: stats.iter().map(|s| s.decode_steps).sum(),
            rung_switch_events: switch_events,
            completed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::server::ScenarioKind;
    use crate::moe::allocation::Allocation;
    use crate::server::replica::ServiceModel;

    fn fixed_ladder(step_s: f64, slots: usize) -> QualityLadder {
        QualityLadder::fixed(
            "base",
            Allocation::uniform(4, 2),
            ServiceModel::synthetic("base", 1e-5, step_s, slots),
        )
    }

    fn scenario() -> Scenario {
        let mut s = Scenario::from_kind(ScenarioKind::Poisson, 10.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        s
    }

    fn cluster(policy: PolicyKind, n: usize) -> Cluster<'static> {
        Cluster::new(n, 4, policy, fixed_ladder(0.01, 4), None, 10_000, 4, 0.0, 0)
    }

    #[test]
    fn drains_a_trace_completely() {
        let s = scenario();
        let trace = s.generate(60, 1);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 60);
        assert_eq!(res.rejected_by_class.iter().sum::<u64>(), 0);
        assert!(res.makespan_s > 0.0);
        // every request's timeline is causally ordered
        for r in &res.completed {
            assert!(r.ttft_s > 0.0 && r.e2e_s >= r.ttft_s);
            assert!(r.finish_s >= r.arrival_s);
        }
    }

    #[test]
    fn all_policies_complete_and_are_deterministic() {
        let s = scenario();
        let trace = s.generate(80, 3);
        for policy in [PolicyKind::RoundRobin, PolicyKind::Jsq, PolicyKind::PowerOfTwo] {
            let a = cluster(policy, 3).run(&s, &trace);
            let b = cluster(policy, 3).run(&s, &trace);
            assert_eq!(a.completed.len(), 80, "{policy:?}");
            assert_eq!(a.completed, b.completed, "{policy:?} not deterministic");
            assert_eq!(a.makespan_s, b.makespan_s);
        }
    }

    #[test]
    fn admission_cap_rejects_overflow() {
        let s = scenario();
        let trace = s.generate(50, 2);
        let mut c = Cluster::new(
            1,
            2,
            PolicyKind::RoundRobin,
            fixed_ladder(10.0, 2), // glacial decode: queue must pile up
            None,
            4,
            4,
            0.0,
            0,
        );
        let res = c.run(&s, &trace);
        let rejected: u64 = res.rejected_by_class.iter().sum();
        assert!(rejected > 0, "cap never triggered");
        assert_eq!(res.completed.len() + rejected as usize, 50);
    }

    #[test]
    fn closed_loop_reissues_to_total() {
        let mut s = Scenario::from_kind(ScenarioKind::ClosedLoop, 5.0);
        s.resolve_slos(|tokens| 1e-4 * tokens as f64, 0.02);
        let trace = s.generate(40, 4);
        assert!(trace.requests.len() < 40);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        assert_eq!(res.completed.len(), 40);
    }

    #[test]
    fn utilization_accounting_is_consistent() {
        let s = scenario();
        let trace = s.generate(30, 5);
        let mut c = cluster(PolicyKind::Jsq, 2);
        let res = c.run(&s, &trace);
        for &busy in &res.replica_busy_s {
            assert!(busy > 0.0 && busy <= res.makespan_s + 1e-9);
        }
        let rung_total: f64 = res.rung_time_s.iter().sum();
        let busy_total: f64 = res.replica_busy_s.iter().sum();
        assert!((rung_total - busy_total).abs() < 1e-9);
    }

    #[test]
    fn routing_policies_are_pluggable_objects() {
        let mut rng = Pcg32::seeded(0);
        let mut rr = PolicyKind::RoundRobin.build();
        assert_eq!(rr.label(), "rr");
        let mut flat = |_: usize| 0u64;
        assert_eq!(rr.route(3, &mut flat, &mut rng), 0);
        assert_eq!(rr.route(3, &mut flat, &mut rng), 1);
        assert_eq!(rr.route(3, &mut flat, &mut rng), 2);
        assert_eq!(rr.route(3, &mut flat, &mut rng), 0);

        let mut jsq = PolicyKind::Jsq.build();
        let loads = [5u64, 1, 9];
        assert_eq!(jsq.route(3, &mut |i| loads[i], &mut rng), 1);
        // ties break toward the lowest index
        assert_eq!(jsq.route(3, &mut |_| 7, &mut rng), 0);

        let mut p2c = PolicyKind::PowerOfTwo.build();
        // single replica short-circuits without touching the rng
        assert_eq!(p2c.route(1, &mut flat, &mut rng), 0);
        for _ in 0..32 {
            let i = p2c.route(4, &mut |i| loads.get(i).copied().unwrap_or(2), &mut rng);
            assert!(i < 4);
        }
    }
}
