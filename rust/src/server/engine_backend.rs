//! Engine-backed replica: the real continuous-batching `engine::Engine`
//! behind the cluster front door.
//!
//! An [`EngineReplica`] keeps the cluster-side EDF queue (so class
//! priorities and TTFT deadlines order dispatch exactly as on the
//! simulated backend), feeds the engine one scheduling step at a time,
//! and maps wall-clock onto the event loop: each `Engine::step` is
//! measured with a monotonic clock and becomes one phase of `now +
//! elapsed` in cluster time. Rung reconfiguration swaps the engine's
//! per-layer `k_vec` from the shared [`QualityLadder`] (the LExI
//! mechanism itself — active experts are a runtime argument, not a
//! recompilation).
//!
//! Trace requests carry only shapes, so prompts are synthesized
//! deterministically from the request id over the shared vocab layout
//! (ids ≥ 3, clear of pad/bos/eos); with real artifacts the same path
//! accepts tokenized text via `engine::Tokenizer`.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use crate::engine::{Engine, SamplingParams, StepKind, StepOutcome};
use crate::obs::trace::{record_opt, EventKind, PhaseKind};
use crate::obs::SharedTracer;
use crate::runtime::ModelBackend;
use crate::util::stats::percentile_sorted;
use crate::util::Pcg32;

use super::backend::{BackendStats, CompletedRequest, ReplicaBackend};
use super::ladder::QualityLadder;
use super::scheduler::{EdfQueue, QueuedRequest};
use super::telemetry::{ReplicaTelemetry, StepSample, StepTimeSummary, TelemetryDetail};

/// Cluster-side bookkeeping for a request inside the engine.
struct Inflight {
    trace_id: u64,
    class: usize,
    arrival_s: f64,
    prompt_len: usize,
    new_tokens: usize,
    /// Event-loop time of the first token (set at the phase boundary of
    /// the prefill that produced it).
    first_token_s: Option<f64>,
}

/// One real engine replica driven through [`ReplicaBackend`].
pub struct EngineReplica<'m, M: ModelBackend> {
    id: usize,
    engine: Engine<'m, M>,
    ladder: Rc<QualityLadder>,
    queue: EdfQueue,
    slots: usize,
    vocab: usize,
    rung: usize,
    last_switch_s: f64,
    pending_penalty_s: f64,
    /// Optional shared span tracer (None = record nothing).
    tracer: Option<SharedTracer>,
    /// Trace ids submitted into the engine by the latest
    /// `submit_waiting` — the prefill cohort for the next phase span.
    just_submitted: Vec<u64>,
    /// In-flight phase: (event-loop end time, what the step did).
    phase: Option<(f64, StepOutcome)>,
    /// Engine request id -> cluster request metadata.
    inflight: HashMap<u64, Inflight>,
    /// Set when the engine errored mid-run: the replica drains itself
    /// (remaining work is dropped and shows up as missing completions)
    /// instead of taking the whole benchmark process down.
    failed: bool,
    /// EWMA of recent measured step times (telemetry signal).
    step_ewma_s: f64,
    /// Bumped on every telemetry-visible mutation (admit / submit /
    /// steal / rung switch / step / completion / failure) so the
    /// cluster's [`SnapshotCache`](super::telemetry::SnapshotCache)
    /// re-reads this replica's row only when something changed.
    telemetry_version: u64,
    /// Every measured `Engine::step`, tagged with phase kind, rung,
    /// occupancy regressor, and residency stall — the run report's
    /// step-time histogram AND the sim `ServiceModel` calibration input
    /// (see [`crate::calibrate`]).
    step_samples: Vec<StepSample>,
    // ---- counters ----
    busy_s: f64,
    prefill_calls: u64,
    decode_steps: u64,
    rung_switches: u64,
    rung_time_s: Vec<f64>,
}

impl<'m, M: ModelBackend> EngineReplica<'m, M> {
    /// Wrap an engine already configured with the ladder's rung-0
    /// `k_vec` (see [`QualityLadder::k_vec`]).
    ///
    /// Fails if the engine's internal waiting queue is smaller than its
    /// slot count: `submit_waiting` tops the engine up to `slots`
    /// outstanding requests per step, so an undersized queue would
    /// reject submissions mid-run. Checking here surfaces the
    /// misconfiguration at cluster construction instead.
    pub fn new(
        id: usize,
        engine: Engine<'m, M>,
        ladder: Rc<QualityLadder>,
    ) -> anyhow::Result<Self> {
        let entry = engine.model.entry();
        let slots = entry.batch;
        let vocab = entry.vocab;
        anyhow::ensure!(
            engine.queue_capacity() >= slots,
            "engine queue capacity {} is below its {} slots; \
             size the queue at least at the batch width",
            engine.queue_capacity(),
            slots
        );
        // k is a runtime graph argument, but intra-expert pruning and
        // gate skipping edit weights / the routing kernel — neither is
        // reconfigurable online. Reject 2-D lattices at construction
        // instead of silently serving dense experts at an s > 0 point.
        anyhow::ensure!(
            ladder.s_dim() == 1,
            "engine backend supports k-axis ladders only (--ladder-axes k); \
             the {}-level sparsity axis is sim-only",
            ladder.s_dim() - 1
        );
        let n_rungs = ladder.n_rungs().max(1);
        Ok(EngineReplica {
            id,
            engine,
            ladder,
            queue: EdfQueue::new(),
            slots,
            vocab,
            rung: 0,
            last_switch_s: f64::NEG_INFINITY,
            pending_penalty_s: 0.0,
            tracer: None,
            just_submitted: Vec::new(),
            phase: None,
            inflight: HashMap::new(),
            failed: false,
            step_ewma_s: 0.0,
            telemetry_version: 1,
            step_samples: Vec::new(),
            busy_s: 0.0,
            prefill_calls: 0,
            decode_steps: 0,
            rung_switches: 0,
            rung_time_s: vec![0.0; n_rungs],
        })
    }

    /// Move EDF-ordered requests from the cluster-side queue into the
    /// engine, up to its free slot capacity.
    fn submit_waiting(&mut self) {
        self.just_submitted.clear();
        let occupied = self.engine.n_active() + self.engine.n_waiting();
        let mut free = self.slots.saturating_sub(occupied);
        while free > 0 {
            let Some(req) = self.queue.pop() else { break };
            // queue -> engine moves queue_len / load_cost / active
            self.telemetry_version += 1;
            let prompt = synth_prompt(req.id, req.prompt_len, self.vocab);
            let sampling = SamplingParams {
                temperature: 0.0,
                top_p: 1.0,
                max_new_tokens: req.new_tokens.max(1),
                stop_on_eos: false,
                seed: req.id,
            };
            let engine_id = match self.engine.submit(prompt, sampling) {
                Ok(id) => id,
                Err(e) => {
                    // the constructor guarantees queue capacity >= slots,
                    // so this is unreachable in practice — but degrade
                    // like a step failure rather than panicking the
                    // whole benchmark process
                    eprintln!(
                        "replica {}: engine rejected a submission ({e:#}); \
                         dropping its workload",
                        self.id
                    );
                    self.failed = true;
                    self.telemetry_version += 1;
                    while self.queue.pop().is_some() {}
                    self.inflight.clear();
                    return;
                }
            };
            self.just_submitted.push(req.id);
            self.inflight.insert(
                engine_id,
                Inflight {
                    trace_id: req.id,
                    class: req.class,
                    arrival_s: req.arrival_s,
                    prompt_len: req.prompt_len,
                    new_tokens: req.new_tokens,
                    first_token_s: None,
                },
            );
            free -= 1;
        }
    }
}

impl<'m, M: ModelBackend> ReplicaBackend for EngineReplica<'m, M> {
    fn id(&self) -> usize {
        self.id
    }

    fn admit(&mut self, req: QueuedRequest) {
        if self.failed {
            // dropped; surfaces as a missing completion in the report
            return;
        }
        self.telemetry_version += 1;
        record_opt(&self.tracer, req.arrival_s, || EventKind::QueuePush {
            id: req.id,
            replica: self.id,
            deadline_ns: req.deadline_ns,
        });
        self.queue.push(req);
    }

    fn set_tracer(&mut self, tracer: SharedTracer) {
        self.tracer = Some(tracer);
    }

    fn telemetry(&self, now_s: f64, detail: TelemetryDetail) -> ReplicaTelemetry {
        // load: queued cost + the full decode budget of everything
        // already inside the engine (per-token progress stays
        // engine-internal)
        let load_cost = self.queue.pending_cost()
            + self
                .inflight
                .values()
                .map(|m| m.new_tokens as u64)
                .sum::<u64>();
        let mut t = ReplicaTelemetry {
            replica: self.id,
            accepting: !self.failed,
            rung: self.rung,
            point: self
                .ladder
                .point_id(self.rung)
                .expect("replica rung off the quality lattice"),
            last_switch_s: self.last_switch_s,
            queue_len: self.queue.len(),
            active: self.inflight.len(),
            load_cost,
            class_occupancy: Vec::new(),
            min_slack_s: None,
            min_interactive_slack_frac: None,
            projected_interactive_slack_frac: None,
            step_ewma_s: self.step_ewma_s,
            hbm_pressure: self.engine.residency_pressure(),
        };
        if detail == TelemetryDetail::Full {
            t.fill_scans(&self.queue, self.inflight.values().map(|m| m.class), now_s);
        }
        t
    }

    fn outstanding(&self) -> usize {
        self.queue.len() + self.inflight.len()
    }

    fn accepts_work(&self) -> bool {
        !self.failed
    }

    fn telemetry_version(&self) -> u64 {
        self.telemetry_version
    }

    fn steal_request(&mut self) -> Option<QueuedRequest> {
        if self.failed {
            return None;
        }
        let req = self.queue.pop_min_deadline();
        if req.is_some() {
            self.telemetry_version += 1;
        }
        req
    }

    fn set_rung(&mut self, rung: usize, now: f64, penalty_s: f64) {
        if rung == self.rung {
            return;
        }
        self.telemetry_version += 1;
        let point = self
            .ladder
            .point(rung)
            .expect("controller set an off-lattice rung index");
        // the constructor rejects lattices with an s axis, so every
        // reachable point reconfigures through k_vec alone
        debug_assert!(
            point.intra_frac == 0.0 && point.skip_threshold == 0.0,
            "engine backend cannot reconfigure intra/skip online"
        );
        let k_vec = point.allocation.k.iter().map(|&k| k as i32).collect();
        self.engine
            .set_k_vec(k_vec)
            .expect("ladder allocation layer count must match the engine graph");
        self.rung = rung;
        self.last_switch_s = now;
        self.rung_switches += 1;
        self.pending_penalty_s += penalty_s;
    }

    fn try_start(&mut self, now: f64) -> bool {
        if self.phase.is_some() || self.failed {
            return false;
        }
        self.submit_waiting();
        if self.engine.idle() {
            return false;
        }
        let wall = Instant::now();
        let stall_before_s = self.engine.metrics.expert_stall_s;
        // calibration regressors, read as before/after deltas around the
        // step: occupied slots for a decode step, admitted prompt tokens
        // for a prefill step
        let occ_before = self.engine.n_active();
        let prefill_tokens_before = self.engine.metrics.prefill_tokens;
        let outcome = match self.engine.step_detail() {
            Ok(o) => o,
            Err(e) => {
                // fail THIS replica, not the process: drop its remaining
                // work so the event loop drains and the report surfaces
                // the shortfall as missing completions
                eprintln!("replica {}: engine step failed ({e:#}); dropping its workload", self.id);
                self.failed = true;
                self.telemetry_version += 1;
                while self.queue.pop().is_some() {}
                self.inflight.clear();
                return false;
            }
        };
        let dt = wall.elapsed().as_secs_f64().max(1e-9);
        // simulated residency stall extends the step in EVENT-LOOP time
        // (same contract as the sim replica's stall-inflated phases);
        // the measured step-time histogram stays pure wall clock
        let stall_s = self.engine.metrics.expert_stall_s - stall_before_s;
        let x = match outcome.kind {
            StepKind::Idle => return false,
            StepKind::Prefill => {
                self.prefill_calls += 1;
                (self.engine.metrics.prefill_tokens - prefill_tokens_before) as f64
            }
            StepKind::Decode => {
                self.decode_steps += 1;
                occ_before as f64
            }
        };
        // the step moved step_ewma_s and (with residency) hbm_pressure
        self.telemetry_version += 1;
        self.step_samples.push(StepSample {
            prefill: outcome.kind == StepKind::Prefill,
            rung: self.rung,
            x,
            dt_s: dt,
            stall_s,
        });
        self.step_ewma_s = if self.step_ewma_s == 0.0 {
            dt + stall_s
        } else {
            0.2 * (dt + stall_s) + 0.8 * self.step_ewma_s
        };
        let dur = self.pending_penalty_s + dt + stall_s;
        self.pending_penalty_s = 0.0;
        self.busy_s += dur;
        self.rung_time_s[self.rung.min(self.rung_time_s.len() - 1)] += dur;
        let prefill = outcome.kind == StepKind::Prefill;
        record_opt(&self.tracer, now, || EventKind::PhaseStart {
            replica: self.id,
            phase: if prefill { PhaseKind::Prefill } else { PhaseKind::Decode },
            rung: self.rung,
            dur_s: dur,
            stall_s,
            active: self.engine.n_active(),
            ids: if prefill { self.just_submitted.clone() } else { Vec::new() },
        });
        self.phase = Some((now + dur, outcome));
        true
    }

    fn next_event_s(&self) -> Option<f64> {
        self.phase.as_ref().map(|(end_s, _)| *end_s)
    }

    fn complete_phase(&mut self, now: f64, out: &mut Vec<CompletedRequest>) {
        let Some((_end_s, outcome)) = self.phase.take() else {
            return;
        };
        self.telemetry_version += 1;
        // first tokens materialize at the phase boundary...
        for id in &outcome.first_tokens {
            if let Some(m) = self.inflight.get_mut(id) {
                m.first_token_s = Some(now);
                let trace_id = m.trace_id;
                record_opt(&self.tracer, now, || EventKind::FirstToken {
                    id: trace_id,
                    replica: self.id,
                });
            }
        }
        // ...so a request finishing in the same step still gets a
        // well-ordered ttft <= e2e
        for o in &outcome.finished {
            if let Some(m) = self.inflight.remove(&o.id) {
                let first = m.first_token_s.unwrap_or(now);
                let c = CompletedRequest {
                    id: m.trace_id,
                    class: m.class,
                    arrival_s: m.arrival_s,
                    prompt_len: m.prompt_len,
                    tokens: o.tokens.len(),
                    ttft_s: first - m.arrival_s,
                    e2e_s: now - m.arrival_s,
                    finish_s: now,
                    replica: self.id,
                };
                record_opt(&self.tracer, now, || EventKind::Finish {
                    id: c.id,
                    replica: c.replica,
                    class: c.class,
                    ttft_s: c.ttft_s,
                    e2e_s: c.e2e_s,
                    tokens: c.tokens,
                });
                out.push(c);
            }
        }
    }

    fn is_drained(&self) -> bool {
        self.phase.is_none() && self.queue.is_empty() && self.inflight.is_empty()
    }

    fn stats(&self) -> BackendStats {
        let step_times = (!self.step_samples.is_empty()).then(|| {
            let mut s: Vec<f64> = self.step_samples.iter().map(|s| s.dt_s).collect();
            s.sort_by(f64::total_cmp);
            StepTimeSummary {
                n: s.len() as u64,
                p50_s: percentile_sorted(&s, 50.0),
                p95_s: percentile_sorted(&s, 95.0),
                max_s: *s.last().unwrap(),
            }
        });
        BackendStats {
            busy_s: self.busy_s,
            prefill_calls: self.prefill_calls,
            decode_steps: self.decode_steps,
            rung_switches: self.rung_switches,
            rung_time_s: self.rung_time_s.clone(),
            step_times,
            step_samples: (!self.step_samples.is_empty()).then(|| self.step_samples.clone()),
            residency: self.engine.residency_stats(),
        }
    }
}

/// Deterministic synthetic prompt for a trace request: seeded by the
/// request id, token ids in `[3, vocab)` (clear of pad/bos/eos).
pub fn synth_prompt(id: u64, len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(id.wrapping_add(1), 0x70a6_2026);
    let span = vocab.saturating_sub(3).max(1) as u32;
    (0..len).map(|_| 3 + rng.gen_range(span) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_prompts_are_deterministic_and_in_vocab() {
        let a = synth_prompt(7, 32, 128);
        let b = synth_prompt(7, 32, 128);
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        assert!(a.iter().all(|&t| (3..128).contains(&t)));
        assert_ne!(a, synth_prompt(8, 32, 128));
    }
}
