//! The cluster's shared telemetry layer: one structured snapshot per
//! control-plane instant.
//!
//! Every cluster-level decision — routing, the quality-ladder
//! controller, cross-replica work stealing — used to poke a disjoint
//! ad-hoc slice of replica state (mean queue depth here, token backlog
//! there). [`ClusterSnapshot`] replaces those scattered getters with one
//! surface: each [`ReplicaBackend`](super::backend::ReplicaBackend)
//! reports a [`ReplicaTelemetry`] for the current event-loop instant,
//! and the routing policies, [`LadderController`](super::ladder::LadderController),
//! and the stealing pass in [`Cluster::run`](super::router::Cluster::run)
//! are pure functions of the snapshot. Adding a future scheduling idea
//! means adding one snapshot consumer, not a new trait getter.
//!
//! Within one event-loop instant the dispatch loop refreshes the
//! snapshot after mutations (an admitted arrival changes the next
//! arrival's routing input), so consumers always see current state —
//! "per instant" is the unit of decision-making, not a caching policy.

use super::backend::{ReplicaBackend, TELEMETRY_UNVERSIONED};
use super::scheduler::EdfQueue;

/// How much telemetry to materialize. The O(1) scheduling fields are
/// always filled; the queue scans are only worth paying for at
/// control-plane instants, not on every routed arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TelemetryDetail {
    /// Only the O(1) fields (queue/active/load/rung/EWMA): the
    /// per-arrival routing input. Scan-derived fields are left empty
    /// (`class_occupancy` empty, slack minima `None`).
    Load,
    /// Everything, including the per-class occupancy and EDF-slack
    /// minima (O(queue) scans): the ladder/stealing input.
    Full,
}

/// One replica's control-plane-visible state at an event-loop instant.
#[derive(Clone, Debug)]
pub struct ReplicaTelemetry {
    /// Stable replica index (= position in the cluster).
    pub replica: usize,
    /// Whether the replica can take on new work (false once an
    /// engine-backed replica has failed mid-run — its `admit` would
    /// silently drop requests, so routing and stealing avoid it).
    pub accepting: bool,
    /// Current quality point as a canonical linear lattice index
    /// (0 = full quality). The wire format for traces and stats; the
    /// typed coordinate lives in [`Self::point`].
    pub rung: usize,
    /// Typed lattice coordinate of [`Self::rung`]: `(k, s)` steps along
    /// the budget and sparsity axes. On a 1-D lattice `point.k == rung`
    /// and `point.s == 0`.
    pub point: super::ladder::PointId,
    /// Event-loop time of the last rung switch (−∞ before the first).
    pub last_switch_s: f64,
    /// Requests waiting in the local queue.
    pub queue_len: usize,
    /// Requests running inside the replica (occupied slots / in-flight
    /// engine requests).
    pub active: usize,
    /// Token-weighted backlog: queued cost + remaining decode tokens of
    /// running requests (the load-aware routing signal).
    pub load_cost: u64,
    /// Queued + running requests per SLO class (index = class id; may
    /// be shorter than the scenario's class count when the tail classes
    /// have no occupancy). Empty at [`TelemetryDetail::Load`].
    pub class_occupancy: Vec<usize>,
    /// Minimum EDF slack `deadline − now` over ALL queued requests
    /// (`None` when the queue is empty, or at
    /// [`TelemetryDetail::Load`]). The work-stealing pressure signal.
    pub min_slack_s: Option<f64>,
    /// Minimum over queued *interactive* (priority-0) requests of
    /// `slack / TTFT SLO` — 1 at arrival, 0 at the deadline, negative
    /// past it. Scale-free, so one threshold works for any model or
    /// cluster speed. `None` when no interactive request is queued (or
    /// at [`TelemetryDetail::Load`]).
    pub min_interactive_slack_frac: Option<f64>,
    /// [`Self::min_interactive_slack_frac`] projected one queue-drain
    /// horizon forward (`step_ewma_s * queue_len` seconds): where the
    /// worst interactive slack WILL be once today's backlog has burned
    /// its expected service time. The `--pressure slack-ewma` signal.
    /// `None` under the same conditions as the instantaneous value.
    pub projected_interactive_slack_frac: Option<f64>,
    /// EWMA of recent phase durations (prefill or decode), seconds.
    /// 0 before the first phase.
    pub step_ewma_s: f64,
    /// Expert-residency pressure: miss-rate EWMA of the replica's HBM
    /// store in [0, 1]. `None` when the replica runs without a
    /// residency model (the default).
    pub hbm_pressure: Option<f64>,
}

impl ReplicaTelemetry {
    /// An idle replica with no history (test/bootstrap fixture).
    pub fn idle(replica: usize) -> Self {
        ReplicaTelemetry {
            replica,
            accepting: true,
            rung: 0,
            point: super::ladder::PointId::default(),
            last_switch_s: f64::NEG_INFINITY,
            queue_len: 0,
            active: 0,
            load_cost: 0,
            class_occupancy: Vec::new(),
            min_slack_s: None,
            min_interactive_slack_frac: None,
            projected_interactive_slack_frac: None,
            step_ewma_s: 0.0,
            hbm_pressure: None,
        }
    }

    /// Queued + running requests (the admission-control signal).
    pub fn outstanding(&self) -> usize {
        self.queue_len + self.active
    }

    /// Full telemetry row as JSON (debug-bundle embedding; `Option`
    /// fields emit as `null` so a bundle reader sees every column).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let opt = |v: Option<f64>| v.map_or(Json::Null, Json::Num);
        Json::obj(vec![
            ("replica", Json::Num(self.replica as f64)),
            ("accepting", Json::Bool(self.accepting)),
            ("rung", Json::Num(self.rung as f64)),
            (
                "last_switch_s",
                if self.last_switch_s.is_finite() {
                    Json::Num(self.last_switch_s)
                } else {
                    Json::Null
                },
            ),
            ("queue_len", Json::Num(self.queue_len as f64)),
            ("active", Json::Num(self.active as f64)),
            ("load_cost", Json::Num(self.load_cost as f64)),
            (
                "class_occupancy",
                Json::Arr(
                    self.class_occupancy
                        .iter()
                        .map(|&c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
            ("min_slack_s", opt(self.min_slack_s)),
            (
                "min_interactive_slack_frac",
                opt(self.min_interactive_slack_frac),
            ),
            (
                "projected_interactive_slack_frac",
                opt(self.projected_interactive_slack_frac),
            ),
            ("step_ewma_s", Json::Num(self.step_ewma_s)),
            ("hbm_pressure", opt(self.hbm_pressure)),
        ])
    }

    /// Fill the O(queue)-scan fields ([`TelemetryDetail::Full`]) from
    /// the local EDF queue plus the classes of currently running
    /// requests — shared by every backend so the two replica families
    /// can never diverge on what the scans mean.
    pub fn fill_scans(
        &mut self,
        queue: &EdfQueue,
        running_classes: impl Iterator<Item = usize>,
        now_s: f64,
    ) {
        crate::prof_scope!("telemetry.fill_scans");
        let mut occupancy = queue.class_counts().to_vec();
        for class in running_classes {
            if class >= occupancy.len() {
                occupancy.resize(class + 1, 0);
            }
            occupancy[class] += 1;
        }
        self.class_occupancy = occupancy;
        self.min_slack_s = queue.min_deadline_ns().map(|ns| ns as f64 / 1e9 - now_s);
        self.min_interactive_slack_frac = queue.min_interactive_slack_frac(now_s);
        // predictive slack: evaluate the same minimum one queue-drain
        // horizon ahead (expects `step_ewma_s` and `queue_len` to be
        // filled before the scans — both backends construct the struct
        // first, then call fill_scans)
        let horizon_s = self.step_ewma_s * self.queue_len as f64;
        self.projected_interactive_slack_frac =
            queue.min_interactive_slack_frac(now_s + horizon_s);
    }
}

/// All replica telemetry at one event-loop instant.
#[derive(Clone, Debug)]
pub struct ClusterSnapshot {
    pub now_s: f64,
    pub replicas: Vec<ReplicaTelemetry>,
}

impl ClusterSnapshot {
    /// Worst (minimum) interactive slack fraction across the cluster
    /// (+∞ when no interactive request is queued anywhere) — the
    /// cluster-global slack-pressure reading.
    pub fn min_interactive_slack_frac(&self) -> f64 {
        self.replicas
            .iter()
            .filter_map(|t| t.min_interactive_slack_frac)
            .fold(f64::INFINITY, f64::min)
    }

    /// Worst (minimum) absolute queued slack across the cluster (+∞
    /// when every queue is empty).
    pub fn min_slack_s(&self) -> f64 {
        self.replicas
            .iter()
            .filter_map(|t| t.min_slack_s)
            .fold(f64::INFINITY, f64::min)
    }

    /// Worst *projected* interactive slack fraction across the cluster
    /// (the `--pressure slack-ewma` aggregate; +∞ when nothing
    /// interactive is queued anywhere).
    pub fn min_projected_interactive_slack_frac(&self) -> f64 {
        self.replicas
            .iter()
            .filter_map(|t| t.projected_interactive_slack_frac)
            .fold(f64::INFINITY, f64::min)
    }

    /// The whole snapshot as JSON (the `cluster` section of a debug
    /// bundle).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("now_s", Json::Num(self.now_s)),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|t| t.to_json()).collect()),
            ),
        ])
    }
}

/// Incrementally maintained [`ClusterSnapshot`]: one persistent row per
/// replica, re-read only when the backend's
/// [`telemetry_version`](ReplicaBackend::telemetry_version) moved —
/// plus, at [`TelemetryDetail::Full`], when the queue scans were taken
/// at a different instant (the scan fields depend on `now_s`; the
/// `Load` fields do not). The cluster keeps one cache per detail level,
/// so the per-arrival `Load` fast path never pays for `Full` scans and
/// a `Load` consumer never sees stale scan fields it expects empty.
#[derive(Debug)]
pub struct SnapshotCache {
    snap: ClusterSnapshot,
    detail: TelemetryDetail,
    /// Backend telemetry version behind each row (`None` = never
    /// materialized, or the backend is unversioned).
    versions: Vec<Option<u64>>,
    /// Instant each row's `Full` scans were taken at (unused at `Load`).
    scan_now_s: Vec<f64>,
    /// Rebuild every row (and the row vector) from scratch on every
    /// refresh — the pre-cache baseline cost model, kept for
    /// `bench-scale --compare` and the equivalence regression test.
    rebuild: bool,
}

impl SnapshotCache {
    pub fn new(n_replicas: usize, detail: TelemetryDetail) -> Self {
        SnapshotCache {
            snap: ClusterSnapshot {
                now_s: 0.0,
                replicas: Vec::with_capacity(n_replicas),
            },
            detail,
            versions: vec![None; n_replicas],
            scan_now_s: vec![f64::NAN; n_replicas],
            rebuild: false,
        }
    }

    /// Force the rebuild-per-call baseline behaviour.
    pub fn set_rebuild(&mut self, rebuild: bool) {
        self.rebuild = rebuild;
    }

    /// The cached snapshot as of the last [`refresh`](Self::refresh).
    pub fn snap(&self) -> &ClusterSnapshot {
        &self.snap
    }

    /// Bring the cache up to date at `now_s`, re-reading only dirty
    /// rows. Billed to the same `cluster.snapshot` self-profiler
    /// section the old per-call rebuild used, so `BENCH_selfprof.json`
    /// entries stay directly comparable across the change.
    pub fn refresh(&mut self, backends: &[Box<dyn ReplicaBackend + '_>], now_s: f64) {
        crate::prof_scope!("cluster.snapshot");
        self.snap.now_s = now_s;
        if self.rebuild {
            // baseline: a fresh row vector (and allocation) per call
            self.snap.replicas = backends
                .iter()
                .map(|b| b.telemetry(now_s, self.detail))
                .collect();
            return;
        }
        if self.snap.replicas.len() != backends.len() {
            // first refresh (or the pool changed between runs)
            self.snap.replicas.clear();
            self.snap
                .replicas
                .extend(backends.iter().map(|b| b.telemetry(now_s, self.detail)));
            self.versions = backends
                .iter()
                .map(|b| {
                    let v = b.telemetry_version();
                    (v != TELEMETRY_UNVERSIONED).then_some(v)
                })
                .collect();
            self.scan_now_s = vec![now_s; backends.len()];
            return;
        }
        for (i, b) in backends.iter().enumerate() {
            let v = b.telemetry_version();
            let clean = v != TELEMETRY_UNVERSIONED
                && self.versions[i] == Some(v)
                && (self.detail == TelemetryDetail::Load || self.scan_now_s[i] == now_s);
            if clean {
                continue;
            }
            self.snap.replicas[i] = b.telemetry(now_s, self.detail);
            self.versions[i] = (v != TELEMETRY_UNVERSIONED).then_some(v);
            self.scan_now_s[i] = now_s;
        }
    }
}

/// Per-replica engine step-time summary (measured wall-clock phases),
/// recorded so the sim `ServiceModel` can be calibrated against real
/// engine step times.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepTimeSummary {
    /// Measured steps (prefill + decode).
    pub n: u64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
}

/// One measured engine scheduling step, tagged with everything the sim
/// `ServiceModel` fitter conditions on: phase kind, quality-ladder rung,
/// and the regressor the service model is linear in (admitted prompt
/// tokens for prefill, active decode slots for decode). Simulated
/// expert-residency stall is virtual time, so it is kept SEPARATE from
/// the measured compute time — the fitter models the two independently
/// (see [`crate::calibrate`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StepSample {
    /// True for batched-prefill steps, false for decode steps.
    pub prefill: bool,
    /// Quality-ladder rung the replica was on during the step.
    pub rung: usize,
    /// Regressor: admitted prompt tokens (prefill) or occupied decode
    /// slots (decode).
    pub x: f64,
    /// Measured wall-clock compute time of the step (residency stall
    /// excluded).
    pub dt_s: f64,
    /// Simulated residency stall charged to the step in event-loop time
    /// (0 without an HBM budget).
    pub stall_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates_worst_slack() {
        let mut a = ReplicaTelemetry::idle(0);
        a.min_slack_s = Some(0.5);
        a.min_interactive_slack_frac = Some(0.8);
        let mut b = ReplicaTelemetry::idle(1);
        b.min_slack_s = Some(0.2);
        let snap = ClusterSnapshot {
            now_s: 1.0,
            replicas: vec![a, b],
        };
        assert_eq!(snap.min_slack_s(), 0.2);
        assert_eq!(snap.min_interactive_slack_frac(), 0.8);
        let empty = ClusterSnapshot {
            now_s: 0.0,
            replicas: vec![ReplicaTelemetry::idle(0)],
        };
        assert!(empty.min_slack_s().is_infinite());
        assert!(empty.min_interactive_slack_frac().is_infinite());
    }

    #[test]
    fn snapshot_json_carries_every_column() {
        let mut t = ReplicaTelemetry::idle(2);
        t.queue_len = 5;
        t.hbm_pressure = Some(0.25);
        let snap = ClusterSnapshot {
            now_s: 3.5,
            replicas: vec![t],
        };
        let j = snap.to_json();
        let re = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.get("now_s").unwrap().as_f64().unwrap(), 3.5);
        let r = &re.get("replicas").unwrap().as_arr().unwrap()[0];
        assert_eq!(r.get("replica").unwrap().as_usize().unwrap(), 2);
        assert_eq!(r.get("queue_len").unwrap().as_usize().unwrap(), 5);
        assert_eq!(r.get("hbm_pressure").unwrap().as_f64().unwrap(), 0.25);
        // None / -inf fields serialize as null, not as garbage numbers
        use crate::util::json::Json;
        assert!(matches!(r.get("min_slack_s").unwrap(), Json::Null));
        assert!(matches!(r.get("last_switch_s").unwrap(), Json::Null));
    }

    #[test]
    fn outstanding_counts_queue_and_active() {
        let mut t = ReplicaTelemetry::idle(3);
        t.queue_len = 4;
        t.active = 2;
        assert_eq!(t.outstanding(), 6);
    }

    use std::cell::Cell;
    use std::rc::Rc;

    use crate::server::backend::{
        BackendStats, CompletedRequest, ReplicaBackend, TELEMETRY_UNVERSIONED,
    };
    use crate::server::scheduler::QueuedRequest;

    /// Minimal backend for cache tests: counts telemetry reads through
    /// a shared cell and exposes a controllable version.
    struct Probe {
        reads: Rc<Cell<usize>>,
        version: Rc<Cell<u64>>,
        queue_len: usize,
    }

    impl ReplicaBackend for Probe {
        fn id(&self) -> usize {
            0
        }
        fn admit(&mut self, _req: QueuedRequest) {}
        fn telemetry(&self, _now_s: f64, detail: TelemetryDetail) -> ReplicaTelemetry {
            self.reads.set(self.reads.get() + 1);
            let mut t = ReplicaTelemetry::idle(0);
            t.queue_len = self.queue_len;
            if detail == TelemetryDetail::Full {
                t.min_slack_s = Some(1.0);
            }
            t
        }
        fn telemetry_version(&self) -> u64 {
            self.version.get()
        }
        fn outstanding(&self) -> usize {
            self.queue_len
        }
        fn set_rung(&mut self, _rung: usize, _now: f64, _penalty_s: f64) {}
        fn steal_request(&mut self) -> Option<QueuedRequest> {
            None
        }
        fn try_start(&mut self, _now: f64) -> bool {
            false
        }
        fn next_event_s(&self) -> Option<f64> {
            None
        }
        fn complete_phase(&mut self, _now: f64, _out: &mut Vec<CompletedRequest>) {}
        fn is_drained(&self) -> bool {
            true
        }
        fn stats(&self) -> BackendStats {
            BackendStats::default()
        }
    }

    #[allow(clippy::type_complexity)]
    fn probe_pool(
        version: u64,
    ) -> (
        Vec<Box<dyn ReplicaBackend>>,
        Rc<Cell<usize>>,
        Rc<Cell<u64>>,
    ) {
        let reads = Rc::new(Cell::new(0));
        let v = Rc::new(Cell::new(version));
        let pool: Vec<Box<dyn ReplicaBackend>> = vec![Box::new(Probe {
            reads: Rc::clone(&reads),
            version: Rc::clone(&v),
            queue_len: 3,
        })];
        (pool, reads, v)
    }

    #[test]
    fn load_cache_rereads_only_when_the_version_moves() {
        let (pool, reads, version) = probe_pool(1);
        let mut cache = SnapshotCache::new(1, TelemetryDetail::Load);
        cache.refresh(&pool, 0.5);
        assert_eq!(reads.get(), 1);
        assert_eq!(cache.snap().replicas[0].queue_len, 3);
        // clean row at new instants: Load fields are now-independent,
        // so no re-read — but the snapshot instant still advances
        cache.refresh(&pool, 1.5);
        cache.refresh(&pool, 2.5);
        assert_eq!(reads.get(), 1);
        assert_eq!(cache.snap().now_s, 2.5);
        // a version bump dirties exactly that row
        version.set(2);
        cache.refresh(&pool, 3.0);
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn full_cache_rescans_at_each_new_instant_but_not_within_one() {
        let (pool, reads, _version) = probe_pool(1);
        let mut cache = SnapshotCache::new(1, TelemetryDetail::Full);
        cache.refresh(&pool, 0.0);
        cache.refresh(&pool, 0.0); // same instant, clean version: reuse
        assert_eq!(reads.get(), 1);
        cache.refresh(&pool, 1.0); // new instant: scans depend on now
        assert_eq!(reads.get(), 2);
        assert_eq!(cache.snap().replicas[0].min_slack_s, Some(1.0));
    }

    #[test]
    fn unversioned_backends_are_reread_every_refresh() {
        let (pool, reads, _version) = probe_pool(TELEMETRY_UNVERSIONED);
        let mut cache = SnapshotCache::new(1, TelemetryDetail::Load);
        cache.refresh(&pool, 0.0);
        cache.refresh(&pool, 0.0);
        cache.refresh(&pool, 1.0);
        assert_eq!(reads.get(), 3);
    }

    #[test]
    fn rebuild_mode_restores_the_per_call_rebuild() {
        let (pool, reads, _version) = probe_pool(1);
        let mut cache = SnapshotCache::new(1, TelemetryDetail::Load);
        cache.set_rebuild(true);
        cache.refresh(&pool, 0.0);
        cache.refresh(&pool, 0.0);
        assert_eq!(reads.get(), 2);
    }

    #[test]
    fn projected_slack_burns_the_queue_drain_horizon() {
        use crate::server::scheduler::{EdfQueue, QueuedRequest};
        let mut q = EdfQueue::new();
        // interactive request: arrived at t=0, TTFT SLO 2s
        q.push(QueuedRequest {
            id: 0,
            class: 0,
            priority: 0,
            arrival_s: 0.0,
            deadline_ns: 2_000_000_000,
            prompt_len: 64,
            new_tokens: 16,
        });
        let mut t = ReplicaTelemetry::idle(0);
        t.queue_len = 1;
        t.step_ewma_s = 0.5; // horizon = 0.5s
        t.fill_scans(&q, std::iter::empty::<usize>(), 1.0);
        // instantaneous: 1s of 2s budget left -> 0.5
        assert!((t.min_interactive_slack_frac.unwrap() - 0.5).abs() < 1e-9);
        // projected: evaluated at now + 0.5 -> 0.25
        assert!((t.projected_interactive_slack_frac.unwrap() - 0.25).abs() < 1e-9);

        // no history -> projection collapses to the instantaneous value
        let mut cold = ReplicaTelemetry::idle(1);
        cold.queue_len = 1;
        cold.fill_scans(&q, std::iter::empty::<usize>(), 1.0);
        assert_eq!(
            cold.projected_interactive_slack_frac,
            cold.min_interactive_slack_frac
        );

        let snap = ClusterSnapshot {
            now_s: 1.0,
            replicas: vec![t, ReplicaTelemetry::idle(2)],
        };
        assert!((snap.min_projected_interactive_slack_frac() - 0.25).abs() < 1e-9);
    }
}
