//! SLO-aware scheduling: admission control + multi-class EDF queues.
//!
//! Every queued request carries a TTFT deadline (`arrival + class TTFT
//! SLO`). Dispatch order is (priority class, earliest deadline, arrival
//! id) — latency-critical classes always preempt batch traffic in the
//! queue, and within a class the request closest to busting its SLO goes
//! first. Deadlines are stored as integer nanoseconds on the request, so
//! the ordering is a total order (bit-reproducible across runs) and
//! `key()` never re-quantizes a float at comparison time.

use std::collections::{BTreeMap, BTreeSet};

use crate::prof_scope;

use super::workload::TraceRequest;

/// A request admitted into the serving queue.
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub class: usize,
    pub priority: u8,
    pub arrival_s: f64,
    /// TTFT deadline in integer nanoseconds of virtual time (the
    /// scheduler's comparison key; see [`QueuedRequest::deadline_s`] for
    /// the float view reports use).
    pub deadline_ns: u64,
    pub prompt_len: usize,
    pub new_tokens: usize,
}

impl QueuedRequest {
    pub fn new(r: &TraceRequest, priority: u8, ttft_slo_s: f64) -> Self {
        QueuedRequest {
            id: r.id,
            class: r.class,
            priority,
            arrival_s: r.arrival_s,
            deadline_ns: ((r.arrival_s + ttft_slo_s) * 1e9) as u64,
            prompt_len: r.prompt_len,
            new_tokens: r.new_tokens,
        }
    }

    /// TTFT deadline (absolute virtual time, seconds) for reports.
    pub fn deadline_s(&self) -> f64 {
        self.deadline_ns as f64 / 1e9
    }

    /// EDF slack at `now`, normalized by the class TTFT SLO: 1 at
    /// arrival, 0 at the deadline, negative past it.
    pub fn slack_frac(&self, now_s: f64) -> f64 {
        let slo = (self.deadline_s() - self.arrival_s).max(1e-9);
        (self.deadline_s() - now_s) / slo
    }

    /// Token-weighted cost used for load-aware routing: decode steps
    /// dominate, prefill tokens are batched and cheap per token.
    pub fn cost(&self) -> u64 {
        (self.prompt_len / 8 + self.new_tokens) as u64
    }

    fn key(&self) -> (u8, u64, u64) {
        (self.priority, self.deadline_ns, self.id)
    }
}

/// Priority + earliest-deadline-first queue, indexed two ways.
///
/// Requests live in a `BTreeMap` ordered by the EDF dispatch key
/// `(priority, deadline_ns, id)`; a mirror `BTreeSet` orders the same
/// membership by `(deadline_ns, id)`, so the work-stealing donor pop
/// ([`pop_min_deadline`](EdfQueue::pop_min_deadline)) is O(log n)
/// instead of the old drain-and-rebuild O(n log n), and
/// [`min_deadline_ns`](EdfQueue::min_deadline_ns) reads the first
/// element instead of scanning the whole queue. Request ids are unique
/// within a queue (the cluster assigns globally unique ids and a
/// request sits in at most one replica's queue), so both keys are
/// total orders and the two indexes stay in lockstep.
#[derive(Clone, Debug, Default)]
pub struct EdfQueue {
    /// Dispatch order: (priority, deadline_ns, id) → request.
    by_edf: BTreeMap<(u8, u64, u64), QueuedRequest>,
    /// Steal order: (deadline_ns, id, priority). The priority rides
    /// along so the dispatch key can be rebuilt on removal.
    by_deadline: BTreeSet<(u64, u64, u8)>,
    pending_cost: u64,
    /// Queued requests per class (index = class id; grown on demand).
    class_counts: Vec<usize>,
}

impl EdfQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: QueuedRequest) {
        prof_scope!("edf.push");
        self.pending_cost += req.cost();
        if req.class >= self.class_counts.len() {
            self.class_counts.resize(req.class + 1, 0);
        }
        self.class_counts[req.class] += 1;
        self.by_deadline.insert((req.deadline_ns, req.id, req.priority));
        let prev = self.by_edf.insert(req.key(), req);
        debug_assert!(prev.is_none(), "duplicate queued request id");
    }

    fn note_pop(&mut self, req: &QueuedRequest) {
        self.pending_cost -= req.cost();
        self.class_counts[req.class] -= 1;
    }

    /// Pop the (highest-priority, earliest-deadline) request. O(log n).
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        prof_scope!("edf.pop");
        let (_, req) = self.by_edf.pop_first()?;
        self.by_deadline
            .remove(&(req.deadline_ns, req.id, req.priority));
        self.note_pop(&req);
        Some(req)
    }

    /// Remove the queued request with the minimum absolute deadline —
    /// the worst-slack entry, whatever its priority class. The
    /// work-stealing donor operation. O(log n) off the deadline index.
    pub fn pop_min_deadline(&mut self) -> Option<QueuedRequest> {
        let (deadline_ns, id, priority) = self.by_deadline.pop_first()?;
        let req = self
            .by_edf
            .remove(&(priority, deadline_ns, id))
            .expect("deadline index out of sync with EDF map");
        self.note_pop(&req);
        Some(req)
    }

    pub fn len(&self) -> usize {
        self.by_edf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_edf.is_empty()
    }

    /// Total token-weighted backlog (for load-aware routing).
    pub fn pending_cost(&self) -> u64 {
        self.pending_cost
    }

    /// Queued requests per class (index = class id; may be shorter than
    /// the scenario's class count).
    pub fn class_counts(&self) -> &[usize] {
        &self.class_counts
    }

    /// Earliest deadline currently queued (None when empty).
    pub fn earliest_deadline_s(&self) -> Option<f64> {
        self.by_edf.first_key_value().map(|(_, r)| r.deadline_s())
    }

    /// Minimum deadline (ns) over ALL queued requests — unlike the
    /// dispatch head, this ignores priority, so it reads the truly
    /// worst slack. O(1) off the deadline index.
    pub fn min_deadline_ns(&self) -> Option<u64> {
        self.by_deadline.first().map(|&(d, _, _)| d)
    }

    /// Minimum normalized slack over queued interactive (priority-0)
    /// requests at `now` (None when no interactive request is queued).
    /// Scans only the priority-0 prefix of the dispatch index.
    pub fn min_interactive_slack_frac(&self, now_s: f64) -> Option<f64> {
        self.by_edf
            .range((0u8, 0u64, 0u64)..(1u8, 0u64, 0u64))
            .map(|(_, r)| r.slack_frac(now_s))
            .min_by(|a, b| a.total_cmp(b))
    }
}

/// Global admission control: bound outstanding work, count rejections.
#[derive(Clone, Debug)]
pub struct AdmissionControl {
    pub cap: usize,
    pub admitted: u64,
    pub rejected_by_class: Vec<u64>,
}

impl AdmissionControl {
    pub fn new(cap: usize, n_classes: usize) -> Self {
        AdmissionControl {
            cap,
            admitted: 0,
            rejected_by_class: vec![0; n_classes],
        }
    }

    /// Admit iff the cluster-wide outstanding count is below the cap.
    pub fn try_admit(&mut self, outstanding: usize, class: usize) -> bool {
        if outstanding >= self.cap {
            self.rejected_by_class[class] += 1;
            false
        } else {
            self.admitted += 1;
            true
        }
    }

    pub fn rejected(&self) -> u64 {
        self.rejected_by_class.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, priority: u8, deadline_s: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            class: priority as usize,
            priority,
            arrival_s: 0.0,
            deadline_ns: (deadline_s * 1e9) as u64,
            prompt_len: 80,
            new_tokens: 40,
        }
    }

    #[test]
    fn edf_pops_earliest_deadline_first() {
        let mut q = EdfQueue::new();
        q.push(req(0, 0, 5.0));
        q.push(req(1, 0, 1.0));
        q.push(req(2, 0, 3.0));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn priority_class_preempts_deadline() {
        let mut q = EdfQueue::new();
        q.push(req(0, 2, 0.1)); // batch class, imminent deadline
        q.push(req(1, 0, 9.0)); // interactive, far deadline
        assert_eq!(q.pop().unwrap().id, 1, "priority must dominate deadline");
        assert_eq!(q.pop().unwrap().id, 0);
    }

    #[test]
    fn ties_break_by_arrival_id() {
        let mut q = EdfQueue::new();
        q.push(req(7, 1, 2.0));
        q.push(req(3, 1, 2.0));
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 7);
    }

    #[test]
    fn deadline_is_integer_ns_with_float_view() {
        let r = QueuedRequest::new(
            &crate::server::workload::TraceRequest {
                id: 9,
                class: 0,
                arrival_s: 1.5,
                prompt_len: 64,
                new_tokens: 16,
            },
            0,
            0.25,
        );
        assert_eq!(r.deadline_ns, 1_750_000_000);
        assert!((r.deadline_s() - 1.75).abs() < 1e-9);
        // slack fraction: 1 at arrival, 0 at deadline, negative past it
        assert!((r.slack_frac(1.5) - 1.0).abs() < 1e-9);
        assert!(r.slack_frac(1.75).abs() < 1e-9);
        assert!(r.slack_frac(2.0) < 0.0);
    }

    #[test]
    fn pending_cost_tracks_push_pop() {
        let mut q = EdfQueue::new();
        assert_eq!(q.pending_cost(), 0);
        q.push(req(0, 0, 1.0));
        q.push(req(1, 0, 2.0));
        let per = 80 / 8 + 40;
        assert_eq!(q.pending_cost(), 2 * per as u64);
        q.pop();
        assert_eq!(q.pending_cost(), per as u64);
        q.pop();
        assert_eq!(q.pending_cost(), 0);
        assert!(q.earliest_deadline_s().is_none());
    }

    #[test]
    fn class_counts_follow_queue_membership() {
        let mut q = EdfQueue::new();
        q.push(req(0, 0, 1.0));
        q.push(req(1, 2, 2.0));
        q.push(req(2, 2, 3.0));
        assert_eq!(q.class_counts(), &[1, 0, 2]);
        q.pop(); // priority 0 leaves first
        assert_eq!(q.class_counts(), &[0, 0, 2]);
        q.pop_min_deadline();
        assert_eq!(q.class_counts(), &[0, 0, 1]);
    }

    #[test]
    fn pop_min_deadline_ignores_priority() {
        let mut q = EdfQueue::new();
        q.push(req(0, 0, 9.0)); // interactive, far deadline
        q.push(req(1, 2, 0.5)); // batch, imminent deadline
        q.push(req(2, 1, 4.0));
        assert_eq!(q.min_deadline_ns(), Some(500_000_000));
        // worst slack is the batch request, even though EDF would pop
        // the interactive one first
        assert_eq!(q.pop_min_deadline().unwrap().id, 1);
        // the rest of the queue is intact and still EDF-ordered
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn interactive_slack_tracks_priority_zero_only() {
        let mut q = EdfQueue::new();
        q.push(req(0, 2, 0.1)); // batch about to bust — ignored
        assert!(q.min_interactive_slack_frac(0.0).is_none());
        q.push(req(1, 0, 2.0));
        let frac = q.min_interactive_slack_frac(1.0).unwrap();
        assert!((frac - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dual_indexes_stay_in_lockstep_under_interleaved_pops() {
        // interleave EDF pops with steal pops: membership, cost, and
        // class counts must agree throughout, and both indexes must
        // drain to exactly the pushed set
        let mut q = EdfQueue::new();
        let n = 60u64;
        for i in 0..n {
            q.push(req(i, (i % 3) as u8, ((i * 7919) % 97) as f64));
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            let before = q.len();
            let r = if before % 2 == 0 {
                q.pop_min_deadline()
            } else {
                q.pop()
            };
            let r = r.expect("non-empty queue must pop from both indexes");
            assert_eq!(q.len(), before - 1);
            seen.push(r.id);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        assert_eq!(q.pending_cost(), 0);
        assert!(q.class_counts().iter().all(|&c| c == 0));
        assert!(q.min_deadline_ns().is_none());
        assert!(q.earliest_deadline_s().is_none());
    }

    #[test]
    fn admission_caps_and_counts() {
        let mut ac = AdmissionControl::new(2, 3);
        assert!(ac.try_admit(0, 0));
        assert!(ac.try_admit(1, 1));
        assert!(!ac.try_admit(2, 2));
        assert!(!ac.try_admit(5, 2));
        assert_eq!(ac.admitted, 2);
        assert_eq!(ac.rejected(), 2);
        assert_eq!(ac.rejected_by_class, vec![0, 0, 2]);
    }
}
