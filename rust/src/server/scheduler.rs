//! SLO-aware scheduling: admission control + multi-class EDF queues.
//!
//! Every queued request carries a TTFT deadline (`arrival + class TTFT
//! SLO`). Dispatch order is (priority class, earliest deadline, arrival
//! id) — latency-critical classes always preempt batch traffic in the
//! queue, and within a class the request closest to busting its SLO goes
//! first. Deadlines are held as integer nanoseconds so the ordering is a
//! total order (bit-reproducible across runs).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::workload::TraceRequest;

/// A request admitted into the serving queue.
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub class: usize,
    pub priority: u8,
    pub arrival_s: f64,
    /// TTFT deadline (absolute virtual time).
    pub deadline_s: f64,
    pub prompt_len: usize,
    pub new_tokens: usize,
}

impl QueuedRequest {
    pub fn new(r: &TraceRequest, priority: u8, ttft_slo_s: f64) -> Self {
        QueuedRequest {
            id: r.id,
            class: r.class,
            priority,
            arrival_s: r.arrival_s,
            deadline_s: r.arrival_s + ttft_slo_s,
            prompt_len: r.prompt_len,
            new_tokens: r.new_tokens,
        }
    }

    /// Token-weighted cost used for load-aware routing: decode steps
    /// dominate, prefill tokens are batched and cheap per token.
    pub fn cost(&self) -> u64 {
        (self.prompt_len / 8 + self.new_tokens) as u64
    }

    fn key(&self) -> (u8, u64, u64) {
        (self.priority, (self.deadline_s * 1e9) as u64, self.id)
    }
}

#[derive(Clone, Debug)]
struct Entry(QueuedRequest);

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.0.key() == other.0.key()
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.key().cmp(&other.0.key())
    }
}

/// Priority + earliest-deadline-first queue.
#[derive(Clone, Debug, Default)]
pub struct EdfQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    pending_cost: u64,
}

impl EdfQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, req: QueuedRequest) {
        self.pending_cost += req.cost();
        self.heap.push(Reverse(Entry(req)));
    }

    /// Pop the (highest-priority, earliest-deadline) request.
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        self.heap.pop().map(|Reverse(Entry(req))| {
            self.pending_cost -= req.cost();
            req
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total token-weighted backlog (for JSQ / p2c routing).
    pub fn pending_cost(&self) -> u64 {
        self.pending_cost
    }

    /// Earliest deadline currently queued (None when empty).
    pub fn earliest_deadline_s(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(Entry(r))| r.deadline_s)
    }
}

/// Global admission control: bound outstanding work, count rejections.
#[derive(Clone, Debug)]
pub struct AdmissionControl {
    pub cap: usize,
    pub admitted: u64,
    pub rejected_by_class: Vec<u64>,
}

impl AdmissionControl {
    pub fn new(cap: usize, n_classes: usize) -> Self {
        AdmissionControl {
            cap,
            admitted: 0,
            rejected_by_class: vec![0; n_classes],
        }
    }

    /// Admit iff the cluster-wide outstanding count is below the cap.
    pub fn try_admit(&mut self, outstanding: usize, class: usize) -> bool {
        if outstanding >= self.cap {
            self.rejected_by_class[class] += 1;
            false
        } else {
            self.admitted += 1;
            true
        }
    }

    pub fn rejected(&self) -> u64 {
        self.rejected_by_class.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, priority: u8, deadline_s: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            class: priority as usize,
            priority,
            arrival_s: 0.0,
            deadline_s,
            prompt_len: 80,
            new_tokens: 40,
        }
    }

    #[test]
    fn edf_pops_earliest_deadline_first() {
        let mut q = EdfQueue::new();
        q.push(req(0, 0, 5.0));
        q.push(req(1, 0, 1.0));
        q.push(req(2, 0, 3.0));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn priority_class_preempts_deadline() {
        let mut q = EdfQueue::new();
        q.push(req(0, 2, 0.1)); // batch class, imminent deadline
        q.push(req(1, 0, 9.0)); // interactive, far deadline
        assert_eq!(q.pop().unwrap().id, 1, "priority must dominate deadline");
        assert_eq!(q.pop().unwrap().id, 0);
    }

    #[test]
    fn ties_break_by_arrival_id() {
        let mut q = EdfQueue::new();
        q.push(req(7, 1, 2.0));
        q.push(req(3, 1, 2.0));
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 7);
    }

    #[test]
    fn pending_cost_tracks_push_pop() {
        let mut q = EdfQueue::new();
        assert_eq!(q.pending_cost(), 0);
        q.push(req(0, 0, 1.0));
        q.push(req(1, 0, 2.0));
        let per = 80 / 8 + 40;
        assert_eq!(q.pending_cost(), 2 * per as u64);
        q.pop();
        assert_eq!(q.pending_cost(), per as u64);
        q.pop();
        assert_eq!(q.pending_cost(), 0);
        assert!(q.earliest_deadline_s().is_none());
    }

    #[test]
    fn admission_caps_and_counts() {
        let mut ac = AdmissionControl::new(2, 3);
        assert!(ac.try_admit(0, 0));
        assert!(ac.try_admit(1, 1));
        assert!(!ac.try_admit(2, 2));
        assert!(!ac.try_admit(5, 2));
        assert_eq!(ac.admitted, 2);
        assert_eq!(ac.rejected(), 2);
        assert_eq!(ac.rejected_by_class, vec![0, 0, 2]);
    }
}
