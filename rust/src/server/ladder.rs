//! Adaptive LExI quality ladder: precomputed Stage-2 allocations at
//! descending budgets, swapped onto replicas under queue pressure.
//!
//! The paper optimizes ONE static per-layer allocation for a fixed
//! budget. Serving load is not static — so the ladder extends Stage 2
//! into the time dimension: rung 0 is the pretrained baseline (full
//! budget, zero quality loss), deeper rungs are LExI allocations at 80 /
//! 65 / 50 % budgets, each the `exact_dp` optimum of the Stage-1
//! sensitivity table (deterministic, so every run and replica agrees on
//! the ladder).
//!
//! Rung decisions are made by ONE [`LadderController`] per cluster — a
//! pure function of the [`ClusterSnapshot`] telemetry layer. It runs in
//! two scopes:
//!
//! * [`LadderScope::PerReplica`] — each replica follows its own
//!   hysteretic rule (the original queue-depth controller, preserved
//!   bit-for-bit: degrade one rung past `degrade_above`, climb back
//!   below `upgrade_below`, dwell between switches).
//! * [`LadderScope::Cluster`] — the controller reads *aggregate*
//!   pressure and co-optimizes the assignment: at most
//!   `max_switches_per_instant` replicas move per event-loop instant,
//!   most-pressured replicas degrade first and least-pressured replicas
//!   recover first, so a cluster under a burst staggers down the ladder
//!   instead of flapping every replica simultaneously.
//!
//! Both scopes support two pressure signals
//! ([`PressureMode`], `--pressure queue|slack`):
//!
//! * `queue` — queue depth against the `degrade_above`/`upgrade_below`
//!   thresholds (the PR 2 rule, bit-identical).
//! * `slack` — normalized EDF slack of queued *interactive* requests:
//!   degrade when the worst queued interactive request has burned more
//!   than `1 - slack_degrade_frac` of its TTFT budget, recover when all
//!   queued interactive slack is above `slack_upgrade_frac`. Reacts to
//!   deadline collapse directly instead of waiting for mean depth to
//!   rise, so a flash crowd is met before the SLO is already lost.

use anyhow::{Context, Result};

use crate::config::model::ModelSpec;
use crate::config::server::{LadderScope, PressureMode, ServerConfig};
use crate::lexi::evolution::exact_dp;
use crate::lexi::SensitivityTable;
use crate::moe::allocation::{Allocation, Bounds};
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;

use super::replica::ServiceModel;
use super::telemetry::{ClusterSnapshot, ReplicaTelemetry};

/// One quality level: allocation + calibrated service model + the
/// Stage-1 proxy loss the allocation costs.
#[derive(Clone, Debug)]
pub struct Rung {
    pub label: String,
    pub allocation: Allocation,
    pub service: ServiceModel,
    /// Stage-1 proxy `phi(k) = sum_j D_j(k_j)`; 0 for the baseline.
    /// NaN marks a transform whose loss is NOT on the Stage-1 scale
    /// (e.g. expert pruning) — reports surface it as unknown, never 0.
    pub quality_loss: f64,
}

/// Rungs ordered best-quality-first (rung 0 = baseline).
#[derive(Clone, Debug)]
pub struct QualityLadder {
    pub rungs: Vec<Rung>,
}

impl QualityLadder {
    /// Build the ladder for a model: baseline rung + one LExI rung per
    /// budget fraction, allocations from `exact_dp` over the Stage-1
    /// table (measured when cached, synthetic depth profile otherwise).
    pub fn for_model(
        spec: &ModelSpec,
        table: &SensitivityTable,
        cfg: &ServerConfig,
        pm: &PerfModel,
    ) -> Result<Self> {
        let k_base = spec.top_k as u32;
        let slots = cfg.slots_per_replica;
        let baseline = Allocation::uniform(spec.n_layers, k_base);
        let mut rungs = vec![Rung {
            label: "base".to_string(),
            service: ServiceModel::from_perf(
                pm,
                &Transform::Baseline,
                slots,
                cfg.service_in_len,
                cfg.service_out_len,
                "base",
            ),
            allocation: baseline,
            quality_loss: 0.0,
        }];
        let bounds = Bounds::paper(k_base);
        let mut fracs = cfg.ladder_fracs.clone();
        fracs.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending budget
        for frac in fracs {
            let budget = ((spec.baseline_budget() as f64 * frac).round() as u32)
                .max(spec.n_layers as u32);
            let allocation = exact_dp(table, budget, bounds)
                .with_context(|| format!("budget {budget} infeasible for {}", spec.name))?;
            let label = format!("lexi-B{budget}");
            let t = Transform::Lexi {
                allocation: allocation.clone(),
            };
            rungs.push(Rung {
                service: ServiceModel::from_perf(
                    pm,
                    &t,
                    slots,
                    cfg.service_in_len,
                    cfg.service_out_len,
                    &label,
                ),
                quality_loss: table.fitness(&allocation.k),
                allocation,
                label,
            });
        }
        Ok(QualityLadder { rungs })
    }

    /// Single-rung ladder: a fixed transform, no adaptation.
    pub fn fixed(label: &str, allocation: Allocation, service: ServiceModel) -> Self {
        Self::fixed_with_loss(label, allocation, service, 0.0)
    }

    /// Single-rung ladder with an explicit Stage-1 proxy loss.
    pub fn fixed_with_loss(
        label: &str,
        allocation: Allocation,
        service: ServiceModel,
        quality_loss: f64,
    ) -> Self {
        QualityLadder {
            rungs: vec![Rung {
                label: label.to_string(),
                allocation,
                service,
                quality_loss,
            }],
        }
    }

    pub fn n_rungs(&self) -> usize {
        self.rungs.len()
    }

    pub fn service(&self, rung: usize) -> &ServiceModel {
        &self.rungs[rung.min(self.rungs.len() - 1)].service
    }

    /// Per-layer top-k vector of a rung, in the engine's `k_vec` format.
    pub fn k_vec(&self, rung: usize) -> Vec<i32> {
        self.rungs[rung.min(self.rungs.len() - 1)]
            .allocation
            .k
            .iter()
            .map(|&k| k as i32)
            .collect()
    }
}

/// Hysteretic rung policy (stateless decision rule + controller scope).
#[derive(Clone, Copy, Debug)]
pub struct LadderPolicy {
    /// Queue depth at which a replica degrades one rung.
    pub degrade_above: usize,
    /// Queue depth below which it climbs back toward rung 0.
    pub upgrade_below: usize,
    /// Minimum time between switches of one replica.
    pub min_dwell_s: f64,
    /// Per-replica rule vs. cluster-global co-optimization.
    pub scope: LadderScope,
    /// Cluster scope only: replicas allowed to switch per event-loop
    /// instant (the stagger knob).
    pub max_switches_per_instant: usize,
    /// Pressure signal: queue depth or interactive EDF slack.
    pub pressure: PressureMode,
    /// Slack mode: degrade when the worst queued interactive slack
    /// fraction falls below this.
    pub slack_degrade_frac: f64,
    /// Slack mode: recover when the worst queued interactive slack
    /// fraction rises above this (hysteresis band between the two).
    pub slack_upgrade_frac: f64,
}

impl Default for LadderPolicy {
    fn default() -> Self {
        LadderPolicy {
            degrade_above: 24,
            upgrade_below: 4,
            min_dwell_s: 0.5,
            scope: LadderScope::PerReplica,
            max_switches_per_instant: 1,
            pressure: PressureMode::Queue,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
        }
    }
}

impl LadderPolicy {
    pub fn from_config(cfg: &ServerConfig) -> Self {
        LadderPolicy {
            degrade_above: cfg.degrade_above,
            upgrade_below: cfg.upgrade_below,
            min_dwell_s: cfg.min_dwell_s,
            scope: cfg.ladder_scope,
            max_switches_per_instant: cfg.max_switches_per_instant,
            pressure: cfg.pressure,
            slack_degrade_frac: cfg.slack_degrade_frac,
            slack_upgrade_frac: cfg.slack_upgrade_frac,
        }
    }

    /// Next rung for a replica given its queue depth. One step at a
    /// time, hysteresis band between the thresholds, dwell time between
    /// switches.
    pub fn decide(
        &self,
        current: usize,
        n_rungs: usize,
        queue_len: usize,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if n_rungs <= 1 || now - last_switch_s < self.min_dwell_s {
            return current;
        }
        if queue_len > self.degrade_above && current + 1 < n_rungs {
            current + 1
        } else if queue_len < self.upgrade_below && current > 0 {
            current - 1
        } else {
            current
        }
    }

    /// Slack-mode twin of [`decide`](LadderPolicy::decide): `frac` is
    /// the replica's worst queued interactive slack fraction (+∞ when
    /// none is queued).
    pub fn decide_slack(
        &self,
        current: usize,
        n_rungs: usize,
        frac: f64,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if n_rungs <= 1 || now - last_switch_s < self.min_dwell_s {
            return current;
        }
        if frac < self.slack_degrade_frac && current + 1 < n_rungs {
            current + 1
        } else if frac > self.slack_upgrade_frac && current > 0 {
            current - 1
        } else {
            current
        }
    }
}

/// The cluster's single rung controller: a pure function from the
/// telemetry snapshot to target rungs each event-loop instant.
#[derive(Clone, Debug)]
pub struct LadderController {
    pub policy: LadderPolicy,
    /// Event-loop instant of the last cluster-scope decision.
    last_instant_s: f64,
    /// Switches already spent at that instant.
    switched_at_instant: usize,
    /// Latest health-engine burn reading ([`PressureMode::Burn`] only):
    /// a slack-like fraction (1 = no burn, 0 = critical burn), fed each
    /// control instant via [`set_burn_frac`](Self::set_burn_frac).
    /// `None` (no evidence yet) reads as +∞ slack — never degrades.
    burn_frac: Option<f64>,
}

impl LadderController {
    pub fn new(policy: LadderPolicy) -> Self {
        LadderController {
            policy,
            last_instant_s: f64::NEG_INFINITY,
            switched_at_instant: 0,
            burn_frac: None,
        }
    }

    /// Feed the health engine's burn reading
    /// ([`HealthEngine::burn_frac`](crate::obs::health::HealthEngine::burn_frac))
    /// ahead of a [`decide`](Self::decide) call under `--pressure burn`.
    pub fn set_burn_frac(&mut self, frac: Option<f64>) {
        self.burn_frac = frac;
    }

    /// Per-replica pressure reading for the configured signal: queued
    /// interactive slack fraction under `slack` (instantaneous) or
    /// `slack-ewma` (projected one queue-drain horizon forward via the
    /// step-time EWMA), +∞ when nothing interactive is queued.
    fn slack_frac_for(t: &ReplicaTelemetry, mode: PressureMode) -> f64 {
        match mode {
            PressureMode::SlackEwma => t
                .projected_interactive_slack_frac
                .unwrap_or(f64::INFINITY),
            _ => t.min_interactive_slack_frac.unwrap_or(f64::INFINITY),
        }
    }

    /// Target rung per replica. The cluster applies any change via
    /// [`ReplicaBackend::set_rung`](super::backend::ReplicaBackend::set_rung).
    pub fn decide(&mut self, snap: &ClusterSnapshot, n_rungs: usize) -> Vec<usize> {
        crate::prof_scope!("ladder.decide");
        let now = snap.now_s;
        match self.policy.scope {
            LadderScope::PerReplica => snap
                .replicas
                .iter()
                .map(|t| match self.policy.pressure {
                    PressureMode::Queue => self
                        .policy
                        .decide(t.rung, n_rungs, t.queue_len, now, t.last_switch_s),
                    PressureMode::Slack | PressureMode::SlackEwma => self.policy.decide_slack(
                        t.rung,
                        n_rungs,
                        Self::slack_frac_for(t, self.policy.pressure),
                        now,
                        t.last_switch_s,
                    ),
                    // burn is a cluster-wide signal; every replica reads
                    // the same fraction through the slack hysteresis
                    PressureMode::Burn => self.policy.decide_slack(
                        t.rung,
                        n_rungs,
                        self.burn_frac.unwrap_or(f64::INFINITY),
                        now,
                        t.last_switch_s,
                    ),
                })
                .collect(),
            LadderScope::Cluster => self.decide_cluster(snap, n_rungs),
        }
    }

    /// Cluster-global co-optimization: one pressure reading for the
    /// whole cluster, a bounded number of staggered moves per instant.
    fn decide_cluster(&mut self, snap: &ClusterSnapshot, n_rungs: usize) -> Vec<usize> {
        let views = &snap.replicas;
        let now = snap.now_s;
        let mut targets: Vec<usize> = views.iter().map(|v| v.rung).collect();
        if n_rungs <= 1 || views.is_empty() {
            return targets;
        }
        // the instant budget makes staggering robust to the event loop
        // revisiting the same timestamp (arrival and completion rounds)
        if now != self.last_instant_s {
            self.last_instant_s = now;
            self.switched_at_instant = 0;
        }
        let mut budget = self
            .policy
            .max_switches_per_instant
            .saturating_sub(self.switched_at_instant);
        if budget == 0 {
            return targets;
        }
        // aggregate pressure + the stagger order for each direction
        let (overloaded, drained) = match self.policy.pressure {
            PressureMode::Queue => {
                let total_q: usize = views.iter().map(|v| v.queue_len).sum();
                let mean_q = total_q as f64 / views.len() as f64;
                (
                    mean_q > self.policy.degrade_above as f64,
                    mean_q < self.policy.upgrade_below as f64,
                )
            }
            PressureMode::Slack | PressureMode::SlackEwma => {
                let worst = match self.policy.pressure {
                    PressureMode::SlackEwma => snap.min_projected_interactive_slack_frac(),
                    _ => snap.min_interactive_slack_frac(),
                };
                (
                    worst < self.policy.slack_degrade_frac,
                    worst > self.policy.slack_upgrade_frac,
                )
            }
            PressureMode::Burn => {
                let f = self.burn_frac.unwrap_or(f64::INFINITY);
                (
                    f < self.policy.slack_degrade_frac,
                    f > self.policy.slack_upgrade_frac,
                )
            }
        };
        let mode = self.policy.pressure;
        let mut order: Vec<usize> = (0..views.len()).collect();
        if overloaded {
            // overload: spread degradation — highest-quality replicas
            // first, most-pressured breaking ties
            match mode {
                // burn has no per-replica reading: stagger by queue
                PressureMode::Queue | PressureMode::Burn => order.sort_by_key(|&i| {
                    (views[i].rung, std::cmp::Reverse(views[i].queue_len), i)
                }),
                PressureMode::Slack | PressureMode::SlackEwma => order.sort_by(|&a, &b| {
                    views[a]
                        .rung
                        .cmp(&views[b].rung)
                        .then(
                            Self::slack_frac_for(&views[a], mode)
                                .total_cmp(&Self::slack_frac_for(&views[b], mode)),
                        )
                        .then(a.cmp(&b))
                }),
            }
            for i in order {
                if budget == 0 {
                    break;
                }
                let v = &views[i];
                if now - v.last_switch_s < self.policy.min_dwell_s {
                    continue;
                }
                if v.rung + 1 < n_rungs {
                    targets[i] = v.rung + 1;
                    budget -= 1;
                    self.switched_at_instant += 1;
                }
            }
        } else if drained {
            // drained: most-degraded replicas recover first,
            // least-pressured breaking ties
            match mode {
                PressureMode::Queue | PressureMode::Burn => order.sort_by_key(|&i| {
                    (std::cmp::Reverse(views[i].rung), views[i].queue_len, i)
                }),
                PressureMode::Slack | PressureMode::SlackEwma => order.sort_by(|&a, &b| {
                    views[b]
                        .rung
                        .cmp(&views[a].rung)
                        .then(
                            Self::slack_frac_for(&views[b], mode)
                                .total_cmp(&Self::slack_frac_for(&views[a], mode)),
                        )
                        .then(a.cmp(&b))
                }),
            }
            for i in order {
                if budget == 0 {
                    break;
                }
                let v = &views[i];
                if now - v.last_switch_s < self.policy.min_dwell_s {
                    continue;
                }
                if v.rung > 0 {
                    targets[i] = v.rung - 1;
                    budget -= 1;
                    self.switched_at_instant += 1;
                }
            }
        }
        targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;

    fn ladder() -> QualityLadder {
        let m = spec("olmoe-1b-7b").unwrap();
        let table = SensitivityTable::synthetic(m.name, m.n_layers, m.top_k as u32, |x| 0.8 + 2.4 * x, 0);
        let cfg = ServerConfig {
            slots_per_replica: 4,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let pm = PerfModel::new(m.clone(), 0);
        QualityLadder::for_model(&m, &table, &cfg, &pm).unwrap()
    }

    #[test]
    fn rungs_trade_quality_for_speed() {
        let l = ladder();
        assert_eq!(l.n_rungs(), 4); // base + 0.8 + 0.65 + 0.5
        for w in l.rungs.windows(2) {
            // monotone: each deeper rung loses quality...
            assert!(
                w[1].quality_loss > w[0].quality_loss - 1e-12,
                "{} -> {}",
                w[0].label,
                w[1].label
            );
            // ...and buys decode speed (smaller budget, faster steps)
            assert!(
                w[1].service.step_time(4) < w[0].service.step_time(4) * 1.001,
                "{} not faster than {}",
                w[1].label,
                w[0].label
            );
            assert!(w[1].allocation.budget() < w[0].allocation.budget());
        }
        assert_eq!(l.rungs[0].quality_loss, 0.0);
        // k_vec export matches the allocation
        let kv = l.k_vec(0);
        assert_eq!(kv.len(), 16);
        assert!(kv.iter().all(|&k| k == 8));
    }

    #[test]
    fn ladder_is_deterministic() {
        let a = ladder();
        let b = ladder();
        for (x, y) in a.rungs.iter().zip(&b.rungs) {
            assert_eq!(x.allocation, y.allocation);
            assert_eq!(x.quality_loss, y.quality_loss);
        }
    }

    #[test]
    fn policy_hysteresis_and_dwell() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 1.0,
            ..Default::default()
        };
        // pressure -> degrade one step
        assert_eq!(p.decide(0, 4, 11, 5.0, 0.0), 1);
        // inside the band -> hold
        assert_eq!(p.decide(1, 4, 5, 5.0, 0.0), 1);
        // drained -> climb back
        assert_eq!(p.decide(1, 4, 1, 5.0, 0.0), 0);
        // dwell not elapsed -> hold even under pressure
        assert_eq!(p.decide(0, 4, 100, 0.5, 0.0), 0);
        // clamped at the deepest rung
        assert_eq!(p.decide(3, 4, 100, 5.0, 0.0), 3);
        // single-rung ladders never switch
        assert_eq!(p.decide(0, 1, 100, 5.0, 0.0), 0);
    }

    fn view(replica: usize, rung: usize, queue_len: usize) -> ReplicaTelemetry {
        let mut t = ReplicaTelemetry::idle(replica);
        t.rung = rung;
        t.queue_len = queue_len;
        t
    }

    fn snap(now_s: f64, views: Vec<ReplicaTelemetry>) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s,
            replicas: views,
        }
    }

    #[test]
    fn per_replica_scope_reproduces_local_rule() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            max_switches_per_instant: 1,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // per-replica ignores the stagger budget: both degrade at once
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 20), view(1, 0, 20)]), 4);
        assert_eq!(t, vec![1, 1]);
    }

    #[test]
    fn cluster_scope_staggers_and_prioritizes_pressure() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // overload everywhere: only the deepest queue degrades now
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 15), view(1, 0, 40)]), 4);
        assert_eq!(t, vec![0, 1]);
        // same instant again: budget spent, nobody else moves
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 15), view(1, 1, 40)]), 4);
        assert_eq!(t, vec![0, 1]);
        // next instant: the other replica takes its step
        let t = ctl.decide(&snap(2.0, vec![view(0, 0, 15), view(1, 1, 40)]), 4);
        assert_eq!(t, vec![1, 1]);
        // drained cluster recovers shallowest-first, one per instant
        let t = ctl.decide(&snap(3.0, vec![view(0, 2, 0), view(1, 2, 1)]), 4);
        assert_eq!(t, vec![1, 2]);
    }

    #[test]
    fn cluster_scope_holds_in_the_hysteresis_band() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 8,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let t = ctl.decide(&snap(1.0, vec![view(0, 1, 5), view(1, 1, 6)]), 4);
        assert_eq!(t, vec![1, 1]);
    }

    fn slack_view(replica: usize, rung: usize, frac: Option<f64>) -> ReplicaTelemetry {
        let mut t = ReplicaTelemetry::idle(replica);
        t.rung = rung;
        t.min_interactive_slack_frac = frac;
        t
    }

    #[test]
    fn slack_pressure_degrades_on_deadline_collapse_not_depth() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::Slack,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            // queue thresholds irrelevant under slack pressure
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // replica 0: slack collapsed -> degrade; replica 1: plenty of
        // slack -> hold; replica 2: nothing interactive queued -> it
        // may recover (but is already at rung 0)
        let t = ctl.decide(
            &snap(
                1.0,
                vec![
                    slack_view(0, 0, Some(0.1)),
                    slack_view(1, 0, Some(0.5)),
                    slack_view(2, 0, None),
                ],
            ),
            4,
        );
        assert_eq!(t, vec![1, 0, 0]);
        // degraded replica recovers once slack is restored
        let t = ctl.decide(&snap(2.0, vec![slack_view(0, 2, Some(0.9))]), 4);
        assert_eq!(t, vec![1]);
        // inside the hysteresis band: hold
        let t = ctl.decide(&snap(3.0, vec![slack_view(0, 2, Some(0.5))]), 4);
        assert_eq!(t, vec![2]);
    }

    #[test]
    fn slack_ewma_degrades_on_projected_collapse_before_instantaneous() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::SlackEwma,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        // instantaneous slack healthy (0.5) but the EWMA projection says
        // the backlog will burn it to 0.1 before service starts
        let mut t = ReplicaTelemetry::idle(0);
        t.min_interactive_slack_frac = Some(0.5);
        t.projected_interactive_slack_frac = Some(0.1);

        let mut predictive = LadderController::new(p);
        assert_eq!(predictive.decide(&snap(1.0, vec![t.clone()]), 4), vec![1]);
        // the instantaneous controller holds on the same telemetry
        let mut inst = LadderController::new(LadderPolicy {
            pressure: PressureMode::Slack,
            ..p
        });
        assert_eq!(inst.decide(&snap(1.0, vec![t.clone()]), 4), vec![0]);

        // cluster scope consumes the projected aggregate the same way
        let mut cluster = LadderController::new(LadderPolicy {
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..p
        });
        assert_eq!(cluster.decide(&snap(2.0, vec![t]), 4), vec![1]);
    }

    #[test]
    fn burn_pressure_degrades_on_budget_burn_and_holds_without_evidence() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::Burn,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // no burn evidence yet: +∞ reading, a degraded replica recovers
        let t = ctl.decide(&snap(1.0, vec![view(0, 2, 0)]), 4);
        assert_eq!(t, vec![1]);
        // burn beyond critical (negative fraction): degrade
        ctl.set_burn_frac(Some(-0.5));
        let t = ctl.decide(&snap(2.0, vec![view(0, 0, 0)]), 4);
        assert_eq!(t, vec![1]);
        // healthy burn: climb back
        ctl.set_burn_frac(Some(0.9));
        let t = ctl.decide(&snap(3.0, vec![view(0, 2, 0)]), 4);
        assert_eq!(t, vec![1]);
        // cluster scope consumes the same reading, staggered
        let mut cluster = LadderController::new(LadderPolicy {
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..p
        });
        cluster.set_burn_frac(Some(0.1));
        let t = cluster.decide(&snap(4.0, vec![view(0, 0, 3), view(1, 0, 9)]), 4);
        assert_eq!(t, vec![0, 1]);
    }

    #[test]
    fn cluster_slack_scope_staggers_worst_slack_first() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            pressure: PressureMode::Slack,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // aggregate slack collapsed: the worst-slack replica degrades
        // first, one move per instant
        let t = ctl.decide(
            &snap(1.0, vec![slack_view(0, 0, Some(0.2)), slack_view(1, 0, Some(0.05))]),
            4,
        );
        assert_eq!(t, vec![0, 1]);
        // fully recovered cluster climbs back, most-degraded first
        let t = ctl.decide(
            &snap(2.0, vec![slack_view(0, 1, None), slack_view(1, 2, None)]),
            4,
        );
        assert_eq!(t, vec![1, 1]);
    }
}
