//! Adaptive LExI quality lattice: precomputed Stage-2 quality points,
//! swapped onto replicas under pressure.
//!
//! The paper optimizes ONE static per-layer allocation for a fixed
//! budget. Serving load is not static — so the ladder extends Stage 2
//! into the time dimension. Historically a rung was an index into a
//! `Vec` of budgets (100 / 80 / 65 / 50 %); it is now a typed point in a
//! 2-D **quality lattice**:
//!
//! * **k axis** — the per-layer active-expert budget fraction, each
//!   point the `exact_dp` optimum of the Stage-1 sensitivity table
//!   (deterministic, so every run and replica agrees on the lattice).
//! * **s axis** (optional, `--ladder-axes k-intra|k-skip`) — intra-expert
//!   structured sparsity (MoE-I²-style FFN-dim pruning) or NAEE-style
//!   dynamic top-2 gate skipping, layered on top of each k-axis
//!   allocation. Points are priced through [`Transform::LexiPlusIntra`]
//!   / [`Transform::LexiPlusSkip`] so each has an honest latency model,
//!   and their quality loss is the Stage-1 proxy at the fractional
//!   effective k (see [`SensitivityTable::fitness_fractional`]).
//!
//! Points are addressed two ways: a typed [`PointId`] `(k, s)` and the
//! canonical **linear index** `idx = s * k_dim + k` — the wire format
//! used by telemetry, traces, and `rung_time_s`. A 1-D lattice
//! (`--ladder-axes k`, the default) has `s_dim == 1`, so linear indices
//! coincide with the historical rung indices and every default artifact
//! stays byte-identical.
//!
//! The **legal-move graph** restricts controller moves to lattice
//! neighbors: one step along one axis. Rung decisions are made by ONE
//! [`LadderController`] per cluster — a pure function of the
//! [`ClusterSnapshot`] telemetry layer. Under pressure it degrades to
//! the neighbor with the best *marginal latency per quality* (decode
//! step time saved per Stage-1 loss added); when drained it recovers
//! along the neighbor with the best quality recovered per latency paid.
//! On a 1-D lattice both neighbor sets are singletons, so the decision
//! reduces bit-identically to the historical ±1 rung walk. It runs in
//! two scopes:
//!
//! * [`LadderScope::PerReplica`] — each replica follows its own
//!   hysteretic rule (the original queue-depth controller: degrade one
//!   step past `degrade_above`, climb back below `upgrade_below`, dwell
//!   between switches).
//! * [`LadderScope::Cluster`] — the controller reads *aggregate*
//!   pressure and co-optimizes the assignment: at most
//!   `max_switches_per_instant` replicas move per event-loop instant,
//!   most-pressured replicas degrade first and least-pressured replicas
//!   recover first (ordered by lattice depth `k + s`), so a cluster
//!   under a burst staggers down the lattice instead of flapping every
//!   replica simultaneously.
//!
//! Both scopes support the same pressure signals
//! ([`PressureMode`], `--pressure queue|slack|slack-ewma|burn`):
//! queue depth against the `degrade_above`/`upgrade_below` thresholds,
//! normalized EDF slack of queued *interactive* requests (instantaneous
//! or EWMA-projected), or the health engine's SLO burn fraction.

use anyhow::{Context, Result};

use crate::config::model::ModelSpec;
use crate::config::server::{
    validate_axis_levels, validate_ladder_fracs, LadderAxes, LadderScope, PressureMode,
    ServerConfig,
};
use crate::lexi::evolution::exact_dp;
use crate::lexi::SensitivityTable;
use crate::moe::allocation::{Allocation, Bounds};
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;
use crate::pruning::dynamic_skip;

use super::replica::ServiceModel;
use super::telemetry::{ClusterSnapshot, ReplicaTelemetry};

/// Typed coordinate of a quality point: `k` steps along the
/// active-expert budget axis (0 = full budget), `s` steps along the
/// intra-expert sparsity / dynamic-skip axis (0 = dense, no skipping).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct PointId {
    pub k: usize,
    pub s: usize,
}

impl PointId {
    /// Manhattan distance from the full-quality corner — the scalar
    /// "how degraded" measure the cluster scope staggers by. On a 1-D
    /// lattice `depth == k == linear index`, matching the historical
    /// rung ordering exactly.
    pub fn depth(&self) -> usize {
        self.k + self.s
    }
}

/// One quality point: allocation + axis knobs + calibrated service
/// model + the Stage-1 proxy loss the configuration costs.
#[derive(Clone, Debug)]
pub struct QualityPoint {
    pub label: String,
    pub allocation: Allocation,
    /// Intra-expert FFN-dim prune fraction in [0, 1); 0 = dense experts.
    pub intra_frac: f64,
    /// Dynamic top-2 skip gate threshold; 0 = skipping off.
    pub skip_threshold: f64,
    pub service: ServiceModel,
    /// Stage-1 proxy `phi(k) = sum_j D_j(k_j)` (fractional-k
    /// interpolated for points off the dense k axis); 0 for the
    /// baseline. NaN marks a transform whose loss is NOT on the Stage-1
    /// scale (e.g. expert pruning) — reports surface it as unknown
    /// (`null` in JSON), never 0.
    pub quality_loss: f64,
}

impl QualityPoint {
    /// A pure k-axis point: dense experts, no skipping. The constructor
    /// every historical `Rung { .. }` literal maps onto.
    pub fn k_only(
        label: &str,
        allocation: Allocation,
        service: ServiceModel,
        quality_loss: f64,
    ) -> Self {
        QualityPoint {
            label: label.to_string(),
            allocation,
            intra_frac: 0.0,
            skip_threshold: 0.0,
            service,
            quality_loss,
        }
    }
}

/// Historical name for a lattice point.
pub type Rung = QualityPoint;

/// The quality surface: `k_dim × s_dim` points in row-major order
/// (`idx = s * k_dim + k`), best quality first on each axis, plus the
/// legal-move graph (neighbors differ by one step on one axis).
///
/// Constructed once per run and shared (`Rc`) across replicas; the
/// accessors are total over `0..n_points()` and return `None` beyond —
/// callers `expect` so a controller emitting an out-of-lattice index
/// fails loudly instead of silently serving the deepest point.
#[derive(Clone, Debug)]
pub struct QualityLattice {
    k_dim: usize,
    s_dim: usize,
    points: Vec<QualityPoint>,
}

/// Historical name: a 1-D lattice is exactly the old quality ladder.
pub type QualityLadder = QualityLattice;

impl QualityLattice {
    /// Build the lattice for a model. The k axis is the historical
    /// ladder — baseline point + one LExI point per budget fraction,
    /// allocations from `exact_dp` over the Stage-1 table. With
    /// `--ladder-axes k-intra|k-skip`, each additional s level replays
    /// the whole k axis through [`Transform::LexiPlusIntra`] /
    /// [`Transform::LexiPlusSkip`] so every point carries its own
    /// priced service model and a Stage-1-comparable quality loss.
    pub fn for_model(
        spec: &ModelSpec,
        table: &SensitivityTable,
        cfg: &ServerConfig,
        pm: &PerfModel,
    ) -> Result<Self> {
        // re-validated here so programmatic configs fail as loudly as
        // parsed ones (a NaN frac used to panic inside the sort below)
        validate_ladder_fracs(&cfg.ladder_fracs)?;
        let k_base = spec.top_k as u32;
        let slots = cfg.slots_per_replica;
        let baseline = Allocation::uniform(spec.n_layers, k_base);
        let mut points = vec![QualityPoint::k_only(
            "base",
            baseline,
            ServiceModel::from_perf(
                pm,
                &Transform::Baseline,
                slots,
                cfg.service_in_len,
                cfg.service_out_len,
                "base",
            ),
            0.0,
        )];
        let bounds = Bounds::paper(k_base);
        let mut fracs = cfg.ladder_fracs.clone();
        fracs.sort_by(|a, b| b.total_cmp(a)); // descending budget
        for frac in fracs {
            let budget = ((spec.baseline_budget() as f64 * frac).round() as u32)
                .max(spec.n_layers as u32);
            let allocation = exact_dp(table, budget, bounds)
                .with_context(|| format!("budget {budget} infeasible for {}", spec.name))?;
            let label = format!("lexi-B{budget}");
            let t = Transform::Lexi {
                allocation: allocation.clone(),
            };
            let service = ServiceModel::from_perf(
                pm,
                &t,
                slots,
                cfg.service_in_len,
                cfg.service_out_len,
                &label,
            );
            let quality_loss = table.fitness(&allocation.k);
            points.push(QualityPoint::k_only(&label, allocation, service, quality_loss));
        }
        let k_dim = points.len();

        // ---- s axis: replay the k axis at each sparsity level ----
        let s_levels: Vec<f64> = match cfg.ladder_axes {
            LadderAxes::K => Vec::new(),
            LadderAxes::KIntra => {
                validate_axis_levels(&cfg.intra_fracs, LadderAxes::KIntra)?;
                let mut v = cfg.intra_fracs.clone();
                v.sort_by(f64::total_cmp); // mild -> aggressive as s grows
                v.dedup();
                v
            }
            LadderAxes::KSkip => {
                dynamic_skip::check_applicable(spec.top_k).with_context(|| {
                    format!(
                        "--ladder-axes k-skip needs a top-2 router; {} routes top-{}",
                        spec.name, spec.top_k
                    )
                })?;
                validate_axis_levels(&cfg.skip_thresholds, LadderAxes::KSkip)?;
                let mut v = cfg.skip_thresholds.clone();
                v.sort_by(f64::total_cmp);
                v.dedup();
                v
            }
        };
        let row0: Vec<(String, Allocation)> = points
            .iter()
            .map(|p| (p.label.clone(), p.allocation.clone()))
            .collect();
        for &level in &s_levels {
            for (base_label, allocation) in &row0 {
                let (t, label, intra_frac, skip_threshold) = match cfg.ladder_axes {
                    LadderAxes::KIntra => (
                        Transform::LexiPlusIntra {
                            allocation: allocation.clone(),
                            frac: level,
                        },
                        format!("{base_label}+intra{:.0}", level * 100.0),
                        level,
                        0.0,
                    ),
                    LadderAxes::KSkip => (
                        Transform::LexiPlusSkip {
                            allocation: allocation.clone(),
                            threshold: level,
                        },
                        format!("{base_label}+skip{level:.2}"),
                        0.0,
                        level,
                    ),
                    LadderAxes::K => unreachable!("no s levels on a 1-D lattice"),
                };
                let service = ServiceModel::from_perf(
                    pm,
                    &t,
                    slots,
                    cfg.service_in_len,
                    cfg.service_out_len,
                    &label,
                );
                let k_eff =
                    effective_k(allocation, cfg.ladder_axes, level, k_base, pm);
                let quality_loss = table.fitness_fractional(&k_eff);
                points.push(QualityPoint {
                    label,
                    allocation: allocation.clone(),
                    intra_frac,
                    skip_threshold,
                    service,
                    quality_loss,
                });
            }
        }
        Ok(QualityLattice {
            k_dim,
            s_dim: 1 + s_levels.len(),
            points,
        })
    }

    /// Single-point lattice: a fixed transform, no adaptation.
    pub fn fixed(label: &str, allocation: Allocation, service: ServiceModel) -> Self {
        Self::fixed_with_loss(label, allocation, service, 0.0)
    }

    /// Single-point lattice with an explicit Stage-1 proxy loss.
    pub fn fixed_with_loss(
        label: &str,
        allocation: Allocation,
        service: ServiceModel,
        quality_loss: f64,
    ) -> Self {
        Self::from_points_1d(vec![QualityPoint::k_only(
            label,
            allocation,
            service,
            quality_loss,
        )])
    }

    /// 1-D lattice over explicit points (k axis only) — the historical
    /// `QualityLadder { rungs }` literal.
    pub fn from_points_1d(points: Vec<QualityPoint>) -> Self {
        assert!(!points.is_empty(), "a lattice needs at least one point");
        QualityLattice {
            k_dim: points.len(),
            s_dim: 1,
            points,
        }
    }

    /// Lattice over an explicit row-major grid (`points.len()` must be a
    /// multiple of `k_dim`). Test/bench constructor.
    pub fn from_grid(k_dim: usize, points: Vec<QualityPoint>) -> Self {
        assert!(k_dim > 0 && !points.is_empty(), "empty lattice");
        assert_eq!(
            points.len() % k_dim,
            0,
            "grid of {} points is not a multiple of k_dim {k_dim}",
            points.len()
        );
        let s_dim = points.len() / k_dim;
        QualityLattice {
            k_dim,
            s_dim,
            points,
        }
    }

    pub fn n_points(&self) -> usize {
        self.points.len()
    }

    /// Historical alias for [`n_points`](Self::n_points).
    pub fn n_rungs(&self) -> usize {
        self.n_points()
    }

    /// Points along the budget axis (s = 0 row length).
    pub fn k_dim(&self) -> usize {
        self.k_dim
    }

    /// Levels along the sparsity axis (1 = the historical 1-D ladder).
    pub fn s_dim(&self) -> usize {
        self.s_dim
    }

    /// All points in canonical (row-major) linear order.
    pub fn points(&self) -> &[QualityPoint] {
        &self.points
    }

    /// Mutable points view — calibration refits service models in
    /// place; the grid shape itself is immutable.
    pub fn points_mut(&mut self) -> &mut [QualityPoint] {
        &mut self.points
    }

    pub fn point(&self, idx: usize) -> Option<&QualityPoint> {
        self.points.get(idx)
    }

    /// Service model of a point, `None` when `idx` is off the lattice
    /// (the historical accessor clamped to the deepest rung, hiding
    /// controller bugs).
    pub fn service(&self, idx: usize) -> Option<&ServiceModel> {
        self.points.get(idx).map(|p| &p.service)
    }

    /// Per-layer top-k vector of a point in the engine's `k_vec`
    /// format, `None` when `idx` is off the lattice.
    pub fn k_vec(&self, idx: usize) -> Option<Vec<i32>> {
        self.points
            .get(idx)
            .map(|p| p.allocation.k.iter().map(|&k| k as i32).collect())
    }

    /// Typed coordinate of a linear index.
    pub fn point_id(&self, idx: usize) -> Option<PointId> {
        (idx < self.points.len()).then(|| PointId {
            k: idx % self.k_dim,
            s: idx / self.k_dim,
        })
    }

    /// Linear index of a typed coordinate.
    pub fn index_of(&self, id: PointId) -> Option<usize> {
        (id.k < self.k_dim && id.s < self.s_dim).then(|| id.s * self.k_dim + id.k)
    }

    /// Lattice depth (`k + s`) of a linear index; out-of-lattice
    /// indices fall back to the index itself so orderings stay total.
    pub fn depth_of(&self, idx: usize) -> usize {
        self.point_id(idx).map_or(idx, |p| p.depth())
    }

    /// Legal quality-reducing moves from `idx`: one step deeper along
    /// exactly one axis, k axis first. Empty at the worst corner.
    pub fn degrade_neighbors(&self, idx: usize) -> Vec<usize> {
        let Some(id) = self.point_id(idx) else {
            return Vec::new();
        };
        let mut v = Vec::with_capacity(2);
        if id.k + 1 < self.k_dim {
            v.push(idx + 1);
        }
        if id.s + 1 < self.s_dim {
            v.push(idx + self.k_dim);
        }
        v
    }

    /// Legal quality-recovering moves from `idx`: one step shallower
    /// along exactly one axis, k axis first. Empty at full quality.
    pub fn upgrade_neighbors(&self, idx: usize) -> Vec<usize> {
        let Some(id) = self.point_id(idx) else {
            return Vec::new();
        };
        let mut v = Vec::with_capacity(2);
        if id.k > 0 {
            v.push(idx - 1);
        }
        if id.s > 0 {
            v.push(idx - self.k_dim);
        }
        v
    }

    /// The full legal-move neighborhood of `idx` (both directions).
    pub fn neighbors(&self, idx: usize) -> Vec<usize> {
        let mut v = self.upgrade_neighbors(idx);
        v.extend(self.degrade_neighbors(idx));
        v
    }
}

/// Per-layer effective active experts of an s-axis point — the
/// fractional k whose interpolated Stage-1 loss prices the point's
/// quality. Intra pruning scales each layer's expert capacity by
/// `1 - frac`; dynamic skipping sheds the per-layer skip probability
/// from layers with top-2 headroom (matching the perf-model pricing's
/// skip distribution exactly, same Monte-Carlo seed).
pub(crate) fn effective_k(
    allocation: &Allocation,
    axes: LadderAxes,
    level: f64,
    k_base: u32,
    pm: &PerfModel,
) -> Vec<f64> {
    allocation
        .k
        .iter()
        .enumerate()
        .map(|(j, &k)| match axes {
            LadderAxes::KIntra => (k as f64 * (1.0 - level)).clamp(1.0, k_base as f64),
            LadderAxes::KSkip if k >= 2 => {
                let p = pm.routing.skip_probability(j, level, 256, pm.seed + j as u64);
                (k as f64 - p).max(1.0)
            }
            _ => k as f64,
        })
        .collect()
}

/// Hysteretic rung policy (stateless decision rule + controller scope).
#[derive(Clone, Copy, Debug)]
pub struct LadderPolicy {
    /// Queue depth at which a replica degrades one step.
    pub degrade_above: usize,
    /// Queue depth below which it climbs back toward full quality.
    pub upgrade_below: usize,
    /// Minimum time between switches of one replica.
    pub min_dwell_s: f64,
    /// Per-replica rule vs. cluster-global co-optimization.
    pub scope: LadderScope,
    /// Cluster scope only: replicas allowed to switch per event-loop
    /// instant (the stagger knob).
    pub max_switches_per_instant: usize,
    /// Pressure signal: queue depth or interactive EDF slack.
    pub pressure: PressureMode,
    /// Slack mode: degrade when the worst queued interactive slack
    /// fraction falls below this.
    pub slack_degrade_frac: f64,
    /// Slack mode: recover when the worst queued interactive slack
    /// fraction rises above this (hysteresis band between the two).
    pub slack_upgrade_frac: f64,
}

impl Default for LadderPolicy {
    fn default() -> Self {
        LadderPolicy {
            degrade_above: 24,
            upgrade_below: 4,
            min_dwell_s: 0.5,
            scope: LadderScope::PerReplica,
            max_switches_per_instant: 1,
            pressure: PressureMode::Queue,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
        }
    }
}

impl LadderPolicy {
    pub fn from_config(cfg: &ServerConfig) -> Self {
        LadderPolicy {
            degrade_above: cfg.degrade_above,
            upgrade_below: cfg.upgrade_below,
            min_dwell_s: cfg.min_dwell_s,
            scope: cfg.ladder_scope,
            max_switches_per_instant: cfg.max_switches_per_instant,
            pressure: cfg.pressure,
            slack_degrade_frac: cfg.slack_degrade_frac,
            slack_upgrade_frac: cfg.slack_upgrade_frac,
        }
    }

    /// The historical 1-D rule: next rung for a replica given its queue
    /// depth. One step at a time, hysteresis band between the
    /// thresholds, dwell time between switches. Kept as the parity
    /// reference the lattice controller must reproduce on 1-D lattices.
    pub fn decide(
        &self,
        current: usize,
        n_rungs: usize,
        queue_len: usize,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if n_rungs <= 1 || now - last_switch_s < self.min_dwell_s {
            return current;
        }
        if queue_len > self.degrade_above && current + 1 < n_rungs {
            current + 1
        } else if queue_len < self.upgrade_below && current > 0 {
            current - 1
        } else {
            current
        }
    }

    /// Slack-mode twin of [`decide`](LadderPolicy::decide): `frac` is
    /// the replica's worst queued interactive slack fraction (+∞ when
    /// none is queued).
    pub fn decide_slack(
        &self,
        current: usize,
        n_rungs: usize,
        frac: f64,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if n_rungs <= 1 || now - last_switch_s < self.min_dwell_s {
            return current;
        }
        if frac < self.slack_degrade_frac && current + 1 < n_rungs {
            current + 1
        } else if frac > self.slack_upgrade_frac && current > 0 {
            current - 1
        } else {
            current
        }
    }
}

/// The cluster's single quality controller: a pure function from the
/// telemetry snapshot to a target lattice point per replica each
/// event-loop instant. Moves follow the lattice's legal-move graph; on
/// a 1-D lattice every decision is bit-identical to the historical
/// [`LadderPolicy`] walk.
#[derive(Clone, Debug)]
pub struct LadderController {
    pub policy: LadderPolicy,
    /// Event-loop instant of the last cluster-scope decision.
    last_instant_s: f64,
    /// Switches already spent at that instant.
    switched_at_instant: usize,
    /// Latest health-engine burn reading ([`PressureMode::Burn`] only):
    /// a slack-like fraction (1 = no burn, 0 = critical burn), fed each
    /// control instant via [`set_burn_frac`](Self::set_burn_frac).
    /// `None` (no evidence yet) reads as +∞ slack — never degrades.
    burn_frac: Option<f64>,
}

impl LadderController {
    pub fn new(policy: LadderPolicy) -> Self {
        LadderController {
            policy,
            last_instant_s: f64::NEG_INFINITY,
            switched_at_instant: 0,
            burn_frac: None,
        }
    }

    /// Feed the health engine's burn reading
    /// ([`HealthEngine::burn_frac`](crate::obs::health::HealthEngine::burn_frac))
    /// ahead of a [`decide`](Self::decide) call under `--pressure burn`.
    pub fn set_burn_frac(&mut self, frac: Option<f64>) {
        self.burn_frac = frac;
    }

    /// Per-replica pressure reading for the configured signal: queued
    /// interactive slack fraction under `slack` (instantaneous) or
    /// `slack-ewma` (projected one queue-drain horizon forward via the
    /// step-time EWMA), +∞ when nothing interactive is queued.
    fn slack_frac_for(t: &ReplicaTelemetry, mode: PressureMode) -> f64 {
        match mode {
            PressureMode::SlackEwma => t
                .projected_interactive_slack_frac
                .unwrap_or(f64::INFINITY),
            _ => t.min_interactive_slack_frac.unwrap_or(f64::INFINITY),
        }
    }

    /// Best quality-reducing neighbor of `current`: the legal move with
    /// the most decode-step time saved per unit of Stage-1 loss added
    /// (free moves rank +∞; unknown-scale losses fall back to raw speed
    /// gain). Ties keep the k axis. `None` at the worst corner.
    fn best_degrade(lattice: &QualityLattice, current: usize) -> Option<usize> {
        let cur = lattice.point(current)?;
        let t_cur = cur.service.step_time(cur.service.slots());
        let mut best: Option<(usize, f64)> = None;
        for n in lattice.degrade_neighbors(current) {
            let p = lattice.point(n)?;
            let gain = t_cur - p.service.step_time(p.service.slots());
            let dloss = p.quality_loss - cur.quality_loss;
            let score = if !dloss.is_finite() {
                gain
            } else if dloss <= 0.0 {
                if gain > 0.0 {
                    f64::INFINITY
                } else {
                    gain
                }
            } else {
                gain / dloss
            };
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((n, score));
            }
        }
        best.map(|(n, _)| n)
    }

    /// Best quality-recovering neighbor of `current`: the legal move
    /// with the most Stage-1 loss recovered per decode-step time paid
    /// (free recoveries rank +∞). Ties keep the k axis. `None` at full
    /// quality.
    fn best_upgrade(lattice: &QualityLattice, current: usize) -> Option<usize> {
        let cur = lattice.point(current)?;
        let t_cur = cur.service.step_time(cur.service.slots());
        let mut best: Option<(usize, f64)> = None;
        for n in lattice.upgrade_neighbors(current) {
            let p = lattice.point(n)?;
            let recovered = cur.quality_loss - p.quality_loss;
            let paid = p.service.step_time(p.service.slots()) - t_cur;
            let score = if !recovered.is_finite() {
                -paid
            } else if paid <= 0.0 {
                f64::INFINITY
            } else {
                recovered / paid
            };
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((n, score));
            }
        }
        best.map(|(n, _)| n)
    }

    /// One hysteretic lattice step for a single replica: degrade to the
    /// best marginal neighbor under pressure, recover along the best
    /// marginal neighbor when drained, hold in the band / during dwell.
    /// With singleton neighbor sets (1-D lattice) this is exactly
    /// [`LadderPolicy::decide`] / [`decide_slack`](LadderPolicy::decide_slack).
    fn step_point(
        &self,
        lattice: &QualityLattice,
        current: usize,
        degrade: bool,
        upgrade: bool,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if lattice.n_points() <= 1 || now - last_switch_s < self.policy.min_dwell_s {
            return current;
        }
        if degrade {
            if let Some(n) = Self::best_degrade(lattice, current) {
                return n;
            }
        }
        if upgrade {
            if let Some(n) = Self::best_upgrade(lattice, current) {
                return n;
            }
        }
        current
    }

    /// Target lattice point (linear index) per replica. The cluster
    /// applies any change via
    /// [`ReplicaBackend::set_rung`](super::backend::ReplicaBackend::set_rung).
    pub fn decide(&mut self, snap: &ClusterSnapshot, lattice: &QualityLattice) -> Vec<usize> {
        crate::prof_scope!("ladder.decide");
        let now = snap.now_s;
        match self.policy.scope {
            LadderScope::PerReplica => snap
                .replicas
                .iter()
                .map(|t| {
                    let (degrade, upgrade) = match self.policy.pressure {
                        PressureMode::Queue => (
                            t.queue_len > self.policy.degrade_above,
                            t.queue_len < self.policy.upgrade_below,
                        ),
                        PressureMode::Slack | PressureMode::SlackEwma => {
                            let f = Self::slack_frac_for(t, self.policy.pressure);
                            (
                                f < self.policy.slack_degrade_frac,
                                f > self.policy.slack_upgrade_frac,
                            )
                        }
                        // burn is a cluster-wide signal; every replica
                        // reads the same fraction through the slack
                        // hysteresis
                        PressureMode::Burn => {
                            let f = self.burn_frac.unwrap_or(f64::INFINITY);
                            (
                                f < self.policy.slack_degrade_frac,
                                f > self.policy.slack_upgrade_frac,
                            )
                        }
                    };
                    self.step_point(lattice, t.rung, degrade, upgrade, now, t.last_switch_s)
                })
                .collect(),
            LadderScope::Cluster => self.decide_cluster(snap, lattice),
        }
    }

    /// Cluster-global co-optimization: one pressure reading for the
    /// whole cluster, a bounded number of staggered moves per instant,
    /// ordered by lattice depth (shallowest degrade first, deepest
    /// recover first).
    fn decide_cluster(&mut self, snap: &ClusterSnapshot, lattice: &QualityLattice) -> Vec<usize> {
        let views = &snap.replicas;
        let now = snap.now_s;
        let mut targets: Vec<usize> = views.iter().map(|v| v.rung).collect();
        if lattice.n_points() <= 1 || views.is_empty() {
            return targets;
        }
        // the instant budget makes staggering robust to the event loop
        // revisiting the same timestamp (arrival and completion rounds)
        if now != self.last_instant_s {
            self.last_instant_s = now;
            self.switched_at_instant = 0;
        }
        let mut budget = self
            .policy
            .max_switches_per_instant
            .saturating_sub(self.switched_at_instant);
        if budget == 0 {
            return targets;
        }
        // aggregate pressure + the stagger order for each direction
        let (overloaded, drained) = match self.policy.pressure {
            PressureMode::Queue => {
                let total_q: usize = views.iter().map(|v| v.queue_len).sum();
                let mean_q = total_q as f64 / views.len() as f64;
                (
                    mean_q > self.policy.degrade_above as f64,
                    mean_q < self.policy.upgrade_below as f64,
                )
            }
            PressureMode::Slack | PressureMode::SlackEwma => {
                let worst = match self.policy.pressure {
                    PressureMode::SlackEwma => snap.min_projected_interactive_slack_frac(),
                    _ => snap.min_interactive_slack_frac(),
                };
                (
                    worst < self.policy.slack_degrade_frac,
                    worst > self.policy.slack_upgrade_frac,
                )
            }
            PressureMode::Burn => {
                let f = self.burn_frac.unwrap_or(f64::INFINITY);
                (
                    f < self.policy.slack_degrade_frac,
                    f > self.policy.slack_upgrade_frac,
                )
            }
        };
        let mode = self.policy.pressure;
        let depth = |i: usize| lattice.depth_of(views[i].rung);
        let mut order: Vec<usize> = (0..views.len()).collect();
        if overloaded {
            // overload: spread degradation — highest-quality replicas
            // first, most-pressured breaking ties
            match mode {
                // burn has no per-replica reading: stagger by queue
                PressureMode::Queue | PressureMode::Burn => order.sort_by_key(|&i| {
                    (depth(i), std::cmp::Reverse(views[i].queue_len), i)
                }),
                PressureMode::Slack | PressureMode::SlackEwma => order.sort_by(|&a, &b| {
                    depth(a)
                        .cmp(&depth(b))
                        .then(
                            Self::slack_frac_for(&views[a], mode)
                                .total_cmp(&Self::slack_frac_for(&views[b], mode)),
                        )
                        .then(a.cmp(&b))
                }),
            }
            for i in order {
                if budget == 0 {
                    break;
                }
                let v = &views[i];
                if now - v.last_switch_s < self.policy.min_dwell_s {
                    continue;
                }
                if let Some(n) = Self::best_degrade(lattice, v.rung) {
                    targets[i] = n;
                    budget -= 1;
                    self.switched_at_instant += 1;
                }
            }
        } else if drained {
            // drained: most-degraded replicas recover first,
            // least-pressured breaking ties
            match mode {
                PressureMode::Queue | PressureMode::Burn => order.sort_by_key(|&i| {
                    (std::cmp::Reverse(depth(i)), views[i].queue_len, i)
                }),
                PressureMode::Slack | PressureMode::SlackEwma => order.sort_by(|&a, &b| {
                    depth(b)
                        .cmp(&depth(a))
                        .then(
                            Self::slack_frac_for(&views[b], mode)
                                .total_cmp(&Self::slack_frac_for(&views[a], mode)),
                        )
                        .then(a.cmp(&b))
                }),
            }
            for i in order {
                if budget == 0 {
                    break;
                }
                let v = &views[i];
                if now - v.last_switch_s < self.policy.min_dwell_s {
                    continue;
                }
                if let Some(n) = Self::best_upgrade(lattice, v.rung) {
                    targets[i] = n;
                    budget -= 1;
                    self.switched_at_instant += 1;
                }
            }
        }
        targets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;

    fn cfg_with(axes: LadderAxes) -> ServerConfig {
        ServerConfig {
            slots_per_replica: 4,
            service_in_len: 256,
            service_out_len: 32,
            ladder_axes: axes,
            ..Default::default()
        }
    }

    fn build(model: &str, axes: LadderAxes) -> Result<QualityLattice> {
        let m = spec(model).unwrap();
        let table =
            SensitivityTable::synthetic(m.name, m.n_layers, m.top_k as u32, |x| 0.8 + 2.4 * x, 0);
        let cfg = cfg_with(axes);
        let pm = PerfModel::new(m.clone(), 0);
        QualityLattice::for_model(&m, &table, &cfg, &pm)
    }

    fn ladder() -> QualityLattice {
        build("olmoe-1b-7b", LadderAxes::K).unwrap()
    }

    #[test]
    fn rungs_trade_quality_for_speed() {
        let l = ladder();
        assert_eq!(l.n_rungs(), 4); // base + 0.8 + 0.65 + 0.5
        assert_eq!((l.k_dim(), l.s_dim()), (4, 1));
        for w in l.points().windows(2) {
            // monotone: each deeper point loses quality...
            assert!(
                w[1].quality_loss > w[0].quality_loss - 1e-12,
                "{} -> {}",
                w[0].label,
                w[1].label
            );
            // ...and buys decode speed (smaller budget, faster steps)
            assert!(
                w[1].service.step_time(4) < w[0].service.step_time(4) * 1.001,
                "{} not faster than {}",
                w[1].label,
                w[0].label
            );
            assert!(w[1].allocation.budget() < w[0].allocation.budget());
        }
        assert_eq!(l.points()[0].quality_loss, 0.0);
        // k_vec export matches the allocation
        let kv = l.k_vec(0).unwrap();
        assert_eq!(kv.len(), 16);
        assert!(kv.iter().all(|&k| k == 8));
    }

    #[test]
    fn ladder_is_deterministic() {
        let a = ladder();
        let b = ladder();
        for (x, y) in a.points().iter().zip(b.points()) {
            assert_eq!(x.allocation, y.allocation);
            assert_eq!(x.quality_loss, y.quality_loss);
        }
    }

    #[test]
    fn accessors_reject_out_of_lattice_indices() {
        let l = ladder();
        assert!(l.service(l.n_points()).is_none());
        assert!(l.k_vec(l.n_points()).is_none());
        assert!(l.point_id(l.n_points()).is_none());
        assert!(l.service(l.n_points() - 1).is_some());
    }

    #[test]
    fn intra_axis_builds_a_grid_with_honest_pricing() {
        let l = build("olmoe-1b-7b", LadderAxes::KIntra).unwrap();
        // defaults: 2 intra levels -> 3 s rows over the 4-point k axis
        assert_eq!((l.k_dim(), l.s_dim(), l.n_points()), (4, 3, 12));
        // the s = 0 row is byte-identical to the 1-D ladder
        let flat = ladder();
        for (a, b) in l.points()[..4].iter().zip(flat.points()) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.allocation, b.allocation);
            assert_eq!(a.quality_loss, b.quality_loss);
            assert_eq!(a.service.decode_step_s, b.service.decode_step_s);
        }
        for s in 1..l.s_dim() {
            for k in 0..l.k_dim() {
                let idx = l.index_of(PointId { k, s }).unwrap();
                let p = l.point(idx).unwrap();
                let above = l.point(idx - l.k_dim()).unwrap();
                assert!(p.intra_frac > above.intra_frac - 1e-12, "{}", p.label);
                // each s step cuts FFN bytes -> strictly faster decode...
                assert!(
                    p.service.step_time(4) < above.service.step_time(4),
                    "{} not faster than {}",
                    p.label,
                    above.label
                );
                // ...and costs quality on the Stage-1 scale
                assert!(
                    p.quality_loss >= above.quality_loss,
                    "{} lost less than {}",
                    p.label,
                    above.label
                );
                assert!(p.quality_loss.is_finite());
            }
        }
    }

    #[test]
    fn skip_axis_requires_a_top2_router() {
        // olmoe routes top-8: construction must fail loudly...
        let err = build("olmoe-1b-7b", LadderAxes::KSkip).unwrap_err();
        assert!(format!("{err:#}").contains("top-2"), "{err:#}");
        // ...while a top-2 model builds a full grid
        let l = build("mixtral-8x7b", LadderAxes::KSkip).unwrap();
        assert_eq!(l.s_dim(), 3);
        assert!(l.points().iter().skip(l.k_dim()).all(|p| p.skip_threshold > 0.0));
        // skipping sheds fractional experts: loss strictly on-scale
        for p in l.points().iter().skip(l.k_dim()) {
            assert!(p.quality_loss.is_finite());
        }
    }

    #[test]
    fn legal_moves_are_single_axis_steps() {
        let l = build("olmoe-1b-7b", LadderAxes::KIntra).unwrap();
        for idx in 0..l.n_points() {
            let id = l.point_id(idx).unwrap();
            assert_eq!(l.index_of(id).unwrap(), idx);
            for n in l.neighbors(idx) {
                let nid = l.point_id(n).unwrap();
                let dk = (nid.k as i64 - id.k as i64).abs();
                let ds = (nid.s as i64 - id.s as i64).abs();
                assert_eq!(dk + ds, 1, "{id:?} -> {nid:?} is not a single-axis step");
            }
            for n in l.degrade_neighbors(idx) {
                assert_eq!(l.depth_of(n), id.depth() + 1);
            }
            for n in l.upgrade_neighbors(idx) {
                assert_eq!(l.depth_of(n) + 1, id.depth());
            }
        }
        // corners
        assert!(l.upgrade_neighbors(0).is_empty());
        assert!(l.degrade_neighbors(l.n_points() - 1).is_empty());
    }

    #[test]
    fn policy_hysteresis_and_dwell() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 1.0,
            ..Default::default()
        };
        // pressure -> degrade one step
        assert_eq!(p.decide(0, 4, 11, 5.0, 0.0), 1);
        // inside the band -> hold
        assert_eq!(p.decide(1, 4, 5, 5.0, 0.0), 1);
        // drained -> climb back
        assert_eq!(p.decide(1, 4, 1, 5.0, 0.0), 0);
        // dwell not elapsed -> hold even under pressure
        assert_eq!(p.decide(0, 4, 100, 0.5, 0.0), 0);
        // clamped at the deepest rung
        assert_eq!(p.decide(3, 4, 100, 5.0, 0.0), 3);
        // single-rung ladders never switch
        assert_eq!(p.decide(0, 1, 100, 5.0, 0.0), 0);
    }

    /// 1-D lattice of `n` synthetic points with decreasing step time
    /// and increasing loss — the controller-test stand-in for the
    /// historical `n_rungs` argument.
    fn lin(n: usize) -> QualityLattice {
        QualityLattice::from_points_1d(
            (0..n)
                .map(|i| {
                    QualityPoint::k_only(
                        &format!("r{i}"),
                        Allocation::uniform(4, 2),
                        ServiceModel::synthetic(
                            &format!("r{i}"),
                            1e-4,
                            0.01 / (i as f64 + 1.0),
                            4,
                        ),
                        i as f64,
                    )
                })
                .collect(),
        )
    }

    fn view(replica: usize, rung: usize, queue_len: usize) -> ReplicaTelemetry {
        let mut t = ReplicaTelemetry::idle(replica);
        t.rung = rung;
        t.queue_len = queue_len;
        t
    }

    fn snap(now_s: f64, views: Vec<ReplicaTelemetry>) -> ClusterSnapshot {
        ClusterSnapshot {
            now_s,
            replicas: views,
        }
    }

    #[test]
    fn per_replica_scope_reproduces_local_rule() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            max_switches_per_instant: 1,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // per-replica ignores the stagger budget: both degrade at once
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 20), view(1, 0, 20)]), &lin(4));
        assert_eq!(t, vec![1, 1]);
    }

    #[test]
    fn lattice_controller_matches_legacy_walk_on_1d() {
        // the tentpole's fallback contract: on a 1-D lattice the
        // marginal-neighbor controller IS the historical ±1 walk
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let l = lin(4);
        let mut legacy = 0usize;
        let mut lattice_rung = 0usize;
        for (i, &q) in [20, 40, 3, 0, 7, 100, 1, 0, 0, 50, 12, 0].iter().enumerate() {
            let now = i as f64;
            legacy = p.decide(legacy, 4, q, now, f64::NEG_INFINITY);
            lattice_rung = ctl.decide(&snap(now, vec![view(0, lattice_rung, q)]), &l)[0];
            assert_eq!(lattice_rung, legacy, "diverged at step {i} (queue {q})");
        }
    }

    #[test]
    fn controller_prefers_the_cheaper_axis_in_2d() {
        // 2x2 grid: the s step buys MORE speed for LESS loss than the k
        // step, so pressure must move down the s axis first
        let mk = |label: &str, step: f64, loss: f64| {
            QualityPoint::k_only(
                label,
                Allocation::uniform(4, 2),
                ServiceModel::synthetic(label, 1e-4, step, 4),
                loss,
            )
        };
        let l = QualityLattice::from_grid(
            2,
            vec![
                mk("k0s0", 0.010, 0.0),
                mk("k1s0", 0.008, 2.0),
                mk("k0s1", 0.007, 1.0),
                mk("k1s1", 0.005, 3.0),
            ],
        );
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        // degrade from (0,0): s neighbor (idx 2) scores 0.003/1 over the
        // k neighbor's 0.002/2
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 20)]), &l);
        assert_eq!(t, vec![2]);
        // degrade again from (0,1): only the k move remains legal
        let t = ctl.decide(&snap(2.0, vec![view(0, 2, 20)]), &l);
        assert_eq!(t, vec![3]);
        // recovery from the worst corner: undo the k step first (most
        // loss recovered per second paid: 1/0.002 vs 2/0.003)
        let t = ctl.decide(&snap(3.0, vec![view(0, 3, 0)]), &l);
        assert_eq!(t, vec![2]);
    }

    #[test]
    fn cluster_scope_staggers_and_prioritizes_pressure() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let l = lin(4);
        // overload everywhere: only the deepest queue degrades now
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 15), view(1, 0, 40)]), &l);
        assert_eq!(t, vec![0, 1]);
        // same instant again: budget spent, nobody else moves
        let t = ctl.decide(&snap(1.0, vec![view(0, 0, 15), view(1, 1, 40)]), &l);
        assert_eq!(t, vec![0, 1]);
        // next instant: the other replica takes its step
        let t = ctl.decide(&snap(2.0, vec![view(0, 0, 15), view(1, 1, 40)]), &l);
        assert_eq!(t, vec![1, 1]);
        // drained cluster recovers shallowest-first, one per instant
        let t = ctl.decide(&snap(3.0, vec![view(0, 2, 0), view(1, 2, 1)]), &l);
        assert_eq!(t, vec![1, 2]);
    }

    #[test]
    fn cluster_scope_holds_in_the_hysteresis_band() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 8,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let t = ctl.decide(&snap(1.0, vec![view(0, 1, 5), view(1, 1, 6)]), &lin(4));
        assert_eq!(t, vec![1, 1]);
    }

    fn slack_view(replica: usize, rung: usize, frac: Option<f64>) -> ReplicaTelemetry {
        let mut t = ReplicaTelemetry::idle(replica);
        t.rung = rung;
        t.min_interactive_slack_frac = frac;
        t
    }

    #[test]
    fn slack_pressure_degrades_on_deadline_collapse_not_depth() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::Slack,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            // queue thresholds irrelevant under slack pressure
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let l = lin(4);
        // replica 0: slack collapsed -> degrade; replica 1: plenty of
        // slack -> hold; replica 2: nothing interactive queued -> it
        // may recover (but is already at rung 0)
        let t = ctl.decide(
            &snap(
                1.0,
                vec![
                    slack_view(0, 0, Some(0.1)),
                    slack_view(1, 0, Some(0.5)),
                    slack_view(2, 0, None),
                ],
            ),
            &l,
        );
        assert_eq!(t, vec![1, 0, 0]);
        // degraded replica recovers once slack is restored
        let t = ctl.decide(&snap(2.0, vec![slack_view(0, 2, Some(0.9))]), &l);
        assert_eq!(t, vec![1]);
        // inside the hysteresis band: hold
        let t = ctl.decide(&snap(3.0, vec![slack_view(0, 2, Some(0.5))]), &l);
        assert_eq!(t, vec![2]);
    }

    #[test]
    fn slack_ewma_degrades_on_projected_collapse_before_instantaneous() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::SlackEwma,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        let l = lin(4);
        // instantaneous slack healthy (0.5) but the EWMA projection says
        // the backlog will burn it to 0.1 before service starts
        let mut t = ReplicaTelemetry::idle(0);
        t.min_interactive_slack_frac = Some(0.5);
        t.projected_interactive_slack_frac = Some(0.1);

        let mut predictive = LadderController::new(p);
        assert_eq!(predictive.decide(&snap(1.0, vec![t.clone()]), &l), vec![1]);
        // the instantaneous controller holds on the same telemetry
        let mut inst = LadderController::new(LadderPolicy {
            pressure: PressureMode::Slack,
            ..p
        });
        assert_eq!(inst.decide(&snap(1.0, vec![t.clone()]), &l), vec![0]);

        // cluster scope consumes the projected aggregate the same way
        let mut cluster = LadderController::new(LadderPolicy {
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..p
        });
        assert_eq!(cluster.decide(&snap(2.0, vec![t]), &l), vec![1]);
    }

    #[test]
    fn burn_pressure_degrades_on_budget_burn_and_holds_without_evidence() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::PerReplica,
            pressure: PressureMode::Burn,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            degrade_above: 1_000_000,
            upgrade_below: 0,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let l = lin(4);
        // no burn evidence yet: +∞ reading, a degraded replica recovers
        let t = ctl.decide(&snap(1.0, vec![view(0, 2, 0)]), &l);
        assert_eq!(t, vec![1]);
        // burn beyond critical (negative fraction): degrade
        ctl.set_burn_frac(Some(-0.5));
        let t = ctl.decide(&snap(2.0, vec![view(0, 0, 0)]), &l);
        assert_eq!(t, vec![1]);
        // healthy burn: climb back
        ctl.set_burn_frac(Some(0.9));
        let t = ctl.decide(&snap(3.0, vec![view(0, 2, 0)]), &l);
        assert_eq!(t, vec![1]);
        // cluster scope consumes the same reading, staggered
        let mut cluster = LadderController::new(LadderPolicy {
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            ..p
        });
        cluster.set_burn_frac(Some(0.1));
        let t = cluster.decide(&snap(4.0, vec![view(0, 0, 3), view(1, 0, 9)]), &l);
        assert_eq!(t, vec![0, 1]);
    }

    #[test]
    fn cluster_slack_scope_staggers_worst_slack_first() {
        let p = LadderPolicy {
            min_dwell_s: 0.0,
            scope: LadderScope::Cluster,
            max_switches_per_instant: 1,
            pressure: PressureMode::Slack,
            slack_degrade_frac: 0.25,
            slack_upgrade_frac: 0.75,
            ..Default::default()
        };
        let mut ctl = LadderController::new(p);
        let l = lin(4);
        // aggregate slack collapsed: the worst-slack replica degrades
        // first, one move per instant
        let t = ctl.decide(
            &snap(1.0, vec![slack_view(0, 0, Some(0.2)), slack_view(1, 0, Some(0.05))]),
            &l,
        );
        assert_eq!(t, vec![0, 1]);
        // fully recovered cluster climbs back, most-degraded first
        let t = ctl.decide(
            &snap(2.0, vec![slack_view(0, 1, None), slack_view(1, 2, None)]),
            &l,
        );
        assert_eq!(t, vec![1, 1]);
    }
}
