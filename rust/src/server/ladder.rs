//! Adaptive LExI quality ladder: precomputed Stage-2 allocations at
//! descending budgets, swapped onto replicas under queue pressure.
//!
//! The paper optimizes ONE static per-layer allocation for a fixed
//! budget. Serving load is not static — so the ladder extends Stage 2
//! into the time dimension: rung 0 is the pretrained baseline (full
//! budget, zero quality loss), deeper rungs are LExI allocations at 80 /
//! 65 / 50 % budgets, each the `exact_dp` optimum of the Stage-1
//! sensitivity table (deterministic, so every run and replica agrees on
//! the ladder). A hysteretic controller degrades a replica one rung when
//! its queue grows past a threshold and climbs back when it drains,
//! trading bounded proxy-quality loss for decode speed exactly when the
//! SLO is at risk.

use anyhow::{Context, Result};

use crate::config::model::ModelSpec;
use crate::config::server::ServerConfig;
use crate::lexi::evolution::exact_dp;
use crate::lexi::SensitivityTable;
use crate::moe::allocation::{Allocation, Bounds};
use crate::moe::transform::Transform;
use crate::perfmodel::PerfModel;

use super::replica::ServiceModel;

/// One quality level: allocation + calibrated service model + the
/// Stage-1 proxy loss the allocation costs.
#[derive(Clone, Debug)]
pub struct Rung {
    pub label: String,
    pub allocation: Allocation,
    pub service: ServiceModel,
    /// Stage-1 proxy `phi(k) = sum_j D_j(k_j)`; 0 for the baseline.
    /// NaN marks a transform whose loss is NOT on the Stage-1 scale
    /// (e.g. expert pruning) — reports surface it as unknown, never 0.
    pub quality_loss: f64,
}

/// Rungs ordered best-quality-first (rung 0 = baseline).
#[derive(Clone, Debug)]
pub struct QualityLadder {
    pub rungs: Vec<Rung>,
}

impl QualityLadder {
    /// Build the ladder for a model: baseline rung + one LExI rung per
    /// budget fraction, allocations from `exact_dp` over the Stage-1
    /// table (measured when cached, synthetic depth profile otherwise).
    pub fn for_model(
        spec: &ModelSpec,
        table: &SensitivityTable,
        cfg: &ServerConfig,
        pm: &PerfModel,
    ) -> Result<Self> {
        let k_base = spec.top_k as u32;
        let slots = cfg.slots_per_replica;
        let baseline = Allocation::uniform(spec.n_layers, k_base);
        let mut rungs = vec![Rung {
            label: "base".to_string(),
            service: ServiceModel::from_perf(
                pm,
                &Transform::Baseline,
                slots,
                cfg.service_in_len,
                cfg.service_out_len,
                "base",
            ),
            allocation: baseline,
            quality_loss: 0.0,
        }];
        let bounds = Bounds::paper(k_base);
        let mut fracs = cfg.ladder_fracs.clone();
        fracs.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending budget
        for frac in fracs {
            let budget = ((spec.baseline_budget() as f64 * frac).round() as u32)
                .max(spec.n_layers as u32);
            let allocation = exact_dp(table, budget, bounds)
                .with_context(|| format!("budget {budget} infeasible for {}", spec.name))?;
            let label = format!("lexi-B{budget}");
            let t = Transform::Lexi {
                allocation: allocation.clone(),
            };
            rungs.push(Rung {
                service: ServiceModel::from_perf(
                    pm,
                    &t,
                    slots,
                    cfg.service_in_len,
                    cfg.service_out_len,
                    &label,
                ),
                quality_loss: table.fitness(&allocation.k),
                allocation,
                label,
            });
        }
        Ok(QualityLadder { rungs })
    }

    /// Single-rung ladder: a fixed transform, no adaptation.
    pub fn fixed(label: &str, allocation: Allocation, service: ServiceModel) -> Self {
        Self::fixed_with_loss(label, allocation, service, 0.0)
    }

    /// Single-rung ladder with an explicit Stage-1 proxy loss.
    pub fn fixed_with_loss(
        label: &str,
        allocation: Allocation,
        service: ServiceModel,
        quality_loss: f64,
    ) -> Self {
        QualityLadder {
            rungs: vec![Rung {
                label: label.to_string(),
                allocation,
                service,
                quality_loss,
            }],
        }
    }

    pub fn n_rungs(&self) -> usize {
        self.rungs.len()
    }

    pub fn service(&self, rung: usize) -> &ServiceModel {
        &self.rungs[rung.min(self.rungs.len() - 1)].service
    }
}

/// Hysteretic rung controller (per replica, stateless policy).
#[derive(Clone, Copy, Debug)]
pub struct LadderPolicy {
    /// Queue depth at which a replica degrades one rung.
    pub degrade_above: usize,
    /// Queue depth below which it climbs back toward rung 0.
    pub upgrade_below: usize,
    /// Minimum time between switches.
    pub min_dwell_s: f64,
}

impl LadderPolicy {
    pub fn from_config(cfg: &ServerConfig) -> Self {
        LadderPolicy {
            degrade_above: cfg.degrade_above,
            upgrade_below: cfg.upgrade_below,
            min_dwell_s: cfg.min_dwell_s,
        }
    }

    /// Next rung for a replica given its queue depth. One step at a
    /// time, hysteresis band between the thresholds, dwell time between
    /// switches.
    pub fn decide(
        &self,
        current: usize,
        n_rungs: usize,
        queue_len: usize,
        now: f64,
        last_switch_s: f64,
    ) -> usize {
        if n_rungs <= 1 || now - last_switch_s < self.min_dwell_s {
            return current;
        }
        if queue_len > self.degrade_above && current + 1 < n_rungs {
            current + 1
        } else if queue_len < self.upgrade_below && current > 0 {
            current - 1
        } else {
            current
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;

    fn ladder() -> QualityLadder {
        let m = spec("olmoe-1b-7b").unwrap();
        let table = SensitivityTable::synthetic(m.name, m.n_layers, m.top_k as u32, |x| 0.8 + 2.4 * x, 0);
        let cfg = ServerConfig {
            slots_per_replica: 4,
            service_in_len: 256,
            service_out_len: 32,
            ..Default::default()
        };
        let pm = PerfModel::new(m.clone(), 0);
        QualityLadder::for_model(&m, &table, &cfg, &pm).unwrap()
    }

    #[test]
    fn rungs_trade_quality_for_speed() {
        let l = ladder();
        assert_eq!(l.n_rungs(), 4); // base + 0.8 + 0.65 + 0.5
        for w in l.rungs.windows(2) {
            // monotone: each deeper rung loses quality...
            assert!(
                w[1].quality_loss > w[0].quality_loss - 1e-12,
                "{} -> {}",
                w[0].label,
                w[1].label
            );
            // ...and buys decode speed (smaller budget, faster steps)
            assert!(
                w[1].service.step_time(4) < w[0].service.step_time(4) * 1.001,
                "{} not faster than {}",
                w[1].label,
                w[0].label
            );
            assert!(w[1].allocation.budget() < w[0].allocation.budget());
        }
        assert_eq!(l.rungs[0].quality_loss, 0.0);
    }

    #[test]
    fn ladder_is_deterministic() {
        let a = ladder();
        let b = ladder();
        for (x, y) in a.rungs.iter().zip(&b.rungs) {
            assert_eq!(x.allocation, y.allocation);
            assert_eq!(x.quality_loss, y.quality_loss);
        }
    }

    #[test]
    fn policy_hysteresis_and_dwell() {
        let p = LadderPolicy {
            degrade_above: 10,
            upgrade_below: 2,
            min_dwell_s: 1.0,
        };
        // pressure -> degrade one step
        assert_eq!(p.decide(0, 4, 11, 5.0, 0.0), 1);
        // inside the band -> hold
        assert_eq!(p.decide(1, 4, 5, 5.0, 0.0), 1);
        // drained -> climb back
        assert_eq!(p.decide(1, 4, 1, 5.0, 0.0), 0);
        // dwell not elapsed -> hold even under pressure
        assert_eq!(p.decide(0, 4, 100, 0.5, 0.0), 0);
        // clamped at the deepest rung
        assert_eq!(p.decide(3, 4, 100, 5.0, 0.0), 3);
        // single-rung ladders never switch
        assert_eq!(p.decide(0, 1, 100, 5.0, 0.0), 0);
    }
}
