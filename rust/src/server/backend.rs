//! The replica-backend trait: one cluster front door for simulated and
//! real engine replicas.
//!
//! [`Cluster`](super::router::Cluster) drives every replica through this
//! surface — admit, start a phase, report the next completion time,
//! finish the phase, reconfigure the quality-ladder rung — so the same
//! routing policies, admission control, SLO scheduling, work stealing,
//! and cluster-global ladder controller apply whether the replica is the
//! perf-model-calibrated virtual-time [`Replica`](super::replica::Replica)
//! or an [`EngineReplica`](super::engine_backend::EngineReplica) wrapping
//! the real continuous-batching [`Engine`](crate::engine::Engine).
//!
//! Cluster-level *decisions* never read backend internals directly: each
//! backend reports a structured [`ReplicaTelemetry`] and every policy
//! (routing, ladder, stealing) consumes the resulting
//! [`ClusterSnapshot`](super::telemetry::ClusterSnapshot).

use crate::experts::ResidencyStats;
use crate::obs::SharedTracer;

use super::scheduler::QueuedRequest;
use super::telemetry::{ReplicaTelemetry, StepSample, StepTimeSummary, TelemetryDetail};

/// A finished request with its serving timeline (event-loop clock).
#[derive(Clone, Debug, PartialEq)]
pub struct CompletedRequest {
    pub id: u64,
    pub class: usize,
    pub arrival_s: f64,
    pub prompt_len: usize,
    pub tokens: usize,
    pub ttft_s: f64,
    pub e2e_s: f64,
    pub finish_s: f64,
    pub replica: usize,
}

impl CompletedRequest {
    /// Mean time per output token after the first.
    pub fn tpot_s(&self) -> f64 {
        (self.e2e_s - self.ttft_s) / (self.tokens.saturating_sub(1).max(1)) as f64
    }
}

/// Lifetime counters a backend reports after a run.
#[derive(Clone, Debug, Default)]
pub struct BackendStats {
    pub busy_s: f64,
    pub prefill_calls: u64,
    pub decode_steps: u64,
    pub rung_switches: u64,
    /// Busy time accumulated per quality-ladder rung.
    pub rung_time_s: Vec<f64>,
    /// Measured step-time distribution (engine backends only; the sim
    /// replica's phases are model outputs, not measurements).
    pub step_times: Option<StepTimeSummary>,
    /// Every measured step, tagged for service-model calibration
    /// (engine backends only — the raw input behind `step_times`).
    pub step_samples: Option<Vec<StepSample>>,
    /// Expert-residency counters (`None` when the replica ran without a
    /// residency model — the default).
    pub residency: Option<ResidencyStats>,
}

/// Sentinel [`telemetry_version`](ReplicaBackend::telemetry_version)
/// for backends that do not track one: the cluster treats the row as
/// permanently dirty and re-reads its telemetry at every snapshot
/// instant (the pre-cache behaviour).
pub const TELEMETRY_UNVERSIONED: u64 = u64::MAX;

/// One replica behind the cluster front door.
///
/// The contract mirrors a discrete-event loop: the cluster calls
/// [`try_start`](ReplicaBackend::try_start) on every idle backend, takes
/// the earliest [`next_event_s`](ReplicaBackend::next_event_s) across
/// backends and pending arrivals, and calls
/// [`complete_phase`](ReplicaBackend::complete_phase) on every backend
/// whose phase is due. Implementations map their own notion of time onto
/// the loop's clock: the simulated replica computes phase durations from
/// a calibrated service model, the engine-backed replica measures the
/// wall-clock cost of each `Engine::step` and advances the loop by it.
pub trait ReplicaBackend {
    /// Stable replica index (= position in the cluster).
    fn id(&self) -> usize;

    /// Admit a routed request into the local queue.
    fn admit(&mut self, req: QueuedRequest);

    /// Attach the run's shared span tracer (see [`crate::obs`]). The
    /// default ignores it, so backends that predate tracing keep
    /// compiling; both bundled backends record queue/phase/finish
    /// events through it when attached.
    fn set_tracer(&mut self, _tracer: SharedTracer) {}

    /// Structured control-plane telemetry at `now_s` — the one signal
    /// surface routing, the ladder controller, and work stealing read.
    /// `detail` bounds the cost: [`TelemetryDetail::Load`] fills only
    /// the O(1) fields (the per-arrival routing input),
    /// [`TelemetryDetail::Full`] adds the O(queue) scan fields.
    fn telemetry(&self, now_s: f64, detail: TelemetryDetail) -> ReplicaTelemetry;

    /// Monotone counter that moves whenever
    /// [`telemetry`](ReplicaBackend::telemetry) output could have
    /// changed (admit,
    /// steal, rung switch, phase start/finish). The cluster's
    /// incremental [`SnapshotCache`](super::telemetry::SnapshotCache)
    /// re-reads a replica's row only when this version moved, so an
    /// implementation must bump it on EVERY telemetry-visible mutation
    /// — a missed bump serves stale telemetry to routing and control.
    /// The default opts out: [`TELEMETRY_UNVERSIONED`] marks the row
    /// permanently dirty and the cache degrades to a per-instant
    /// rebuild for that replica.
    fn telemetry_version(&self) -> u64 {
        TELEMETRY_UNVERSIONED
    }

    /// Queued + running requests (the admission-control signal).
    fn outstanding(&self) -> usize;

    /// Whether this replica can take on new work. A backend that has
    /// failed mid-run reports false so the stealing pass never moves a
    /// healthy replica's queued request INTO it (its `admit` would
    /// silently drop the request, breaking steal conservation).
    fn accepts_work(&self) -> bool {
        true
    }

    /// Switch ladder rungs; `penalty_s` is charged to the next phase.
    fn set_rung(&mut self, rung: usize, now: f64, penalty_s: f64);

    /// Remove the queued request with the least absolute EDF slack (the
    /// work-stealing donor operation). `None` when nothing is queued.
    fn steal_request(&mut self) -> Option<QueuedRequest>;

    /// Begin the next phase if idle. Returns false when there is
    /// nothing to do.
    fn try_start(&mut self, now: f64) -> bool;

    /// Event-loop time at which the in-flight phase finishes (`None`
    /// while idle).
    fn next_event_s(&self) -> Option<f64>;

    /// Finish the in-flight phase at `now`, appending completions.
    fn complete_phase(&mut self, now: f64, out: &mut Vec<CompletedRequest>);

    /// No queued, running, or in-flight work left.
    fn is_drained(&self) -> bool;

    /// Lifetime counters for the run report.
    fn stats(&self) -> BackendStats;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpot_guards_single_token_requests() {
        let c = CompletedRequest {
            id: 0,
            class: 0,
            arrival_s: 0.0,
            prompt_len: 8,
            tokens: 1,
            ttft_s: 0.5,
            e2e_s: 0.5,
            finish_s: 0.5,
            replica: 0,
        };
        assert_eq!(c.tpot_s(), 0.0);
        let c2 = CompletedRequest { tokens: 5, e2e_s: 0.9, ..c };
        assert!((c2.tpot_s() - 0.1).abs() < 1e-12);
    }
}
