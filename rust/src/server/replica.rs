//! One simulated engine replica: continuous batching in virtual time.
//!
//! A [`Replica`] mirrors the real `engine::Engine` scheduling discipline
//! — admit waiting requests into free slots with one batched prefill, or
//! advance every active slot one decode step — but takes its step
//! durations from a [`ServiceModel`] calibrated against the analytical
//! H100 perf model instead of executing XLA graphs. That makes cluster
//! experiments deterministic, artifact-free, and fast enough to replay
//! hundreds of thousands of virtual requests. It is the default
//! implementation of [`ReplicaBackend`]; the engine-backed twin lives in
//! [`super::engine_backend`].

use std::rc::Rc;

use crate::experts::ExpertResidency;
use crate::moe::transform::Transform;
use crate::obs::trace::{record_opt, EventKind, PhaseKind};
use crate::obs::SharedTracer;
use crate::perfmodel::PerfModel;

use super::backend::{BackendStats, ReplicaBackend};
use super::ladder::QualityLadder;
use super::scheduler::{EdfQueue, QueuedRequest};
use super::telemetry::{ReplicaTelemetry, TelemetryDetail};

pub use super::backend::CompletedRequest;

/// Step-time model of one replica under one transform / ladder rung.
#[derive(Clone, Debug)]
pub struct ServiceModel {
    pub label: String,
    /// Fixed per-prefill-call overhead (scheduling + upload).
    pub prefill_overhead_s: f64,
    /// Marginal prefill cost per prompt token.
    pub prefill_s_per_token: f64,
    /// Decode-step wall time by batch occupancy (index `occ - 1`).
    pub decode_step_s: Vec<f64>,
}

impl ServiceModel {
    /// Calibrate against the analytical perf model: per-token prefill
    /// cost from a full-batch prefill, per-occupancy decode-step cost
    /// from the decode phase of a `(occ, in_len, out_len)` run.
    pub fn from_perf(
        pm: &PerfModel,
        t: &Transform,
        slots: usize,
        in_len: usize,
        out_len: usize,
        label: &str,
    ) -> Self {
        let full = pm.throughput(t, slots, in_len, out_len);
        let prefill_s_per_token = full.prefill_s / (slots * in_len) as f64;
        let decode_step_s = (1..=slots)
            .map(|occ| pm.throughput(t, occ, in_len, out_len).decode_s / out_len as f64)
            .collect();
        ServiceModel {
            label: label.to_string(),
            prefill_overhead_s: 1e-3,
            prefill_s_per_token,
            decode_step_s,
        }
    }

    /// Fixed-cost model for unit tests and benches.
    pub fn synthetic(label: &str, prefill_s_per_token: f64, step_s: f64, slots: usize) -> Self {
        ServiceModel {
            label: label.to_string(),
            prefill_overhead_s: 0.0,
            prefill_s_per_token,
            decode_step_s: vec![step_s; slots],
        }
    }

    pub fn slots(&self) -> usize {
        self.decode_step_s.len()
    }

    /// Batched prefill over `tokens` total prompt tokens.
    pub fn prefill_time(&self, tokens: usize) -> f64 {
        self.prefill_overhead_s + self.prefill_s_per_token * tokens as f64
    }

    /// One decode step at the given occupancy.
    pub fn step_time(&self, occupancy: usize) -> f64 {
        let occ = occupancy.clamp(1, self.decode_step_s.len());
        self.decode_step_s[occ - 1]
    }

    /// Steady-state capacity estimate (requests/s) for a mean request
    /// shape: one batch cohort = full-batch prefill + mean-length decode.
    pub fn capacity_rps(&self, mean_prompt: f64, mean_gen: f64) -> f64 {
        let slots = self.slots();
        let cohort = self.prefill_time((mean_prompt * slots as f64) as usize)
            + mean_gen * self.step_time(slots);
        slots as f64 / cohort
    }
}

/// A request occupying one decode slot.
#[derive(Clone, Debug)]
pub struct SimSlot {
    pub req: super::scheduler::QueuedRequest,
    pub first_token_s: Option<f64>,
    pub produced: usize,
}

#[derive(Clone, Debug)]
enum Phase {
    Idle,
    Prefill { finish_s: f64, slot_idxs: Vec<usize> },
    Decode { finish_s: f64 },
}

/// One replica: local EDF queue + slots + phase clock + rung state.
/// Rung → service-model resolution goes through the shared
/// [`QualityLadder`].
#[derive(Debug)]
pub struct Replica {
    pub id: usize,
    pub queue: EdfQueue,
    pub slots: Vec<Option<SimSlot>>,
    ladder: Rc<QualityLadder>,
    phase: Phase,
    /// Optional expert-residency model: phase durations absorb its
    /// demand-miss stall time, rung switches repin the hot set, and the
    /// stats land in [`BackendStats::residency`].
    residency: Option<ExpertResidency>,
    /// Optional shared span tracer (None = record nothing; the
    /// default, which keeps runs byte-identical to untraced ones).
    tracer: Option<SharedTracer>,
    /// Current quality-ladder rung (0 = full quality).
    pub rung: usize,
    pub last_switch_s: f64,
    pending_penalty_s: f64,
    /// EWMA of recent phase durations (telemetry signal).
    step_ewma_s: f64,
    /// Occupied-slot count, maintained on slot fill/drain so
    /// [`n_active`](Replica::n_active) — and through it the cluster's
    /// per-arrival admission signal — is O(1) instead of O(slots).
    active_slots: usize,
    /// Bumped on every telemetry-visible mutation (admit / steal /
    /// rung switch / phase start / phase finish) so the cluster's
    /// [`SnapshotCache`](super::telemetry::SnapshotCache) re-reads
    /// this replica's row only when something actually changed.
    telemetry_version: u64,
    // ---- counters ----
    pub busy_s: f64,
    pub prefill_calls: u64,
    pub decode_steps: u64,
    pub rung_switches: u64,
    /// Busy time accumulated per rung.
    pub rung_time_s: Vec<f64>,
}

impl Replica {
    pub fn new(id: usize, slots: usize, ladder: Rc<QualityLadder>) -> Self {
        let n_rungs = ladder.n_rungs();
        Replica {
            id,
            queue: EdfQueue::new(),
            slots: (0..slots).map(|_| None).collect(),
            ladder,
            phase: Phase::Idle,
            residency: None,
            tracer: None,
            rung: 0,
            last_switch_s: f64::NEG_INFINITY,
            pending_penalty_s: 0.0,
            step_ewma_s: 0.0,
            active_slots: 0,
            telemetry_version: 1,
            busy_s: 0.0,
            prefill_calls: 0,
            decode_steps: 0,
            rung_switches: 0,
            rung_time_s: vec![0.0; n_rungs.max(1)],
        }
    }

    /// Attach an expert-residency model (already pinned for the current
    /// rung's `k_vec` — see [`ExpertResidency::new`]).
    pub fn with_residency(mut self, residency: ExpertResidency) -> Self {
        assert_eq!(
            residency.n_layers(),
            self.ladder
                .k_vec(self.rung)
                .expect("replica rung off the quality lattice")
                .len(),
            "residency layer count != ladder k_vec length"
        );
        self.residency = Some(residency);
        self
    }

    pub fn n_active(&self) -> usize {
        debug_assert_eq!(
            self.active_slots,
            self.slots.iter().filter(|s| s.is_some()).count(),
            "active-slot counter out of sync with slot occupancy"
        );
        self.active_slots
    }

    /// Queued + running requests on this replica.
    pub fn outstanding(&self) -> usize {
        self.queue.len() + self.n_active()
    }

    /// Token-weighted backlog: queued cost + remaining decode tokens of
    /// running requests. The JSQ / p2c routing signal.
    pub fn load_cost(&self) -> u64 {
        self.queue.pending_cost()
            + self
                .slots
                .iter()
                .flatten()
                .map(|s| (s.req.new_tokens.saturating_sub(s.produced)) as u64)
                .sum::<u64>()
    }

    pub fn is_drained(&self) -> bool {
        matches!(self.phase, Phase::Idle) && self.queue.is_empty() && self.n_active() == 0
    }

    /// When the in-flight phase finishes (None while idle).
    pub fn next_event_s(&self) -> Option<f64> {
        match self.phase {
            Phase::Idle => None,
            Phase::Prefill { finish_s, .. } | Phase::Decode { finish_s } => Some(finish_s),
        }
    }

    /// Switch ladder rungs; charges `penalty_s` to the next phase. With
    /// a residency model, the rung's `k_vec` invalidates and prewarms
    /// the pinned hot set.
    pub fn set_rung(&mut self, rung: usize, now: f64, penalty_s: f64) {
        if rung != self.rung {
            self.telemetry_version += 1;
            self.rung = rung;
            self.last_switch_s = now;
            self.rung_switches += 1;
            self.pending_penalty_s += penalty_s;
            if let Some(r) = &mut self.residency {
                // a controller emitting an off-lattice index is a bug;
                // fail loudly instead of serving the deepest point
                r.set_k_vec(
                    &self
                        .ladder
                        .k_vec(rung)
                        .expect("controller set an off-lattice rung index"),
                );
            }
        }
    }

    /// Start the next phase if idle: batched prefill when slots and
    /// queued work exist (the vLLM admission discipline), else one decode
    /// step over the active slots. Returns false when there is nothing
    /// to do.
    pub fn try_start(&mut self, now: f64) -> bool {
        if !matches!(self.phase, Phase::Idle) {
            return false;
        }
        let ladder = Rc::clone(&self.ladder);
        let svc = ladder
            .service(self.rung)
            .expect("replica rung off the quality lattice");
        let free: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();
        if !free.is_empty() && !self.queue.is_empty() {
            let mut slot_idxs = Vec::new();
            let mut prompt_tokens = 0usize;
            for idx in free {
                let Some(req) = self.queue.pop() else { break };
                prompt_tokens += req.prompt_len;
                self.slots[idx] = Some(SimSlot {
                    req,
                    first_token_s: None,
                    produced: 0,
                });
                self.active_slots += 1;
                slot_idxs.push(idx);
            }
            // residency: the batched prefill demands every layer's
            // routed experts; misses stall the phase
            let stall = self
                .residency
                .as_mut()
                .map_or(0.0, |r| r.step(prompt_tokens.max(1)).stall_s);
            let dur = self.pending_penalty_s + svc.prefill_time(prompt_tokens) + stall;
            self.pending_penalty_s = 0.0;
            self.account(dur);
            self.prefill_calls += 1;
            record_opt(&self.tracer, now, || EventKind::PhaseStart {
                replica: self.id,
                phase: PhaseKind::Prefill,
                rung: self.rung,
                dur_s: dur,
                stall_s: stall,
                active: self.n_active(),
                ids: slot_idxs
                    .iter()
                    .map(|&i| self.slots[i].as_ref().unwrap().req.id)
                    .collect(),
            });
            self.phase = Phase::Prefill {
                finish_s: now + dur,
                slot_idxs,
            };
            true
        } else if self.n_active() > 0 {
            let active = self.n_active();
            let stall = self.residency.as_mut().map_or(0.0, |r| r.step(active).stall_s);
            let dur = self.pending_penalty_s + svc.step_time(active) + stall;
            self.pending_penalty_s = 0.0;
            self.account(dur);
            self.decode_steps += 1;
            record_opt(&self.tracer, now, || EventKind::PhaseStart {
                replica: self.id,
                phase: PhaseKind::Decode,
                rung: self.rung,
                dur_s: dur,
                stall_s: stall,
                active,
                ids: Vec::new(),
            });
            self.phase = Phase::Decode {
                finish_s: now + dur,
            };
            true
        } else {
            false
        }
    }

    fn account(&mut self, dur: f64) {
        // called exactly once per started phase: slots, load_cost,
        // step_ewma_s and (with residency) hbm_pressure all moved
        self.telemetry_version += 1;
        self.busy_s += dur;
        self.rung_time_s[self.rung.min(self.rung_time_s.len() - 1)] += dur;
        self.step_ewma_s = if self.step_ewma_s == 0.0 {
            dur
        } else {
            0.2 * dur + 0.8 * self.step_ewma_s
        };
    }

    /// Control-plane telemetry at `now_s` (see [`ReplicaTelemetry`]).
    pub fn telemetry(&self, now_s: f64, detail: TelemetryDetail) -> ReplicaTelemetry {
        let mut t = ReplicaTelemetry {
            replica: self.id,
            accepting: true,
            rung: self.rung,
            point: self
                .ladder
                .point_id(self.rung)
                .expect("replica rung off the quality lattice"),
            last_switch_s: self.last_switch_s,
            queue_len: self.queue.len(),
            active: self.n_active(),
            load_cost: self.load_cost(),
            class_occupancy: Vec::new(),
            min_slack_s: None,
            min_interactive_slack_frac: None,
            projected_interactive_slack_frac: None,
            step_ewma_s: self.step_ewma_s,
            hbm_pressure: self.residency.as_ref().map(|r| r.pressure()),
        };
        if detail == TelemetryDetail::Full {
            t.fill_scans(&self.queue, self.slots.iter().flatten().map(|s| s.req.class), now_s);
        }
        t
    }

    /// Finish the in-flight phase at `now`, emitting completed requests.
    pub fn complete_phase(&mut self, now: f64, out: &mut Vec<CompletedRequest>) {
        self.telemetry_version += 1;
        match std::mem::replace(&mut self.phase, Phase::Idle) {
            Phase::Idle => {}
            Phase::Prefill { slot_idxs, .. } => {
                let rid = self.id;
                for i in slot_idxs {
                    if let Some(slot) = self.slots[i].as_mut() {
                        slot.first_token_s = Some(now);
                        slot.produced = 1;
                        let id = slot.req.id;
                        record_opt(&self.tracer, now, || EventKind::FirstToken {
                            id,
                            replica: rid,
                        });
                    }
                }
                self.collect_finished(now, out);
            }
            Phase::Decode { .. } => {
                for slot in self.slots.iter_mut().flatten() {
                    slot.produced += 1;
                }
                self.collect_finished(now, out);
            }
        }
    }

    fn collect_finished(&mut self, now: f64, out: &mut Vec<CompletedRequest>) {
        let id = self.id;
        for slot_opt in self.slots.iter_mut() {
            let done = matches!(slot_opt, Some(s) if s.produced >= s.req.new_tokens);
            if done {
                let s = slot_opt.take().unwrap();
                self.active_slots -= 1;
                let first = s.first_token_s.unwrap_or(now);
                let c = CompletedRequest {
                    id: s.req.id,
                    class: s.req.class,
                    arrival_s: s.req.arrival_s,
                    prompt_len: s.req.prompt_len,
                    tokens: s.produced,
                    ttft_s: first - s.req.arrival_s,
                    e2e_s: now - s.req.arrival_s,
                    finish_s: now,
                    replica: id,
                };
                record_opt(&self.tracer, now, || EventKind::Finish {
                    id: c.id,
                    replica: c.replica,
                    class: c.class,
                    ttft_s: c.ttft_s,
                    e2e_s: c.e2e_s,
                    tokens: c.tokens,
                });
                out.push(c);
            }
        }
    }
}

impl ReplicaBackend for Replica {
    fn id(&self) -> usize {
        self.id
    }

    fn admit(&mut self, req: QueuedRequest) {
        self.telemetry_version += 1;
        record_opt(&self.tracer, req.arrival_s, || EventKind::QueuePush {
            id: req.id,
            replica: self.id,
            deadline_ns: req.deadline_ns,
        });
        self.queue.push(req);
    }

    fn set_tracer(&mut self, tracer: SharedTracer) {
        self.tracer = Some(tracer);
    }

    fn telemetry(&self, now_s: f64, detail: TelemetryDetail) -> ReplicaTelemetry {
        Replica::telemetry(self, now_s, detail)
    }

    fn outstanding(&self) -> usize {
        Replica::outstanding(self)
    }

    fn set_rung(&mut self, rung: usize, now: f64, penalty_s: f64) {
        Replica::set_rung(self, rung, now, penalty_s);
    }

    fn telemetry_version(&self) -> u64 {
        self.telemetry_version
    }

    fn steal_request(&mut self) -> Option<QueuedRequest> {
        let req = self.queue.pop_min_deadline();
        if req.is_some() {
            self.telemetry_version += 1;
        }
        req
    }

    fn try_start(&mut self, now: f64) -> bool {
        Replica::try_start(self, now)
    }

    fn next_event_s(&self) -> Option<f64> {
        Replica::next_event_s(self)
    }

    fn complete_phase(&mut self, now: f64, out: &mut Vec<CompletedRequest>) {
        Replica::complete_phase(self, now, out);
    }

    fn is_drained(&self) -> bool {
        Replica::is_drained(self)
    }

    fn stats(&self) -> BackendStats {
        BackendStats {
            busy_s: self.busy_s,
            prefill_calls: self.prefill_calls,
            decode_steps: self.decode_steps,
            rung_switches: self.rung_switches,
            rung_time_s: self.rung_time_s.clone(),
            step_times: None,
            step_samples: None,
            residency: self.residency.as_ref().map(|r| r.stats()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::allocation::Allocation;
    use crate::server::scheduler::QueuedRequest;

    fn queued(id: u64, prompt: usize, gen: usize) -> QueuedRequest {
        QueuedRequest {
            id,
            class: 0,
            priority: 0,
            arrival_s: 0.0,
            deadline_ns: 10_000_000_000,
            prompt_len: prompt,
            new_tokens: gen,
        }
    }

    /// Single-rung ladder around one synthetic service model.
    fn fixed_ladder(step_s: f64, slots: usize) -> Rc<QualityLadder> {
        Rc::new(QualityLadder::fixed(
            "t",
            Allocation::uniform(4, 2),
            ServiceModel::synthetic("t", 1e-4, step_s, slots),
        ))
    }

    /// `n`-rung ladder that reuses one service model per rung.
    fn multi_rung_ladder(n: usize, slots: usize) -> Rc<QualityLadder> {
        let base = QualityLadder::fixed(
            "t",
            Allocation::uniform(4, 2),
            ServiceModel::synthetic("t", 1e-4, 0.01, slots),
        );
        Rc::new(QualityLadder::from_points_1d(
            (0..n).map(|_| base.points()[0].clone()).collect(),
        ))
    }

    #[test]
    fn phase_cycle_prefill_then_decode_to_completion() {
        let mut r = Replica::new(0, 4, fixed_ladder(0.01, 4));
        r.queue.push(queued(0, 100, 3));
        let mut done = Vec::new();

        assert!(r.try_start(0.0));
        let t1 = r.next_event_s().unwrap();
        assert!((t1 - 0.01).abs() < 1e-12); // 100 tokens * 1e-4
        r.complete_phase(t1, &mut done);
        assert!(done.is_empty()); // 1 of 3 tokens after prefill

        // two decode steps finish the request
        let mut now = t1;
        for _ in 0..2 {
            assert!(r.try_start(now));
            now = r.next_event_s().unwrap();
            r.complete_phase(now, &mut done);
        }
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.tokens, 3);
        assert!((c.ttft_s - 0.01).abs() < 1e-9);
        assert!((c.e2e_s - 0.03).abs() < 1e-9);
        assert!(r.is_drained());
        assert_eq!(r.prefill_calls, 1);
        assert_eq!(r.decode_steps, 2);
    }

    #[test]
    fn single_token_request_finishes_at_prefill() {
        let mut r = Replica::new(0, 2, fixed_ladder(0.01, 2));
        r.queue.push(queued(0, 50, 1));
        let mut done = Vec::new();
        r.try_start(0.0);
        r.complete_phase(r.next_event_s().unwrap(), &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens, 1);
    }

    #[test]
    fn load_cost_counts_queue_and_slots() {
        let mut r = Replica::new(0, 2, fixed_ladder(0.01, 2));
        r.queue.push(queued(0, 80, 40));
        r.queue.push(queued(1, 80, 40));
        r.queue.push(queued(2, 80, 40));
        let per = (80 / 8 + 40) as u64;
        assert_eq!(r.load_cost(), 3 * per);
        r.try_start(0.0); // admits 2 into slots, 1 stays queued
        let mut done = Vec::new();
        r.complete_phase(r.next_event_s().unwrap(), &mut done);
        // queued: 1 full cost; running: 2 * (40 - 1) remaining tokens
        assert_eq!(r.load_cost(), per + 2 * 39);
        assert_eq!(r.outstanding(), 3);
    }

    #[test]
    fn rung_switch_counts_and_charges_penalty() {
        let mut r = Replica::new(0, 2, multi_rung_ladder(3, 2));
        r.queue.push(queued(0, 100, 4));
        r.set_rung(2, 0.0, 0.5);
        r.set_rung(2, 0.0, 0.5); // no-op: already there
        assert_eq!(r.rung_switches, 1);
        r.try_start(0.0);
        // prefill = penalty 0.5 + 100 * 1e-4
        assert!((r.next_event_s().unwrap() - 0.51).abs() < 1e-9);
        assert!(r.rung_time_s[2] > 0.5);
        assert_eq!(r.rung_time_s[0], 0.0);
    }

    #[test]
    fn telemetry_reports_queue_slots_and_slack() {
        let mut r = Replica::new(3, 2, fixed_ladder(0.01, 2));
        let t = r.telemetry(0.0, TelemetryDetail::Full);
        assert_eq!(t.replica, 3);
        assert_eq!(t.outstanding(), 0);
        assert!(t.min_slack_s.is_none() && t.min_interactive_slack_frac.is_none());
        assert_eq!(t.step_ewma_s, 0.0);

        let mut a = queued(0, 80, 40); // interactive, deadline 10s
        a.arrival_s = 0.0;
        let mut b = queued(1, 80, 40);
        b.class = 1;
        b.priority = 2;
        b.deadline_ns = 4_000_000_000; // batch, worst absolute slack
        let c = queued(2, 80, 40);
        r.queue.push(a);
        r.queue.push(b);
        r.queue.push(c);
        r.try_start(0.0); // admits 2 into slots (EDF: batch id 1 waits)
        let t = r.telemetry(1.0, TelemetryDetail::Full);
        assert_eq!(t.queue_len, 1);
        assert_eq!(t.active, 2);
        assert_eq!(t.outstanding(), 3);
        // queued: only the batch request remains
        assert_eq!(t.class_occupancy, vec![2, 1]);
        assert!((t.min_slack_s.unwrap() - 3.0).abs() < 1e-9);
        // no interactive request queued -> no interactive slack signal
        assert!(t.min_interactive_slack_frac.is_none());
        assert!(t.step_ewma_s > 0.0);
        assert!(t.load_cost > 0);

        // the cheap routing level skips the scan fields but keeps the
        // O(1) scheduling signals
        let light = r.telemetry(1.0, TelemetryDetail::Load);
        assert_eq!(light.load_cost, t.load_cost);
        assert_eq!(light.queue_len, 1);
        assert!(light.class_occupancy.is_empty());
        assert!(light.min_slack_s.is_none());
    }

    #[test]
    fn residency_stall_inflates_phase_durations() {
        use crate::config::server::EvictKind;
        use crate::experts::{ExpertResidency, ResidencyConfig};
        let ladder = fixed_ladder(0.01, 2);
        let mk = || {
            // tight budget, no prefetch: cold misses must stall
            let mut cfg = ResidencyConfig::for_dims(4, 8, 1 << 20, 0.25, EvictKind::Lru, 3);
            cfg.prefetch = false;
            ExpertResidency::new(&cfg, ladder.k_vec(0).unwrap(), 0)
        };
        let mut cold = Replica::new(0, 2, Rc::clone(&ladder)).with_residency(mk());
        let mut free = Replica::new(1, 2, Rc::clone(&ladder));
        cold.queue.push(queued(0, 100, 3));
        free.queue.push(queued(0, 100, 3));
        assert!(cold.try_start(0.0) && free.try_start(0.0));
        // the stalled prefill finishes strictly later
        assert!(cold.next_event_s().unwrap() > free.next_event_s().unwrap());
        let stats = ReplicaBackend::stats(&cold).residency.unwrap();
        assert!(stats.misses > 0 && stats.stall_s > 0.0);
        assert!(ReplicaBackend::stats(&free).residency.is_none());
        // pressure surfaces in telemetry only for the residency replica
        assert!(cold.telemetry(0.0, TelemetryDetail::Load).hbm_pressure.is_some());
        assert!(free.telemetry(0.0, TelemetryDetail::Load).hbm_pressure.is_none());
    }

    #[test]
    fn steal_request_takes_worst_slack_from_queue() {
        let mut r = Replica::new(0, 1, fixed_ladder(0.01, 1));
        let mut a = queued(0, 80, 40);
        a.deadline_ns = 9_000_000_000;
        let mut b = queued(1, 80, 40);
        b.deadline_ns = 2_000_000_000;
        r.queue.push(a);
        r.queue.push(b);
        let stolen = ReplicaBackend::steal_request(&mut r).unwrap();
        assert_eq!(stolen.id, 1);
        assert_eq!(r.queue.len(), 1);
        assert!(ReplicaBackend::steal_request(&mut r).is_some());
        assert!(ReplicaBackend::steal_request(&mut r).is_none());
    }

    #[test]
    fn service_model_from_perf_orders_by_budget() {
        use crate::config::model::spec;
        let m = spec("qwen1.5-moe-a2.7b").unwrap();
        let pm = PerfModel::new(m.clone(), 0);
        let base = ServiceModel::from_perf(&pm, &Transform::Baseline, 8, 256, 32, "base");
        let lexi = ServiceModel::from_perf(
            &pm,
            &Transform::Lexi {
                allocation: Allocation::uniform(m.n_layers, 2),
            },
            8,
            256,
            32,
            "lexi",
        );
        assert_eq!(base.slots(), 8);
        // half the active experts must make decode steps faster
        assert!(lexi.step_time(8) < base.step_time(8));
        assert!(lexi.capacity_rps(400.0, 64.0) > base.capacity_rps(400.0, 64.0));
        // step time grows (weakly) with occupancy
        assert!(base.step_time(8) >= base.step_time(1) * 0.99);
    }
}
