//! Compiled-model runtime: prefill / decode / moe_layer execution with
//! device-resident weights and KV caches.
//!
//! §Perf L3 iteration 3 (the big one): all execution goes through
//! `execute_b` with *caller-owned* device buffers. The crate's literal
//! `execute` path leaks every input device buffer per call
//! (`BufferFromHostLiteral(...).release()` without a matching delete in
//! xla_rs.cc) — at ~20 MB of inputs per forward this OOM-killed long
//! figure runs. With `execute_b`:
//!   * weights upload ONCE per model (not per call),
//!   * per-call activations are owned `PjRtBuffer`s dropped after the
//!     call,
//!   * the KV cache stays device-resident between decode steps.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{Context, Result};

use super::manifest::{Manifest, ManifestModel};
use super::tensor::HostTensor;
use super::weights::HostParams;
use super::Runtime;

fn xerr<T>(r: xla::Result<T>) -> Result<T> {
    r.map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// KV-cache state between decode steps. Device-resident in the steady
/// state; host literals appear only around engine-side slot splicing.
pub enum KvState {
    Device(xla::PjRtBuffer),
    Host(xla::Literal),
}

impl KvState {
    pub fn to_host(&self) -> Result<HostTensor> {
        match self {
            KvState::Device(buf) => HostTensor::from_literal(&xerr(buf.to_literal_sync())?),
            KvState::Host(lit) => HostTensor::from_literal(lit),
        }
    }
}

/// Output of one prefill call.
pub struct PrefillOut {
    /// Logits [B, T, V] flattened.
    pub logits: Vec<f32>,
    pub kv: KvState,
}

/// Output of one decode step.
pub struct DecodeOut {
    /// Logits [B, V] flattened.
    pub logits: Vec<f32>,
    pub kv: KvState,
}

/// Compiled graphs of one model (shared across weight variants — §Perf
/// L3 iteration 2: intra-pruning sweeps re-upload weights without
/// recompiling the HLO).
pub struct Executables {
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
    moe_layer: xla::PjRtLoadedExecutable,
}

/// One model's compiled executables + device-resident weights.
pub struct ModelRuntime {
    pub entry: ManifestModel,
    client: xla::PjRtClient,
    exes: std::rc::Rc<Executables>,
    /// Host copy of (possibly edited) weights — needed for layer slicing.
    pub params: HostParams,
    /// Device-resident weights in execute order (uploaded once).
    param_buffers: Vec<xla::PjRtBuffer>,
    /// Lazily-uploaded per-layer MoE weight slices for Stage-1 probing.
    layer_cache: RefCell<HashMap<usize, Vec<xla::PjRtBuffer>>>,
    /// (prefill, decode) call counters for metrics.
    pub calls: std::cell::Cell<(u64, u64)>,
}

impl ModelRuntime {
    /// Load + compile one model from the artifacts directory with
    /// unmodified weights.
    pub fn load(rt: &Runtime, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let dir = manifest.model_dir(name);
        let params = HostParams::load_npz(dir.join(&entry.files.params), &entry)?;
        Self::with_params(rt, manifest, name, params)
    }

    /// Load with externally edited weights (intra-pruning etc.).
    pub fn with_params(
        rt: &Runtime,
        manifest: &Manifest,
        name: &str,
        params: HostParams,
    ) -> Result<Self> {
        let entry = manifest.model(name)?.clone();
        let dir = manifest.model_dir(name);
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xerr(xla::HloModuleProto::from_text_file(&path))
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            xerr(rt.client.compile(&comp))
        };
        let exes = std::rc::Rc::new(Executables {
            prefill: compile(&entry.files.prefill)?,
            decode: compile(&entry.files.decode)?,
            moe_layer: compile(&entry.files.moe_layer)?,
        });
        let client = rt.client.clone();
        let param_buffers = upload_params(&client, &params, &entry)?;
        Ok(ModelRuntime {
            entry,
            client,
            exes,
            params,
            param_buffers,
            layer_cache: RefCell::new(HashMap::new()),
            calls: std::cell::Cell::new((0, 0)),
        })
    }

    /// A weight-variant view sharing this model's compiled executables
    /// (no recompilation — used by the intra-pruning sweeps).
    pub fn reload_with_params(&self, params: HostParams) -> Result<Self> {
        let param_buffers = upload_params(&self.client, &params, &self.entry)?;
        Ok(ModelRuntime {
            entry: self.entry.clone(),
            client: self.client.clone(),
            exes: self.exes.clone(),
            params,
            param_buffers,
            layer_cache: RefCell::new(HashMap::new()),
            calls: std::cell::Cell::new((0, 0)),
        })
    }

    /// Upload a host KV tensor as a device-resident cache state (used by
    /// the engine after slot splicing so subsequent decode steps stay
    /// upload-free).
    pub fn upload_kv(&self, t: &HostTensor) -> Result<KvState> {
        Ok(KvState::Device(self.up_f32(&t.shape, &t.data)?))
    }

    /// Re-upload parameters after an in-place weight edit.
    pub fn refresh_params(&mut self) -> Result<()> {
        self.param_buffers = upload_params(&self.client, &self.params, &self.entry)?;
        self.layer_cache.borrow_mut().clear();
        Ok(())
    }

    // ----------------------------------------------------------------
    // upload helpers
    // ----------------------------------------------------------------

    fn up_f32(&self, dims: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        xerr(self.client.buffer_from_host_buffer(data, dims, None))
    }

    fn up_i32(&self, dims: &[usize], data: &[i32]) -> Result<xla::PjRtBuffer> {
        xerr(self.client.buffer_from_host_buffer(data, dims, None))
    }

    /// Execute with param buffers + borrowed extra buffers; unpack
    /// `n_outputs` (handles both untupled and single-tuple returns).
    fn exec(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        extra: Vec<&xla::PjRtBuffer>,
        n_outputs: usize,
    ) -> Result<Vec<OutBuf>> {
        let mut args: Vec<&xla::PjRtBuffer> = self.param_buffers.iter().collect();
        args.extend(extra);
        let mut outs = xerr(exe.execute_b::<&xla::PjRtBuffer>(&args))?;
        let bufs = std::mem::take(&mut outs[0]);
        if bufs.len() == n_outputs {
            Ok(bufs.into_iter().map(OutBuf::Device).collect())
        } else {
            // return_tuple=True graphs come back as one tuple buffer
            anyhow::ensure!(bufs.len() == 1, "unexpected output arity {}", bufs.len());
            let mut lit = xerr(bufs[0].to_literal_sync())?;
            let parts = xerr(lit.decompose_tuple())?;
            anyhow::ensure!(
                parts.len() == n_outputs,
                "expected {n_outputs} outputs, got {}",
                parts.len()
            );
            Ok(parts.into_iter().map(OutBuf::Host).collect())
        }
    }

    /// Prefill: tokens [B*T] (row-major [B, T]), per-layer k, gate bias
    /// [L*E]. Returns full logits + the KV cache.
    pub fn prefill(&self, tokens: &[i32], k_vec: &[i32], gate_bias: &[f32]) -> Result<PrefillOut> {
        let e = &self.entry;
        anyhow::ensure!(tokens.len() == e.batch * e.prefill_len);
        anyhow::ensure!(k_vec.len() == e.n_layers);
        anyhow::ensure!(gate_bias.len() == e.n_layers * e.n_experts);
        let b_tokens = self.up_i32(&[e.batch, e.prefill_len], tokens)?;
        let b_k = self.up_i32(&[e.n_layers], k_vec)?;
        let b_bias = self.up_f32(&[e.n_layers, e.n_experts], gate_bias)?;
        let mut outs = self.exec(&self.exes.prefill, vec![&b_tokens, &b_k, &b_bias], 2)?;
        let kv = outs.pop().unwrap().into_kv();
        let logits = outs.pop().unwrap().to_f32()?;
        let (c0, c1) = self.calls.get();
        self.calls.set((c0 + 1, c1));
        Ok(PrefillOut { logits, kv })
    }

    /// One decode step over all batch slots. The cache flows through as
    /// a device buffer — no host copies in the steady-state loop.
    pub fn decode(
        &self,
        kv: &KvState,
        tokens: &[i32],
        pos: &[i32],
        k_vec: &[i32],
        gate_bias: &[f32],
    ) -> Result<DecodeOut> {
        let e = &self.entry;
        anyhow::ensure!(tokens.len() == e.batch && pos.len() == e.batch);
        let kv_uploaded; // keep alive when the input was a host literal
        let kv_ref: &xla::PjRtBuffer = match kv {
            KvState::Device(buf) => buf,
            KvState::Host(lit) => {
                kv_uploaded = xerr(self.client.buffer_from_host_literal(None, lit))?;
                &kv_uploaded
            }
        };
        let b_tokens = self.up_i32(&[e.batch], tokens)?;
        let b_pos = self.up_i32(&[e.batch], pos)?;
        let b_k = self.up_i32(&[e.n_layers], k_vec)?;
        let b_bias = self.up_f32(&[e.n_layers, e.n_experts], gate_bias)?;
        let mut outs = self.exec(
            &self.exes.decode,
            vec![kv_ref, &b_tokens, &b_pos, &b_k, &b_bias],
            2,
        )?;
        let kv = outs.pop().unwrap().into_kv();
        let logits = outs.pop().unwrap().to_f32()?;
        let (c0, c1) = self.calls.get();
        self.calls.set((c0, c1 + 1));
        Ok(DecodeOut { logits, kv })
    }

    /// Stage-1 probe: run one MoE layer on host-provided activations.
    /// x is [profile_tokens * hidden]; returns y of the same size. The
    /// layer's weight slices are uploaded once and cached.
    pub fn moe_layer(&self, layer: usize, x: &[f32], k: i32) -> Result<Vec<f32>> {
        let e = &self.entry;
        anyhow::ensure!(layer < e.n_layers);
        anyhow::ensure!(x.len() == e.profile_tokens * e.hidden);
        {
            let mut cache = self.layer_cache.borrow_mut();
            if !cache.contains_key(&layer) {
                let (gate, w1, w3, w2) = self.params.moe_layer_slices(layer)?;
                let bias = HostTensor::zeros(vec![e.n_experts]);
                cache.insert(
                    layer,
                    vec![
                        self.up_f32(&gate.shape, &gate.data)?,
                        self.up_f32(&bias.shape, &bias.data)?,
                        self.up_f32(&w1.shape, &w1.data)?,
                        self.up_f32(&w3.shape, &w3.data)?,
                        self.up_f32(&w2.shape, &w2.data)?,
                    ],
                );
            }
        }
        let b_x = self.up_f32(&[e.profile_tokens, e.hidden], x)?;
        let b_k = self.up_i32(&[], &[k])?;
        let cache = self.layer_cache.borrow();
        let lw = &cache[&layer];
        let args: Vec<&xla::PjRtBuffer> =
            vec![&b_x, &lw[0], &lw[1], &lw[2], &lw[3], &lw[4], &b_k];
        let mut outs = xerr(self.exes.moe_layer.execute_b::<&xla::PjRtBuffer>(&args))?;
        let bufs = std::mem::take(&mut outs[0]);
        let lit = if bufs.len() == 1 {
            let mut l = xerr(bufs[0].to_literal_sync())?;
            match l.decompose_tuple() {
                Ok(mut parts) if !parts.is_empty() => parts.remove(0),
                _ => l,
            }
        } else {
            xerr(bufs[0].to_literal_sync())?
        };
        Ok(xerr(lit.to_vec::<f32>())?)
    }
}

/// Upload all parameters as device buffers in manifest execute order.
fn upload_params(
    client: &xla::PjRtClient,
    params: &HostParams,
    entry: &ManifestModel,
) -> Result<Vec<xla::PjRtBuffer>> {
    entry
        .param_order
        .iter()
        .map(|n| {
            let t = params.get(n)?;
            xerr(client.buffer_from_host_buffer(&t.data, &t.shape, None))
        })
        .collect()
}

/// One graph output: device buffer (untupled) or host literal (tuple).
enum OutBuf {
    Device(xla::PjRtBuffer),
    Host(xla::Literal),
}

impl OutBuf {
    fn into_kv(self) -> KvState {
        match self {
            OutBuf::Device(b) => KvState::Device(b),
            OutBuf::Host(l) => KvState::Host(l),
        }
    }

    fn to_f32(&self) -> Result<Vec<f32>> {
        match self {
            OutBuf::Device(b) => Ok(xerr(xerr(b.to_literal_sync())?.to_vec::<f32>())?),
            OutBuf::Host(l) => Ok(xerr(l.to_vec::<f32>())?),
        }
    }
}

impl super::ModelBackend for ModelRuntime {
    fn entry(&self) -> &ManifestModel {
        &self.entry
    }

    fn prefill(&self, tokens: &[i32], k_vec: &[i32], gate_bias: &[f32]) -> Result<PrefillOut> {
        ModelRuntime::prefill(self, tokens, k_vec, gate_bias)
    }

    fn decode(
        &self,
        kv: &KvState,
        tokens: &[i32],
        pos: &[i32],
        k_vec: &[i32],
        gate_bias: &[f32],
    ) -> Result<DecodeOut> {
        ModelRuntime::decode(self, kv, tokens, pos, k_vec, gate_bias)
    }

    fn upload_kv(&self, t: &HostTensor) -> Result<KvState> {
        ModelRuntime::upload_kv(self, t)
    }
}
