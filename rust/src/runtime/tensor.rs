//! Minimal host tensor (f32/i32 + shape) used for weight edits, layer
//! slicing, and literal marshalling. Deliberately tiny — the heavy math
//! lives in the XLA executables.

use anyhow::Result;

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Slice index `j` of the leading axis (e.g. one layer of a stacked
    /// [L, ...] parameter).
    pub fn slice_leading(&self, j: usize) -> HostTensor {
        assert!(j < self.shape[0], "index {j} out of {}", self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        HostTensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[j * inner..(j + 1) * inner].to_vec(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &self.shape, bytes)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(HostTensor::new(shape, data))
    }
}

/// i32 literal from a slice + shape (tokens, k_vec, positions).
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Scalar i32 literal.
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_leading_extracts_layer() {
        let t = HostTensor::new(vec![3, 2], vec![0., 1., 2., 3., 4., 5.]);
        let s = t.slice_leading(1);
        assert_eq!(s.shape, vec![2]);
        assert_eq!(s.data, vec![2., 3.]);
    }

    #[test]
    fn fro_norm() {
        let t = HostTensor::new(vec![2], vec![3.0, 4.0]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-12);
    }
}
