//! PJRT runtime bridge: loads the AOT artifacts (HLO text + npz weights)
//! and executes them on the request path. Python is never involved here.
//!
//! Flow: `manifest.json` -> [`manifest::Manifest`] -> [`ModelRuntime`]
//! (compile prefill/decode/moe_layer, upload weights once as device
//! buffers) -> `prefill`/`decode`/`moe_layer` calls from the engine, the
//! LExI profiler, and the eval harness.

pub mod executable;
pub mod manifest;
pub mod synthetic;
pub mod tensor;
pub mod weights;

pub use executable::{DecodeOut, KvState, ModelRuntime, PrefillOut};
pub use manifest::{Manifest, ManifestModel};
pub use synthetic::SyntheticModel;
pub use tensor::HostTensor;
pub use weights::HostParams;

use anyhow::Result;

/// The executable-model surface the serving engine drives: prefill,
/// decode, and KV upload against one model entry. Implemented by the
/// compiled PJRT [`ModelRuntime`] (artifact-backed deployments) and by
/// the host-side [`SyntheticModel`] (deterministic stand-in when no
/// artifacts / real XLA bindings are available), so the whole
/// engine + cluster stack is exercisable in both worlds.
pub trait ModelBackend {
    /// Graph shapes + vocabulary of the bound model.
    fn entry(&self) -> &ManifestModel;

    /// Batched prefill: tokens `[B*T]` row-major, per-layer active-expert
    /// counts, gate bias `[L*E]`. Returns full logits + the KV cache.
    fn prefill(&self, tokens: &[i32], k_vec: &[i32], gate_bias: &[f32]) -> Result<PrefillOut>;

    /// One decode step over all batch slots.
    fn decode(
        &self,
        kv: &KvState,
        tokens: &[i32],
        pos: &[i32],
        k_vec: &[i32],
        gate_bias: &[f32],
    ) -> Result<DecodeOut>;

    /// Upload a host KV tensor as the running cache state.
    fn upload_kv(&self, t: &HostTensor) -> Result<KvState>;
}

/// Shared PJRT client (CPU). One per process.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
