//! PJRT runtime bridge: loads the AOT artifacts (HLO text + npz weights)
//! and executes them on the request path. Python is never involved here.
//!
//! Flow: `manifest.json` -> [`manifest::Manifest`] -> [`ModelRuntime`]
//! (compile prefill/decode/moe_layer, upload weights once as device
//! buffers) -> `prefill`/`decode`/`moe_layer` calls from the engine, the
//! LExI profiler, and the eval harness.

pub mod executable;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use executable::{KvState, ModelRuntime};
pub use manifest::{Manifest, ManifestModel};
pub use tensor::HostTensor;
pub use weights::HostParams;

use anyhow::Result;

/// Shared PJRT client (CPU). One per process.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
