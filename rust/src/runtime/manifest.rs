//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime (graph shapes, parameter ordering, file layout).
//! Parsed with the in-crate JSON module (no serde offline).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse_file, Json};

#[derive(Clone, Debug)]
pub struct ManifestFiles {
    pub params: String,
    pub prefill: String,
    pub decode: String,
    pub moe_layer: String,
    pub calib: String,
    pub train_log: String,
}

#[derive(Clone, Debug)]
pub struct ManifestModel {
    pub name: String,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub is_vlm: bool,
    pub profile_tokens: usize,
    pub files: ManifestFiles,
    /// Flattened param names in jax traversal order — execute() input order.
    pub param_order: Vec<String>,
    pub param_shapes: HashMap<String, Vec<usize>>,
}

impl ManifestModel {
    fn from_json(name: &str, v: &Json) -> Result<Self> {
        let files = v.get("files")?;
        let mut param_shapes = HashMap::new();
        for (k, shape) in v.get("param_shapes")?.as_obj()? {
            param_shapes.insert(k.clone(), shape.usize_vec()?);
        }
        Ok(ManifestModel {
            name: name.to_string(),
            n_layers: v.get("n_layers")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            hidden: v.get("hidden")?.as_usize()?,
            ffn: v.get("ffn")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            prefill_len: v.get("prefill_len")?.as_usize()?,
            batch: v.get("batch")?.as_usize()?,
            is_vlm: v.get("is_vlm")?.as_bool()?,
            profile_tokens: v.get("profile_tokens")?.as_usize()?,
            files: ManifestFiles {
                params: files.get("params")?.as_str()?.into(),
                prefill: files.get("prefill")?.as_str()?.into(),
                decode: files.get("decode")?.as_str()?.into(),
                moe_layer: files.get("moe_layer")?.as_str()?.into(),
                calib: files.get("calib")?.as_str()?.into(),
                train_log: files.get("train_log")?.as_str()?.into(),
            },
            param_order: v.get("param_order")?.str_vec()?,
            param_shapes,
        })
    }

    /// KV cache element count: [L, 2, B, maxT, nh, hd].
    pub fn kv_len(&self) -> usize {
        self.n_layers * 2 * self.batch * self.max_seq * self.n_heads * self.head_dim
    }

    pub fn kv_dims(&self) -> [usize; 6] {
        [
            self.n_layers,
            2,
            self.batch,
            self.max_seq,
            self.n_heads,
            self.head_dim,
        ]
    }
}

/// Special-token layout shared with python/compile/configs.py.
#[derive(Clone, Debug)]
pub struct VocabLayout {
    pub size: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub key: i32,
    pub qry: i32,
    pub fact: i32,
    pub ask: i32,
    pub ans: i32,
    pub sep: i32,
    pub img: i32,
    pub val_base: i32,
    pub n_vals: i32,
    pub text_base: i32,
    pub n_text: i32,
    pub img_base: i32,
    pub n_img: i32,
}

impl VocabLayout {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(VocabLayout {
            size: v.get("size")?.as_usize()?,
            pad: v.get("pad")?.as_i32()?,
            bos: v.get("bos")?.as_i32()?,
            eos: v.get("eos")?.as_i32()?,
            key: v.get("key")?.as_i32()?,
            qry: v.get("qry")?.as_i32()?,
            fact: v.get("fact")?.as_i32()?,
            ask: v.get("ask")?.as_i32()?,
            ans: v.get("ans")?.as_i32()?,
            sep: v.get("sep")?.as_i32()?,
            img: v.get("img")?.as_i32()?,
            val_base: v.get("val_base")?.as_i32()?,
            n_vals: v.get("n_vals")?.as_i32()?,
            text_base: v.get("text_base")?.as_i32()?,
            n_text: v.get("n_text")?.as_i32()?,
            img_base: v.get("img_base")?.as_i32()?,
            n_img: v.get("n_img")?.as_i32()?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: HashMap<String, ManifestModel>,
    pub vocab: VocabLayout,
    pub corpora_dir: String,
    pub root: PathBuf,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let v = parse_file(&path)
            .with_context(|| format!("loading {path:?} — run `make artifacts` first"))?;
        let mut models = HashMap::new();
        for (name, entry) in v.get("models")?.as_obj()? {
            models.insert(name.clone(), ManifestModel::from_json(name, entry)?);
        }
        Ok(Manifest {
            models,
            vocab: VocabLayout::from_json(v.get("vocab")?)?,
            corpora_dir: v.get("corpora_dir")?.as_str()?.into(),
            root,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ManifestModel> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest"))
    }

    pub fn model_dir(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    pub fn corpora_path(&self, file: &str) -> PathBuf {
        self.root.join(&self.corpora_dir).join(file)
    }

    /// Default artifacts location: $LEXI_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("LEXI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}
