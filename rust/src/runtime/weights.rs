//! Weight loading + in-memory editing.
//!
//! `params.npz` (written by `train.save_params_npz`) is read into
//! [`HostParams`]; transforms (intra-pruning's FFN-column zeroing) edit it
//! in memory; [`super::ModelRuntime`] then uploads each array once as a
//! device buffer. One npz on disk serves every configuration.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};
use xla::FromRawBytes;

use super::manifest::ManifestModel;
use super::tensor::HostTensor;

#[derive(Clone, Debug, Default)]
pub struct HostParams {
    pub tensors: HashMap<String, HostTensor>,
}

impl HostParams {
    pub fn load_npz<P: AsRef<Path>>(path: P, entry: &ManifestModel) -> Result<Self> {
        let arrays = xla::Literal::read_npz(path.as_ref(), &())
            .map_err(|e| anyhow::anyhow!("reading npz: {e:?}"))?;
        let mut tensors = HashMap::new();
        for (name, lit) in arrays {
            tensors.insert(name, HostTensor::from_literal(&lit)?);
        }
        // validate against the manifest
        for name in &entry.param_order {
            let t = tensors
                .get(name)
                .with_context(|| format!("param '{name}' missing from npz"))?;
            let want = &entry.param_shapes[name];
            anyhow::ensure!(
                &t.shape == want,
                "param '{name}' shape {:?} != manifest {:?}",
                t.shape,
                want
            );
        }
        Ok(HostParams { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("param '{name}' not loaded"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut HostTensor> {
        self.tensors
            .get_mut(name)
            .ok_or_else(|| anyhow::anyhow!("param '{name}' not loaded"))
    }

    /// Literals in manifest execute() order.
    pub fn literals_in_order(&self, entry: &ManifestModel) -> Result<Vec<xla::Literal>> {
        entry
            .param_order
            .iter()
            .map(|n| self.get(n)?.to_literal())
            .collect()
    }

    /// One layer's MoE weights (for the Stage-1 moe_layer graph):
    /// (gate [H,E], w1 [E,H,F], w3 [E,H,F], w2 [E,F,H]).
    pub fn moe_layer_slices(
        &self,
        layer: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor, HostTensor)> {
        Ok((
            self.get("layers/gate")?.slice_leading(layer),
            self.get("layers/w1")?.slice_leading(layer),
            self.get("layers/w3")?.slice_leading(layer),
            self.get("layers/w2")?.slice_leading(layer),
        ))
    }
}

/// Calibration statistics exported at build time (calib.npz): the
/// data-dependent signal the NAEE-style baselines consume (and LExI does
/// not need).
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// Mean full-softmax router probability per (layer, expert).
    pub mean_prob: Vec<Vec<f32>>,
    /// Top-k selection frequency per (layer, expert).
    pub sel_freq: Vec<Vec<f32>>,
    /// Total gate mass per (layer, expert).
    pub gate_mass: Vec<Vec<f32>>,
}

impl CalibStats {
    pub fn load_npz<P: AsRef<Path>>(path: P, n_layers: usize, n_experts: usize) -> Result<Self> {
        let arrays = xla::Literal::read_npz(path.as_ref(), &())
            .map_err(|e| anyhow::anyhow!("reading calib npz: {e:?}"))?;
        let mut by_name: HashMap<String, Vec<f32>> = HashMap::new();
        for (name, lit) in arrays {
            by_name.insert(name, lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
        }
        let reshape = |name: &str| -> Result<Vec<Vec<f32>>> {
            let flat = by_name
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("calib '{name}' missing"))?;
            anyhow::ensure!(flat.len() == n_layers * n_experts);
            Ok(flat
                .chunks(n_experts)
                .map(|c| c.to_vec())
                .collect())
        };
        Ok(CalibStats {
            mean_prob: reshape("mean_prob")?,
            sel_freq: reshape("sel_freq")?,
            gate_mass: reshape("gate_mass")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_params_accessors() {
        let mut p = HostParams::default();
        p.tensors.insert(
            "layers/gate".into(),
            HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
        );
        assert!(p.get("layers/gate").is_ok());
        assert!(p.get("nope").is_err());
        p.get_mut("layers/gate").unwrap().data[0] = 9.0;
        assert_eq!(p.get("layers/gate").unwrap().data[0], 9.0);
    }
}
