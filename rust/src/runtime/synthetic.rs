//! Host-side synthetic model backend: the deterministic stand-in that
//! lets the FULL engine stack (batcher, KV accounting, sampler, metrics,
//! `server::EngineReplica`) run end-to-end without compiled artifacts or
//! real XLA bindings.
//!
//! The "model" maps each `(last token, position)` pair to one hot logit
//! via an integer hash, so greedy decoding yields reproducible token
//! streams at negligible cost. The KV cache keeps the real layout
//! ([`ManifestModel::kv_dims`]) with minimal head dims, so the engine's
//! prefill-splice and upload paths execute unchanged. Quality numbers
//! from this backend are meaningless by construction — it exists to
//! exercise scheduling, not accuracy.

use std::collections::HashMap;

use anyhow::Result;

use super::executable::{DecodeOut, KvState, PrefillOut};
use super::manifest::{ManifestFiles, ManifestModel};
use super::tensor::HostTensor;
use super::ModelBackend;

/// A host-only model with real graph shapes and hash-derived logits.
pub struct SyntheticModel {
    entry: ManifestModel,
}

impl SyntheticModel {
    /// Build a backend with the structural dims that matter to serving
    /// (layer/expert counts drive `k_vec`/`gate_bias` shapes; batch and
    /// sequence shapes drive slots and KV capacity). Head/hidden dims
    /// are kept minimal so per-step KV traffic stays cheap.
    pub fn new(
        name: &str,
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
        batch: usize,
        prefill_len: usize,
        max_seq: usize,
    ) -> Self {
        assert!(batch >= 1 && n_layers >= 1 && prefill_len >= 1);
        assert!(max_seq > prefill_len, "max_seq must leave decode headroom");
        let entry = ManifestModel {
            name: name.to_string(),
            n_layers,
            n_experts,
            top_k,
            hidden: 8,
            ffn: 8,
            n_heads: 1,
            head_dim: 2,
            vocab: 128,
            max_seq,
            prefill_len,
            batch,
            is_vlm: false,
            profile_tokens: 16,
            files: ManifestFiles {
                params: String::new(),
                prefill: String::new(),
                decode: String::new(),
                moe_layer: String::new(),
                calib: String::new(),
                train_log: String::new(),
            },
            param_order: Vec::new(),
            param_shapes: HashMap::new(),
        };
        SyntheticModel { entry }
    }

    /// One-hot "next token" for a `(token, pos)` pair: a fixed integer
    /// mix, never landing on the special ids 0..3 (pad/bos/eos).
    fn write_logit_row(&self, token: i32, pos: i32, row: &mut [f32]) {
        let v = self.entry.vocab as u64;
        let h = (token as u64)
            .wrapping_mul(0x9e37_79b9)
            .wrapping_add((pos as u64).wrapping_mul(0x85eb_ca6b))
            .wrapping_add(0x27d4_eb2f);
        row[3 + (h % (v - 3)) as usize] = 1.0;
    }

    /// A KV literal of the real layout (content carries no state the
    /// synthetic logits depend on).
    fn blank_kv(&self) -> Result<KvState> {
        Ok(KvState::Host(
            HostTensor::zeros(self.entry.kv_dims().to_vec()).to_literal()?,
        ))
    }
}

impl ModelBackend for SyntheticModel {
    fn entry(&self) -> &ManifestModel {
        &self.entry
    }

    fn prefill(&self, tokens: &[i32], k_vec: &[i32], gate_bias: &[f32]) -> Result<PrefillOut> {
        let e = &self.entry;
        anyhow::ensure!(tokens.len() == e.batch * e.prefill_len);
        anyhow::ensure!(k_vec.len() == e.n_layers);
        anyhow::ensure!(gate_bias.len() == e.n_layers * e.n_experts);
        let mut logits = vec![0.0f32; e.batch * e.prefill_len * e.vocab];
        for b in 0..e.batch {
            for p in 0..e.prefill_len {
                let at = b * e.prefill_len + p;
                self.write_logit_row(
                    tokens[at],
                    p as i32,
                    &mut logits[at * e.vocab..(at + 1) * e.vocab],
                );
            }
        }
        Ok(PrefillOut {
            logits,
            kv: self.blank_kv()?,
        })
    }

    fn decode(
        &self,
        kv: &KvState,
        tokens: &[i32],
        pos: &[i32],
        k_vec: &[i32],
        gate_bias: &[f32],
    ) -> Result<DecodeOut> {
        let e = &self.entry;
        anyhow::ensure!(tokens.len() == e.batch && pos.len() == e.batch);
        anyhow::ensure!(k_vec.len() == e.n_layers);
        anyhow::ensure!(gate_bias.len() == e.n_layers * e.n_experts);
        let mut logits = vec![0.0f32; e.batch * e.vocab];
        for b in 0..e.batch {
            self.write_logit_row(
                tokens[b],
                pos[b],
                &mut logits[b * e.vocab..(b + 1) * e.vocab],
            );
        }
        // pass the cache through; its contents are inert here
        let kv = match kv {
            KvState::Host(lit) => KvState::Host(lit.clone()),
            KvState::Device(_) => self.blank_kv()?,
        };
        Ok(DecodeOut { logits, kv })
    }

    fn upload_kv(&self, t: &HostTensor) -> Result<KvState> {
        Ok(KvState::Host(t.to_literal()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SyntheticModel {
        SyntheticModel::new("syn", 4, 8, 2, 2, 16, 32)
    }

    #[test]
    fn shapes_match_the_manifest_contract() {
        let m = model();
        let e = m.entry();
        assert_eq!(e.kv_len(), 4 * 2 * 2 * 32 * 1 * 2);
        let tokens = vec![5i32; e.batch * e.prefill_len];
        let k = vec![2i32; e.n_layers];
        let bias = vec![0.0f32; e.n_layers * e.n_experts];
        let out = ModelBackend::prefill(&m, &tokens, &k, &bias).unwrap();
        assert_eq!(out.logits.len(), e.batch * e.prefill_len * e.vocab);
        let host = out.kv.to_host().unwrap();
        assert_eq!(host.len(), e.kv_len());
    }

    #[test]
    fn decode_is_deterministic_and_avoids_special_tokens() {
        let m = model();
        let e = m.entry().clone();
        let kv = m.upload_kv(&HostTensor::zeros(e.kv_dims().to_vec())).unwrap();
        let k = vec![2i32; e.n_layers];
        let bias = vec![0.0f32; e.n_layers * e.n_experts];
        let a = ModelBackend::decode(&m, &kv, &[7, 9], &[3, 4], &k, &bias).unwrap();
        let b = ModelBackend::decode(&m, &kv, &[7, 9], &[3, 4], &k, &bias).unwrap();
        assert_eq!(a.logits, b.logits);
        for slot in 0..e.batch {
            let row = &a.logits[slot * e.vocab..(slot + 1) * e.vocab];
            let arg = crate::engine::sampler::argmax(row) as usize;
            assert!(arg >= 3, "special token {arg} sampled");
            assert_eq!(row[arg], 1.0);
        }
        // different inputs move the argmax
        let c = ModelBackend::decode(&m, &kv, &[8, 9], &[3, 4], &k, &bias).unwrap();
        assert_ne!(a.logits, c.logits);
    }

    #[test]
    fn bad_shapes_are_rejected() {
        let m = model();
        let e = m.entry().clone();
        let bias = vec![0.0f32; e.n_layers * e.n_experts];
        assert!(ModelBackend::prefill(&m, &[1, 2, 3], &[2; 4], &bias).is_err());
        let kv = m.upload_kv(&HostTensor::zeros(e.kv_dims().to_vec())).unwrap();
        assert!(ModelBackend::decode(&m, &kv, &[1, 2], &[0, 0], &[2; 3], &bias).is_err());
    }
}
