//! Synthetic-vocabulary tokenizer.
//!
//! The analogue models speak the structured vocabulary defined in
//! python/compile/configs.py (special markers, value tokens, Markov text
//! tokens, image patches). This tokenizer renders ids readably for demos
//! and maps ASCII text into the text-token range for ad-hoc prompts.

use crate::runtime::manifest::VocabLayout;

pub struct Tokenizer {
    pub vocab: VocabLayout,
}

impl Tokenizer {
    pub fn new(vocab: VocabLayout) -> Self {
        Tokenizer { vocab }
    }

    /// Human-readable rendering of one token id.
    pub fn render(&self, tok: i32) -> String {
        let v = &self.vocab;
        match tok {
            t if t == v.pad => "<pad>".into(),
            t if t == v.bos => "<bos>".into(),
            t if t == v.eos => "<eos>".into(),
            t if t == v.key => "<key>".into(),
            t if t == v.qry => "<qry>".into(),
            t if t == v.fact => "<fact>".into(),
            t if t == v.ask => "<ask>".into(),
            t if t == v.ans => "<ans>".into(),
            t if t == v.sep => "<sep>".into(),
            t if t == v.img => "<img>".into(),
            t if t >= v.val_base && t < v.val_base + v.n_vals => {
                format!("v{}", t - v.val_base)
            }
            t if t >= v.text_base && t < v.text_base + v.n_text => {
                format!("w{}", t - v.text_base)
            }
            t if t >= v.img_base && t < v.img_base + v.n_img => {
                format!("p{}", t - v.img_base)
            }
            t => format!("?{t}"),
        }
    }

    pub fn render_seq(&self, toks: &[i32]) -> String {
        toks.iter()
            .map(|&t| self.render(t))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Map arbitrary ASCII text into the text-token range (deterministic,
    /// for demo prompts only — the models were trained on Markov data).
    pub fn encode_text(&self, text: &str) -> Vec<i32> {
        let v = &self.vocab;
        let mut out = vec![v.bos];
        for w in text.split_whitespace() {
            let mut h = 1469598103934665603u64;
            for b in w.bytes() {
                h = (h ^ b as u64).wrapping_mul(1099511628211);
            }
            out.push(v.text_base + (h % v.n_text as u64) as i32);
        }
        out
    }

    /// Is the token a value token (answer alphabet of the tasks)?
    pub fn is_value(&self, tok: i32) -> bool {
        tok >= self.vocab.val_base && tok < self.vocab.val_base + self.vocab.n_vals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> VocabLayout {
        VocabLayout {
            size: 256,
            pad: 0,
            bos: 1,
            eos: 2,
            key: 3,
            qry: 4,
            fact: 5,
            ask: 6,
            ans: 7,
            sep: 8,
            img: 9,
            val_base: 10,
            n_vals: 32,
            text_base: 42,
            n_text: 128,
            img_base: 170,
            n_img: 64,
        }
    }

    #[test]
    fn renders_specials_and_ranges() {
        let t = Tokenizer::new(vocab());
        assert_eq!(t.render(1), "<bos>");
        assert_eq!(t.render(10), "v0");
        assert_eq!(t.render(42), "w0");
        assert_eq!(t.render(170), "p0");
        assert_eq!(t.render_seq(&[1, 10, 2]), "<bos> v0 <eos>");
    }

    #[test]
    fn encode_text_in_range_and_deterministic() {
        let t = Tokenizer::new(vocab());
        let a = t.encode_text("hello moe world");
        let b = t.encode_text("hello moe world");
        assert_eq!(a, b);
        assert_eq!(a[0], 1);
        for &tok in &a[1..] {
            assert!((42..170).contains(&tok));
        }
    }

    #[test]
    fn value_range_check() {
        let t = Tokenizer::new(vocab());
        assert!(t.is_value(10) && t.is_value(41));
        assert!(!t.is_value(42) && !t.is_value(9));
    }
}
