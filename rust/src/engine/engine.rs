//! The serving engine: continuous batching over a model backend.
//!
//! One `step()` either (a) admits waiting requests into free slots — a
//! batched prefill whose per-slot KV rows are spliced into the running
//! cache, alongside in-flight decodes — or (b) advances every active slot
//! one decode step. `run_until_complete` drains the queue;
//! [`Engine::step_detail`] exposes the same scheduling step
//! non-blockingly (which requests got their first token, which finished)
//! so a replica backend can drive the engine from an event loop.
//!
//! The engine is generic over [`ModelBackend`]: the compiled PJRT
//! [`ModelRuntime`] in artifact-backed deployments, or the host-side
//! [`SyntheticModel`](crate::runtime::SyntheticModel) when no artifacts
//! are available (the scheduling, KV accounting, and sampling paths are
//! identical either way).

use anyhow::Result;

use crate::config::serving::ServingConfig;
use crate::experts::{ExpertResidency, ResidencyStats};
use crate::runtime::executable::KvState;
use crate::runtime::{ModelBackend, ModelRuntime};
use crate::util::Pcg32;

use super::batcher::{Batcher, Slot};
use super::kv_manager::KvBlockManager;
use super::metrics::EngineMetrics;
use super::request::{FinishReason, Request, RequestId, RequestOutput, SamplingParams};
use super::sampler;

/// What one scheduling step did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// Admitted waiting requests with one batched prefill.
    Prefill,
    /// Advanced every active slot one decode step.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Outcome of one scheduling step — the non-blocking drain surface used
/// by event-loop drivers (`server::EngineReplica`).
#[derive(Debug)]
pub struct StepOutcome {
    pub kind: StepKind,
    /// Requests whose first token was produced by this step.
    pub first_tokens: Vec<RequestId>,
    /// Requests that finished during this step.
    pub finished: Vec<RequestOutput>,
}

/// Per-model serving engine bound to one transform configuration
/// (k_vec + gate_bias + already-edited weights inside `model`).
pub struct Engine<'m, M: ModelBackend = ModelRuntime> {
    pub model: &'m M,
    pub cfg: ServingConfig,
    k_vec: Vec<i32>,
    gate_bias: Vec<f32>,
    batcher: Batcher,
    kv_mgr: KvBlockManager,
    /// Running KV cache (literal handed to the decode graph by
    /// reference; host-copied only when splicing in fresh prefills).
    kv: KvState,
    pub metrics: EngineMetrics,
    /// Optional expert-residency model: each scheduling step demands the
    /// routed expert sets, charging HBM miss stalls into the metrics.
    /// `None` (the default) keeps the historical every-expert-resident
    /// behavior.
    residency: Option<ExpertResidency>,
    rng: Pcg32,
    next_id: RequestId,
    outputs: Vec<RequestOutput>,
}

impl<'m, M: ModelBackend> Engine<'m, M> {
    pub fn new(
        model: &'m M,
        cfg: ServingConfig,
        k_vec: Vec<i32>,
        gate_bias: Vec<f32>,
    ) -> Result<Self> {
        let e = model.entry();
        anyhow::ensure!(cfg.batch == e.batch, "config batch != graph batch");
        anyhow::ensure!(k_vec.len() == e.n_layers);
        anyhow::ensure!(gate_bias.len() == e.n_layers * e.n_experts);
        let kv = KvState::Host(
            crate::runtime::tensor::HostTensor::zeros(e.kv_dims().to_vec()).to_literal()?,
        );
        Ok(Engine {
            model,
            batcher: Batcher::new(cfg.batch, cfg.queue_cap),
            kv_mgr: KvBlockManager::new(cfg.kv_blocks_total, cfg.kv_block),
            kv,
            metrics: EngineMetrics::default(),
            residency: None,
            rng: Pcg32::seeded(0x5e41),
            next_id: 0,
            outputs: Vec::new(),
            k_vec,
            gate_bias,
            cfg,
        })
    }

    /// Enqueue a request; returns its id. Prompts longer than the static
    /// prefill graph keep their tail; every truncation is counted in
    /// [`EngineMetrics::truncated_prompts`] (and surfaced in the
    /// summary) instead of disappearing silently.
    pub fn submit(&mut self, prompt: Vec<i32>, sampling: SamplingParams) -> Result<RequestId> {
        let id = self.next_id;
        self.next_id += 1;
        let mut prompt = prompt;
        let dropped = prompt.len().saturating_sub(self.cfg.prefill_len);
        if dropped > 0 {
            prompt.drain(0..dropped); // keep the tail
        }
        self.batcher.push(Request::new(id, prompt, sampling))?;
        // count only after admission: a queue-full rejection is not a
        // served-and-truncated request
        if dropped > 0 {
            self.metrics.truncated_prompts += 1;
            self.metrics.truncated_tokens += dropped as u64;
        }
        Ok(id)
    }

    pub fn idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// Requests currently occupying decode slots.
    pub fn n_active(&self) -> usize {
        self.batcher.n_active()
    }

    /// Requests waiting in the engine-internal queue.
    pub fn n_waiting(&self) -> usize {
        self.batcher.waiting.len()
    }

    /// Capacity of the engine-internal waiting queue.
    pub fn queue_capacity(&self) -> usize {
        self.batcher.queue_capacity()
    }

    /// Current per-layer active-expert budgets.
    pub fn k_vec(&self) -> &[i32] {
        &self.k_vec
    }

    /// Swap the per-layer active-expert budgets (LExI quality-ladder
    /// rung reconfiguration). Takes effect from the next forward call —
    /// no recompilation, k is a runtime graph argument.
    pub fn set_k_vec(&mut self, k_vec: Vec<i32>) -> Result<()> {
        anyhow::ensure!(
            k_vec.len() == self.model.entry().n_layers,
            "k_vec has {} entries, graph has {} layers",
            k_vec.len(),
            self.model.entry().n_layers
        );
        // residency repins + prewarms the new per-layer hot sets
        if let Some(r) = &mut self.residency {
            r.set_k_vec(&k_vec);
        }
        self.k_vec = k_vec;
        Ok(())
    }

    /// Attach an expert-residency model (must match the graph's layer
    /// count). Every subsequent step consults the store; hit/miss/stall
    /// counters land in [`EngineMetrics`].
    pub fn set_residency(&mut self, mut residency: ExpertResidency) -> Result<()> {
        anyhow::ensure!(
            residency.n_layers() == self.model.entry().n_layers,
            "residency models {} layers, graph has {}",
            residency.n_layers(),
            self.model.entry().n_layers
        );
        residency.set_k_vec(&self.k_vec);
        self.residency = Some(residency);
        Ok(())
    }

    /// Residency counters (`None` when no residency model is attached).
    pub fn residency_stats(&self) -> Option<ResidencyStats> {
        self.residency.as_ref().map(|r| r.stats())
    }

    /// Residency pressure in [0, 1] (miss-rate EWMA; `None` without a
    /// residency model) — the telemetry signal replica backends report.
    pub fn residency_pressure(&self) -> Option<f64> {
        self.residency.as_ref().map(|r| r.pressure())
    }

    /// Drain finished outputs without waiting for the queue to empty
    /// (the non-blocking sibling of [`Engine::run_until_complete`]).
    pub fn take_outputs(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.outputs)
    }

    /// Drive the engine until every submitted request has completed.
    pub fn run_until_complete(&mut self) -> Result<Vec<RequestOutput>> {
        self.metrics.start();
        while !self.idle() {
            self.step()?;
        }
        self.metrics.finish();
        Ok(std::mem::take(&mut self.outputs))
    }

    /// One scheduling step. Returns false when there was nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        let outcome = self.step_detail()?;
        let progressed = outcome.kind != StepKind::Idle;
        // keep run_until_complete semantics: outputs accumulate until
        // drained at the end
        self.outputs.extend(outcome.finished);
        Ok(progressed)
    }

    /// One scheduling step, reporting which requests got their first
    /// token and which finished. Finished outputs are handed to the
    /// caller (NOT retained for [`Engine::run_until_complete`]).
    pub fn step_detail(&mut self) -> Result<StepOutcome> {
        let before = self.outputs.len();
        let first_tokens = self.try_admit()?;
        let kind = if !first_tokens.is_empty() {
            StepKind::Prefill
        } else if self.batcher.n_active() > 0 {
            self.decode_step()?;
            StepKind::Decode
        } else {
            StepKind::Idle
        };
        let finished = self.outputs.split_off(before);
        Ok(StepOutcome {
            kind,
            first_tokens,
            finished,
        })
    }

    // ----------------------------------------------------------------
    // prefill path
    // ----------------------------------------------------------------

    /// Admit as many waiting requests as slots + KV blocks allow; run one
    /// batched prefill for all of them. Returns the admitted request ids
    /// (each produced its first token).
    fn try_admit(&mut self) -> Result<Vec<RequestId>> {
        let free = self.batcher.free_slot_indices();
        if free.is_empty() || self.batcher.waiting.is_empty() {
            return Ok(Vec::new());
        }
        let e = self.model.entry().clone();
        let mut admitted: Vec<(usize, super::request::Tracked)> = Vec::new();
        for &slot_idx in &free {
            let kv_mgr = &mut self.kv_mgr;
            let max_seq = self.cfg.max_seq;
            let popped = self.batcher.pop_admissible(|t| {
                let demand = (t.req.prompt.len() + t.req.sampling.max_new_tokens).min(max_seq);
                kv_mgr.can_admit(demand)
            });
            match popped {
                Some(t) => {
                    let demand = (t.req.prompt.len() + t.req.sampling.max_new_tokens)
                        .min(self.cfg.max_seq);
                    self.kv_mgr.admit(t.req.id, demand)?;
                    admitted.push((slot_idx, t));
                }
                None => break,
            }
        }
        if admitted.is_empty() {
            return Ok(Vec::new());
        }

        // Build the padded token matrix.
        let mut tokens = vec![0i32; e.batch * e.prefill_len];
        for (slot_idx, t) in &admitted {
            let p = &t.req.prompt;
            tokens[slot_idx * e.prefill_len..slot_idx * e.prefill_len + p.len()]
                .copy_from_slice(p);
        }
        let prompt_tokens: usize = admitted.iter().map(|(_, t)| t.req.prompt.len()).sum();
        let out = self
            .model
            .prefill(&tokens, &self.k_vec, &self.gate_bias)?;
        self.metrics.prefill_calls += 1;
        self.metrics.prefill_tokens += prompt_tokens as u64;
        if let Some(r) = &mut self.residency {
            let step = r.step(prompt_tokens.max(1));
            self.metrics.record_residency(&step);
        }

        // Splice the admitted slots' cache rows into the running cache
        // (the only host-side KV copy in the engine; decode steps pass
        // the literal through by reference — §Perf L3).
        let kv_new = out.kv.to_host()?;
        let mut kv_run = self.kv.to_host()?;
        let row = e.max_seq * e.n_heads * e.head_dim;
        let per_lane = e.batch * row; // one (layer, k/v) lane
        for (slot_idx, _) in &admitted {
            for lane in 0..e.n_layers * 2 {
                let off = lane * per_lane + slot_idx * row;
                kv_run.data[off..off + row].copy_from_slice(&kv_new.data[off..off + row]);
            }
        }
        self.kv = self.model.upload_kv(&kv_run)?;

        let mut ids = Vec::with_capacity(admitted.len());
        for (slot_idx, mut t) in admitted {
            let plen = t.req.prompt.len();
            ids.push(t.req.id);
            // first token from the last prompt position's logits
            let row = &out.logits
                [(slot_idx * e.prefill_len + plen - 1) * e.vocab..][..e.vocab];
            let tok = sampler::sample(row, &t.req.sampling, &mut self.rng);
            t.first_token = Some(std::time::Instant::now());
            t.generated.push(tok);
            self.batcher.occupy(
                slot_idx,
                Slot {
                    tracked: t,
                    pos: plen,
                    last: tok,
                },
            );
            // single-token requests finish immediately
            self.maybe_finish(slot_idx)?;
        }
        Ok(ids)
    }

    // ----------------------------------------------------------------
    // decode path
    // ----------------------------------------------------------------

    fn decode_step(&mut self) -> Result<()> {
        let e = self.model.entry().clone();
        let mut tokens = vec![0i32; e.batch];
        let mut pos = vec![(e.max_seq - 1) as i32; e.batch]; // inactive parking
        let mut active = Vec::new();
        for (i, s) in self.batcher.slots.iter().enumerate() {
            if let Some(slot) = s {
                tokens[i] = slot.last;
                pos[i] = slot.pos as i32;
                active.push(i);
            }
        }
        let out = self
            .model
            .decode(&self.kv, &tokens, &pos, &self.k_vec, &self.gate_bias)?;
        self.metrics
            .record_decode_step(active.len(), e.batch);
        if let Some(r) = &mut self.residency {
            let step = r.step(active.len());
            self.metrics.record_residency(&step);
        }
        self.kv = out.kv;

        for i in active {
            let row = &out.logits[i * e.vocab..(i + 1) * e.vocab];
            let (tok, max_new, _eos) = {
                let slot = self.batcher.slots[i].as_mut().unwrap();
                let tok = sampler::sample(row, &slot.tracked.req.sampling, &mut self.rng);
                slot.pos += 1;
                slot.last = tok;
                slot.tracked.generated.push(tok);
                (
                    tok,
                    slot.tracked.req.sampling.max_new_tokens,
                    slot.tracked.req.sampling.stop_on_eos,
                )
            };
            let _ = (tok, max_new);
            self.maybe_finish(i)?;
        }
        Ok(())
    }

    /// Finish the slot if EOS / token budget / KV capacity says so.
    fn maybe_finish(&mut self, idx: usize) -> Result<()> {
        let e = self.model.entry();
        let (done, reason) = {
            let slot = self.batcher.slots[idx].as_ref().unwrap();
            let t = &slot.tracked;
            let sp = &t.req.sampling;
            if sp.stop_on_eos && t.generated.last() == Some(&EOS_TOKEN) {
                (true, FinishReason::Eos)
            } else if t.generated.len() >= sp.max_new_tokens {
                (true, FinishReason::MaxTokens)
            } else if slot.pos + 1 >= e.max_seq {
                (true, FinishReason::CapacityTruncated)
            } else {
                (false, FinishReason::MaxTokens)
            }
        };
        if !done {
            return Ok(());
        }
        let slot = self.batcher.vacate(idx).unwrap();
        let t = slot.tracked;
        self.kv_mgr.release(t.req.id);
        let now = std::time::Instant::now();
        let first = t.first_token.unwrap_or(now);
        let out = RequestOutput {
            id: t.req.id,
            prompt_len: t.req.prompt.len(),
            tokens: t.generated,
            finish: reason,
            ttft_s: (first - t.enqueued).as_secs_f64(),
            e2e_s: (now - t.enqueued).as_secs_f64(),
        };
        self.metrics.record(out.clone());
        self.outputs.push(out);
        Ok(())
    }

    /// Raw single-shot generation helper used by the eval harness: fills
    /// up to `batch` prompts, greedy-decodes `n_new` tokens each, returns
    /// the generated ids per prompt. Bypasses queueing/metrics.
    pub fn generate_batch(
        model: &M,
        prompts: &[&[i32]],
        n_new: usize,
        k_vec: &[i32],
        gate_bias: &[f32],
    ) -> Result<Vec<Vec<i32>>> {
        let e = model.entry();
        anyhow::ensure!(prompts.len() <= e.batch);
        let mut tokens = vec![0i32; e.batch * e.prefill_len];
        for (i, p) in prompts.iter().enumerate() {
            anyhow::ensure!(p.len() <= e.prefill_len, "prompt too long");
            tokens[i * e.prefill_len..i * e.prefill_len + p.len()].copy_from_slice(p);
        }
        let out = model.prefill(&tokens, k_vec, gate_bias)?;
        let mut gen: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut last = vec![0i32; e.batch];
        let mut pos = vec![(e.max_seq - 1) as i32; e.batch];
        for (i, p) in prompts.iter().enumerate() {
            let row = &out.logits[(i * e.prefill_len + p.len() - 1) * e.vocab..][..e.vocab];
            last[i] = sampler::argmax(row);
            pos[i] = p.len() as i32;
            gen[i].push(last[i]);
        }
        let mut kv = out.kv;
        for _ in 1..n_new {
            let d = model.decode(&kv, &last, &pos, k_vec, gate_bias)?;
            for (i, g) in gen.iter_mut().enumerate() {
                let row = &d.logits[i * e.vocab..(i + 1) * e.vocab];
                last[i] = sampler::argmax(row);
                pos[i] += 1;
                g.push(last[i]);
            }
            kv = d.kv;
        }
        Ok(gen)
    }
}

/// EOS id of the shared vocabulary (python/compile/configs.py).
pub const EOS_TOKEN: i32 = 2;
