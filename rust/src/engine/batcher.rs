//! Continuous batcher: waiting queue + fixed decode slots.
//!
//! New requests are admitted into free slots whenever the KV manager has
//! capacity (prefill happens alongside in-flight decodes — the vLLM
//! scheduling discipline); finished slots free immediately.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use super::request::{Request, Tracked};

/// One occupied decode slot.
#[derive(Debug)]
pub struct Slot {
    pub tracked: Tracked,
    /// Next cache write position (= tokens currently in context).
    pub pos: usize,
    /// Last sampled token (input to the next decode step).
    pub last: i32,
}

#[derive(Debug)]
pub struct Batcher {
    pub waiting: VecDeque<Tracked>,
    pub slots: Vec<Option<Slot>>,
    queue_cap: usize,
}

impl Batcher {
    pub fn new(n_slots: usize, queue_cap: usize) -> Self {
        Batcher {
            waiting: VecDeque::new(),
            slots: (0..n_slots).map(|_| None).collect(),
            queue_cap,
        }
    }

    pub fn push(&mut self, req: Request) -> Result<()> {
        if self.waiting.len() >= self.queue_cap {
            bail!("queue full ({} waiting)", self.queue_cap);
        }
        self.waiting.push_back(Tracked::new(req));
        Ok(())
    }

    pub fn free_slot_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Maximum number of requests the waiting queue accepts.
    pub fn queue_capacity(&self) -> usize {
        self.queue_cap
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.n_active() == 0
    }

    /// Pop the next waiting request if `admit` approves it; the caller
    /// places it into a slot after prefill.
    pub fn pop_admissible<F: FnMut(&Tracked) -> bool>(
        &mut self,
        mut admit: F,
    ) -> Option<Tracked> {
        match self.waiting.front() {
            Some(t) if admit(t) => self.waiting.pop_front(),
            _ => None,
        }
    }

    pub fn occupy(&mut self, idx: usize, slot: Slot) {
        debug_assert!(self.slots[idx].is_none(), "slot {idx} already occupied");
        self.slots[idx] = Some(slot);
    }

    pub fn vacate(&mut self, idx: usize) -> Option<Slot> {
        self.slots[idx].take()
    }

    /// Consistency invariant: a request id appears at most once anywhere.
    pub fn check_invariant(&self) -> Result<()> {
        let mut ids = std::collections::HashSet::new();
        for t in &self.waiting {
            anyhow::ensure!(ids.insert(t.req.id), "duplicate id {} in queue", t.req.id);
        }
        for s in self.slots.iter().flatten() {
            anyhow::ensure!(
                ids.insert(s.tracked.req.id),
                "id {} both queued and running",
                s.tracked.req.id
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::SamplingParams;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], SamplingParams::default())
    }

    #[test]
    fn queue_cap_enforced() {
        let mut b = Batcher::new(2, 2);
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        assert!(b.push(req(3)).is_err());
    }

    #[test]
    fn admit_occupy_vacate_cycle() {
        let mut b = Batcher::new(2, 8);
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        assert_eq!(b.free_slot_indices(), vec![0, 1]);
        let t = b.pop_admissible(|_| true).unwrap();
        b.occupy(
            0,
            Slot {
                tracked: t,
                pos: 3,
                last: 5,
            },
        );
        b.check_invariant().unwrap();
        assert_eq!(b.free_slot_indices(), vec![1]);
        assert_eq!(b.n_active(), 1);
        let s = b.vacate(0).unwrap();
        assert_eq!(s.tracked.req.id, 1);
        assert!(!b.is_idle()); // one still waiting
    }

    #[test]
    fn pop_respects_admission() {
        let mut b = Batcher::new(1, 8);
        b.push(req(1)).unwrap();
        assert!(b.pop_admissible(|_| false).is_none());
        assert_eq!(b.waiting.len(), 1);
    }
}
