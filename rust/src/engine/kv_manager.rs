//! Block-granular KV-cache accounting (vLLM-style).
//!
//! The compiled decode graph owns a dense per-slot cache; this manager
//! does the *allocator's* job: admission control (a sequence may only be
//! scheduled when its worst-case block demand fits), per-sequence
//! bookkeeping, and preemption (release everything a victim holds).

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct KvBlockManager {
    block_size: usize,
    total_blocks: usize,
    free_blocks: usize,
    /// seq id -> blocks held.
    held: HashMap<u64, usize>,
}

impl KvBlockManager {
    pub fn new(total_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        KvBlockManager {
            block_size,
            total_blocks,
            free_blocks: total_blocks,
            held: HashMap::new(),
        }
    }

    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn held_by(&self, seq: u64) -> usize {
        self.held.get(&seq).copied().unwrap_or(0)
    }

    /// Can a sequence with this worst-case token demand be admitted now?
    pub fn can_admit(&self, max_tokens: usize) -> bool {
        self.blocks_for_tokens(max_tokens) <= self.free_blocks
    }

    /// Reserve blocks for `seq` to cover `max_tokens` tokens.
    pub fn admit(&mut self, seq: u64, max_tokens: usize) -> Result<()> {
        if self.held.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        let need = self.blocks_for_tokens(max_tokens);
        if need > self.free_blocks {
            bail!("kv capacity: need {need} blocks, {} free", self.free_blocks);
        }
        self.free_blocks -= need;
        self.held.insert(seq, need);
        Ok(())
    }

    /// Grow a running sequence's reservation (decode past the estimate).
    pub fn extend(&mut self, seq: u64, new_total_tokens: usize) -> Result<()> {
        let need = self.blocks_for_tokens(new_total_tokens);
        let have = self.held_by(seq);
        if need <= have {
            return Ok(());
        }
        let extra = need - have;
        if extra > self.free_blocks {
            bail!(
                "kv capacity: extend needs {extra} blocks, {} free",
                self.free_blocks
            );
        }
        self.free_blocks -= extra;
        self.held.insert(seq, need);
        Ok(())
    }

    /// Release everything a sequence holds (finish or preemption).
    pub fn release(&mut self, seq: u64) -> usize {
        let n = self.held.remove(&seq).unwrap_or(0);
        self.free_blocks += n;
        debug_assert!(self.free_blocks <= self.total_blocks);
        n
    }

    /// Allocator invariant: free + held == total.
    pub fn check_invariant(&self) -> Result<()> {
        let held: usize = self.held.values().sum();
        anyhow::ensure!(
            held + self.free_blocks == self.total_blocks,
            "leak: held {held} + free {} != total {}",
            self.free_blocks,
            self.total_blocks
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_release_roundtrip() {
        let mut m = KvBlockManager::new(8, 16);
        m.admit(1, 100).unwrap(); // 7 blocks
        assert_eq!(m.free_blocks(), 1);
        assert!(m.admit(2, 32).is_err()); // needs 2
        m.admit(3, 16).unwrap();
        assert_eq!(m.free_blocks(), 0);
        assert_eq!(m.release(1), 7);
        assert_eq!(m.free_blocks(), 7);
        m.check_invariant().unwrap();
    }

    #[test]
    fn double_admit_rejected() {
        let mut m = KvBlockManager::new(8, 16);
        m.admit(1, 16).unwrap();
        assert!(m.admit(1, 16).is_err());
    }

    #[test]
    fn extend_grows_reservation() {
        let mut m = KvBlockManager::new(4, 16);
        m.admit(1, 16).unwrap();
        m.extend(1, 48).unwrap(); // 1 -> 3 blocks
        assert_eq!(m.held_by(1), 3);
        assert_eq!(m.free_blocks(), 1);
        m.extend(1, 32).unwrap(); // shrink request is a no-op
        assert_eq!(m.held_by(1), 3);
        assert!(m.extend(1, 1000).is_err());
        m.check_invariant().unwrap();
    }

    #[test]
    fn release_unknown_is_zero() {
        let mut m = KvBlockManager::new(4, 16);
        assert_eq!(m.release(99), 0);
        m.check_invariant().unwrap();
    }
}
