//! Request/response types of the serving engine.

use std::time::Instant;

pub type RequestId = u64;

/// Sampling settings per request.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f32,
    /// Top-p nucleus mass (1.0 = disabled).
    pub top_p: f32,
    pub max_new_tokens: usize,
    /// Stop at EOS (disable for fixed-length probes).
    pub stop_on_eos: bool,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_p: 1.0,
            max_new_tokens: 16,
            stop_on_eos: true,
            seed: 0,
        }
    }
}

/// An inference request (prompt tokens in, generated tokens out).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub sampling: SamplingParams,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, sampling: SamplingParams) -> Self {
        Request {
            id,
            prompt,
            sampling,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// KV capacity exhausted mid-generation.
    CapacityTruncated,
}

/// Completed request with timing metadata.
#[derive(Clone, Debug)]
pub struct RequestOutput {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Queue-entry -> first-token latency (s).
    pub ttft_s: f64,
    /// Queue-entry -> completion latency (s).
    pub e2e_s: f64,
}

/// Engine-internal per-request state.
#[derive(Debug)]
pub(crate) struct Tracked {
    pub req: Request,
    pub enqueued: Instant,
    pub first_token: Option<Instant>,
    pub generated: Vec<i32>,
}

impl Tracked {
    pub fn new(req: Request) -> Self {
        Tracked {
            req,
            enqueued: Instant::now(),
            first_token: None,
            generated: Vec::new(),
        }
    }
}
