//! vLLM-lite serving stack: continuous batching, KV accounting, sampling,
//! metrics — all over the compiled PJRT executables.

pub mod batcher;
#[allow(clippy::module_inception)]
pub mod engine;
pub mod kv_manager;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod tokenizer;

pub use engine::{Engine, StepKind, StepOutcome};
pub use kv_manager::KvBlockManager;
pub use metrics::MetricsSummary;
pub use request::{FinishReason, Request, RequestId, RequestOutput, SamplingParams};
pub use tokenizer::Tokenizer;
