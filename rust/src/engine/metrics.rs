//! Serving metrics: latency percentiles, token throughput, utilization.

use std::time::{Duration, Instant};

use crate::obs::Quantiles;

use super::request::RequestOutput;

#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub completed: Vec<RequestOutput>,
    pub prefill_calls: u64,
    /// Prompt tokens admitted across all prefill calls — read as a
    /// before/after delta by `server::EngineReplica` to tag each
    /// measured prefill step with its token count (the calibration
    /// fitter's prefill regressor; see `calibrate`).
    pub prefill_tokens: u64,
    pub decode_calls: u64,
    pub decode_steps_active_slots: u64,
    pub decode_steps_total_slots: u64,
    /// Submitted prompts truncated to the static prefill length.
    pub truncated_prompts: u64,
    /// Total prompt tokens dropped by those truncations.
    pub truncated_tokens: u64,
    /// Expert residency counters (all zero unless the engine runs with
    /// an [`ExpertResidency`](crate::experts::ExpertResidency) model).
    pub expert_hits: u64,
    pub expert_misses: u64,
    pub expert_prefetch_hits: u64,
    /// Simulated stall time charged to expert demand misses.
    pub expert_stall_s: f64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

#[derive(Debug, Clone, Copy)]
pub struct MetricsSummary {
    pub n_requests: usize,
    pub wall_s: f64,
    /// Generated tokens per second.
    pub gen_tok_s: f64,
    /// (prompt + generated) tokens per second — the paper's metric.
    pub total_tok_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Mean fraction of decode-batch slots doing useful work.
    pub slot_utilization: f64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
    /// Prompts truncated at submit (prompt > prefill_len).
    pub truncated_prompts: u64,
    /// Expert HBM hit rate (`None` when no residency model ran).
    pub expert_hit_rate: Option<f64>,
    /// Total simulated expert-fetch stall (0 without a residency model).
    pub expert_stall_s: f64,
}

impl EngineMetrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn finish(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn record(&mut self, out: RequestOutput) {
        self.completed.push(out);
    }

    pub fn record_decode_step(&mut self, active: usize, total: usize) {
        self.decode_calls += 1;
        self.decode_steps_active_slots += active as u64;
        self.decode_steps_total_slots += total as u64;
    }

    /// Fold one scheduling step's residency outcome into the counters.
    pub fn record_residency(&mut self, step: &crate::experts::StepResidency) {
        self.expert_hits += step.hits;
        self.expert_misses += step.misses;
        self.expert_prefetch_hits += step.prefetch_hits;
        self.expert_stall_s += step.stall_s;
    }

    pub fn wall(&self) -> Duration {
        match (self.started, self.finished) {
            (Some(s), Some(f)) => f - s,
            (Some(s), None) => s.elapsed(),
            _ => Duration::ZERO,
        }
    }

    pub fn summary(&self) -> MetricsSummary {
        let wall = self.wall().as_secs_f64().max(1e-9);
        let gen_tokens: usize = self.completed.iter().map(|o| o.tokens.len()).sum();
        let total_tokens: usize = self
            .completed
            .iter()
            .map(|o| o.tokens.len() + o.prompt_len)
            .sum();
        // the shared exact-percentile implementation (see crate::obs)
        let ttft = Quantiles::from_samples(self.completed.iter().map(|o| o.ttft_s));
        let e2e = Quantiles::from_samples(self.completed.iter().map(|o| o.e2e_s));
        MetricsSummary {
            n_requests: self.completed.len(),
            wall_s: wall,
            gen_tok_s: gen_tokens as f64 / wall,
            total_tok_s: total_tokens as f64 / wall,
            ttft_p50_s: ttft.q(50.0),
            ttft_p99_s: ttft.q(99.0),
            e2e_p50_s: e2e.q(50.0),
            e2e_p99_s: e2e.q(99.0),
            slot_utilization: if self.decode_steps_total_slots > 0 {
                self.decode_steps_active_slots as f64 / self.decode_steps_total_slots as f64
            } else {
                0.0
            },
            prefill_calls: self.prefill_calls,
            decode_calls: self.decode_calls,
            truncated_prompts: self.truncated_prompts,
            expert_hit_rate: (self.expert_hits + self.expert_misses > 0).then(|| {
                self.expert_hits as f64 / (self.expert_hits + self.expert_misses) as f64
            }),
            expert_stall_s: self.expert_stall_s,
        }
    }
}

impl std::fmt::Display for MetricsSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests={} wall={:.2}s throughput={:.1} tok/s (gen {:.1} tok/s)",
            self.n_requests, self.wall_s, self.total_tok_s, self.gen_tok_s
        )?;
        writeln!(
            f,
            "ttft p50={:.1}ms p99={:.1}ms  e2e p50={:.1}ms p99={:.1}ms",
            self.ttft_p50_s * 1e3,
            self.ttft_p99_s * 1e3,
            self.e2e_p50_s * 1e3,
            self.e2e_p99_s * 1e3
        )?;
        write!(
            f,
            "prefill_calls={} decode_calls={} slot_util={:.0}% truncated_prompts={}",
            self.prefill_calls,
            self.decode_calls,
            self.slot_utilization * 100.0,
            self.truncated_prompts
        )?;
        // residency line only when a residency model actually ran, so
        // default-configuration output is unchanged byte for byte
        if let Some(rate) = self.expert_hit_rate {
            write!(
                f,
                "\nexpert_hbm_hit_rate={:.1}% expert_stall={:.1}ms",
                rate * 100.0,
                self.expert_stall_s * 1e3
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::request::FinishReason;

    #[test]
    fn summary_aggregates() {
        let mut m = EngineMetrics::default();
        m.start();
        for i in 0..4 {
            m.record(RequestOutput {
                id: i,
                prompt_len: 10,
                tokens: vec![1, 2, 3],
                finish: FinishReason::MaxTokens,
                ttft_s: 0.1 * (i + 1) as f64,
                e2e_s: 0.2 * (i + 1) as f64,
            });
        }
        m.record_decode_step(6, 8);
        m.record_decode_step(2, 8);
        m.finish();
        let s = m.summary();
        assert_eq!(s.n_requests, 4);
        assert!((s.slot_utilization - 0.5).abs() < 1e-9);
        assert!(s.ttft_p50_s > 0.0 && s.e2e_p99_s >= s.e2e_p50_s);
        assert_eq!(s.truncated_prompts, 0);
    }

    #[test]
    fn truncations_surface_in_summary() {
        let mut m = EngineMetrics::default();
        m.truncated_prompts = 3;
        m.truncated_tokens = 120;
        let s = m.summary();
        assert_eq!(s.truncated_prompts, 3);
        assert!(format!("{s}").contains("truncated_prompts=3"));
    }

    #[test]
    fn residency_counters_surface_only_when_present() {
        let mut m = EngineMetrics::default();
        let s = m.summary();
        assert!(s.expert_hit_rate.is_none());
        assert!(!format!("{s}").contains("expert_hbm_hit_rate"));

        m.record_residency(&crate::experts::StepResidency {
            stall_s: 0.25,
            hits: 6,
            misses: 2,
            prefetch_hits: 1,
        });
        m.record_residency(&crate::experts::StepResidency {
            stall_s: 0.05,
            hits: 8,
            misses: 0,
            prefetch_hits: 0,
        });
        let s = m.summary();
        assert!((s.expert_hit_rate.unwrap() - 14.0 / 16.0).abs() < 1e-12);
        assert!((s.expert_stall_s - 0.3).abs() < 1e-12);
        assert!(format!("{s}").contains("expert_hbm_hit_rate"));
    }
}
