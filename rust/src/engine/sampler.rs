//! Token sampling over a logits row (greedy / temperature / top-p).

use crate::util::Pcg32;

use super::request::SamplingParams;

/// Sample one token from `logits` (length = vocab).
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Pcg32) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // temperature softmax
    let inv_t = 1.0 / params.temperature;
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) * inv_t) as f64).exp())
        .collect();
    // top-p nucleus truncation
    if params.top_p < 1.0 {
        let mut order: Vec<usize> = (0..probs.len()).collect();
        order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let total: f64 = probs.iter().sum();
        let mut mass = 0.0;
        let mut cut = probs.len();
        for (rank, &i) in order.iter().enumerate() {
            mass += probs[i] / total;
            if mass >= params.top_p as f64 {
                cut = rank + 1;
                break;
            }
        }
        for &i in &order[cut..] {
            probs[i] = 0.0;
        }
    }
    rng.sample_weighted(&probs) as i32
}

/// Greedy argmax with lowest-index tie-break.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Log-softmax probability of `token` under `logits`.
pub fn log_prob(logits: &[f32], token: i32) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&l| ((l as f64) - max).exp()).sum();
    (logits[token as usize] as f64) - max - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(argmax(&logits), 1);
        let p = SamplingParams::default();
        let mut rng = Pcg32::seeded(0);
        assert_eq!(sample(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn log_probs_normalize() {
        let logits = vec![1.0, 2.0, 3.0];
        let total: f64 = (0..3).map(|t| log_prob(&logits, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(log_prob(&logits, 2) > log_prob(&logits, 0));
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let p = SamplingParams {
            temperature: 1.0,
            ..Default::default()
        };
        let mut rng = Pcg32::seeded(1);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn top_p_truncates_tail() {
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let p = SamplingParams {
            temperature: 1.0,
            top_p: 0.5,
            ..Default::default()
        };
        let mut rng = Pcg32::seeded(2);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &p, &mut rng), 0);
        }
    }
}
