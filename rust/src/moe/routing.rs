//! Token-to-expert routing simulation: per-expert loads and the load
//! imbalance that makes pruning throughput-neutral (the paper's §1/§3
//! observation and the mechanism behind Fig. 2's flat/degrading curves).

use crate::util::Pcg32;

/// Simulates a batch of tokens selecting top-k experts from a popularity
/// distribution. Popularity is drawn once per instance (a softmax of
/// N(0, spread) logits), standing in for the trained router's preferences;
/// `spread`=0 gives a uniform router, larger values give the skewed
/// routing real models exhibit.
#[derive(Clone, Debug)]
pub struct RoutingSim {
    /// Routing probability per expert (sums to 1).
    pub popularity: Vec<f64>,
}

impl RoutingSim {
    pub fn new(n_experts: usize, spread: f64, rng: &mut Pcg32) -> Self {
        let logits: Vec<f64> = (0..n_experts).map(|_| rng.gen_normal() * spread).collect();
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        RoutingSim {
            popularity: exps.iter().map(|e| e / z).collect(),
        }
    }

    /// From measured calibration frequencies (the NAEE-style data path).
    pub fn from_frequencies(freq: &[f32]) -> Self {
        let z: f64 = freq.iter().map(|&f| f as f64).sum::<f64>().max(1e-12);
        RoutingSim {
            popularity: freq.iter().map(|&f| f as f64 / z).collect(),
        }
    }

    pub fn n_experts(&self) -> usize {
        self.popularity.len()
    }

    /// Expert indices ordered by descending routing probability (ties
    /// break toward the lower index, so the order is a deterministic
    /// total order). The "hot set" ranking shared by the residency
    /// prefetcher, the k_vec-aware pin computation, inter-pruning, and
    /// the figures — one definition instead of four ad-hoc sorts.
    pub fn by_popularity(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.popularity.len()).collect();
        order.sort_by(|&a, &b| {
            self.popularity[b]
                .total_cmp(&self.popularity[a])
                .then(a.cmp(&b))
        });
        order
    }

    /// Cumulative routing mass of the `k` most popular experts — the
    /// probability that a routed token lands in the top-k hot set (1.0
    /// once `k >= n_experts`). Drives the residency model: it is the
    /// expected fraction of expert traffic a k-expert HBM cache covers.
    pub fn top_p_mass(&self, k: usize) -> f64 {
        self.by_popularity()
            .into_iter()
            .take(k)
            .map(|e| self.popularity[e])
            .sum()
    }

    /// Restrict to a surviving-expert subset (inter-pruning): removed
    /// experts' probability mass is redistributed onto survivors by
    /// renormalization — the "remaining experts absorb the pruned experts'
    /// tokens" effect.
    pub fn pruned(&self, keep: &[bool]) -> Self {
        assert_eq!(keep.len(), self.popularity.len());
        // guard: pruning every expert with mass used to yield NaN
        // popularity; an all-false mask now degrades to all-zero instead
        let kept_mass: f64 = self
            .popularity
            .iter()
            .zip(keep)
            .filter(|(_, &k)| k)
            .map(|(p, _)| p)
            .sum::<f64>()
            .max(1e-12);
        RoutingSim {
            popularity: self
                .popularity
                .iter()
                .zip(keep)
                .map(|(p, &k)| if k { p / kept_mass } else { 0.0 })
                .collect(),
        }
    }

    /// Sample per-expert token loads: `tokens` tokens each select `k`
    /// *distinct* experts (weighted without replacement). Returns counts
    /// of length n_experts; the counts sum to tokens*k.
    pub fn sample_loads(&self, tokens: usize, k: usize, rng: &mut Pcg32) -> Vec<u64> {
        let e = self.n_experts();
        assert!(k <= self.popularity.iter().filter(|&&p| p > 0.0).count());
        let mut loads = vec![0u64; e];
        let mut w = vec![0.0f64; e];
        for _ in 0..tokens {
            w.copy_from_slice(&self.popularity);
            for _ in 0..k {
                let j = rng.sample_weighted(&w);
                loads[j] += 1;
                w[j] = 0.0; // without replacement within a token
            }
        }
        loads
    }

    /// Load statistics over Monte-Carlo trials.
    pub fn load_stats(&self, tokens: usize, k: usize, trials: usize, seed: u64) -> LoadStats {
        let mut rng = Pcg32::seeded(seed);
        let mut max_sum = 0.0;
        let mut nonzero_sum = 0.0;
        for _ in 0..trials {
            let loads = self.sample_loads(tokens, k, &mut rng);
            let max = *loads.iter().max().unwrap() as f64;
            max_sum += max;
            nonzero_sum += loads.iter().filter(|&&l| l > 0).count() as f64;
        }
        let mean_load = (tokens * k) as f64 / self.n_experts() as f64;
        let exp_max = max_sum / trials as f64;
        LoadStats {
            mean_load,
            expected_max_load: exp_max,
            imbalance: exp_max / mean_load.max(1e-12),
            expected_active_experts: nonzero_sum / trials as f64,
        }
    }
}

/// Summary of a routing simulation.
#[derive(Clone, Copy, Debug)]
pub struct LoadStats {
    /// tokens * k / E.
    pub mean_load: f64,
    /// E[max_e load_e] over trials.
    pub expected_max_load: f64,
    /// expected_max_load / mean_load; >= 1, equality iff perfectly uniform.
    pub imbalance: f64,
    /// Expected number of experts that received at least one token
    /// (drives decode-phase weight traffic).
    pub expected_active_experts: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_sum_to_tokens_times_k() {
        let mut rng = Pcg32::seeded(0);
        let sim = RoutingSim::new(8, 1.0, &mut rng);
        let loads = sim.sample_loads(100, 2, &mut rng);
        assert_eq!(loads.iter().sum::<u64>(), 200);
    }

    #[test]
    fn imbalance_at_least_one() {
        let mut rng = Pcg32::seeded(1);
        for spread in [0.0, 0.5, 2.0] {
            let sim = RoutingSim::new(16, spread, &mut rng);
            let s = sim.load_stats(256, 4, 16, 7);
            assert!(s.imbalance >= 1.0 - 1e-9, "imbalance {}", s.imbalance);
        }
    }

    #[test]
    fn skew_increases_imbalance() {
        let mut rng = Pcg32::seeded(2);
        let flat = RoutingSim::new(32, 0.0, &mut rng).load_stats(256, 4, 32, 9);
        let skew = RoutingSim::new(32, 2.0, &mut rng).load_stats(256, 4, 32, 9);
        assert!(skew.imbalance > flat.imbalance);
    }

    #[test]
    fn top_p_mass_is_monotone_and_saturates() {
        let mut rng = Pcg32::seeded(5);
        let sim = RoutingSim::new(16, 2.0, &mut rng);
        let mut prev = 0.0;
        for k in 0..=16 {
            let m = sim.top_p_mass(k);
            assert!(m >= prev - 1e-12, "mass not monotone at k={k}");
            prev = m;
        }
        assert_eq!(sim.top_p_mass(0), 0.0);
        assert!((sim.top_p_mass(16) - 1.0).abs() < 1e-9);
        assert!((sim.top_p_mass(32) - 1.0).abs() < 1e-9);
        // the ranking really is by popularity: top-1 mass equals the max
        let max_p = sim.popularity.iter().cloned().fold(0.0, f64::max);
        assert!((sim.top_p_mass(1) - max_p).abs() < 1e-12);
        // skewed routers concentrate more mass in the same top-k
        let flat = RoutingSim::new(16, 0.0, &mut rng);
        assert!(sim.top_p_mass(4) > flat.top_p_mass(4));
    }

    #[test]
    fn by_popularity_is_a_deterministic_total_order() {
        let sim = RoutingSim::from_frequencies(&[1.0, 3.0, 3.0, 2.0]);
        assert_eq!(sim.by_popularity(), vec![1, 2, 3, 0]);
    }

    #[test]
    fn pruning_concentrates_load() {
        let mut rng = Pcg32::seeded(3);
        let sim = RoutingSim::new(8, 1.0, &mut rng);
        let mut keep = vec![true; 8];
        keep[0] = false;
        keep[1] = false;
        let pruned = sim.pruned(&keep);
        let z: f64 = pruned.popularity.iter().sum();
        assert!((z - 1.0).abs() < 1e-9);
        assert_eq!(pruned.popularity[0], 0.0);
        // per-surviving-expert mean load grows
        let base = sim.load_stats(256, 2, 16, 11);
        let after = pruned.load_stats(256, 2, 16, 11);
        assert!(after.expected_max_load >= base.expected_max_load * 0.99);
    }
}
