//! Model transforms: everything Figs. 2 & 4-8 compare.
//!
//! A [`Transform`] describes how a pretrained MoE is modified post-training.
//! Each variant maps onto the shared runtime mechanism (DESIGN.md §3):
//! per-layer `k_vec` input, per-expert `gate_bias` input (-1e9 = removed),
//! and in-memory weight edits (intra-pruning zeroes FFN columns) — so ONE
//! compiled executable serves every configuration.

use crate::config::model::ModelSpec;
use crate::moe::allocation::Allocation;

pub const PRUNE_BIAS: f32 = -1e9;

/// A post-training model configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum Transform {
    /// Unmodified pretrained model (uniform k_base everywhere).
    Baseline,
    /// NAEE-style inter-expert pruning: remove `frac` of the experts in
    /// every layer (lowest calibration importance first). Token top-k is
    /// unchanged — survivors absorb the removed experts' tokens.
    InterPrune { frac: f64 },
    /// MoE-I2-style intra-expert pruning: shrink every expert's FFN
    /// intermediate dim by `frac` (smallest-magnitude columns first).
    IntraPrune { frac: f64 },
    /// NAEE dynamic expert skipping: drop the weakest of the top-2 experts
    /// when its gate weight is below `threshold` x the top-1 weight.
    /// Only defined for k_base = 2 (the paper notes it "cannot work
    /// beyond top-k = 2"); modeled in the perf model.
    DynamicSkip { threshold: f64 },
    /// LExI: static per-layer active-expert allocation.
    Lexi { allocation: Allocation },
    /// LExI combined with inter-expert pruning — the joint compute +
    /// memory optimization the paper's Limitations section proposes
    /// ("our method can be effectively combined with existing MoE
    /// pruning methods").
    LexiPlusInter { allocation: Allocation, frac: f64 },
    /// LExI combined with intra-expert pruning: the Stage-2 per-layer
    /// allocation with every expert's FFN intermediate dim shrunk by
    /// `frac` — one point on the 2-D quality lattice's
    /// (active-experts x intra-sparsity) surface. Pruning cuts the
    /// per-expert weight traffic the decode roofline streams, so this
    /// axis buys latency where reducing k alone saturates.
    LexiPlusIntra { allocation: Allocation, frac: f64 },
    /// LExI combined with NAEE dynamic skipping: the Stage-2 allocation
    /// with the weakest of each layer's top-2 experts dropped when its
    /// gate weight falls below `threshold` x the top-1 weight. Only
    /// layers whose allocated k is >= 2 can skip; like
    /// [`Transform::DynamicSkip`] it is defined for k_base = 2 models.
    LexiPlusSkip { allocation: Allocation, threshold: f64 },
}

impl Transform {
    /// Effective per-layer k for the runtime `k_vec` input and FLOP model.
    /// (DynamicSkip's *expected* k is input-dependent; callers use
    /// [`Transform::expected_k`] for it.)
    pub fn k_per_layer(&self, spec: &ModelSpec) -> Vec<u32> {
        match self {
            Transform::Lexi { allocation }
            | Transform::LexiPlusIntra { allocation, .. }
            | Transform::LexiPlusSkip { allocation, .. } => allocation.k.clone(),
            Transform::LexiPlusInter { allocation, .. } => {
                let kept = self.experts_kept(spec) as u32;
                allocation.k.iter().map(|&k| k.min(kept)).collect()
            }
            // Inter/intra pruning keep the pretrained top-k. If inter
            // pruning leaves fewer experts than k_base, top-k saturates.
            Transform::InterPrune { .. } => {
                let kept = self.experts_kept(spec);
                vec![(spec.top_k as u32).min(kept as u32); spec.n_layers]
            }
            _ => vec![spec.top_k as u32; spec.n_layers],
        }
    }

    /// Experts remaining per layer after the transform.
    pub fn experts_kept(&self, spec: &ModelSpec) -> usize {
        match self {
            Transform::InterPrune { frac } | Transform::LexiPlusInter { frac, .. } => {
                let removed = (spec.n_experts as f64 * frac).round() as usize;
                (spec.n_experts - removed).max(1)
            }
            _ => spec.n_experts,
        }
    }

    /// Per-expert FFN dim after the transform (paper-scale `ffn` input).
    pub fn ffn_dim(&self, ffn: usize) -> usize {
        match self {
            Transform::IntraPrune { frac } | Transform::LexiPlusIntra { frac, .. } => {
                ((ffn as f64 * (1.0 - frac)).round() as usize).max(1)
            }
            _ => ffn,
        }
    }

    /// Expected active experts per token per layer (drives the FLOP term).
    /// For DynamicSkip this is the expected value under the gate-weight
    /// distribution summarized by `skip_prob` (probability the 2nd expert
    /// is skipped); everything else is deterministic.
    pub fn expected_k(&self, spec: &ModelSpec, skip_prob: f64) -> f64 {
        match self {
            Transform::DynamicSkip { .. } => spec.top_k as f64 - skip_prob,
            Transform::Lexi { allocation }
            | Transform::LexiPlusIntra { allocation, .. }
            | Transform::LexiPlusInter { allocation, .. } => allocation.mean_k(),
            // skipping drops the 2nd expert, so only layers allocated
            // k >= 2 have anything to skip
            Transform::LexiPlusSkip { allocation, .. } => {
                allocation
                    .k
                    .iter()
                    .map(|&k| {
                        if k >= 2 {
                            (k as f64 - skip_prob).max(1.0)
                        } else {
                            k as f64
                        }
                    })
                    .sum::<f64>()
                    / allocation.k.len() as f64
            }
            _ => self.k_per_layer(spec).iter().sum::<u32>() as f64
                / spec.n_layers as f64,
        }
    }

    /// Does this transform shrink the weight memory footprint?
    /// (The paper's Limitations section: LExI does NOT.)
    pub fn reduces_memory(&self) -> bool {
        matches!(
            self,
            Transform::InterPrune { .. }
                | Transform::IntraPrune { .. }
                | Transform::LexiPlusInter { .. }
                | Transform::LexiPlusIntra { .. }
        )
    }

    /// Expert-weight memory at paper scale in GiB under this transform
    /// (dtype bytes = 2, BF16). The paper's Limitations section: LExI
    /// does NOT reduce the footprint; pruning does.
    pub fn expert_memory_gib(&self, spec: &ModelSpec) -> f64 {
        let kept = self.experts_kept(spec) as f64;
        let ffn = self.ffn_dim(spec.paper.ffn) as f64;
        spec.n_layers as f64 * kept * 3.0 * spec.paper.hidden as f64 * ffn * 2.0
            / (1u64 << 30) as f64
    }

    /// Short label used in figure CSVs.
    pub fn label(&self) -> String {
        match self {
            Transform::Baseline => "base".into(),
            Transform::InterPrune { frac } => format!("inter{:.1}", frac * 100.0),
            Transform::IntraPrune { frac } => format!("intra{:.1}", frac * 100.0),
            Transform::DynamicSkip { threshold } => format!("skip{threshold:.2}"),
            Transform::Lexi { allocation } => format!("lexi-B{}", allocation.budget()),
            Transform::LexiPlusInter { allocation, frac } => {
                format!("lexi-B{}+inter{:.0}", allocation.budget(), frac * 100.0)
            }
            Transform::LexiPlusIntra { allocation, frac } => {
                format!("lexi-B{}+intra{:.0}", allocation.budget(), frac * 100.0)
            }
            Transform::LexiPlusSkip { allocation, threshold } => {
                format!("lexi-B{}+skip{threshold:.2}", allocation.budget())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::spec;

    #[test]
    fn inter_prune_keeps_topk_until_saturation() {
        let m = spec("qwen1.5-moe-a2.7b").unwrap(); // E=60, k=4
        let t = Transform::InterPrune { frac: 0.5 };
        assert_eq!(t.experts_kept(&m), 30);
        assert_eq!(t.k_per_layer(&m), vec![4; 24]);
        // saturation: pruning mixtral (E=8, k=2) at 93% leaves 1 expert
        let mx = spec("mixtral-8x7b").unwrap();
        let t = Transform::InterPrune { frac: 0.9 };
        assert_eq!(t.experts_kept(&mx), 1);
        assert_eq!(t.k_per_layer(&mx), vec![1; 32]);
    }

    #[test]
    fn intra_prune_shrinks_ffn_only() {
        let m = spec("mixtral-8x7b").unwrap();
        let t = Transform::IntraPrune { frac: 0.25 };
        assert_eq!(t.ffn_dim(14336), 10752);
        assert_eq!(t.experts_kept(&m), 8);
        assert_eq!(t.k_per_layer(&m), vec![2; 32]);
    }

    #[test]
    fn lexi_k_is_the_allocation() {
        let m = spec("mixtral-8x7b").unwrap();
        let alloc = Allocation::new(vec![1; 16].into_iter().chain(vec![2; 16]).collect());
        let t = Transform::Lexi { allocation: alloc.clone() };
        assert_eq!(t.k_per_layer(&m), alloc.k);
        assert!((t.expected_k(&m, 0.0) - 1.5).abs() < 1e-12);
        assert!(!t.reduces_memory());
    }

    #[test]
    fn combined_transform_composes_both_levers() {
        let m = spec("olmoe-1b-7b").unwrap(); // E=64, k=8, L=16
        let alloc = Allocation::uniform(16, 4);
        let t = Transform::LexiPlusInter { allocation: alloc, frac: 0.5 };
        assert_eq!(t.experts_kept(&m), 32);
        assert_eq!(t.k_per_layer(&m), vec![4; 16]);
        assert!(t.reduces_memory());
        // memory halves relative to baseline
        let base = Transform::Baseline.expert_memory_gib(&m);
        assert!((t.expert_memory_gib(&m) / base - 0.5).abs() < 1e-9);
        // while plain LExI keeps the full footprint (the Limitation)
        let lexi = Transform::Lexi { allocation: Allocation::uniform(16, 4) };
        assert!((lexi.expert_memory_gib(&m) / base - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixtral_memory_matches_param_count() {
        // 32 layers x 8 experts x 3 x 4096 x 14336 x 2B ≈ 84 GiB of
        // expert weights (BF16) — the bulk of 46.7B params.
        let m = spec("mixtral-8x7b").unwrap();
        let gib = Transform::Baseline.expert_memory_gib(&m);
        assert!((gib - 84.0).abs() < 2.0, "{gib}");
    }

    #[test]
    fn lexi_plus_intra_composes_allocation_and_ffn() {
        let m = spec("mixtral-8x7b").unwrap(); // E=8, k=2, L=32
        let alloc = Allocation::uniform(32, 2);
        let t = Transform::LexiPlusIntra { allocation: alloc.clone(), frac: 0.25 };
        assert_eq!(t.k_per_layer(&m), alloc.k);
        assert_eq!(t.ffn_dim(14336), 10752);
        assert!(t.reduces_memory());
        // footprint shrinks by exactly the pruned FFN fraction
        let base = Transform::Baseline.expert_memory_gib(&m);
        assert!((t.expert_memory_gib(&m) / base - 0.75).abs() < 1e-9);
        assert_eq!(t.label(), "lexi-B64+intra25");
    }

    #[test]
    fn lexi_plus_skip_only_thins_layers_with_headroom() {
        let m = spec("mixtral-8x7b").unwrap(); // k=2
        // half the layers allocated k=1 (nothing to skip), half k=2
        let alloc = Allocation::new(
            vec![1u32; 16].into_iter().chain(vec![2u32; 16]).collect(),
        );
        let t = Transform::LexiPlusSkip { allocation: alloc.clone(), threshold: 0.3 };
        assert_eq!(t.k_per_layer(&m), alloc.k);
        assert_eq!(t.ffn_dim(14336), 14336);
        assert!(!t.reduces_memory());
        // expected k: k=1 layers stay at 1, k=2 layers lose skip_prob
        let ek = t.expected_k(&m, 0.4);
        assert!((ek - (16.0 * 1.0 + 16.0 * 1.6) / 32.0).abs() < 1e-12, "{ek}");
        assert_eq!(t.label(), "lexi-B48+skip0.30");
    }

    #[test]
    fn dynamic_skip_expected_k() {
        let m = spec("mixtral-8x7b").unwrap();
        let t = Transform::DynamicSkip { threshold: 0.3 };
        assert!((t.expected_k(&m, 0.4) - 1.6).abs() < 1e-12);
    }
}
