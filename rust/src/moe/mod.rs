//! MoE architecture substrate: geometry, per-layer top-k allocations,
//! routing/load simulation, and model transforms (pruning / LExI).

pub mod allocation;
pub mod arch;
pub mod routing;
pub mod transform;

pub use allocation::Allocation;
pub use arch::ModelGeom;
pub use routing::RoutingSim;
pub use transform::Transform;
